//! Communication-cost simulator: ETP vs Soft Expert-Tensor Parallelism
//! (paper §3.3 + Fig. 5 + Fig. 9).
//!
//! Stand-in for the paper's NCCL real-node measurements and ASTRA-sim
//! runs (DESIGN.md §2): an α–β–γ model — per-collective kernel-launch
//! overhead (α), per-hop step latency, per-peer message overhead (γ),
//! and link-bandwidth-limited transfer (β) — over three topologies:
//! a single 8×H20 NVLink node, NVL72, and CloudMatrix384. This captures
//! exactly the effect S-ETP exploits: one balanced AlltoAll per
//! direction instead of the "AlltoAll+AllGather" / "ReduceScatter+
//! AlltoAll" chains, i.e. fewer launches, fewer synchronization points,
//! and full-fabric link utilization.

/// Fabric model. All devices share a homogeneous switched fabric with
/// per-device link bandwidth `link_bw` (bytes/s).
#[derive(Debug, Clone)]
pub struct Topology {
    pub name: String,
    pub world: usize,
    /// Per-device injection bandwidth, bytes/s.
    pub link_bw: f64,
    /// Per-collective kernel-launch + sync overhead, seconds.
    pub launch: f64,
    /// Per-algorithm-step latency (ring hop / switch traversal), seconds.
    pub step_lat: f64,
    /// Per-peer message-setup overhead inside one collective, seconds.
    pub per_peer: f64,
    /// Achieved fraction of link bandwidth for a balanced full-fabric
    /// AlltoAll (switch fabrics sustain close to line rate).
    pub a2a_eff: f64,
    /// Achieved fraction for ring AllGather/ReduceScatter: ring steps
    /// serialize and the two chained collectives cannot overlap, so
    /// measured NCCL efficiency is materially lower (this is the Fig. 9
    /// "link utilization" effect the paper attributes S-ETP's win to).
    pub ring_eff: f64,
}

impl Topology {
    /// One 8×H20 node over NVLink (~900 GB/s aggregate, ~450 effective
    /// per direction).
    pub fn h20_node() -> Topology {
        Topology {
            name: "8xH20".into(),
            world: 8,
            link_bw: 450e9,
            launch: 12e-6,
            step_lat: 2.0e-6,
            per_peer: 0.25e-6,
            a2a_eff: 0.95,
            ring_eff: 0.82,
        }
    }

    /// NVIDIA GB200 NVL72: 72 GPUs, homogeneous NVLink fabric.
    pub fn nvl72() -> Topology {
        Topology {
            name: "NVL72".into(),
            world: 72,
            link_bw: 900e9,
            launch: 15e-6,
            step_lat: 2.5e-6,
            per_peer: 0.15e-6,
            a2a_eff: 0.95,
            ring_eff: 0.80,
        }
    }

    /// Huawei CloudMatrix384: 384 NPUs, unified-bus full-mesh fabric.
    pub fn cm384() -> Topology {
        Topology {
            name: "CM384".into(),
            world: 384,
            link_bw: 392e9,
            launch: 18e-6,
            // Unified-bus full mesh: transfers are hardware DMA writes,
            // so the per-peer software overhead is far below NCCL's.
            step_lat: 3.0e-6,
            per_peer: 0.075e-6,
            a2a_eff: 0.93,
            ring_eff: 0.82,
        }
    }
}

/// AlltoAll over `group` ranks, each sending `send_bytes` total
/// (spread over the group). Balanced: limited by injection bandwidth.
///
/// Only a degenerate group (≤ 1 rank) costs bare `launch`. A
/// zero-payload collective in a real group still synchronizes every
/// peer, so it costs `launch + step_lat + per_peer·(group−1)` —
/// consistent with how `allgather_time`/`reducescatter_time` charge
/// step latency for zero bytes.
pub fn alltoall_time(t: &Topology, group: usize, send_bytes: f64) -> f64 {
    if group <= 1 {
        return t.launch;
    }
    let fixed = t.launch + t.step_lat + t.per_peer * (group - 1) as f64;
    if send_bytes <= 0.0 {
        return fixed;
    }
    fixed + send_bytes / (t.link_bw * t.a2a_eff)
}

/// Ring AllGather within `group`: each rank contributes `bytes_per_rank`
/// and ends with the full group's data.
pub fn allgather_time(t: &Topology, group: usize, bytes_per_rank: f64) -> f64 {
    if group <= 1 {
        return t.launch;
    }
    let steps = (group - 1) as f64;
    t.launch + steps * t.step_lat + steps * bytes_per_rank / (t.link_bw * t.ring_eff)
}

/// Ring ReduceScatter within `group` over `bytes_per_rank` input per rank.
pub fn reducescatter_time(t: &Topology, group: usize, bytes_per_rank: f64) -> f64 {
    if group <= 1 {
        return t.launch;
    }
    let steps = (group - 1) as f64;
    t.launch + steps * t.step_lat
        + steps * (bytes_per_rank / group as f64) / (t.link_bw * t.ring_eff)
}

/// One MoE layer's communication under classic **ETP** (Fig. 5a):
/// dispatch = AlltoAll(EP) then AllGather(TP); return = ReduceScatter(TP)
/// then AlltoAll(EP). `input_bytes` = activation bytes per device.
///
/// AG/RS byte accounting — the two calls are explicit duals:
/// * dispatch AllGather: each TP rank contributes its `s` activation
///   bytes (per-rank **input** = `s`) and ends holding `s·tp`;
/// * return ReduceScatter: each TP rank holds `s·tp` partial-sum bytes
///   (per-rank **input** = `s·tp`) and keeps its reduced `s` shard.
///
/// Both move `s·(tp−1)` bytes per rank over the ring, so
/// `allgather_time(t, tp, s) == reducescatter_time(t, tp, s·tp)`
/// exactly (pinned by the `ag_rs_duality` test).
pub fn etp_time(t: &Topology, ep: usize, tp: usize, input_bytes: f64) -> f64 {
    assert!(ep * tp <= t.world, "EP*TP exceeds topology world size");
    let s = input_bytes;
    let a2a = alltoall_time(t, ep, s * (ep - 1) as f64 / ep as f64);
    let ag = allgather_time(t, tp, s);
    let rs = reducescatter_time(t, tp, s * tp as f64);
    let a2a_back = alltoall_time(t, ep, s * (ep - 1) as f64 / ep as f64);
    a2a + ag + rs + a2a_back
}

/// One MoE layer's communication under **S-ETP** (Fig. 5b): expert
/// partition (partial transformation, P = tp) turns the whole EP×TP
/// grid into one EP·P expert-parallel group; dispatch and return are
/// each a single balanced AlltoAll carrying the P-replicated tokens.
pub fn setp_time(t: &Topology, ep: usize, tp: usize, input_bytes: f64) -> f64 {
    assert!(ep * tp <= t.world, "EP*TP exceeds topology world size");
    let world = (ep * tp) as f64;
    let send = input_bytes * tp as f64 * (world - 1.0) / world;
    2.0 * alltoall_time(t, ep * tp, send)
}

/// Paper's Fig. 9 metric: per-device input size / total comm time (GB/s).
pub fn bandwidth_gbps(input_bytes: f64, time: f64) -> f64 {
    input_bytes / time / 1e9
}

/// One Fig. 9 sweep point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub input_bytes: f64,
    pub etp_gbps: f64,
    pub setp_gbps: f64,
    pub improvement_pct: f64,
}

/// Sweep input sizes on a topology/parallel config (Fig. 9).
pub fn sweep(t: &Topology, ep: usize, tp: usize, sizes: &[f64]) -> Vec<SweepPoint> {
    sizes
        .iter()
        .map(|&s| {
            let et = etp_time(t, ep, tp, s);
            let st = setp_time(t, ep, tp, s);
            let eb = bandwidth_gbps(s, et);
            let sb = bandwidth_gbps(s, st);
            SweepPoint {
                input_bytes: s,
                etp_gbps: eb,
                setp_gbps: sb,
                improvement_pct: 100.0 * (sb - eb) / eb,
            }
        })
        .collect()
}

/// Default Fig. 9 input-size grid (bytes per device).
pub fn default_sizes() -> Vec<f64> {
    (0..12).map(|i| 4096.0 * 4f64.powi(i)).collect() // 4 KiB … 64 MiB+
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collectives_scale_with_bytes() {
        let t = Topology::h20_node();
        assert!(alltoall_time(&t, 8, 2e9) > alltoall_time(&t, 8, 1e9));
        assert!(allgather_time(&t, 4, 2e9) > allgather_time(&t, 4, 1e9));
        assert!(reducescatter_time(&t, 4, 2e9) > reducescatter_time(&t, 4, 1e9));
    }

    #[test]
    fn degenerate_groups_cost_only_launch() {
        let t = Topology::h20_node();
        assert_eq!(alltoall_time(&t, 1, 1e9), t.launch);
        assert_eq!(allgather_time(&t, 1, 1e9), t.launch);
    }

    #[test]
    fn zero_payload_collective_still_synchronizes_the_group() {
        // A zero-byte AlltoAll in a >1 group is a barrier, not a no-op:
        // it must charge the fixed latency terms, like AG/RS do.
        let t = Topology::h20_node();
        let expect = t.launch + t.step_lat + t.per_peer * 7.0;
        assert_eq!(alltoall_time(&t, 8, 0.0), expect);
        assert_eq!(alltoall_time(&t, 8, -1.0), expect);
        // …and only the degenerate group stays at bare launch.
        assert_eq!(alltoall_time(&t, 1, 0.0), t.launch);
        // Zero bytes is the infimum of positive payloads, not a cliff.
        assert!(alltoall_time(&t, 8, 1.0) > alltoall_time(&t, 8, 0.0));
    }

    #[test]
    fn ag_rs_duality() {
        // AllGather with per-rank input b moves the same ring traffic as
        // ReduceScatter with per-rank input b·g (see `etp_time` docs).
        for t in [Topology::h20_node(), Topology::nvl72(), Topology::cm384()] {
            for g in [2usize, 4, 8] {
                for b in [4096.0, 1.5e6, 2e9] {
                    let ag = allgather_time(&t, g, b);
                    let rs = reducescatter_time(&t, g, b * g as f64);
                    assert!(
                        (ag - rs).abs() <= 1e-12 * ag.abs(),
                        "{}: AG({g},{b})={ag} vs RS({g},{})={rs}",
                        t.name,
                        b * g as f64
                    );
                }
            }
        }
    }

    #[test]
    fn setp_beats_etp_on_all_topologies() {
        for (t, ep, tp) in [
            (Topology::h20_node(), 4, 2),
            (Topology::h20_node(), 2, 4),
            (Topology::nvl72(), 9, 8),
            (Topology::cm384(), 48, 8),
        ] {
            for &s in &default_sizes() {
                assert!(
                    setp_time(&t, ep, tp, s) < etp_time(&t, ep, tp, s),
                    "S-ETP should win on {} EP={ep} TP={tp} S={s}",
                    t.name
                );
            }
        }
    }

    #[test]
    fn improvement_larger_at_small_sizes() {
        // Fixed overheads dominate at small messages (paper: up to 80%
        // on NVL72 at the small end, ~10% at the large end).
        let t = Topology::nvl72();
        let pts = sweep(&t, 9, 8, &default_sizes());
        assert!(pts.first().unwrap().improvement_pct > pts.last().unwrap().improvement_pct);
        assert!(pts.last().unwrap().improvement_pct > 0.0);
    }

    #[test]
    fn bandwidth_metric() {
        assert!((bandwidth_gbps(1e9, 1.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn oversubscribed_world_panics() {
        etp_time(&Topology::h20_node(), 8, 2, 1e6);
    }
}
