//! Arrival-driven serving scheduler: the request lifecycle behind every
//! measured serving number in this repo.
//!
//! Every request walks an explicit state machine
//!
//! ```text
//! Queued → Prefill → Decode → Done
//!        ↘ Rejected            (queue full: bounded admission control)
//!                  ↘ Done      (immediate EOS / max_new ≤ 1)
//!                  ↘ Rejected  (admission validation: prompt + max_new
//!                               exceed the KV window)
//! ```
//!
//! driven by a continuous-batching loop under one of two arrival modes:
//!
//! * [`ArrivalMode::Closed`] — the classic closed batch loop: every
//!   request is available at t = 0 and admission is limited only by KV
//!   slots. Completion texts reproduce the legacy `serve()` loop
//!   byte-for-byte (pinned by `rust/tests/scheduler.rs`).
//! * [`ArrivalMode::Open`] — open-loop serving: deterministic Poisson
//!   arrivals (SplitMix64 exponential inter-arrival gaps); a request
//!   becomes admissible only once the wall clock reaches its arrival
//!   time. This is the arrival process the serving literature (and the
//!   paper's §5.3.2 efficiency methodology) measures under.
//!
//! Two decisions are pluggable via [`crate::engine::policy`]
//! (see [`serve_policy`]):
//!
//! * **who is admitted next** — a
//!   [`SchedulingPolicy`](crate::engine::policy::SchedulingPolicy)
//!   picks from the waiting queue (`fcfs` / `spf` / `priority`);
//!   [`serve_with`] runs FCFS, which reproduces the pre-policy
//!   scheduler byte-for-byte.
//! * **whether an arrival may wait at all** — an
//!   [`AdmissionControl`](crate::engine::policy::AdmissionControl)
//!   queue bound turns open-loop overload into `queue full` rejections
//!   (Queued → Rejected), so [`ServeStats::goodput_rps`] reports
//!   goodput against offered load instead of an unbounded queue.
//!
//! Latency accounting is **arrival-anchored**: `latency` includes queue
//! wait, `ttft` is arrival → first token, and the old admission-anchored
//! number survives as `service_secs` so a report can show both side by
//! side. Request-level faults are **per-request**: a prompt that fails
//! admission validation (it cannot fit the KV window together with its
//! `max_new` budget — since chunked prefill, length is bounded by KV
//! capacity, not by the largest prefill bucket) is Rejected without
//! consuming a KV slot and every other request keeps decoding, while a
//! backend execution error past validation still aborts the run
//! (swallowing it as rejections would report a dead backend as a
//! successful run).

use std::collections::VecDeque;

use anyhow::Result;

use super::policy::{AdmissionControl, Fcfs, QueuedRequest, SchedulingPolicy};
use super::{Engine, EOS, MAX_SLOTS};
use crate::util::rng::SplitMix64;
use crate::util::stats::{mean, percentile};
use crate::util::Timer;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    pub prompt: String,
    pub max_new: usize,
    /// Scheduling lane for
    /// [`PriorityLanes`](crate::engine::policy::PriorityLanes); higher =
    /// more urgent. 0 (the conventional default lane) everywhere a
    /// workload does not say otherwise; FCFS and SPF ignore it.
    pub priority: u8,
}

/// When requests become admissible.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalMode {
    /// Closed batch loop: every request has arrival time 0.
    Closed,
    /// Open loop: Poisson arrivals at `rate` requests/second,
    /// deterministic given `seed` (SplitMix64 exponential gaps).
    Open { rate: f64, seed: u64 },
}

/// Lifecycle states of one request inside the scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Queued,
    Prefill,
    Decode,
    Done,
    Rejected,
}

#[derive(Debug, Clone)]
pub struct Completion {
    pub id: usize,
    /// The request's scheduling lane (copied from
    /// [`Request::priority`]).
    pub priority: u8,
    pub text: String,
    /// Generated tokens excluding the EOS terminator (== `text.len()`).
    pub new_tokens: usize,
    /// Arrival time (seconds from run start; 0 in closed-loop mode).
    pub arrival: f64,
    /// Arrival → admission (time spent waiting for a KV slot).
    pub queue_secs: f64,
    /// Arrival → first token (queue wait + prefill).
    pub ttft: f64,
    /// Admission → completion — the legacy, admission-anchored metric.
    pub service_secs: f64,
    /// Arrival → completion (queue-inclusive — the honest number).
    pub latency: f64,
    /// First token → completion (decode-phase wall time).
    pub decode_secs: f64,
}

/// A request rejected without consuming a KV slot and without affecting
/// any other request — either at admission validation (prompt cannot
/// fit the KV window) or on arrival at a full bounded queue.
#[derive(Debug, Clone)]
pub struct Rejection {
    pub id: usize,
    pub reason: String,
    pub arrival: f64,
    pub rejected_at: f64,
}

#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub wall_secs: f64,
    /// Completed requests.
    pub requests: usize,
    /// Rejected requests (per-request failures; the run kept going).
    /// Includes both capacity-validation and queue-full rejections.
    pub rejected: usize,
    /// The subset of `rejected` turned away by the
    /// [`AdmissionControl`] queue bound (`reason` = "queue full…").
    pub rejected_queue_full: usize,
    /// Completed requests per wall-clock second — the goodput to plot
    /// against offered load (open-loop arrival rate). Diverges from the
    /// offered rate past the knee, where the queue bound rejects.
    pub goodput_rps: f64,
    pub generated_tokens: u64,
    pub prefill_tokens: u64,
    pub tokens_per_sec: f64,
    /// Arrival-anchored (queue-inclusive) latency.
    pub mean_latency: f64,
    pub p50_latency: f64,
    pub p99_latency: f64,
    /// Admission-anchored service time (the pre-scheduler "latency").
    pub p50_service: f64,
    pub p99_service: f64,
    /// Time to first token, measured from arrival.
    pub mean_ttft: f64,
    pub p50_ttft: f64,
    pub p99_ttft: f64,
    /// Mean arrival → admission wait across completions.
    pub mean_queue_secs: f64,
    /// Mean decode-phase seconds per generated token.
    pub mean_decode_secs_per_token: f64,
    /// Time-weighted average queue depth over the whole run.
    pub mean_queue_depth: f64,
    pub max_queue_depth: usize,
    /// Seconds inside MoE artifacts (gate + FFN).
    pub moe_secs: f64,
    /// Seconds inside all artifacts.
    pub artifact_secs: f64,
    pub drop_rate: f64,
}

/// Everything one serving run produced.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    /// Sorted by request id.
    pub completions: Vec<Completion>,
    pub rejections: Vec<Rejection>,
    pub stats: ServeStats,
}

/// Deterministic Poisson arrival offsets (seconds from run start):
/// exponential inter-arrival gaps at `rate` requests/second drawn from
/// a SplitMix64 stream. Strictly increasing.
pub fn poisson_arrivals(n: usize, rate: f64, seed: u64) -> Vec<f64> {
    let mut rng = SplitMix64::new(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            t += rng.exp(rate);
            t
        })
        .collect()
}

/// One in-flight request; index in the active list == its KV slot.
struct ActiveSlot {
    id: usize,
    priority: u8,
    /// Index into the `requests` slice (drives the phase table).
    ridx: usize,
    arrival: f64,
    admitted_at: f64,
    first_token_at: f64,
    out: Vec<u8>,
    next: u8,
    max_new: usize,
    /// Decode steps this request participated in.
    steps: u64,
}

fn set_phase(phases: &mut [Phase], ri: usize, to: Phase) {
    let from = phases[ri];
    debug_assert!(
        matches!(
            (from, to),
            (Phase::Queued, Phase::Prefill)
                | (Phase::Queued, Phase::Rejected) // queue full at arrival
                | (Phase::Prefill, Phase::Decode)
                | (Phase::Prefill, Phase::Done)
                | (Phase::Prefill, Phase::Rejected)
                | (Phase::Decode, Phase::Done)
        ),
        "illegal lifecycle transition {from:?} → {to:?}"
    );
    phases[ri] = to;
}

fn finish(a: ActiveSlot, now: f64) -> Completion {
    let end = a.out.iter().position(|&c| c == EOS).unwrap_or(a.out.len());
    Completion {
        id: a.id,
        priority: a.priority,
        text: a.out[..end].iter().map(|&b| b as char).collect(),
        new_tokens: end,
        arrival: a.arrival,
        queue_secs: a.admitted_at - a.arrival,
        ttft: a.first_token_at - a.arrival,
        service_secs: now - a.admitted_at,
        latency: now - a.arrival,
        decode_secs: if a.steps > 0 { now - a.first_token_at } else { 0.0 },
    }
}

/// Run `requests` to completion (or rejection) under `mode` with the
/// legacy scheduling configuration: FCFS admission order, unbounded
/// queue. Byte-for-byte identical to the pre-policy scheduler (pinned
/// by `rust/tests/scheduler.rs`).
pub fn serve_with(
    engine: &mut Engine,
    requests: &[Request],
    mode: ArrivalMode,
) -> Result<ServeOutcome> {
    serve_policy(engine, requests, mode, &Fcfs, AdmissionControl::unbounded())
}

/// Run `requests` to completion (or rejection) under `mode`, admitting
/// in the order `policy` chooses and bounding the waiting queue with
/// `admission`.
///
/// The loop: pull arrived requests into the admission queue (rejecting
/// arrivals the queue bound refuses), let `policy` pick which queued
/// request claims each free KV slot (prefill), decode the whole active
/// set in lockstep, retire finished rows (slot freed, cache compacted).
/// In open-loop mode the scheduler sleeps until the next arrival when
/// idle, so wall time — and therefore every latency column — reflects
/// the arrival process, not just raw compute.
pub fn serve_policy(
    engine: &mut Engine,
    requests: &[Request],
    mode: ArrivalMode,
    policy: &dyn SchedulingPolicy,
    admission: AdmissionControl,
) -> Result<ServeOutcome> {
    let n = requests.len();
    engine.kv.reset();
    engine.reset_metrics();
    let arrivals: Vec<f64> = match mode {
        ArrivalMode::Closed => vec![0.0; n],
        ArrivalMode::Open { rate, seed } => poisson_arrivals(n, rate, seed),
    };
    // Arrivals are monotone in request order (cumulative gaps), so the
    // not-yet-arrived set is a simple index queue.
    let mut pending: VecDeque<usize> = (0..n).collect();
    let mut queue: VecDeque<usize> = VecDeque::new();
    let mut phases = vec![Phase::Queued; n];
    let mut active: Vec<ActiveSlot> = Vec::new(); // index == slot
    let mut done: Vec<Completion> = Vec::new();
    let mut rejections: Vec<Rejection> = Vec::new();
    let mut queue_full = 0usize;
    // Scratch for the policy's queue snapshot, reused across admissions
    // so picking never allocates on the serving hot path.
    let mut view: Vec<QueuedRequest> = Vec::new();
    // Time-weighted queue-depth integral: the depth observed at one
    // sample point weights the wall-clock interval until the next.
    let mut qd_integral = 0.0f64;
    let mut qd_prev = 0usize;
    let mut qd_last_t = 0.0f64;
    let mut qd_max = 0usize;
    let mut decode_busy = 0.0f64;
    let mut decode_toks = 0u64;
    let timer = Timer::start();

    loop {
        // 1. arrivals: move everything whose time has come into the
        // queue — unless the admission-control bound refuses it, in
        // which case the request is rejected on the spot (Queued →
        // Rejected, no slot ever involved).
        let now = timer.secs();
        while pending.front().map(|&i| arrivals[i] <= now).unwrap_or(false) {
            let i = pending.pop_front().unwrap();
            if !admission.admits(queue.len()) {
                set_phase(&mut phases, i, Phase::Rejected);
                queue_full += 1;
                rejections.push(Rejection {
                    id: requests[i].id,
                    reason: format!(
                        "queue full: {} waiting at max_queue_depth {}",
                        queue.len(),
                        admission.max_queue_depth.unwrap_or(0)
                    ),
                    arrival: arrivals[i],
                    rejected_at: timer.secs(),
                });
                continue;
            }
            queue.push_back(i);
        }

        // 2. admission: the policy picks which queued request claims
        // each free slot; validation + prefill follow. Validation
        // failures (prompt cannot fit the KV window) reject exactly
        // that request before any slot is claimed; a prefill error past
        // validation is a backend failure and aborts the run (after
        // freeing the just-claimed slot, which is the last one, so the
        // free never relocates another request's cache).
        while engine.kv.has_free() && active.len() < MAX_SLOTS && !queue.is_empty() {
            // A singleton queue has only one possible pick (out-of-range
            // picks clamp to the last element anyway), so skip the
            // snapshot entirely — the common case at low load.
            let pos = if queue.len() == 1 {
                0
            } else {
                view.clear();
                view.extend(queue.iter().map(|&i| QueuedRequest {
                    id: requests[i].id,
                    prompt_len: requests[i].prompt.len(),
                    priority: requests[i].priority,
                    arrival: arrivals[i],
                }));
                policy.pick(&view).min(queue.len() - 1)
            };
            let ri = queue.remove(pos).expect("pos clamped into range");
            let req = &requests[ri];
            set_phase(&mut phases, ri, Phase::Prefill);
            let capacity = engine.prompt_capacity(req.max_new);
            if req.prompt.len() > capacity {
                set_phase(&mut phases, ri, Phase::Rejected);
                rejections.push(Rejection {
                    id: req.id,
                    reason: format!(
                        "prompt too long: {} tokens + max_new {} exceed the \
                         KV window (max_seq {})",
                        req.prompt.len(),
                        req.max_new,
                        engine.cfg.max_seq
                    ),
                    arrival: arrivals[ri],
                    rejected_at: timer.secs(),
                });
                continue;
            }
            let slot = engine.kv.alloc();
            debug_assert_eq!(slot, active.len());
            let admitted_at = timer.secs();
            match engine.prefill(slot, req.prompt.as_bytes()) {
                Ok(first) => {
                    let a = ActiveSlot {
                        id: req.id,
                        priority: req.priority,
                        ridx: ri,
                        arrival: arrivals[ri],
                        admitted_at,
                        first_token_at: timer.secs(),
                        // max_new == 0 honors the bound: zero tokens kept.
                        out: if req.max_new == 0 { Vec::new() } else { vec![first] },
                        next: first,
                        max_new: req.max_new,
                        steps: 0,
                    };
                    if first == EOS || req.max_new <= 1 {
                        // Finished at prefill: retire immediately instead
                        // of burning a decode step on a dead row.
                        let moved = engine.kv.free(slot);
                        debug_assert!(moved.is_none());
                        set_phase(&mut phases, ri, Phase::Done);
                        done.push(finish(a, timer.secs()));
                    } else {
                        set_phase(&mut phases, ri, Phase::Decode);
                        active.push(a);
                    }
                }
                Err(err) => {
                    // Execution failure, not a request fault: nothing
                    // leaks, but the run must not masquerade as healthy.
                    let moved = engine.kv.free(slot);
                    debug_assert!(moved.is_none());
                    return Err(err);
                }
            }
        }
        let qd_now = timer.secs();
        qd_integral += qd_prev as f64 * (qd_now - qd_last_t);
        qd_last_t = qd_now;
        qd_prev = queue.len();
        qd_max = qd_max.max(queue.len());

        if active.is_empty() {
            if queue.is_empty() && pending.is_empty() {
                break;
            }
            if queue.is_empty() {
                // Idle until the next arrival (open-loop only; capped so
                // the loop re-checks the clock at a sane cadence).
                let next_at = arrivals[*pending.front().unwrap()];
                let wait = next_at - timer.secs();
                if wait > 0.0 {
                    std::thread::sleep(std::time::Duration::from_secs_f64(wait.min(0.05)));
                }
            }
            continue;
        }

        // 3. one decode step for the whole active set.
        let step_t0 = timer.secs();
        let tokens: Vec<u8> = active.iter().map(|a| a.next).collect();
        let next = engine.decode_step(&tokens)?;
        let step_secs = timer.secs() - step_t0;
        decode_busy += step_secs * active.len() as f64;
        decode_toks += active.len() as u64;
        for (a, &t) in active.iter_mut().zip(&next) {
            a.out.push(t);
            a.next = t;
            a.steps += 1;
        }

        // 4. retire finished rows (reverse order keeps slot remaps simple).
        let mut slot = active.len();
        while slot > 0 {
            slot -= 1;
            let fin = active[slot].next == EOS || active[slot].out.len() >= active[slot].max_new;
            if !fin {
                continue;
            }
            let a = active.swap_remove(slot); // mirrors kv.free's move-last
            let moved = engine.kv.free(slot);
            debug_assert_eq!(
                moved.is_some(),
                slot < active.len(),
                "kv compaction must mirror active-list compaction"
            );
            set_phase(&mut phases, a.ridx, Phase::Done);
            done.push(finish(a, timer.secs()));
        }
    }

    debug_assert!(
        phases.iter().all(|&p| matches!(p, Phase::Done | Phase::Rejected)),
        "every request must end Done or Rejected: {phases:?}"
    );
    debug_assert_eq!(engine.kv.n_active, 0, "all KV slots must return to free");

    let wall = timer.secs();
    qd_integral += qd_prev as f64 * (wall - qd_last_t); // close the last interval
    let lats: Vec<f64> = done.iter().map(|c| c.latency).collect();
    let servs: Vec<f64> = done.iter().map(|c| c.service_secs).collect();
    let ttfts: Vec<f64> = done.iter().map(|c| c.ttft).collect();
    let queues: Vec<f64> = done.iter().map(|c| c.queue_secs).collect();
    let stats = ServeStats {
        wall_secs: wall,
        requests: done.len(),
        rejected: rejections.len(),
        rejected_queue_full: queue_full,
        goodput_rps: done.len() as f64 / wall.max(1e-9),
        generated_tokens: engine.metrics.generated_tokens,
        prefill_tokens: engine.metrics.prefill_tokens,
        tokens_per_sec: engine.metrics.generated_tokens as f64 / wall.max(1e-9),
        mean_latency: mean(&lats),
        p50_latency: percentile(&lats, 50.0),
        p99_latency: percentile(&lats, 99.0),
        p50_service: percentile(&servs, 50.0),
        p99_service: percentile(&servs, 99.0),
        mean_ttft: mean(&ttfts),
        p50_ttft: percentile(&ttfts, 50.0),
        p99_ttft: percentile(&ttfts, 99.0),
        mean_queue_secs: mean(&queues),
        mean_decode_secs_per_token: if decode_toks > 0 {
            decode_busy / decode_toks as f64
        } else {
            0.0
        },
        mean_queue_depth: if wall > 0.0 { qd_integral / wall } else { 0.0 },
        max_queue_depth: qd_max,
        moe_secs: engine.moe_time(),
        artifact_secs: engine.total_artifact_time(),
        drop_rate: engine.metrics.drop_rate(),
    };
    done.sort_by_key(|c| c.id);
    rejections.sort_by_key(|r| r.id);
    Ok(ServeOutcome { completions: done, rejections, stats })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_arrivals_are_deterministic_and_increasing() {
        let a = poisson_arrivals(64, 10.0, 7);
        let b = poisson_arrivals(64, 10.0, 7);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "strictly increasing");
        assert!(a[0] > 0.0);
        // mean gap ≈ 1/rate (loose bound; 64 samples)
        let mean_gap = a.last().unwrap() / 64.0;
        assert!(mean_gap > 0.02 && mean_gap < 0.5, "mean gap {mean_gap}");
        let c = poisson_arrivals(64, 10.0, 8);
        assert_ne!(a, c, "seed must matter");
    }

    #[test]
    fn phase_transitions_legal_paths_only() {
        let mut p = vec![Phase::Queued];
        set_phase(&mut p, 0, Phase::Prefill);
        set_phase(&mut p, 0, Phase::Decode);
        set_phase(&mut p, 0, Phase::Done);
        assert_eq!(p[0], Phase::Done);
        let mut p = vec![Phase::Queued];
        set_phase(&mut p, 0, Phase::Prefill);
        set_phase(&mut p, 0, Phase::Rejected);
        assert_eq!(p[0], Phase::Rejected);
        // queue-full admission control rejects straight from Queued.
        let mut p = vec![Phase::Queued];
        set_phase(&mut p, 0, Phase::Rejected);
        assert_eq!(p[0], Phase::Rejected);
    }

    #[test]
    #[should_panic(expected = "illegal lifecycle transition")]
    #[cfg(debug_assertions)]
    fn phase_skipping_prefill_is_illegal() {
        let mut p = vec![Phase::Queued];
        set_phase(&mut p, 0, Phase::Done);
    }
}
