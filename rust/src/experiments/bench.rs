//! `dualsparse bench` — the measured CPU perf sweep behind
//! `BENCH_cpu.json`.
//!
//! Sweeps drop policies × decode-batch sizes × worker thread counts on
//! a synthetic preset and records *measured* serving numbers
//! (tokens/sec, MoE-module busy seconds, wall seconds) plus the
//! speedup of each drop policy against the no-drop run of the same
//! (threads, batch) group. This seeds the repo's perf trajectory:
//! every future PR can diff its `BENCH_cpu.json` against the last one.
//!
//! Unlike the EP *simulation* (fig10/fig11), nothing here is modeled —
//! drop rate shrinks capacity buckets, which shrinks real GEMMs, which
//! moves real wall-clock time.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::engine::batcher::serve;
use crate::engine::{Engine, EngineOptions};
use crate::moe::DropPolicy;
use crate::server;
use crate::util::json::{num, obj, s, Json};
use crate::util::threads;

/// CLI-facing bench options.
pub struct BenchConfig {
    /// Few-config smoke sweep (CI); full sweep otherwise.
    pub quick: bool,
    /// Output path for the JSON record.
    pub out: PathBuf,
    /// Synthetic preset (or serialized model) to bench.
    pub model: String,
}

/// One measured configuration.
pub struct BenchRow {
    pub threads: usize,
    pub batch: usize,
    pub policy: String,
    pub drop_rate: f64,
    pub tokens_per_sec: f64,
    pub wall_secs: f64,
    /// Cumulative MoE (gate + FFN) busy seconds across workers.
    pub moe_secs: f64,
    /// tokens/sec vs the no-drop row of the same (threads, batch).
    pub speedup_vs_no_drop: f64,
}

/// Run the sweep; rows are ordered (threads, batch, policy) with the
/// no-drop policy first in each group.
pub fn sweep(artifacts: &Path, model: &str, quick: bool) -> Result<Vec<BenchRow>> {
    // Thresholds sit around 0.5 on purpose: top-2 normalized gating
    // scores of the near-uniform synthetic gates cluster there, so this
    // ladder yields monotonically growing drop rates (cf. the 2T band
    // note in rust/tests/integration.rs).
    let policies: Vec<(&str, DropPolicy)> = if quick {
        vec![
            ("none", DropPolicy::NoDrop),
            ("2t:0.45", DropPolicy::two_t(0.45)),
        ]
    } else {
        vec![
            ("none", DropPolicy::NoDrop),
            ("2t:0.44", DropPolicy::two_t(0.44)),
            ("2t:0.48", DropPolicy::two_t(0.48)),
            ("1t:0.52", DropPolicy::OneT(0.52)),
        ]
    };
    let threads_sweep: Vec<usize> = if quick { vec![1, 2] } else { vec![1, 2, 4] };
    let batches: Vec<usize> = if quick { vec![8] } else { vec![4, 8, 16] };
    let (req_mult, max_new) = if quick { (1, 6) } else { (2, 10) };
    let mut engine =
        Engine::new(artifacts, model, DropPolicy::NoDrop, EngineOptions::default())?;
    let mut rows = Vec::new();
    for &t in &threads_sweep {
        for &batch in &batches {
            let reqs = server::workload(batch * req_mult, max_new, 7);
            let warm = server::workload(batch.min(4), 3, 13);
            let mut base_tps: Option<f64> = None;
            for (label, pol) in &policies {
                engine.policy = *pol;
                threads::set_thread_override(Some(t));
                // restore the process-global override even on error —
                // a leaked Some(t) would silently re-thread everything
                // that runs later in this process (paper_benches).
                let measured = (|| {
                    serve(&mut engine, &warm)?; // touch every artifact bucket
                    serve(&mut engine, &reqs)
                })();
                threads::set_thread_override(None);
                let (_, stats) = measured?;
                let speedup = match base_tps {
                    Some(b) if b > 0.0 && stats.tokens_per_sec > 0.0 => {
                        stats.tokens_per_sec / b
                    }
                    _ => 1.0,
                };
                if base_tps.is_none() {
                    base_tps = Some(stats.tokens_per_sec);
                }
                rows.push(BenchRow {
                    threads: t,
                    batch,
                    policy: label.to_string(),
                    drop_rate: stats.drop_rate,
                    tokens_per_sec: stats.tokens_per_sec,
                    wall_secs: stats.wall_secs,
                    moe_secs: stats.moe_secs,
                    speedup_vs_no_drop: speedup,
                });
            }
        }
    }
    Ok(rows)
}

/// Serialize sweep rows to the `BENCH_cpu.json` schema.
pub fn write_json(model: &str, quick: bool, rows: &[BenchRow], out: &Path) -> Result<()> {
    let runs = Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("threads", num(r.threads as f64)),
                    ("batch", num(r.batch as f64)),
                    ("policy", s(&r.policy)),
                    ("drop_rate", num(r.drop_rate)),
                    ("tokens_per_sec", num(r.tokens_per_sec)),
                    ("wall_secs", num(r.wall_secs)),
                    ("moe_secs", num(r.moe_secs)),
                    ("speedup_vs_no_drop", num(r.speedup_vs_no_drop)),
                ])
            })
            .collect(),
    );
    let ap = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let j = obj(vec![
        ("model", s(model)),
        ("quick", Json::Bool(quick)),
        ("available_parallelism", num(ap as f64)),
        ("runs", runs),
    ]);
    let text = j.to_string() + "\n";
    std::fs::write(out, text).with_context(|| format!("writing {out:?}"))?;
    Ok(())
}

/// Full CLI entry: sweep, print a table, write the JSON record.
pub fn run(artifacts: &Path, cfg: &BenchConfig) -> Result<()> {
    println!(
        "dualsparse bench — model {} ({} sweep, CpuRef measured)",
        cfg.model,
        if cfg.quick { "quick" } else { "full" }
    );
    let rows = sweep(artifacts, &cfg.model, cfg.quick)?;
    println!(
        "{:>7} {:>6} {:>8} {:>7} {:>11} {:>9} {:>9}",
        "threads", "batch", "policy", "drop%", "tok/s", "moe_s", "vs-nodrop"
    );
    for r in &rows {
        println!(
            "{:>7} {:>6} {:>8} {:>6.1}% {:>11.1} {:>9.3} {:>8.2}x",
            r.threads,
            r.batch,
            r.policy,
            100.0 * r.drop_rate,
            r.tokens_per_sec,
            r.moe_secs,
            r.speedup_vs_no_drop,
        );
    }
    write_json(&cfg.model, cfg.quick, &rows, &cfg.out)?;
    println!("wrote {:?}", cfg.out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_writes_valid_json() {
        let rows = sweep(Path::new("/nonexistent-artifacts"), "mixtral_ish", true)
            .expect("hermetic sweep on synthetic weights");
        assert_eq!(rows.len(), 2 * 1 * 2, "threads × batches × policies");
        for r in &rows {
            assert!(r.tokens_per_sec > 0.0, "measured, not simulated");
            if r.policy == "none" {
                assert!((r.speedup_vs_no_drop - 1.0).abs() < 1e-9);
            } else {
                assert!(r.drop_rate > 0.0, "drop ladder must actually drop");
            }
        }
        let out = std::env::temp_dir().join("dualsparse_bench_selftest.json");
        write_json("mixtral_ish", true, &rows, &out).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(j.get("model").unwrap().as_str().unwrap(), "mixtral_ish");
        assert_eq!(j.get("runs").unwrap().as_arr().unwrap().len(), rows.len());
        let _ = std::fs::remove_file(&out);
    }
}
