//! S-ETP vs ETP communication simulation (paper §3.3 / Fig. 9) on the
//! three fabric models, plus a custom sweep.
//!
//!     cargo run --release --example comm_sim [ep] [tp]

#![allow(clippy::needless_range_loop, clippy::manual_memcpy, clippy::type_complexity)]

use dualsparse::commsim::{default_sizes, etp_time, setp_time, sweep, Topology};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let ep: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let tp: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(2);

    for topo in [Topology::h20_node(), Topology::nvl72(), Topology::cm384()] {
        if ep * tp > topo.world {
            continue;
        }
        println!("== {} (world {}) EP={ep} TP={tp} ==", topo.name, topo.world);
        println!(
            "{:>12} {:>11} {:>11} {:>8}",
            "bytes/dev", "ETP GB/s", "S-ETP GB/s", "gain"
        );
        for p in sweep(&topo, ep, tp, &default_sizes()) {
            println!(
                "{:>12.0} {:>11.2} {:>11.2} {:>+7.1}%",
                p.input_bytes, p.etp_gbps, p.setp_gbps, p.improvement_pct
            );
        }
        // decomposition at one representative size
        let s = 1 << 20;
        println!(
            "at 1 MiB/device: ETP {:.1} µs vs S-ETP {:.1} µs\n",
            1e6 * etp_time(&topo, ep, tp, s as f64),
            1e6 * setp_time(&topo, ep, tp, s as f64),
        );
    }
    println!(
        "S-ETP replaces AlltoAll+AllGather / ReduceScatter+AlltoAll with a\n\
         single balanced AlltoAll each way (fewer launches + better link\n\
         utilization) by partitioning experts algorithmically — partial\n\
         transformation, Eq. 12/13."
    );
}
