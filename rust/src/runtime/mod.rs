//! Pluggable execution backends.
//!
//! The engine's heavy math goes through the [`Backend`] trait: a small
//! artifact-oriented interface (upload weights once, execute a named
//! shape-bucketed kernel). Two implementations exist:
//!
//! * [`cpu::CpuRef`] — a pure-Rust reference executor, numerically
//!   equivalent to the jnp oracles in `python/compile/kernels/ref.py`.
//!   Always available; makes the whole serving stack hermetic (tests
//!   and CI run with no artifacts and no Python).
//! * `pjrt::PjrtRuntime` — the AOT PJRT runtime that loads HLO-text
//!   artifacts produced by `make artifacts`. Gated behind the `pjrt`
//!   cargo feature (needs the `xla` crate in the vendor set).
//!
//! Artifact names carry the dispatch contract shared by both backends
//! (see `python/compile/aot.py::lower_artifacts`):
//!
//! | name                       | args                                         |
//! |----------------------------|----------------------------------------------|
//! | `ffn_h{H}_c{C}`            | `x [C,d], w1 [d,H], w3 [d,H], w2 [H,d]`      |
//! | `ffn_mask_h{H}k{K}_c{C}`   | `… + kept (i32 [K])` — only the K probe-ranked intermediate rows run (CpuRef-only) |
//! | `ffn_q8_h{H}_c{C}`         | `x, q1, q3, q2 (int8 codes as f32), scales [3]` — dequantize-in-register (CpuRef-only) |
//! | `ffn_q8_mask_h{H}k{K}_c{C}`| `… q8 args + kept (i32 [K])` — masked + quantized composition (CpuRef-only) |
//! | `gate_b{B}_e{E}`           | `x [B,d], wg [d,E]`                          |
//! | `probe_h{H}`               | `x [C,d], w1 [d,H], w3 [d,H]`                |
//! | `attn_prefill_s{S}`        | `x, ln1, wq, wk, wv, wo, ln2`                |
//! | `attn_prefill_chunk_s{S}`  | `… + kcache, vcache, base (i32)`             |
//! | `attn_step_b{B}`           | `… + kcache, vcache, pos (i32)`              |
//! | `lm_head_b{B}`             | `x [B,d], lnf [d], emb [V,d]`                |
//!
//! Backend selection: [`BackendKind`] on `EngineOptions`, overridable
//! with the `DUALSPARSE_BACKEND` env var (`cpu` | `pjrt`); `Auto` picks
//! PJRT when compiled in *and* artifacts exist, `CpuRef` otherwise.

pub mod cpu;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::model::{ModelConfig, Tensor};

pub use cpu::CpuRef;

/// Opaque handle to a backend-resident buffer (uploaded weights). The
/// hot path passes handles so weights are never re-copied per call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BufId(pub usize);

/// Host-side input for one executable argument.
pub enum Arg<'a> {
    F32(&'a Tensor),
    /// Zero-copy view: a logical tensor whose leading dimension is
    /// split into borrowed row blocks. `F32Slices(slices, shape)` has
    /// `slices.len() == shape[0]`, each slice holding
    /// `shape[1..].product()` elements. The engine feeds per-slot
    /// KV-cache slices to `attn_step_*` this way, so the decode hot
    /// path never clones the cache; backends without host-pointer
    /// access materialize the view on upload.
    F32Slices(&'a [&'a [f32]], &'a [usize]),
    /// Zero-copy *paged* KV view: a logical `[B, H, t_max, dh]` cache
    /// tensor whose positions are scattered over fixed-size pages
    /// (`engine::kv::PagedKvCache`). Row `bi` owns
    /// `pages[row_starts[bi]..row_starts[bi + 1]]` (CSR layout); each
    /// page slice holds `n_heads · page · d_head` floats laid out
    /// `[H, page, dh]`, covering `page` consecutive logical positions.
    /// A row with an empty page range is an all-zero padding row.
    /// CpuRef walks the pages in place (per-head runs stay contiguous
    /// within a page); backends without host-pointer access gather into
    /// the contiguous `[B, H, t_max, dh]` layout on upload.
    F32Pages {
        pages: &'a [&'a [f32]],
        /// Length `B + 1`, monotone, `row_starts[B] == pages.len()`.
        row_starts: &'a [usize],
        n_heads: usize,
        /// Positions per page.
        page: usize,
        d_head: usize,
        /// Logical position window (the contiguous materialization
        /// size; positions past a row's mapped pages read as zero).
        t_max: usize,
    },
    I32(&'a [i32]),
    /// A buffer uploaded once via [`Backend::upload`] (weights path).
    Buf(BufId),
}

/// Which execution backend to build.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// PJRT when compiled in and artifacts exist, otherwise CpuRef.
    #[default]
    Auto,
    /// Pure-Rust reference executor (hermetic; no artifacts needed).
    CpuRef,
    /// AOT PJRT runtime (requires the `pjrt` feature + artifacts).
    Pjrt,
}

impl BackendKind {
    /// Parse a `DUALSPARSE_BACKEND` value.
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(BackendKind::Auto),
            "cpu" | "cpuref" | "cpu_ref" => Ok(BackendKind::CpuRef),
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            _ => bail!("unknown backend {s:?}; use auto | cpu | pjrt"),
        }
    }
}

/// An execution backend: weight upload + named-artifact execution.
///
/// Object-safe on purpose — the engine holds a `Box<dyn Backend>` so
/// the backend is a *runtime* choice (env var / options), and future
/// GPU or multi-node runtimes slot in without touching the engine.
///
/// `Sync` is a supertrait: the engine issues concurrent `exec` calls
/// from its scoped expert-dispatch workers, so implementations use
/// lock/atomic interior state rather than `RefCell`/`Cell`.
pub trait Backend: Sync {
    /// Human-readable platform tag (e.g. "cpu-ref", "Host").
    fn platform(&self) -> String;

    /// Attention kernels need head geometry that artifact names do not
    /// carry; the engine calls this once after construction.
    fn set_model(&self, _cfg: &ModelConfig) {}

    /// Whether `exec` may be invoked from several threads at once. The
    /// engine's threaded expert dispatch consults this and falls back
    /// to serial execution when false — backends whose FFI handles are
    /// not proven thread-safe must keep the default.
    fn supports_concurrent_exec(&self) -> bool {
        false
    }

    /// Whether this backend can execute the named artifact. Callers on
    /// long-running paths (serving) probe this up front to fail fast
    /// with a clear error instead of erroring mid-run on the first
    /// request that needs the artifact. CpuRef synthesizes every
    /// artifact, so the default is `true`; AOT backends override it
    /// with an artifact-exists check.
    fn supports_artifact(&self, _name: &str) -> bool {
        true
    }

    /// Upload a host tensor to a backend-resident buffer.
    fn upload(&self, t: &Tensor) -> Result<BufId>;

    /// Execute the named artifact; returns the decomposed output tuple.
    fn exec(&self, name: &str, args: &[Arg]) -> Result<Vec<Tensor>>;

    /// Number of distinct artifacts compiled/executed by this backend.
    fn compiled_count(&self) -> usize;

    /// Reset the perf counters (used between bench phases).
    fn reset_counters(&self);

    /// Total wall seconds inside execute calls whose artifact name
    /// matches `prefix` (e.g. "ffn_" for MoE-module time).
    fn time_with_prefix(&self, prefix: &str) -> f64;

    /// Snapshot of per-artifact (execution count, wall seconds).
    fn exec_counts(&self) -> HashMap<String, (u64, f64)>;
}

/// Cumulative executions + wall seconds per artifact, shared by all
/// backends (perf accounting behind `EngineMetrics` / fig10-11).
/// Mutex-guarded so backends can record from concurrent `exec` calls;
/// under threaded dispatch the per-artifact seconds are cumulative
/// *busy* time across workers (may exceed wall time).
#[derive(Debug, Default)]
pub struct ExecCounters {
    counts: Mutex<HashMap<String, (u64, f64)>>,
}

impl ExecCounters {
    pub fn record(&self, name: &str, secs: f64) {
        let mut counts = self.counts.lock().unwrap();
        let entry = counts.entry(name.to_string()).or_insert((0, 0.0));
        entry.0 += 1;
        entry.1 += secs;
    }

    pub fn reset(&self) {
        self.counts.lock().unwrap().clear();
    }

    pub fn snapshot(&self) -> HashMap<String, (u64, f64)> {
        self.counts.lock().unwrap().clone()
    }

    pub fn distinct(&self) -> usize {
        self.counts.lock().unwrap().len()
    }

    pub fn time_with_prefix(&self, prefix: &str) -> f64 {
        self.counts
            .lock()
            .unwrap()
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(_, (_, t))| t)
            .sum()
    }
}

/// Whether `dir` holds any AOT HLO-text artifacts.
pub fn has_artifacts(dir: &Path) -> bool {
    std::fs::read_dir(dir)
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .any(|e| e.path().to_string_lossy().ends_with(".hlo.txt"))
        })
        .unwrap_or(false)
}

#[cfg(feature = "pjrt")]
fn make_pjrt(artifacts_dir: &Path) -> Result<Box<dyn Backend>> {
    Ok(Box::new(pjrt::PjrtRuntime::new(artifacts_dir)?))
}

#[cfg(not(feature = "pjrt"))]
fn make_pjrt(_artifacts_dir: &Path) -> Result<Box<dyn Backend>> {
    bail!(
        "the PJRT backend is not compiled into this build — rebuild with \
         `--features pjrt` (and the `xla` dependency), or select the \
         CpuRef backend (DUALSPARSE_BACKEND=cpu)"
    )
}

/// Build a backend. `DUALSPARSE_BACKEND` (auto | cpu | pjrt) overrides
/// `kind` when set.
pub fn make_backend(kind: BackendKind, artifacts_dir: &Path) -> Result<Box<dyn Backend>> {
    let kind = match std::env::var("DUALSPARSE_BACKEND") {
        Ok(v) if !v.is_empty() => BackendKind::parse(&v)?,
        _ => kind,
    };
    match kind {
        BackendKind::CpuRef => Ok(Box::new(cpu::CpuRef::new())),
        BackendKind::Pjrt => make_pjrt(artifacts_dir),
        BackendKind::Auto => {
            if cfg!(feature = "pjrt") && has_artifacts(artifacts_dir) {
                make_pjrt(artifacts_dir)
            } else {
                Ok(Box::new(cpu::CpuRef::new()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("cpu").unwrap(), BackendKind::CpuRef);
        assert_eq!(BackendKind::parse("CPUREF").unwrap(), BackendKind::CpuRef);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert_eq!(BackendKind::parse("auto").unwrap(), BackendKind::Auto);
        assert!(BackendKind::parse("tpu").is_err());
    }

    #[test]
    fn counters_accumulate_and_filter() {
        let c = ExecCounters::default();
        c.record("ffn_h64_c4", 0.5);
        c.record("ffn_h64_c4", 0.25);
        c.record("gate_b2_e8", 1.0);
        assert_eq!(c.distinct(), 2);
        assert_eq!(c.snapshot()["ffn_h64_c4"].0, 2);
        assert!((c.time_with_prefix("ffn_") - 0.75).abs() < 1e-12);
        assert!((c.time_with_prefix("") - 1.75).abs() < 1e-12);
        c.reset();
        assert_eq!(c.distinct(), 0);
    }

    #[test]
    fn auto_backend_without_artifacts_is_cpu() {
        let b = make_backend(BackendKind::Auto, Path::new("/nonexistent-dir")).unwrap();
        assert_eq!(b.platform(), "cpu-ref");
    }
}
