//! Pure-Rust math kernels over host tensors — the CPU hot path.
//!
//! These are the shared kernels behind the `CpuRef` backend
//! (`runtime::cpu`) — the hermetic serving hot path when no AOT
//! artifacts exist — and are also used by property tests
//! (partition/reconstruction invariants), baseline weight surgery
//! (Wanda 2:4), and cross-checking artifact outputs without a Python
//! round trip.
//!
//! Layout: everything is built from two autovectorization-friendly
//! primitives —
//!
//! * [`gemv_acc`]: one output row accumulated as a 4-way-unrolled
//!   sequence of fused axpy passes over rows of B (`i/k/j` order, B
//!   traversed row-major, no strided access);
//! * [`dot`]: a 4-accumulator reduction over `chunks_exact(4)`.
//!
//! [`matmul`] tiles rows across worker threads when the product is
//! large enough to amortize the spawn (`util::threads`); rows are
//! independent, so results are **bit-identical for every thread count
//! and every row-block partition**. [`swiglu_ffn`] fuses gate/up
//! projection, the swish ⊙ up elementwise stage and the down
//! projection per row — the `[rows, width]` intermediates are never
//! materialized.

use crate::model::Tensor;
use crate::util::threads;

/// Below this `m·k·n` volume a GEMM runs serial — the scoped-thread
/// spawn (~tens of µs) would dominate the kernel.
const PAR_MIN_VOLUME: usize = 1 << 20;

/// `orow[j] += Σ_p arow[p] · b[p·n + j]` — one GEMM output row, B
/// row-major. Four A-scalars drive one fused pass over the output row
/// (4-way k-unroll), which both quarters the `orow` traffic and gives
/// the autovectorizer a wide independent inner loop.
#[inline]
pub fn gemv_acc(arow: &[f32], b: &[f32], n: usize, orow: &mut [f32]) {
    debug_assert_eq!(arow.len() * n, b.len());
    debug_assert_eq!(orow.len(), n);
    let k = arow.len();
    let mut p = 0;
    while p + 4 <= k {
        let a0 = arow[p];
        let a1 = arow[p + 1];
        let a2 = arow[p + 2];
        let a3 = arow[p + 3];
        let b0 = &b[p * n..(p + 1) * n];
        let b1 = &b[(p + 1) * n..(p + 2) * n];
        let b2 = &b[(p + 2) * n..(p + 3) * n];
        let b3 = &b[(p + 3) * n..(p + 4) * n];
        for ((((o, &v0), &v1), &v2), &v3) in
            orow.iter_mut().zip(b0).zip(b1).zip(b2).zip(b3)
        {
            *o += a0 * v0 + a1 * v1 + a2 * v2 + a3 * v3;
        }
        p += 4;
    }
    while p < k {
        let a0 = arow[p];
        for (o, &v) in orow.iter_mut().zip(&b[p * n..(p + 1) * n]) {
            *o += a0 * v;
        }
        p += 1;
    }
}

/// Dot product with four independent accumulators over
/// `chunks_exact(4)` — a fixed reduction order that autovectorizes.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let xc = x.chunks_exact(4);
    let yc = y.chunks_exact(4);
    let xr = xc.remainder();
    let yr = yc.remainder();
    let mut acc = [0.0f32; 4];
    for (xs, ys) in xc.zip(yc) {
        acc[0] += xs[0] * ys[0];
        acc[1] += xs[1] * ys[1];
        acc[2] += xs[2] * ys[2];
        acc[3] += xs[3] * ys[3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for (xv, yv) in xr.iter().zip(yr) {
        s += xv * yv;
    }
    s
}

/// C = A[m,k] @ B[k,n]. Rows are computed independently (tiled across
/// worker threads above [`PAR_MIN_VOLUME`]), so the result does not
/// depend on the thread count.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape.len(), 2);
    assert_eq!(b.shape.len(), 2);
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "matmul shape mismatch");
    let nt = threads::num_threads();
    if nt > 1 && m >= 2 && m * k * n >= PAR_MIN_VOLUME {
        // Row blocks across workers; each block is the serial kernel.
        let nb = nt.min(m);
        let chunk = m.div_ceil(nb);
        let blocks = threads::parallel_map(m.div_ceil(chunk), |t| {
            let r0 = t * chunk;
            let r1 = ((t + 1) * chunk).min(m);
            let mut block = vec![0.0f32; (r1 - r0) * n];
            for i in r0..r1 {
                gemv_acc(
                    &a.data[i * k..(i + 1) * k],
                    &b.data,
                    n,
                    &mut block[(i - r0) * n..(i - r0 + 1) * n],
                );
            }
            block
        });
        let mut out = Vec::with_capacity(m * n);
        for blk in blocks {
            out.extend_from_slice(&blk);
        }
        return Tensor::new(vec![m, n], out);
    }
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        gemv_acc(
            &a.data[i * k..(i + 1) * k],
            &b.data,
            n,
            &mut out[i * n..(i + 1) * n],
        );
    }
    Tensor::new(vec![m, n], out)
}

/// C = A[m,k] @ B[n,k]ᵀ (B is accessed row-wise — the tied-embedding
/// LM head projects onto `emb` rows without materializing a transpose).
/// Four B rows are reduced per A-row pass so the A row stays in
/// registers; each dot uses the fixed [`dot`] reduction order.
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape.len(), 2);
    assert_eq!(b.shape.len(), 2);
    let (m, k) = (a.shape[0], a.shape[1]);
    let (n, k2) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "matmul_bt shape mismatch");
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        let mut j = 0;
        while j + 4 <= n {
            let r0 = &b.data[j * k..(j + 1) * k];
            let r1 = &b.data[(j + 1) * k..(j + 2) * k];
            let r2 = &b.data[(j + 2) * k..(j + 3) * k];
            let r3 = &b.data[(j + 3) * k..(j + 4) * k];
            let mut s0 = 0.0f32;
            let mut s1 = 0.0f32;
            let mut s2 = 0.0f32;
            let mut s3 = 0.0f32;
            for ((((&x, &y0), &y1), &y2), &y3) in
                arow.iter().zip(r0).zip(r1).zip(r2).zip(r3)
            {
                s0 += x * y0;
                s1 += x * y1;
                s2 += x * y2;
                s3 += x * y3;
            }
            orow[j] = s0;
            orow[j + 1] = s1;
            orow[j + 2] = s2;
            orow[j + 3] = s3;
            j += 4;
        }
        while j < n {
            orow[j] = dot(arow, &b.data[j * k..(j + 1) * k]);
            j += 1;
        }
    }
    Tensor::new(vec![m, n], out)
}

/// [`gemv_acc`] with in-register dequantization: `b` holds
/// integer-valued int8 codes (carried as f32) and `scale` is the
/// symmetric per-tensor scale. `a · (s·q) = (a·s) · q`, so the scale
/// commutes onto the register-resident A scalars of the 4-way-unrolled
/// pass — the weight stream is consumed as raw codes, one multiply per
/// pass dequantizes, and the inner loop stays identical to the f32
/// kernel.
#[inline]
pub fn gemv_acc_scaled(arow: &[f32], b: &[f32], n: usize, scale: f32, orow: &mut [f32]) {
    debug_assert_eq!(arow.len() * n, b.len());
    debug_assert_eq!(orow.len(), n);
    let k = arow.len();
    let mut p = 0;
    while p + 4 <= k {
        let a0 = arow[p] * scale;
        let a1 = arow[p + 1] * scale;
        let a2 = arow[p + 2] * scale;
        let a3 = arow[p + 3] * scale;
        let b0 = &b[p * n..(p + 1) * n];
        let b1 = &b[(p + 1) * n..(p + 2) * n];
        let b2 = &b[(p + 2) * n..(p + 3) * n];
        let b3 = &b[(p + 3) * n..(p + 4) * n];
        for ((((o, &v0), &v1), &v2), &v3) in
            orow.iter_mut().zip(b0).zip(b1).zip(b2).zip(b3)
        {
            *o += a0 * v0 + a1 * v1 + a2 * v2 + a3 * v3;
        }
        p += 4;
    }
    while p < k {
        let a0 = arow[p] * scale;
        for (o, &v) in orow.iter_mut().zip(&b[p * n..(p + 1) * n]) {
            *o += a0 * v;
        }
        p += 1;
    }
}

pub fn swish(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// SwiGLU FFN (paper Eq. 4), fused per row: gate/up projections run as
/// [`gemv_acc`] passes into two width-sized scratch rows, the
/// `swish(g) ⊙ u` stage happens in place, and the down projection
/// accumulates straight into the output row. The `[rows, width]`
/// intermediates of the unfused formulation are never materialized.
pub fn swiglu_ffn(x: &Tensor, w1: &Tensor, w3: &Tensor, w2: &Tensor) -> Tensor {
    assert_eq!(x.shape.len(), 2);
    let (m, d) = (x.shape[0], x.shape[1]);
    let h = w1.shape[1];
    assert_eq!(w1.shape[0], d, "swiglu w1 shape mismatch");
    assert_eq!(w3.shape, w1.shape, "swiglu w3 shape mismatch");
    assert_eq!(w2.shape[0], h, "swiglu w2 shape mismatch");
    let dout = w2.shape[1];
    let mut out = vec![0.0f32; m * dout];
    let mut g = vec![0.0f32; h];
    let mut u = vec![0.0f32; h];
    for i in 0..m {
        let xrow = &x.data[i * d..(i + 1) * d];
        g.fill(0.0);
        u.fill(0.0);
        gemv_acc(xrow, &w1.data, h, &mut g);
        gemv_acc(xrow, &w3.data, h, &mut u);
        for (gv, &uv) in g.iter_mut().zip(u.iter()) {
            *gv = swish(*gv) * uv;
        }
        gemv_acc(&g, &w2.data, dout, &mut out[i * dout..(i + 1) * dout]);
    }
    Tensor::new(vec![m, dout], out)
}

/// Neuron-masked SwiGLU FFN: only the intermediate rows named in
/// `kept` are computed — their w1/w3 columns and w2 rows are gathered
/// once per call and the dense fused kernel runs at width
/// `kept.len()`. Every masked neuron contributes **exactly zero** (it
/// is absent from the sum, not approximated), so the result equals the
/// unmasked kernel on weights whose masked columns/rows were zeroed.
/// `kept` may be in any order, empty (all-zero output) or the full
/// width (byte-identical to [`swiglu_ffn`] when `kept = 0..h` in
/// order, since the gather is then an identity copy).
///
/// The gather is O(d·K + K·d_out) per call; the serving engine
/// amortizes it by memoizing the gathered triple per (weights, mask)
/// in the backend (see `runtime::cpu`).
pub fn swiglu_ffn_masked(
    x: &Tensor,
    w1: &Tensor,
    w3: &Tensor,
    w2: &Tensor,
    kept: &[usize],
) -> Tensor {
    let h = w1.shape[1];
    debug_assert!(kept.iter().all(|&j| j < h), "kept index out of range");
    let _ = h;
    let (w1k, w3k, w2k) = gather_ffn_kept(w1, w3, w2, kept);
    swiglu_ffn(x, &w1k, &w3k, &w2k)
}

/// Gather the kept intermediate rows of an FFN weight triple:
/// w1/w3 keep columns `kept`, w2 keeps rows `kept`. The width-K result
/// feeds the dense fused kernels directly.
pub fn gather_ffn_kept(
    w1: &Tensor,
    w3: &Tensor,
    w2: &Tensor,
    kept: &[usize],
) -> (Tensor, Tensor, Tensor) {
    (w1.gather_cols(kept), w3.gather_cols(kept), w2.gather_rows(kept))
}

/// Int8-quantized SwiGLU FFN. `q1`/`q3`/`q2` hold integer codes in
/// [-127, 127] (f32 carrier — see [`quantize_symmetric`]) and
/// `scales = [s1, s3, s2]` are the per-tensor symmetric scales.
/// Dequantization happens in-register via [`gemv_acc_scaled`]; the
/// `[rows, width]` intermediates are never materialized, exactly like
/// [`swiglu_ffn`].
pub fn swiglu_ffn_q8(
    x: &Tensor,
    q1: &Tensor,
    q3: &Tensor,
    q2: &Tensor,
    scales: &[f32; 3],
) -> Tensor {
    assert_eq!(x.shape.len(), 2);
    let (m, d) = (x.shape[0], x.shape[1]);
    let h = q1.shape[1];
    assert_eq!(q1.shape[0], d, "swiglu_q8 q1 shape mismatch");
    assert_eq!(q3.shape, q1.shape, "swiglu_q8 q3 shape mismatch");
    assert_eq!(q2.shape[0], h, "swiglu_q8 q2 shape mismatch");
    let dout = q2.shape[1];
    let mut out = vec![0.0f32; m * dout];
    let mut g = vec![0.0f32; h];
    let mut u = vec![0.0f32; h];
    for i in 0..m {
        let xrow = &x.data[i * d..(i + 1) * d];
        g.fill(0.0);
        u.fill(0.0);
        gemv_acc_scaled(xrow, &q1.data, h, scales[0], &mut g);
        gemv_acc_scaled(xrow, &q3.data, h, scales[1], &mut u);
        for (gv, &uv) in g.iter_mut().zip(u.iter()) {
            *gv = swish(*gv) * uv;
        }
        gemv_acc_scaled(&g, &q2.data, dout, scales[2], &mut out[i * dout..(i + 1) * dout]);
    }
    Tensor::new(vec![m, dout], out)
}

/// Masked + quantized SwiGLU FFN: gather the kept codes, then run the
/// dequantize-in-register kernel at width `kept.len()`. Gathering
/// codes commutes with dequantization (both are elementwise), so this
/// equals [`swiglu_ffn_q8`] on weights whose masked rows were zeroed.
pub fn swiglu_ffn_masked_q8(
    x: &Tensor,
    q1: &Tensor,
    q3: &Tensor,
    q2: &Tensor,
    scales: &[f32; 3],
    kept: &[usize],
) -> Tensor {
    let (q1k, q3k, q2k) = gather_ffn_kept(q1, q3, q2, kept);
    swiglu_ffn_q8(x, &q1k, &q3k, &q2k, scales)
}

/// Symmetric per-tensor int8 quantization: `scale = max|w| / 127`,
/// codes are `round(w / scale)` clamped to [-127, 127], carried as
/// integer-valued f32 so they flow through the existing `upload`/exec
/// ABI unchanged. Round-trip error is ≤ scale/2 per element (round to
/// nearest; the clamp never binds because `max|w| = 127·scale`
/// exactly). An all-zero tensor gets scale 1.0 so dequantization is
/// exact.
pub fn quantize_symmetric(w: &Tensor) -> (Tensor, f32) {
    let maxabs = w.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let scale = if maxabs > 0.0 { maxabs / 127.0 } else { 1.0 };
    let q = w
        .data
        .iter()
        .map(|&v| (v / scale).round().clamp(-127.0, 127.0))
        .collect();
    (Tensor::new(w.shape.clone(), q), scale)
}

/// Inverse of [`quantize_symmetric`]: `q · scale`, elementwise.
pub fn dequantize(q: &Tensor, scale: f32) -> Tensor {
    Tensor::new(q.shape.clone(), q.data.iter().map(|&v| v * scale).collect())
}

/// Row-wise softmax of a 2-D tensor.
pub fn softmax_rows(x: &Tensor) -> Tensor {
    let (m, n) = (x.shape[0], x.shape[1]);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let row = &x.data[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for j in 0..n {
            let e = (row[j] - mx).exp();
            out[i * n + j] = e;
            sum += e;
        }
        for j in 0..n {
            out[i * n + j] /= sum;
        }
    }
    Tensor::new(vec![m, n], out)
}

/// RMSNorm with gain g (matches `python/compile/model.py::rmsnorm`).
pub fn rmsnorm_rows(x: &Tensor, g: &[f32]) -> Tensor {
    let (m, n) = (x.shape[0], x.shape[1]);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let row = &x.data[i * n..(i + 1) * n];
        let ms: f32 = dot(row, row) / n as f32;
        let scale = 1.0 / (ms + 1e-6).sqrt();
        for j in 0..n {
            out[i * n + j] = row[j] * scale * g[j];
        }
    }
    Tensor::new(vec![m, n], out)
}

/// Elementwise a + b.
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape, b.shape);
    Tensor::new(
        a.shape.clone(),
        a.data.iter().zip(&b.data).map(|(x, y)| x + y).collect(),
    )
}

/// a + k * b (scaled accumulate, used for gating-weighted expert sums).
pub fn add_scaled(a: &mut Tensor, b: &Tensor, k: f32) {
    assert_eq!(a.shape, b.shape);
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x += k * y;
    }
}

/// Max absolute difference between two tensors.
pub fn max_abs_diff(a: &Tensor, b: &Tensor) -> f32 {
    assert_eq!(a.shape, b.shape);
    a.data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(vec![2, 2], vec![1., 0., 0., 1.]);
        assert_eq!(matmul(&a, &b).data, a.data);
        let c = matmul(&a, &a);
        assert_eq!(c.data, vec![7., 10., 15., 22.]);
    }

    #[test]
    fn matmul_unroll_remainders() {
        // k = 5 and n = 3 exercise both the 4-way-unroll remainder in
        // gemv_acc and the j-remainder in matmul_bt.
        let a = Tensor::new(vec![2, 5], (0..10).map(|x| x as f32).collect());
        let b = Tensor::new(vec![5, 3], (0..15).map(|x| x as f32).collect());
        let c = matmul(&a, &b);
        // reference by plain triple loop
        let mut want = vec![0.0f32; 2 * 3];
        for i in 0..2 {
            for p in 0..5 {
                for j in 0..3 {
                    want[i * 3 + j] += a.data[i * 5 + p] * b.data[p * 3 + j];
                }
            }
        }
        assert_eq!(c.data, want);
    }

    #[test]
    fn matmul_bt_matches_explicit_transpose() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(vec![2, 3], vec![1., 0., 1., 0., 1., 0.]);
        // bᵀ is [[1,0],[0,1],[1,0]] → a@bᵀ = [[4,2],[10,5]]
        assert_eq!(matmul_bt(&a, &b).data, vec![4., 2., 10., 5.]);
        assert_eq!(matmul_bt(&a, &b).shape, vec![2, 2]);
    }

    #[test]
    fn dot_matches_serial_sum() {
        let x: Vec<f32> = (0..11).map(|v| v as f32 * 0.5).collect();
        let y: Vec<f32> = (0..11).map(|v| (v as f32 - 3.0) * 0.25).collect();
        let want: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - want).abs() < 1e-4);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = Tensor::new(vec![2, 3], vec![1., 2., 3., -1., 0., 1.]);
        let s = softmax_rows(&x);
        for i in 0..2 {
            let sum: f32 = s.data[i * 3..(i + 1) * 3].iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn swish_values() {
        assert!((swish(0.0) - 0.0).abs() < 1e-9);
        assert!((swish(10.0) - 10.0 / (1.0 + (-10.0f32).exp())).abs() < 1e-6);
    }

    #[test]
    fn swiglu_matches_unfused_composition() {
        let a = Tensor::new(vec![3, 4], (0..12).map(|x| x as f32 * 0.1).collect());
        let w1 = Tensor::new(vec![4, 6], (0..24).map(|x| (x as f32 - 12.0) * 0.05).collect());
        let w3 = Tensor::new(vec![4, 6], (0..24).map(|x| (x as f32 - 6.0) * 0.04).collect());
        let w2 = Tensor::new(vec![6, 4], (0..24).map(|x| (x as f32 - 9.0) * 0.03).collect());
        let gate = matmul(&a, &w1);
        let up = matmul(&a, &w3);
        let h: Vec<f32> = gate
            .data
            .iter()
            .zip(&up.data)
            .map(|(&g, &u)| swish(g) * u)
            .collect();
        let want = matmul(&Tensor::new(gate.shape.clone(), h), &w2);
        let got = swiglu_ffn(&a, &w1, &w3, &w2);
        assert_eq!(got.shape, want.shape);
        assert!(max_abs_diff(&got, &want) < 1e-6);
    }

    #[test]
    fn masked_swiglu_full_mask_is_byte_identical_to_dense() {
        let x = Tensor::new(vec![3, 4], (0..12).map(|v| v as f32 * 0.1).collect());
        let w1 = Tensor::new(vec![4, 6], (0..24).map(|v| (v as f32 - 12.0) * 0.05).collect());
        let w3 = Tensor::new(vec![4, 6], (0..24).map(|v| (v as f32 - 6.0) * 0.04).collect());
        let w2 = Tensor::new(vec![6, 4], (0..24).map(|v| (v as f32 - 9.0) * 0.03).collect());
        let kept: Vec<usize> = (0..6).collect();
        let got = swiglu_ffn_masked(&x, &w1, &w3, &w2, &kept);
        let want = swiglu_ffn(&x, &w1, &w3, &w2);
        // in-order full mask = identity gather = the same op sequence
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn masked_swiglu_empty_mask_is_exactly_zero() {
        let x = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let w1 = Tensor::new(vec![3, 4], vec![1.0; 12]);
        let w3 = w1.clone();
        let w2 = Tensor::new(vec![4, 3], vec![1.0; 12]);
        let got = swiglu_ffn_masked(&x, &w1, &w3, &w2, &[]);
        assert_eq!(got.shape, vec![2, 3]);
        assert!(got.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn quantize_round_trip_error_bounded_by_half_scale() {
        use crate::util::rng::SplitMix64;
        let mut rng = SplitMix64::new(0x51AB);
        for _ in 0..20 {
            let n = 1 + rng.below(64);
            let w = Tensor::new(
                vec![n],
                (0..n).map(|_| rng.gauss() as f32 * 0.3).collect(),
            );
            let (q, s) = quantize_symmetric(&w);
            assert!(q.data.iter().all(|&v| v == v.round() && v.abs() <= 127.0));
            let back = dequantize(&q, s);
            for (a, b) in w.data.iter().zip(&back.data) {
                assert!((a - b).abs() <= s / 2.0 + 1e-7, "|{a} - {b}| > {s}/2");
            }
        }
        // all-zero tensor round-trips exactly
        let z = Tensor::new(vec![3], vec![0.0; 3]);
        let (q, s) = quantize_symmetric(&z);
        assert_eq!(dequantize(&q, s).data, z.data);
    }

    #[test]
    fn q8_swiglu_tracks_dequantized_dense_reference() {
        use crate::util::rng::SplitMix64;
        let mut rng = SplitMix64::new(0x0855);
        for case in 0..20 {
            let c = 1 + rng.below(5);
            let d = 2 + rng.below(9);
            let h = 2 + rng.below(13);
            let mk = |rng: &mut SplitMix64, shape: Vec<usize>| {
                let n: usize = shape.iter().product();
                Tensor::new(shape, (0..n).map(|_| rng.gauss() as f32 * 0.2).collect())
            };
            let x = mk(&mut rng, vec![c, d]);
            let w1 = mk(&mut rng, vec![d, h]);
            let w3 = mk(&mut rng, vec![d, h]);
            let w2 = mk(&mut rng, vec![h, d]);
            let (q1, s1) = quantize_symmetric(&w1);
            let (q3, s3) = quantize_symmetric(&w3);
            let (q2, s2) = quantize_symmetric(&w2);
            let got = swiglu_ffn_q8(&x, &q1, &q3, &q2, &[s1, s3, s2]);
            // reference: dense f32 kernel on the dequantized weights —
            // only the rounding order of the scale multiply differs
            let want = swiglu_ffn(
                &x,
                &dequantize(&q1, s1),
                &dequantize(&q3, s3),
                &dequantize(&q2, s2),
            );
            let err = max_abs_diff(&got, &want);
            assert!(err <= 2e-3, "case {case}: q8 |Δ|={err} (c={c} d={d} h={h})");
        }
    }

    #[test]
    fn rmsnorm_unit_gain() {
        let x = Tensor::new(vec![1, 2], vec![3.0, 4.0]);
        let y = rmsnorm_rows(&x, &[1.0, 1.0]);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        let rms = (12.5f32 + 1e-6).sqrt();
        assert!((y.data[0] - 3.0 / rms).abs() < 1e-5);
    }

    #[test]
    fn add_scaled_accumulates() {
        let mut a = Tensor::new(vec![2], vec![1.0, 1.0]);
        let b = Tensor::new(vec![2], vec![2.0, 4.0]);
        add_scaled(&mut a, &b, 0.5);
        assert_eq!(a.data, vec![2.0, 3.0]);
    }

    #[test]
    fn parallel_matmul_is_thread_count_invariant() {
        use crate::util::rng::SplitMix64;
        let mut rng = SplitMix64::new(42);
        // big enough to cross PAR_MIN_VOLUME: 64·128·256 = 2M
        let (m, k, n) = (64usize, 128usize, 256usize);
        let a = Tensor::new(
            vec![m, k],
            (0..m * k).map(|_| rng.gauss() as f32 * 0.1).collect(),
        );
        let b = Tensor::new(
            vec![k, n],
            (0..k * n).map(|_| rng.gauss() as f32 * 0.1).collect(),
        );
        // Serial reference built directly from the row kernel — no
        // dependence on the process-global thread override, which
        // concurrently-running tests may flip.
        let mut serial = vec![0.0f32; m * n];
        for i in 0..m {
            gemv_acc(
                &a.data[i * k..(i + 1) * k],
                &b.data,
                n,
                &mut serial[i * n..(i + 1) * n],
            );
        }
        crate::util::threads::set_thread_override(Some(4));
        let par = matmul(&a, &b);
        crate::util::threads::set_thread_override(None);
        assert_eq!(serial, par.data, "row partition must not change bits");
    }
}
