//! Continuous batcher: vLLM-style request loop over the engine.
//!
//! Admits queued requests into free KV slots (prefill), then decodes
//! the whole active set in lockstep; retiring requests free their slot
//! and the KV cache compacts so the decode batch stays a contiguous
//! slot prefix.

use anyhow::Result;

use super::{Engine, EOS, MAX_SLOTS};
use crate::util::stats::percentile;
use crate::util::Timer;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    pub prompt: String,
    pub max_new: usize,
}

#[derive(Debug, Clone)]
pub struct Completion {
    pub id: usize,
    pub text: String,
    /// Seconds from admission to completion.
    pub latency: f64,
    pub new_tokens: usize,
}

#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub wall_secs: f64,
    pub requests: usize,
    pub generated_tokens: u64,
    pub prefill_tokens: u64,
    pub tokens_per_sec: f64,
    pub mean_latency: f64,
    pub p50_latency: f64,
    pub p99_latency: f64,
    /// Seconds inside MoE artifacts (gate + FFN).
    pub moe_secs: f64,
    /// Seconds inside all artifacts.
    pub artifact_secs: f64,
    pub drop_rate: f64,
}

struct Active {
    id: usize,
    start: f64,
    out: Vec<u8>,
    next: u8,
    max_new: usize,
}

/// Run all `requests` to completion with continuous batching.
pub fn serve(engine: &mut Engine, requests: &[Request]) -> Result<(Vec<Completion>, ServeStats)> {
    engine.kv.n_active = 0;
    engine.reset_metrics();
    let timer = Timer::start();
    let mut queue: std::collections::VecDeque<&Request> = requests.iter().collect();
    let mut active: Vec<Active> = Vec::new(); // index == slot
    let mut done: Vec<Completion> = Vec::new();

    while !queue.is_empty() || !active.is_empty() {
        // Admit while there is room.
        while engine.kv.has_free() && active.len() < MAX_SLOTS {
            let Some(req) = queue.pop_front() else { break };
            let slot = engine.kv.alloc();
            debug_assert_eq!(slot, active.len());
            let start = timer.secs();
            let first = engine.prefill(slot, req.prompt.as_bytes())?;
            active.push(Active {
                id: req.id,
                start,
                out: vec![first],
                next: first,
                max_new: req.max_new,
            });
        }
        if active.is_empty() {
            break;
        }
        // One decode step for the whole active set.
        let tokens: Vec<u8> = active.iter().map(|a| a.next).collect();
        let next = engine.decode_step(&tokens)?;
        for (a, &t) in active.iter_mut().zip(&next) {
            a.out.push(t);
            a.next = t;
        }
        // Retire finished rows (reverse order keeps slot remaps simple).
        let mut slot = active.len();
        while slot > 0 {
            slot -= 1;
            let fin = active[slot].next == EOS || active[slot].out.len() >= active[slot].max_new;
            if !fin {
                continue;
            }
            let a = active.swap_remove(slot); // mirrors kv.free's move-last
            let moved = engine.kv.free(slot);
            debug_assert_eq!(
                moved.is_some(),
                slot < active.len(),
                "kv compaction must mirror active-list compaction"
            );
            let end = a.out.iter().position(|&c| c == EOS).unwrap_or(a.out.len());
            done.push(Completion {
                id: a.id,
                text: a.out[..end].iter().map(|&b| b as char).collect(),
                latency: timer.secs() - a.start,
                new_tokens: a.out.len(),
            });
        }
    }

    let wall = timer.secs();
    let lats: Vec<f64> = done.iter().map(|c| c.latency).collect();
    let stats = ServeStats {
        wall_secs: wall,
        requests: done.len(),
        generated_tokens: engine.metrics.generated_tokens,
        prefill_tokens: engine.metrics.prefill_tokens,
        tokens_per_sec: engine.metrics.generated_tokens as f64 / wall.max(1e-9),
        mean_latency: crate::util::stats::mean(&lats),
        p50_latency: percentile(&lats, 50.0),
        p99_latency: percentile(&lats, 99.0),
        moe_secs: engine.moe_time(),
        artifact_secs: engine.total_artifact_time(),
        drop_rate: engine.metrics.drop_rate(),
    };
    done.sort_by_key(|c| c.id);
    Ok((done, stats))
}

/// Build a serving workload from the benchmark tasks (round-robin over
/// tasks), standing in for the paper's "2000 random prompts".
pub fn task_workload(n: usize, max_new: usize) -> Vec<Request> {
    let tasks = crate::tasks::TASKS;
    let mut out = Vec::with_capacity(n);
    let mut per_task: Vec<Vec<(String, String)>> = tasks
        .iter()
        .map(|t| crate::tasks::eval_set(t, n / tasks.len() + 1, false))
        .collect();
    for i in 0..n {
        let t = i % tasks.len();
        let (prompt, _) = per_task[t].pop().expect("enough prompts");
        out.push(Request { id: i, prompt, max_new });
    }
    out
}
