//! Profiling/distribution experiments: Fig. 1, Fig. 4 (reads the
//! build-time loss logs), Fig. 6, Fig. 12, Fig. 13.

use std::path::Path;

use anyhow::{Context, Result};

use super::{ensure_importance, mk_engine, n_eval, save_result};
use crate::moe::DropPolicy;
use crate::tasks::eval::evaluate;
use crate::util::json::{arr_f64, num, obj, s, Json};
use crate::util::stats::histogram;

/// Fig. 1 — dual-sparsity heatmap: accumulated |activation| per neuron
/// per expert (OLMoE stand-in, one MoE layer).
pub fn fig1(artifacts: &Path) -> Result<()> {
    let model = "olmoe_ish";
    let tables = ensure_importance(artifacts, model)?;
    let layer = tables.t.len() / 2; // a middle layer, like the paper
    println!("Fig.1 — accumulated |gate| per neuron, layer {layer}, {model}");
    println!("(rows = experts: tensor-level sparsity; cols = neurons: neuron-level sparsity)");
    let mut rows = Vec::new();
    for (e, exp) in tables.t[layer].iter().enumerate() {
        let absgate = &exp[1];
        let total: f32 = absgate.iter().sum();
        let mx = absgate.iter().cloned().fold(0.0f32, f32::max);
        let mn = absgate.iter().cloned().fold(f32::INFINITY, f32::min);
        println!(
            "expert {e:>2}: total={total:>10.1} max={mx:>8.2} min={mn:>8.3} \
             max/min={:>8.1}",
            mx / mn.max(1e-6)
        );
        rows.push(arr_f64(
            &absgate.iter().map(|&x| x as f64).collect::<Vec<_>>(),
        ));
    }
    // Tensor-level spread: per-expert totals should be visibly imbalanced.
    let totals: Vec<f64> = tables.t[layer]
        .iter()
        .map(|e| e[1].iter().sum::<f32>() as f64)
        .collect();
    let tmax = totals.iter().cloned().fold(0.0, f64::max);
    let tmin = totals.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("tensor-level imbalance (max/min expert total): {:.1}", tmax / tmin.max(1e-9));
    save_result(
        artifacts,
        "fig1",
        obj(vec![
            ("model", s(model)),
            ("layer", num(layer as f64)),
            ("abs_gate_heatmap", Json::Arr(rows)),
        ]),
    )?;
    Ok(())
}

/// Fig. 4 — fine-tuning loss curves for P = 1/2/4 complete
/// transformations (generated at build time by the trainer).
pub fn fig4(artifacts: &Path) -> Result<()> {
    let path = artifacts.join("results/fig4_curves.json");
    let j = Json::parse(
        &std::fs::read_to_string(&path)
            .with_context(|| format!("{path:?} missing — run `make artifacts`"))?,
    )?;
    println!("Fig.4 — fine-tuning loss (lower is better; paper: finer P wins)");
    let mut out = Vec::new();
    for p in ["P=1", "P=2", "P=4"] {
        let log = j.get(p)?.as_arr()?;
        let losses: Vec<f64> = log
            .iter()
            .map(|e| e.get("loss").and_then(|l| l.as_f64()))
            .collect::<Result<Vec<_>>>()?;
        let last5 = &losses[losses.len().saturating_sub(5)..];
        let final_loss = last5.iter().sum::<f64>() / last5.len() as f64;
        println!(
            "{p}: start={:.3} final(avg last 5)={:.4}",
            losses[0], final_loss
        );
        out.push((p, final_loss));
    }
    let ok = out[2].1 <= out[0].1;
    println!(
        "finer-grained (P=4) vs original final loss: {}",
        if ok { "LOWER ✓ (matches paper)" } else { "not lower ✗" }
    );
    Ok(())
}

/// Fig. 6 — distributions of expert selection, gating scores and
/// normalized gating scores across four benchmark tasks.
pub fn fig6(artifacts: &Path) -> Result<()> {
    let model = "olmoe_ish";
    let tasks = ["add", "lm", "ind", "srt"]; // GSM8K/HellaSwag/ARC/MMLU stand-ins
    println!("Fig.6 — gating distributions on {model} across tasks");
    let mut records = Vec::new();
    for task in tasks {
        let mut engine = mk_engine(artifacts, model, DropPolicy::NoDrop)?;
        engine.opts.collect_stats = true;
        let set = crate::tasks::eval_set(task, n_eval(), false);
        for chunk in set.chunks(crate::engine::MAX_SLOTS) {
            let prompts: Vec<&str> = chunk.iter().map(|(p, _)| p.as_str()).collect();
            engine.generate_batch(&prompts, 8)?;
        }
        let m = &engine.metrics;
        let raw: Vec<f64> = m.raw_scores.iter().map(|&x| x as f64).collect();
        let norm: Vec<f64> = m.norm_scores.iter().map(|&x| x as f64).collect();
        let raw_h = histogram(&raw, 0.0, 0.5, 10);
        let norm_h = histogram(&norm, 0.0, 1.0, 10);
        // aggregate expert selection over layers
        let mut sel = vec![0u64; engine.cfg.n_experts];
        for layer in &m.expert_counts {
            for (e, &c) in layer.iter().enumerate() {
                sel[e] += c;
            }
        }
        println!("task {task}:");
        println!("  raw score hist  (0-0.5, 10 bins): {raw_h:?}");
        println!("  norm score hist (0-1.0, 10 bins): {norm_h:?}");
        println!("  expert selection: {sel:?}");
        records.push(obj(vec![
            ("task", s(task)),
            ("raw_hist", arr_f64(&raw_h.iter().map(|&x| x as f64).collect::<Vec<_>>())),
            ("norm_hist", arr_f64(&norm_h.iter().map(|&x| x as f64).collect::<Vec<_>>())),
            ("selection", arr_f64(&sel.iter().map(|&x| x as f64).collect::<Vec<_>>())),
        ]));
    }
    save_result(artifacts, "fig6", Json::Arr(records))?;
    println!(
        "(paper's observation: selection varies strongly by task, while the\n\
         normalized-score distribution is stable across tasks)"
    );
    Ok(())
}

/// Fig. 12 — per-layer drop rate as a function of the 1T threshold.
pub fn fig12(artifacts: &Path) -> Result<()> {
    let model = "olmoe_ish";
    let thresholds = [0.04f32, 0.08, 0.12, 0.16];
    println!("Fig.12 — per-layer drop rates vs threshold ({model})");
    let mut records = Vec::new();
    for &t in &thresholds {
        let mut engine = mk_engine(artifacts, model, DropPolicy::OneT(t))?;
        engine.reset_metrics();
        evaluate(&mut engine, n_eval().min(12), false)?;
        let per_layer: Vec<f64> = engine
            .metrics
            .per_layer_drop
            .iter()
            .map(|d| d.drop_rate())
            .collect();
        let overall = engine.metrics.drop_rate();
        println!(
            "T={t:.2}: overall={:.1}%  per-layer={:?}",
            100.0 * overall,
            per_layer
                .iter()
                .map(|r| format!("{:.1}%", 100.0 * r))
                .collect::<Vec<_>>()
        );
        records.push(obj(vec![
            ("threshold", num(t as f64)),
            ("overall", num(overall)),
            ("per_layer", arr_f64(&per_layer)),
        ]));
    }
    save_result(artifacts, "fig12", Json::Arr(records))?;
    println!("(drop rate is non-linear in the threshold and varies per layer)");
    Ok(())
}

/// Fig. 13 — the four neuron-importance profiles for a high-load vs a
/// low-load expert (DeepSeek stand-in).
pub fn fig13(artifacts: &Path) -> Result<()> {
    let model = "deepseek_ish";
    let tables = ensure_importance(artifacts, model)?;
    // find high-/low-load experts by calibration selection counts
    let mut engine = mk_engine(artifacts, model, DropPolicy::NoDrop)?;
    engine.opts.collect_stats = true;
    let stream = crate::tasks::calibration_tokens(1024);
    for chunk in stream.chunks(32) {
        if chunk.len() < 2 {
            break;
        }
        engine.kv.reset();
        let slot = engine.kv.alloc();
        engine.prefill(slot, chunk)?;
    }
    let layer = engine.cfg.n_layers / 2;
    let counts = &engine.metrics.expert_counts[layer];
    let hi = (0..counts.len()).max_by_key(|&e| counts[e]).unwrap();
    let lo = (0..counts.len()).min_by_key(|&e| counts[e]).unwrap();
    println!(
        "Fig.13 — importance profiles, layer {layer}: high-load expert {hi} \
         ({} sel) vs low-load expert {lo} ({} sel)",
        counts[hi], counts[lo]
    );
    let metric_names = crate::calib::METRICS;
    let mut rec = Vec::new();
    for (mi, name) in metric_names.iter().enumerate() {
        for (tag, e) in [("high", hi), ("low", lo)] {
            let prof = &tables.t[layer][e][mi];
            let neg = prof.iter().filter(|&&x| x < 0.0).count();
            let total: f32 = prof.iter().map(|x| x.abs()).sum();
            println!(
                "  {name:<12} {tag:<4} expert: |sum|={total:>9.1} negative neurons={neg}/{}",
                prof.len()
            );
            rec.push(obj(vec![
                ("metric", s(name)),
                ("load", s(tag)),
                ("expert", num(e as f64)),
                ("negatives", num(neg as f64)),
                (
                    "profile",
                    arr_f64(&prof.iter().map(|&x| x as f64).collect::<Vec<_>>()),
                ),
            ]));
        }
    }
    save_result(artifacts, "fig13", Json::Arr(rec))?;
    println!(
        "(paper: low-load experts show many negative accumulated-gate values;\n\
         absolute-value metrics avoid positive/negative cancellation)"
    );
    Ok(())
}
