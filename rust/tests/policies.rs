//! Scheduling-policy and admission-control tests: FCFS pins the legacy
//! order, SPF admits by prompt length, priority lanes admit by lane,
//! and a bounded queue rejects exactly the overflow while completions ∪
//! rejections stay exhaustive.
//!
//! Hermetic: CpuRef backend + synthetic SplitMix64 weights.

use std::path::PathBuf;

use dualsparse::engine::policy::{
    AdmissionControl, AgingConfig, Fcfs, PolicyKind, PriorityLanes, ShortestPromptFirst,
};
use dualsparse::engine::scheduler::{
    serve_opts, serve_policy, serve_with, ArrivalMode, Request, SchedOptions,
};
use dualsparse::engine::{Engine, EngineOptions, MAX_SLOTS};
use dualsparse::moe::DropPolicy;
use dualsparse::server::workload;

fn artifacts() -> PathBuf {
    std::env::var("DUALSPARSE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn engine() -> Engine {
    Engine::new(&artifacts(), "mixtral_ish", DropPolicy::NoDrop, EngineOptions::default())
        .expect("hermetic engine (CpuRef + synthetic weights)")
}

/// n requests whose prompt lengths descend with the id (id 0 longest),
/// so FCFS and SPF admission orders are opposites.
fn descending_length_requests(n: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request {
            id: i,
            prompt: "x".repeat(4 + (n - 1 - i) * 5),
            max_new: 3,
            priority: 0,
            deadline_secs: None,
        })
        .collect()
}

#[test]
fn fcfs_policy_is_byte_identical_to_default_serve() {
    let mut e = engine();
    let reqs = workload(20, 5, 7);
    for mode in [ArrivalMode::Closed, ArrivalMode::Open { rate: 200.0, seed: 3 }] {
        let a = serve_with(&mut e, &reqs, mode).unwrap();
        let b = serve_policy(&mut e, &reqs, mode, &Fcfs, AdmissionControl::unbounded())
            .unwrap();
        let c = serve_policy(
            &mut e,
            &reqs,
            mode,
            PolicyKind::Fcfs.policy(),
            AdmissionControl::unbounded(),
        )
        .unwrap();
        assert_eq!(a.completions.len(), b.completions.len(), "{mode:?}: completion counts");
        assert_eq!(a.completions.len(), c.completions.len(), "{mode:?}: completion counts");
        assert_eq!(a.rejections.len(), b.rejections.len(), "{mode:?}: rejection counts");
        assert_eq!(a.rejections.len(), c.rejections.len(), "{mode:?}: rejection counts");
        for (x, y) in a.completions.iter().zip(&b.completions) {
            assert_eq!((x.id, &x.text), (y.id, &y.text), "{mode:?}: explicit Fcfs diverged");
        }
        for (x, y) in a.completions.iter().zip(&c.completions) {
            assert_eq!((x.id, &x.text), (y.id, &y.text), "{mode:?}: PolicyKind path diverged");
        }
    }
}

/// Admission order is observable through `queue_secs` (closed-loop
/// arrival is t = 0, so queue wait == admission time, which is strictly
/// monotone in admission order): everything admitted in the first wave
/// waited less than everything admitted after the first retirement.
fn first_wave_ids(completions: &[dualsparse::engine::scheduler::Completion]) -> Vec<usize> {
    let mut by_wait: Vec<(f64, usize)> =
        completions.iter().map(|c| (c.queue_secs, c.id)).collect();
    by_wait.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    by_wait[..MAX_SLOTS].iter().map(|&(_, id)| id).collect()
}

#[test]
fn spf_admits_shortest_prompts_first() {
    let mut e = engine();
    let n = MAX_SLOTS + 4;
    let reqs = descending_length_requests(n);
    let out =
        serve_policy(&mut e, &reqs, ArrivalMode::Closed, &ShortestPromptFirst,
                     AdmissionControl::unbounded())
            .unwrap();
    assert_eq!(out.completions.len(), n);
    // the four LONGEST prompts (lowest ids) wait for the second wave
    let wave1 = first_wave_ids(&out.completions);
    for id in 0..4 {
        assert!(
            !wave1.contains(&id),
            "longest prompt {id} must be deferred by SPF (wave1: {wave1:?})"
        );
    }

    // FCFS control: the first 16 by arrival are the first wave.
    let out = serve_policy(&mut e, &reqs, ArrivalMode::Closed, &Fcfs,
                           AdmissionControl::unbounded())
        .unwrap();
    let wave1 = first_wave_ids(&out.completions);
    for id in 0..MAX_SLOTS {
        assert!(wave1.contains(&id), "FCFS wave1 must be ids 0..16 (got {wave1:?})");
    }
}

#[test]
fn saturated_aging_degrades_spf_to_arrival_order() {
    // Starvation control, driven to its limit: with a vanishing aging
    // step every queued request's effective prompt length collapses to
    // zero by the first admission pass, so SPF's tie-break (earliest
    // arrival among equals) must reproduce FCFS — the longest prompts
    // (lowest ids) can no longer be starved out of wave 1.
    let mut e = engine();
    let n = MAX_SLOTS + 4;
    let reqs = descending_length_requests(n);
    let out = serve_opts(
        &mut e,
        &reqs,
        ArrivalMode::Closed,
        &ShortestPromptFirst,
        SchedOptions { aging: Some(AgingConfig { step_secs: 1e-12 }), ..Default::default() },
    )
    .unwrap();
    assert_eq!(out.completions.len(), n);
    let wave1 = first_wave_ids(&out.completions);
    for id in 0..MAX_SLOTS {
        assert!(
            wave1.contains(&id),
            "fully aged SPF must admit in arrival order (wave1: {wave1:?})"
        );
    }
    // The per-lane TTFT report column is populated (single lane 0 here).
    assert_eq!(out.stats.lane_ttft50.len(), 1);
    assert_eq!(out.stats.lane_ttft50[0].0, 0);
    assert!(out.stats.lane_ttft50[0].1 > 0.0);
}

#[test]
fn priority_lanes_admit_high_lanes_first_fcfs_within_lane() {
    let mut e = engine();
    let n = MAX_SLOTS + 4;
    // equal lengths; lane = id % 3 (lane 2 most urgent).
    let reqs: Vec<Request> = (0..n)
        .map(|i| Request {
            id: i,
            prompt: "y".repeat(24),
            max_new: 3,
            priority: (i % 3) as u8,
            deadline_secs: None,
        })
        .collect();
    let out = serve_policy(&mut e, &reqs, ArrivalMode::Closed, &PriorityLanes,
                           AdmissionControl::unbounded())
        .unwrap();
    assert_eq!(out.completions.len(), n);
    // lanes 2 and 1 (13 requests) all fit wave 1; lane 0 fills the
    // remaining 3 slots in arrival order (ids 0, 3, 6), deferring ids
    // 9, 12, 15, 18.
    let wave1 = first_wave_ids(&out.completions);
    for c in &out.completions {
        assert_eq!(c.priority, (c.id % 3) as u8, "priority must thread into Completion");
    }
    for id in [9usize, 12, 15, 18] {
        assert!(
            !wave1.contains(&id),
            "late lane-0 request {id} must be deferred (wave1: {wave1:?})"
        );
    }
    for id in [0usize, 3, 6] {
        assert!(
            wave1.contains(&id),
            "early lane-0 request {id} rides wave 1 FCFS-within-lane (wave1: {wave1:?})"
        );
    }
}

#[test]
fn bounded_queue_rejects_exactly_the_overflow() {
    let mut e = engine();
    let k = 6usize;
    let reqs = workload(24, 4, 5);
    let out = serve_policy(&mut e, &reqs, ArrivalMode::Closed, &Fcfs,
                           AdmissionControl::bounded(k))
        .unwrap();
    // Closed loop: all 24 arrive in one burst before any admission, so
    // exactly k enter the queue and the overflow is rejected.
    assert_eq!(out.completions.len(), k, "exactly max_queue_depth complete");
    assert_eq!(out.rejections.len(), 24 - k, "exactly the overflow is rejected");
    assert_eq!(out.stats.rejected_queue_full, 24 - k);
    for c in &out.completions {
        assert!(c.id < k, "the k earliest arrivals complete (got id {})", c.id);
    }
    for r in &out.rejections {
        assert!(r.id >= k, "only overflow arrivals reject (got id {})", r.id);
        assert!(r.reason.contains("queue full"), "reason: {}", r.reason);
    }
    // exhaustive coverage + no slot leak + goodput bookkeeping
    let mut seen = vec![0usize; reqs.len()];
    for c in &out.completions {
        seen[c.id] += 1;
    }
    for r in &out.rejections {
        seen[r.id] += 1;
    }
    assert!(seen.iter().all(|&s| s == 1), "completions ∪ rejections exhaustive: {seen:?}");
    assert_eq!(e.kv.n_active, 0, "no KV slot leaks");
    let expect_gp = k as f64 / out.stats.wall_secs;
    assert!((out.stats.goodput_rps - expect_gp).abs() < 1e-9, "goodput = completed / wall");
}

#[test]
fn open_loop_bounded_queue_stays_exhaustive_and_consistent() {
    let mut e = engine();
    // Arrivals far faster than service so the tiny queue bound is
    // exercised; exact rejection counts are timing-dependent, but the
    // conservation laws are not.
    let reqs = workload(20, 4, 9);
    let out = serve_policy(
        &mut e,
        &reqs,
        ArrivalMode::Open { rate: 500.0, seed: 7 },
        &ShortestPromptFirst,
        AdmissionControl::bounded(2),
    )
    .unwrap();
    assert_eq!(out.completions.len() + out.rejections.len(), reqs.len());
    assert_eq!(out.stats.requests + out.stats.rejected, reqs.len());
    let queue_full =
        out.rejections.iter().filter(|r| r.reason.contains("queue full")).count();
    assert_eq!(out.stats.rejected_queue_full, queue_full);
    assert_eq!(e.kv.n_active, 0);
}
