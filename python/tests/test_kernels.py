"""L1 Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/magnitudes; fixed cases pin the artifact
shapes used by the Rust engine. This is the core correctness signal of
the compile path: the same kernels lower into every ffn_/probe_ HLO
artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import moe_ffn, probe, ref
from compile.kernels.cost import ffn_cost, probe_cost, VMEM_BYTES

D_MODEL = 64


def rand(key, shape, scale=0.2):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


@pytest.mark.parametrize("c", [4, 8, 16, 32, 64, 128])
@pytest.mark.parametrize("h", [32, 64, 128])
def test_ffn_matches_ref_artifact_shapes(c, h):
    """Every (capacity, width) bucket the AOT exporter emits."""
    x = rand(0, (c, D_MODEL), 0.5)
    w1, w3, w2 = rand(1, (D_MODEL, h)), rand(2, (D_MODEL, h)), rand(3, (h, D_MODEL))
    got = moe_ffn.swiglu_ffn_tiled(x, w1, w3, w2) if c >= 64 else \
        moe_ffn.swiglu_ffn(x, w1, w3, w2)
    want = ref.swiglu_ffn_ref(x, w1, w3, w2)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    c=st.sampled_from([1, 2, 3, 4, 5, 8, 16]),
    h=st.sampled_from([16, 32, 64, 128, 256]),
    scale=st.floats(0.01, 2.0),
    seed=st.integers(0, 2**16),
)
def test_ffn_matches_ref_hypothesis(c, h, scale, seed):
    """Shape/magnitude sweep (1-D grid variant handles any C)."""
    x = rand(seed, (c, D_MODEL), scale)
    w1 = rand(seed + 1, (D_MODEL, h), scale)
    w3 = rand(seed + 2, (D_MODEL, h), scale)
    w2 = rand(seed + 3, (h, D_MODEL), scale)
    got = moe_ffn.swiglu_ffn(x, w1, w3, w2)
    want = ref.swiglu_ffn_ref(x, w1, w3, w2)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


@settings(max_examples=15, deadline=None)
@given(
    tt=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_ffn_tiled_token_tiles(tt, seed):
    x = rand(seed, (64, D_MODEL), 0.4)
    w1, w3 = rand(seed + 1, (D_MODEL, 128)), rand(seed + 2, (D_MODEL, 128))
    w2 = rand(seed + 3, (128, D_MODEL))
    got = moe_ffn.swiglu_ffn_tiled(x, w1, w3, w2, token_tile=tt)
    want = ref.swiglu_ffn_ref(x, w1, w3, w2)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_ffn_zero_input_gives_zero():
    x = jnp.zeros((4, D_MODEL))
    w1, w3, w2 = rand(1, (D_MODEL, 64)), rand(2, (D_MODEL, 64)), rand(3, (64, D_MODEL))
    got = moe_ffn.swiglu_ffn(x, w1, w3, w2)
    np.testing.assert_allclose(got, jnp.zeros((4, D_MODEL)), atol=1e-7)


@pytest.mark.parametrize("h", [32, 64, 128])
def test_probe_matches_ref(h):
    x = rand(7, (32, D_MODEL), 0.5)
    w1, w3 = rand(8, (D_MODEL, h)), rand(9, (D_MODEL, h))
    got = probe.probe(x, w1, w3)
    want = ref.probe_ref(x, w1, w3)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), scale=st.floats(0.05, 1.5))
def test_probe_hypothesis(seed, scale):
    x = rand(seed, (32, D_MODEL), scale)
    w1, w3 = rand(seed + 1, (D_MODEL, 64), scale), rand(seed + 2, (D_MODEL, 64), scale)
    got = probe.probe(x, w1, w3)
    want = ref.probe_ref(x, w1, w3)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_probe_abs_rows_dominate():
    """|accumulated| rows are pointwise >= plain rows in magnitude."""
    x = rand(3, (32, D_MODEL), 0.7)
    w1, w3 = rand(4, (D_MODEL, 64)), rand(5, (D_MODEL, 64))
    p = np.asarray(probe.probe(x, w1, w3))
    assert (p[1] >= np.abs(p[0]) - 1e-4).all()
    assert (p[3] >= np.abs(p[2]) - 1e-4).all()


def test_probe_padding_rows_are_neutral():
    """Zero token rows contribute exactly nothing (calibration pads)."""
    x = rand(11, (16, D_MODEL), 0.5)
    xp = jnp.concatenate([x, jnp.zeros((16, D_MODEL))])
    w1, w3 = rand(12, (D_MODEL, 64)), rand(13, (D_MODEL, 64))
    a = probe.probe(xp, w1, w3)
    b = ref.probe_ref(x, w1, w3)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Analytic cost model invariants (L1 perf deliverable)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c", [4, 8, 16, 32, 64, 128])
@pytest.mark.parametrize("h", [32, 64, 128])
def test_vmem_within_budget(c, h):
    k = ffn_cost(c, D_MODEL, h, token_tile=32 if c >= 64 else None)
    assert k.vmem_bytes < VMEM_BYTES
    assert 0.0 <= k.mxu_utilization <= 1.0


def test_bigger_capacity_increases_intensity():
    a = ffn_cost(4, D_MODEL, 128)
    b = ffn_cost(128, D_MODEL, 128, token_tile=128)
    assert b.arithmetic_intensity > a.arithmetic_intensity


def test_probe_cost_sane():
    k = probe_cost(32, D_MODEL, 128)
    assert k.vmem_bytes < VMEM_BYTES
    assert k.flops > 0
