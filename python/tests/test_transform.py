"""Expert partition + reconstruction properties (paper §3, §4.2).

The central mathematical claims, tested to f.p. tolerance:
  * complete transformation preserves the MoE layer output (Eq. 11);
  * partial transformation preserves it with repeated scores (Eq. 13);
  * reconstruction permutation is output-invariant when both halves run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import configs, model, transform
from compile.kernels import ref

CFG = configs.ModelConfig(name="t", n_experts=4, d_ffn=32, top_k=2)


def make_layer(seed, cfg=CFG):
    k = jax.random.split(jax.random.PRNGKey(seed), 4)
    return {
        "wg": jax.random.normal(k[0], (cfg.d_model, cfg.n_experts)) * 0.3,
        "w1": jax.random.normal(k[1], (cfg.n_experts, cfg.d_model, cfg.d_ffn)) * 0.2,
        "w3": jax.random.normal(k[2], (cfg.n_experts, cfg.d_model, cfg.d_ffn)) * 0.2,
        "w2": jax.random.normal(k[3], (cfg.n_experts, cfg.d_ffn, cfg.d_model)) * 0.2,
    }


def moe_out(layer, x, n_experts, top_k):
    return ref.moe_ref(x, layer["wg"], layer["w1"], layer["w3"], layer["w2"], top_k)


def params_of(layer):
    return {"layers": [layer]}


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), p=st.sampled_from([2, 4]))
def test_complete_transform_preserves_output(seed, p):
    """Eq. 11: the transformed model (E·P experts, top-K·P, W2 scaled)
    produces the same layer output."""
    layer = make_layer(seed)
    x = jax.random.normal(jax.random.PRNGKey(seed + 99), (6, CFG.d_model)) * 0.5
    y0 = moe_out(layer, x, CFG.n_experts, CFG.top_k)
    newp, newc = transform.complete_transform(params_of(layer), CFG, p)
    nl = newp["layers"][0]
    y1 = ref.moe_ref(x, nl["wg"], nl["w1"], nl["w3"], nl["w2"], newc.top_k)
    np.testing.assert_allclose(y0, y1, rtol=2e-4, atol=2e-4)


def test_complete_transform_shapes():
    newp, newc = transform.complete_transform(params_of(make_layer(0)), CFG, 2)
    nl = newp["layers"][0]
    assert nl["wg"].shape == (CFG.d_model, 8)
    assert nl["w1"].shape == (8, CFG.d_model, 16)
    assert nl["w2"].shape == (8, 16, CFG.d_model)
    assert newc.top_k == 4 and newc.n_experts == 8 and newc.d_ffn == 16


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), p=st.sampled_from([2, 4]))
def test_partial_transform_preserves_expert_output(seed, p):
    """Eq. 10/13: sub-expert outputs sum to the original expert output
    (no W2 scaling, repeated original score)."""
    layer = make_layer(seed)
    x = jax.random.normal(jax.random.PRNGKey(seed + 7), (5, CFG.d_model)) * 0.5
    newp = transform.partial_transform_weights(params_of(layer), CFG, p)
    nl = newp["layers"][0]
    for e in range(CFG.n_experts):
        y0 = ref.swiglu_ffn_ref(x, layer["w1"][e], layer["w3"][e], layer["w2"][e])
        parts = [
            ref.swiglu_ffn_ref(x, nl["w1"][e * p + i], nl["w3"][e * p + i],
                               nl["w2"][e * p + i])
            for i in range(p)
        ]
        np.testing.assert_allclose(y0, sum(parts), rtol=2e-4, atol=2e-4)


def test_remap_indices_eq12():
    assert transform.remap_indices([3, 1], 2) == [6, 2, 7, 3]
    assert transform.remap_indices([0], 3) == [0, 1, 2]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_reconstruction_is_output_invariant(seed):
    """§4.2b: permuting FFN neurons never changes the expert output."""
    layer = make_layer(seed)
    x = jax.random.normal(jax.random.PRNGKey(seed + 3), (4, CFG.d_model)) * 0.5
    imp = np.asarray(
        jax.random.normal(jax.random.PRNGKey(seed + 5), (CFG.n_experts, CFG.d_ffn))
    )
    newp, perms = transform.reconstruct(params_of(layer), [imp])
    nl = newp["layers"][0]
    for e in range(CFG.n_experts):
        y0 = ref.swiglu_ffn_ref(x, layer["w1"][e], layer["w3"][e], layer["w2"][e])
        y1 = ref.swiglu_ffn_ref(x, nl["w1"][e], nl["w3"][e], nl["w2"][e])
        np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-4)


def test_reconstruction_puts_important_first():
    imp = np.array([[1.0, 5.0, 3.0, 2.0]])
    order = transform.reconstruct_permutation(imp)
    assert list(order[0]) == [1, 2, 3, 0]


def test_reconstruction_major_half_has_top_importance():
    layer = make_layer(1)
    imp = np.asarray(
        jax.random.normal(jax.random.PRNGKey(11), (CFG.n_experts, CFG.d_ffn))
    )
    _, perms = transform.reconstruct(params_of(layer), [imp])
    order = perms[0]  # layer 0: [E, h]
    h = CFG.d_ffn
    for e in range(CFG.n_experts):
        major = imp[e][order[e][: h // 2]]
        minor = imp[e][order[e][h // 2:]]
        assert major.min() >= minor.max() - 1e-7


@pytest.mark.parametrize("metric", ["gate", "abs_gate", "gate_up", "abs_gate_up"])
def test_profile_importance_shapes(metric):
    cfg = configs.ModelConfig(name="p", n_experts=4, d_ffn=32, top_k=2, n_layers=2)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.zeros((2, 12), jnp.int32)
    tables = transform.profile_importance(params, cfg, toks, metric)
    assert tables.shape == (2, 4, 32)


def test_profile_abs_metrics_nonnegative():
    cfg = configs.ModelConfig(name="p", n_experts=4, d_ffn=32, top_k=2, n_layers=1)
    params = model.init_params(jax.random.PRNGKey(1), cfg)
    toks = jnp.arange(24, dtype=jnp.int32).reshape(2, 12) % 255
    t_abs = transform.profile_importance(params, cfg, toks, "abs_gate")
    assert (t_abs >= 0).all()
