//! Fig. 9 — ETP vs S-ETP communication bandwidth, on the single-node
//! NVLink model ("real-world" stand-in) and the NVL72 / CloudMatrix384
//! fabric models (ASTRA-sim stand-in). See `commsim`.

use std::path::Path;

use anyhow::Result;

use super::save_result;
use crate::commsim::{default_sizes, sweep, Topology};
use crate::util::json::{num, obj, s, Json};

pub fn fig9(artifacts: &Path) -> Result<()> {
    println!("Fig.9 — communication bandwidth: ETP vs S-ETP");
    let configs: [(Topology, usize, usize, &str); 4] = [
        (Topology::h20_node(), 2, 4, "8xH20 E2T4"),
        (Topology::h20_node(), 4, 2, "8xH20 E4T2"),
        (Topology::nvl72(), 9, 8, "NVL72 E9T8"),
        (Topology::cm384(), 48, 8, "CM384 E48T8"),
    ];
    let sizes = default_sizes();
    let mut records = Vec::new();
    for (topo, ep, tp, label) in configs {
        println!("--- {label} ---");
        println!(
            "{:>12} {:>12} {:>12} {:>8}",
            "bytes/dev", "ETP GB/s", "S-ETP GB/s", "gain"
        );
        let pts = sweep(&topo, ep, tp, &sizes);
        let (mut gmin, mut gmax) = (f64::INFINITY, f64::NEG_INFINITY);
        for p in &pts {
            println!(
                "{:>12.0} {:>12.2} {:>12.2} {:>+7.1}%",
                p.input_bytes, p.etp_gbps, p.setp_gbps, p.improvement_pct
            );
            gmin = gmin.min(p.improvement_pct);
            gmax = gmax.max(p.improvement_pct);
            records.push(obj(vec![
                ("config", s(label)),
                ("bytes", num(p.input_bytes)),
                ("etp_gbps", num(p.etp_gbps)),
                ("setp_gbps", num(p.setp_gbps)),
                ("improvement_pct", num(p.improvement_pct)),
            ]));
        }
        println!("improvement range: {gmin:+.1}% … {gmax:+.1}%");
    }
    save_result(artifacts, "fig9", Json::Arr(records))?;
    println!(
        "(paper: +3.0…29.9% E4T2 / +9.2…15.2% E2T4 on the real node,\n\
         +10.2…80.4% on NVL72, +9.9…28.3% on CM384 — gains shrink as\n\
         transfers amortize the per-collective overheads)"
    );
    Ok(())
}
