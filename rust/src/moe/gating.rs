//! Gating: Top-K selection over softmax scores + activated-set
//! normalization (paper §2.1.1 Eqs. 1-3 and §4.1).
//!
//! The gate *scores* come from the AOT `gate_b{B}_e{E}` artifact
//! (softmax over all experts); everything downstream — Top-K, the
//! normalization used by the drop thresholds, the drop decisions — is
//! coordinator logic and lives here in Rust.

/// One token's routing decision before drop policies are applied.
#[derive(Debug, Clone)]
pub struct TokenRouting {
    /// (expert index, original gating score, normalized gating score),
    /// sorted by descending score. The *original* score is the
    /// combination weight (Eq. 3); the *normalized* score feeds the
    /// drop thresholds (§4.1).
    pub experts: Vec<(usize, f32, f32)>,
}

/// Total-order comparator for descending score sorts: higher scores
/// first, NaN strictly last (regardless of its sign bit), ties broken
/// by ascending index. Degenerate calibrated weights can push NaN
/// through the gate; `partial_cmp().unwrap()` panics on it and
/// `unwrap_or(Equal)` builds an *inconsistent* comparator (sort_by may
/// panic or reorder nondeterministically). This one stays total.
pub fn cmp_desc_nan_last(ia: usize, sa: f32, ib: usize, sb: f32) -> std::cmp::Ordering {
    use std::cmp::Ordering;
    match (sa.is_nan(), sb.is_nan()) {
        (true, true) => ia.cmp(&ib),
        (true, false) => Ordering::Greater, // NaN sorts after any real score
        (false, true) => Ordering::Less,
        (false, false) => sb.total_cmp(&sa).then(ia.cmp(&ib)),
    }
}

/// Top-K indices + scores, descending, ties toward the lower index.
/// NaN scores order last, so a poisoned gate row degrades to routing
/// the finite scores first instead of panicking.
pub fn top_k(scores: &[f32], k: usize) -> Vec<(usize, f32)> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| cmp_desc_nan_last(a, scores[a], b, scores[b]));
    idx.truncate(k);
    idx.into_iter().map(|i| (i, scores[i])).collect()
}

/// Route one token: Top-K + normalization over the activated set.
///
/// `already_normalized` models architectures (DeepSeek-V3 / Qwen3-style)
/// whose gate normalizes activated scores itself — then the normalized
/// score *is* the original score (paper §4.1 note).
pub fn route_token(scores: &[f32], k: usize, already_normalized: bool) -> TokenRouting {
    let sel = top_k(scores, k);
    let sum: f32 = sel.iter().map(|(_, s)| *s).sum();
    let experts = sel
        .into_iter()
        .map(|(e, s)| {
            let norm = if already_normalized {
                s
            } else if sum > 0.0 {
                s / sum
            } else {
                1.0 / k as f32
            };
            (e, s, norm)
        })
        .collect();
    TokenRouting { experts }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_orders_descending() {
        let s = [0.1, 0.5, 0.2, 0.2];
        let t = top_k(&s, 3);
        assert_eq!(t[0], (1, 0.5));
        assert_eq!(t[1].0, 2); // tie 0.2/0.2 → lower index first
        assert_eq!(t[2].0, 3);
    }

    #[test]
    fn normalization_sums_to_one() {
        let s = [0.05, 0.6, 0.15, 0.2];
        let r = route_token(&s, 2, false);
        let total: f32 = r.experts.iter().map(|(_, _, n)| n).sum();
        assert!((total - 1.0).abs() < 1e-6);
        // original scores preserved as combination weights
        assert_eq!(r.experts[0].1, 0.6);
    }

    #[test]
    fn already_normalized_passthrough() {
        let s = [0.1, 0.6, 0.3];
        let r = route_token(&s, 2, true);
        assert_eq!(r.experts[0].2, 0.6);
        assert_eq!(r.experts[1].2, 0.3);
    }

    #[test]
    fn top1_is_argmax() {
        let s = [0.2, 0.1, 0.7];
        let r = route_token(&s, 1, false);
        assert_eq!(r.experts.len(), 1);
        assert_eq!(r.experts[0].0, 2);
        assert_eq!(r.experts[0].2, 1.0);
    }

    #[test]
    fn zero_scores_fall_back_uniform() {
        let s = [0.0, 0.0, 0.0, 0.0];
        let r = route_token(&s, 2, false);
        assert!((r.experts[0].2 - 0.5).abs() < 1e-6);
    }

    #[test]
    fn nan_scores_sort_last_deterministically() {
        let s = [0.2, f32::NAN, 0.7, f32::NAN, 0.1];
        let t = top_k(&s, 5);
        let order: Vec<usize> = t.iter().map(|(i, _)| *i).collect();
        // Finite scores descending, then NaN indices ascending.
        assert_eq!(order, vec![2, 0, 4, 1, 3]);
        // Negative-sign-bit NaN orders last too (total_cmp alone would
        // put it *before* every finite score in a descending sort).
        let s2 = [0.3, -f32::NAN, 0.1];
        let order2: Vec<usize> = top_k(&s2, 3).iter().map(|(i, _)| *i).collect();
        assert_eq!(order2, vec![0, 2, 1]);
    }

    #[test]
    fn nan_score_routes_without_panicking() {
        let s = [0.6, f32::NAN, 0.3, 0.1];
        // NaN-last ordering keeps the poisoned expert out of a small
        // activated set entirely…
        let r = route_token(&s, 2, false);
        assert_eq!(r.experts.len(), 2);
        assert_eq!(r.experts[0].0, 0);
        assert!(r.experts.iter().all(|(_, _, n)| n.is_finite()));
        // …and when k is large enough to include it, the NaN poisons
        // the normalization sum and the `sum > 0.0` guard falls back to
        // uniform weights — still finite, never a panic.
        let r4 = route_token(&s, 4, false);
        assert!(r4.experts.iter().all(|(_, _, n)| (n - 0.25).abs() < 1e-6));
    }
}
