//! Quickstart: load a TinyMoE model through the pluggable backend and
//! generate completions with and without DualSparse dropping. Runs
//! hermetically on the pure-Rust `CpuRef` backend (synthetic weights);
//! `make artifacts` upgrades it to trained weights on PJRT.
//!
//!     cargo run --release --example quickstart

#![allow(clippy::needless_range_loop, clippy::manual_memcpy, clippy::type_complexity)]

use anyhow::Result;
use dualsparse::engine::{artifacts_dir, EngineOptions};
use dualsparse::moe::DropPolicy;
use dualsparse::runtime::Backend as _;
use dualsparse::Engine;

fn main() -> Result<()> {
    let artifacts = artifacts_dir();
    let mut engine = Engine::new(
        &artifacts,
        "mixtral_ish",
        DropPolicy::NoDrop,
        EngineOptions::default(),
    )?;
    println!("platform: {}", engine.rt.platform());

    let prompts = [
        "cpy:abcd|",       // copy
        "rev:hgf|",        // reverse
        "add:3+4|",        // arithmetic (GSM8K stand-in)
        "srt:dbca|",       // sort
        "lm:the cat s|",   // language-model continuation
    ];
    println!("\n--- no drop ---");
    for (p, o) in prompts.iter().zip(engine.generate_batch(&prompts, 10)?) {
        println!("{p:<16} -> {o:?}");
    }

    // 1T-Drop: skip token-expert pairs with low normalized gating score.
    engine.policy = DropPolicy::OneT(0.15);
    engine.reset_metrics();
    println!("\n--- 1T-Drop (T=0.15) ---");
    for (p, o) in prompts.iter().zip(engine.generate_batch(&prompts, 10)?) {
        println!("{p:<16} -> {o:?}");
    }
    println!(
        "dropped {:.1}% of token-expert compute",
        100.0 * engine.metrics.drop_rate()
    );

    // 2T-Drop: dual thresholds over major/minor sub-experts.
    engine.policy = DropPolicy::two_t(0.15);
    engine.reset_metrics();
    println!("\n--- 2T-Drop (T²=(0.14, 0.16)) ---");
    for (p, o) in prompts.iter().zip(engine.generate_batch(&prompts, 10)?) {
        println!("{p:<16} -> {o:?}");
    }
    let d = engine.metrics.total_drop();
    println!(
        "full={} major-only={} dropped={} (drop rate {:.1}%)",
        d.full,
        d.major_only,
        d.dropped,
        100.0 * engine.metrics.drop_rate()
    );
    Ok(())
}
