"""Build-time trainer: pre-train the TinyMoE family, run the Figure-4
fine-tuning comparison (original vs complete-transformed P=2 / P=4).

Hand-rolled Adam (optax is not available offline). Everything is
deterministic given the seeds in data.py. Loss logs land in
artifacts/results/ so `dualsparse exp fig4` and EXPERIMENTS.md can
consume them without re-training.
"""

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import configs, data
from .configs import ModelConfig
from .model import init_params, loss_fn
from .transform import complete_transform

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.95, 1e-8


def _adam_init(params):
    zeros = lambda p: jax.tree_util.tree_map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params), "t": jnp.zeros((), jnp.int32)}


def _adam_update(params, grads, state, lr):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(
        lambda m, g: ADAM_B1 * m + (1 - ADAM_B1) * g, state["m"], grads
    )
    v = jax.tree_util.tree_map(
        lambda v, g: ADAM_B2 * v + (1 - ADAM_B2) * g * g, state["v"], grads
    )
    mhat_scale = 1.0 / (1 - ADAM_B1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - ADAM_B2 ** t.astype(jnp.float32))
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p
        - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + ADAM_EPS),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def _batches(token_bytes, batch, seq, n_steps, seed):
    """Deterministic [batch, seq] windows over the corpus byte stream."""
    arr = np.frombuffer(token_bytes, dtype=np.uint8).astype(np.int32)
    rng = np.random.default_rng(seed)
    n_win = len(arr) - seq - 1
    for _ in range(n_steps):
        starts = rng.integers(0, n_win, size=batch)
        yield np.stack([arr[s : s + seq] for s in starts])


def lr_schedule(base_lr, step, total_steps, warmup=50):
    """Linear warmup then cosine decay to 10% of base."""
    import math

    if step < warmup:
        return base_lr * (step + 1) / warmup
    frac = (step - warmup) / max(1, total_steps - warmup)
    return base_lr * (0.1 + 0.9 * 0.5 * (1 + math.cos(math.pi * frac)))


def train(cfg: ModelConfig, params, steps, corpus, lr=configs.LR, seed=7,
          log_every=10, tag=""):
    """Run `steps` Adam steps; returns (params, loss_log)."""

    @jax.jit
    def step(params, opt, batch, lr_now):
        (loss, (nll, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, cfg, configs.AUX_LOSS_COEF
        )
        params, opt = _adam_update(params, grads, opt, lr_now)
        return params, opt, loss, nll, aux

    opt = _adam_init(params)
    log = []
    t0 = time.time()
    for i, batch in enumerate(
        _batches(corpus, configs.BATCH, configs.SEQ, steps, seed)
    ):
        lr_now = lr_schedule(lr, i, steps)
        params, opt, loss, nll, aux = step(
            params, opt, jnp.asarray(batch), jnp.float32(lr_now)
        )
        if i % log_every == 0 or i == steps - 1:
            log.append({"step": i, "loss": float(nll), "aux": float(aux)})
            print(
                f"[train{tag}] step {i:4d} nll={float(nll):.4f} "
                f"aux={float(aux):.3f} ({time.time() - t0:.0f}s)",
                flush=True,
            )
    return params, log


def pretrain(cfg: ModelConfig, steps=None):
    """Pre-train one variant on the base task mixture."""
    steps = steps or configs.PRETRAIN_STEPS
    corpus = data.corpus_tokens(2_000_000, data.TRAIN_SEED)
    params = init_params(jax.random.PRNGKey(hash(cfg.name) & 0xFFFF), cfg)
    return train(cfg, params, steps, corpus, tag=f":{cfg.name}")


def finetune(cfg: ModelConfig, params, steps=None, lr=None):
    """Fine-tune on the shifted mixture (Fig. 4 / Table 1)."""
    steps = steps or configs.FINETUNE_STEPS
    corpus = data.corpus_tokens(
        800_000, data.FINETUNE_SEED, shift=True,
        task_weights=data.FINETUNE_WEIGHTS,
    )
    # Full LR: the gate columns of a partitioned model start identical
    # and only diverge through the (small) per-sub-expert output
    # differences — too low an LR freezes that symmetry breaking and
    # hides the Fig. 4 effect.
    return train(cfg, params, steps, corpus, lr=lr or configs.LR,
                 seed=13, tag=f":ft:{cfg.name}")


def fig4_experiment(base_cfg: ModelConfig, base_params, out_path):
    """Fine-tune original vs P=2 vs P=4 complete transformations; write
    the three loss curves (the paper's Figure 4)."""
    curves = {}
    for P in (1, 2, 4):
        if P == 1:
            cfg, params = base_cfg, base_params
        else:
            params, cfg = complete_transform(base_params, base_cfg, P)
        tuned, log = finetune(cfg, params, steps=configs.FINETUNE_STEPS)
        curves[f"P={P}"] = log
        yield P, cfg, tuned
    with open(out_path, "w") as f:
        json.dump(curves, f, indent=1)
