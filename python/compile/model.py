"""Layer-2 JAX model: the TinyMoE transformer family.

Two faces of the same parameters:

* `forward_train` — a dense (all-experts, top-k-masked) differentiable
  forward used only at build time by the trainer (`train.py`).
* The `serve_*` functions — the per-artifact decomposition that
  `aot.py` lowers to HLO text for the Rust engine. Weights are runtime
  *inputs* to every artifact, so one artifact per shape bucket serves
  every layer and every model variant of the family.

The two paths share layer math exactly (RMSNorm placement, softmax-then-
TopK gating with *original* scores as combination weights, shared-expert
addition), which is property-tested in python/tests/test_model.py:
decomposed serving == dense forward, token for token.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.moe_ffn import swiglu_ffn, swiglu_ffn_tiled
from .kernels.ref import swiglu_ffn_ref, gate_ref, topk_mask_ref

EPS = 1e-6


def rmsnorm(x, g):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS) * g


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def init_params(rng, cfg: ModelConfig):
    """Initialize a parameter pytree (dict of arrays)."""
    keys = jax.random.split(rng, 8 + cfg.n_layers)
    s = 0.02
    p = {
        "emb": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * s,
        "pos": jax.random.normal(keys[1], (cfg.max_seq, cfg.d_model)) * s,
        "lnf": jnp.ones((cfg.d_model,)),
        "layers": [],
    }
    for li in range(cfg.n_layers):
        k = jax.random.split(keys[8 + li], 12)
        layer = {
            "ln1": jnp.ones((cfg.d_model,)),
            "wq": jax.random.normal(k[0], (cfg.d_model, cfg.d_attn)) * s,
            "wk": jax.random.normal(k[1], (cfg.d_model, cfg.d_attn)) * s,
            "wv": jax.random.normal(k[2], (cfg.d_model, cfg.d_attn)) * s,
            "wo": jax.random.normal(k[3], (cfg.d_attn, cfg.d_model)) * s,
            "ln2": jnp.ones((cfg.d_model,)),
            "wg": jax.random.normal(k[4], (cfg.d_model, cfg.n_experts)) * s,
            "w1": jax.random.normal(k[5], (cfg.n_experts, cfg.d_model, cfg.d_ffn)) * s,
            "w3": jax.random.normal(k[6], (cfg.n_experts, cfg.d_model, cfg.d_ffn)) * s,
            "w2": jax.random.normal(k[7], (cfg.n_experts, cfg.d_ffn, cfg.d_model)) * s,
        }
        if cfg.n_shared:
            layer["sw1"] = jax.random.normal(k[8], (cfg.d_model, cfg.d_ffn_shared)) * s
            layer["sw3"] = jax.random.normal(k[9], (cfg.d_model, cfg.d_ffn_shared)) * s
            layer["sw2"] = jax.random.normal(k[10], (cfg.d_ffn_shared, cfg.d_model)) * s
        p["layers"].append(layer)
    return p


# --------------------------------------------------------------------------
# Dense training forward (build-time only)
# --------------------------------------------------------------------------

def _attn_dense(x, layer, cfg: ModelConfig):
    """Causal self-attention over a full sequence. x: [B, S, d]."""
    b, s, _ = x.shape
    xn = rmsnorm(x, layer["ln1"])
    def heads(w):
        return (xn @ w).reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    q, k, v = heads(layer["wq"]), heads(layer["wk"]), heads(layer["wv"])
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(cfg.d_head))
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask[None, None], scores, -1e9)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_attn)
    return x + out @ layer["wo"]


def _moe_dense(ln2x, layer, cfg: ModelConfig):
    """All-experts masked MoE (training path). ln2x: [T, d].

    Returns (moe_out [T, d], aux_loss scalar).
    """
    scores = jax.nn.softmax(ln2x @ layer["wg"], axis=-1)  # [T, E]
    # Discrete selection: no gradient flows through the mask itself (and
    # sort's VJP lowers to a batched gather this xla_client cannot build).
    mask = jax.lax.stop_gradient(topk_mask_ref(scores, cfg.top_k))
    g = scores * mask  # original scores as combination weights (Eq. 3)
    # Dense compute of every expert (cheap at TinyMoE scale, jit-friendly).
    h = jnp.einsum("td,edf->tef", ln2x, layer["w1"])
    gate = h * jax.nn.sigmoid(h)
    up = jnp.einsum("td,edf->tef", ln2x, layer["w3"])
    outs = jnp.einsum("tef,efd->ted", gate * up, layer["w2"])
    y = jnp.einsum("te,ted->td", g, outs)
    if cfg.n_shared:
        y = y + swiglu_ffn_ref(ln2x, layer["sw1"], layer["sw3"], layer["sw2"])
    # Switch-style load-balancing aux loss.
    frac = jnp.mean(mask, axis=0)
    prob = jnp.mean(scores, axis=0)
    aux = cfg.n_experts * jnp.sum(frac * prob)
    return y, aux


def forward_train(params, tokens, cfg: ModelConfig):
    """tokens: [B, S] int32 → (logits [B, S, V], aux_loss)."""
    b, s = tokens.shape
    x = params["emb"][tokens] + params["pos"][:s][None]
    aux_total = 0.0
    for layer in params["layers"]:
        x = _attn_dense(x, layer, cfg)
        ln2x = rmsnorm(x, layer["ln2"])
        flat = ln2x.reshape(b * s, cfg.d_model)
        moe_out, aux = _moe_dense(flat, layer, cfg)
        x = x + moe_out.reshape(b, s, cfg.d_model)
        aux_total = aux_total + aux
    xn = rmsnorm(x, params["lnf"])
    logits = xn @ params["emb"].T
    return logits, aux_total / cfg.n_layers


def loss_fn(params, tokens, cfg: ModelConfig, aux_coef):
    """Next-token cross-entropy + load-balance aux."""
    logits, aux = forward_train(params, tokens, cfg)
    tgt = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    # one-hot selection instead of take_along_axis: its VJP lowers to a
    # batched gather this image's xla_client cannot build.
    hot = jax.nn.one_hot(tgt, cfg.vocab, dtype=lp.dtype)
    nll = -jnp.sum(lp * hot) / (tgt.shape[0] * tgt.shape[1])
    return nll + aux_coef * aux, (nll, aux)


# --------------------------------------------------------------------------
# Serving decomposition (AOT artifacts)
# --------------------------------------------------------------------------

def serve_attn_step(x, ln1, wq, wk, wv, wo, ln2, kcache, vcache, pos,
                    n_heads, d_head):
    """Single-token decode step with KV cache.

    x:       [B, d]  residual stream at this layer's input
    kcache:  [B, H, T, dh], vcache likewise (positions < pos are valid)
    pos:     [B] int32 — current position of each row (cache fill level)

    Returns (y [B, d], ln2x [B, d], new_k [B, H, dh], new_v [B, H, dh]).
    The engine (Rust) writes new_k/new_v into the host cache at `pos`.
    """
    b, d = x.shape
    t = kcache.shape[2]
    xn = rmsnorm(x, ln1)
    q = (xn @ wq).reshape(b, n_heads, d_head)
    new_k = (xn @ wk).reshape(b, n_heads, d_head)
    new_v = (xn @ wv).reshape(b, n_heads, d_head)
    scale = 1.0 / jnp.sqrt(float(d_head))
    cache_scores = jnp.einsum("bhd,bhtd->bht", q, kcache) * scale
    valid = jnp.arange(t)[None, :] < pos[:, None]  # [B, T]
    cache_scores = jnp.where(valid[:, None, :], cache_scores, -1e9)
    self_score = jnp.einsum("bhd,bhd->bh", q, new_k)[..., None] * scale  # [B,H,1]
    scores = jnp.concatenate([cache_scores, self_score], axis=-1)
    attn = jax.nn.softmax(scores, axis=-1)
    out = (
        jnp.einsum("bht,bhtd->bhd", attn[..., :t], vcache)
        + attn[..., t:] * new_v
    )
    y = x + out.reshape(b, n_heads * d_head) @ wo
    return y, rmsnorm(y, ln2), new_k, new_v


def serve_attn_prefill(x, ln1, wq, wk, wv, wo, ln2, n_heads, d_head):
    """Full-sequence causal prefill for one request. x: [S, d].

    Returns (y [S, d], ln2x [S, d], K [S, H, dh], V [S, H, dh]).
    """
    s, d = x.shape
    xn = rmsnorm(x, ln1)
    q = (xn @ wq).reshape(s, n_heads, d_head)
    k = (xn @ wk).reshape(s, n_heads, d_head)
    v = (xn @ wv).reshape(s, n_heads, d_head)
    scores = jnp.einsum("qhd,khd->hqk", q, k) / jnp.sqrt(float(d_head))
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask[None], scores, -1e9)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hqk,khd->qhd", attn, v).reshape(s, n_heads * d_head)
    y = x + out @ wo
    return y, rmsnorm(y, ln2), k, v


def serve_gate(ln2x, wg):
    """Gating scores (Eq. 1). Top-K / normalization / drop live in Rust."""
    return gate_ref(ln2x, wg)


def serve_ffn(x, w1, w3, w2):
    """Expert FFN — routes through the L1 Pallas kernel."""
    c = x.shape[0]
    if c >= 64:
        return swiglu_ffn_tiled(x, w1, w3, w2)
    return swiglu_ffn(x, w1, w3, w2)


def serve_lm_head(x, lnf, emb):
    """Final norm + tied-embedding projection. x: [B, d] → [B, V]."""
    return rmsnorm(x, lnf) @ emb.T
