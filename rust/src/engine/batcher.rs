//! Continuous batcher — the closed-loop compatibility surface over the
//! arrival-driven scheduler ([`super::scheduler`]).
//!
//! The admit-all batch loop that used to live here is now one mode of
//! the scheduler's request lifecycle (Queued → Prefill → Decode →
//! Done | Rejected). `serve()` runs it in [`ArrivalMode::Closed`] and
//! keeps the historical `(completions, stats)` shape; new code that
//! needs open-loop arrivals or the rejection list should call
//! [`serve_with`] directly, and code that wants a non-FCFS admission
//! order or a bounded queue should call [`serve_policy`] with a
//! [`SchedulingPolicy`] + [`AdmissionControl`] (both re-exported here).

use anyhow::Result;

pub use super::faults::{CancelSet, DegradeController, FaultPlan, FaultSpec};
pub use super::policy::{
    AdmissionControl, AgingConfig, Fcfs, PolicyKind, PriorityLanes, SchedConfig,
    SchedulingPolicy, ShortestPromptFirst,
};
pub use super::scheduler::{
    poisson_arrivals, serve_opts, serve_policy, serve_with, ArrivalMode, Casualty, Completion,
    Phase, Rejection, Request, SchedOptions, ServeOutcome, ServeStats,
};
use super::Engine;

/// Run all `requests` to completion with continuous batching in
/// closed-loop mode (every request available at t = 0).
///
/// An oversized prompt no longer aborts the run: the offending request
/// is rejected at admission validation (no KV slot consumed) and the
/// count shows up in [`ServeStats::rejected`].
pub fn serve(engine: &mut Engine, requests: &[Request]) -> Result<(Vec<Completion>, ServeStats)> {
    let out = serve_with(engine, requests, ArrivalMode::Closed)?;
    Ok((out.completions, out.stats))
}

/// Build a serving workload from the benchmark tasks (round-robin over
/// tasks), standing in for the paper's "2000 random prompts".
pub fn task_workload(n: usize, max_new: usize) -> Vec<Request> {
    let tasks = crate::tasks::TASKS;
    let mut out = Vec::with_capacity(n);
    let mut per_task: Vec<Vec<(String, String)>> = tasks
        .iter()
        .map(|t| crate::tasks::eval_set(t, n / tasks.len() + 1, false))
        .collect();
    for i in 0..n {
        let t = i % tasks.len();
        let (prompt, _) = per_task[t].pop().expect("enough prompts");
        out.push(Request { id: i, prompt, max_new, priority: 0, deadline_secs: None });
    }
    out
}
