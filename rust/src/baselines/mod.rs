//! Prior-work baselines for Table 3: EES, EEP (Lu et al. 2024) and a
//! Wanda-style 2:4 static weight pruning row.
//!
//! * **EES** (Efficient Expert Skipping): skip the non-top expert when
//!   its score < β × top-1 score, with β = the median score ratio over
//!   calibration samples.
//! * **EEP** (Efficient Expert Pruning): permanently remove the least-
//!   activated experts (per layer) and renormalize routing over the
//!   kept set. r = experts kept.
//! * **Wanda 2:4**: magnitude-based 2-of-4 structured weight sparsity on
//!   the expert FFN matrices (accuracy-impact row only — dense kernels
//!   gain nothing from it, which is exactly the paper's point about
//!   fine-grained sparsity needing special hardware).

use anyhow::Result;

use crate::engine::Engine;
use crate::model::{Tensor, Weights};
use crate::tasks::calibration_tokens;
use crate::util::stats::percentile;

/// Calibrate EES's β: median over calibration tokens of (2nd score /
/// top score) at every MoE layer (paper §5.4).
pub fn calibrate_ees_beta(engine: &mut Engine, n_tokens: usize) -> Result<f32> {
    let k = engine.cfg.top_k;
    assert!(k >= 2, "EES needs top-k >= 2");
    engine.opts.collect_stats = true;
    engine.reset_metrics();
    let stream = calibration_tokens(n_tokens);
    for chunk in stream.chunks(32) {
        if chunk.len() < 2 {
            break;
        }
        engine.kv.reset();
        let slot = engine.kv.alloc();
        engine.prefill(slot, chunk)?;
    }
    // raw_scores is laid out per token: k descending entries.
    let raw = &engine.metrics.raw_scores;
    let ratios: Vec<f64> = raw
        .chunks_exact(k)
        .map(|c| (c[1] / c[0].max(1e-9)) as f64)
        .collect();
    engine.opts.collect_stats = false;
    Ok(percentile(&ratios, 50.0) as f32)
}

/// Calibrate EEP's kept set: per layer, keep the `r` most-selected
/// experts on calibration traffic.
pub fn calibrate_eep_kept(engine: &mut Engine, n_tokens: usize, r: usize) -> Result<Vec<Vec<usize>>> {
    engine.opts.collect_stats = true;
    engine.reset_metrics();
    let stream = calibration_tokens(n_tokens);
    for chunk in stream.chunks(32) {
        if chunk.len() < 2 {
            break;
        }
        engine.kv.reset();
        let slot = engine.kv.alloc();
        engine.prefill(slot, chunk)?;
    }
    let kept = engine
        .metrics
        .expert_counts
        .iter()
        .map(|counts| {
            let mut idx: Vec<usize> = (0..counts.len()).collect();
            idx.sort_by(|&a, &b| counts[b].cmp(&counts[a]).then(a.cmp(&b)));
            let mut k: Vec<usize> = idx.into_iter().take(r).collect();
            k.sort();
            k
        })
        .collect();
    engine.opts.collect_stats = false;
    Ok(kept)
}

/// Fraction of expert-weight memory EEP saves (Table 3 "Memory").
pub fn eep_memory_saving(n_experts: usize, r: usize) -> f64 {
    1.0 - r as f64 / n_experts as f64
}

/// Apply Wanda-style 2:4 structured pruning in place: in every group of
/// 4 consecutive weights along the input dimension, zero the 2 smallest
/// by |magnitude|.
pub fn apply_wanda_2_4(w: &mut Weights) -> Result<()> {
    let n_layers = w.config.n_layers;
    for li in 0..n_layers {
        for key in ["w1", "w3", "w2"] {
            let name = format!("layers.{li}.{key}");
            let t = w.tensors.get_mut(&name).expect("expert tensor");
            prune_2_4_rows(t);
        }
    }
    Ok(())
}

/// 2:4 pruning along the innermost dimension of an arbitrary-rank tensor.
fn prune_2_4_rows(t: &mut Tensor) {
    let cols = *t.shape.last().unwrap();
    for row in t.data.chunks_mut(cols) {
        for g in row.chunks_mut(4) {
            if g.len() < 4 {
                continue;
            }
            let mut idx = [0usize, 1, 2, 3];
            // total_cmp keeps this total under NaN weights (NaN sorts
            // largest, i.e. survives the prune — deterministic either way).
            idx.sort_by(|&a, &b| g[a].abs().total_cmp(&g[b].abs()));
            g[idx[0]] = 0.0;
            g[idx[1]] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prune_2_4_keeps_two_largest() {
        let mut t = Tensor::new(vec![1, 4], vec![0.1, -5.0, 3.0, 0.2]);
        prune_2_4_rows(&mut t);
        assert_eq!(t.data, vec![0.0, -5.0, 3.0, 0.0]);
    }

    #[test]
    fn prune_2_4_zero_fraction_is_half() {
        let mut t = Tensor::new(
            vec![2, 8],
            (1..=16).map(|x| x as f32).collect(),
        );
        prune_2_4_rows(&mut t);
        let zeros = t.data.iter().filter(|&&x| x == 0.0).count();
        assert_eq!(zeros, 8);
    }

    #[test]
    fn eep_memory() {
        assert!((eep_memory_saving(8, 6) - 0.25).abs() < 1e-12);
        assert!((eep_memory_saving(8, 4) - 0.5).abs() < 1e-12);
    }
}
