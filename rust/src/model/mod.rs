//! Model host: configuration, serialized-weight loading, tokenizer.
//!
//! Weights are produced once at build time by `python -m compile.aot`
//! (flat little-endian f32 `.bin` + JSON manifest); this module loads
//! them into host memory for the Rust engine. The tokenizer is
//! byte-level (vocab 256) so it needs no vocabulary file.

pub mod weights;

pub use weights::{Tensor, Weights};

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Mirror of `python/compile/configs.py::ModelConfig`.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub n_experts: usize,
    pub d_ffn: usize,
    pub top_k: usize,
    pub n_shared: usize,
    pub d_ffn_shared: usize,
    pub normalized_gating: bool,
}

impl ModelConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            name: j.get("name")?.as_str()?.to_string(),
            d_model: j.get("d_model")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            d_head: j.get("d_head")?.as_usize()?,
            vocab: j.get("vocab")?.as_usize()?,
            max_seq: j.get("max_seq")?.as_usize()?,
            n_experts: j.get("n_experts")?.as_usize()?,
            d_ffn: j.get("d_ffn")?.as_usize()?,
            top_k: j.get("top_k")?.as_usize()?,
            n_shared: j.get("n_shared")?.as_usize()?,
            d_ffn_shared: j.get("d_ffn_shared")?.as_usize()?,
            normalized_gating: j.get("normalized_gating")?.as_bool()?,
        })
    }

    /// Names accepted by [`ModelConfig::preset`] (single source of
    /// truth for error messages and `dualsparse info`).
    pub const PRESET_NAMES: [&'static str; 3] =
        ["mixtral_ish", "olmoe_ish", "deepseek_ish"];

    /// Built-in mirror of `python/compile/configs.py::MODELS` — the
    /// three TinyMoE variants the paper experiments stand on. Used to
    /// materialize synthetic test weights when no serialized model
    /// exists (the hermetic `CpuRef` path).
    pub fn preset(name: &str) -> Option<ModelConfig> {
        let base = ModelConfig {
            name: name.to_string(),
            d_model: 64,
            n_layers: 4,
            n_heads: 4,
            d_head: 16,
            vocab: 256,
            max_seq: 160,
            n_experts: 8,
            d_ffn: 128,
            top_k: 2,
            n_shared: 0,
            d_ffn_shared: 0,
            normalized_gating: false,
        };
        match name {
            "mixtral_ish" => Some(base),
            "olmoe_ish" => Some(ModelConfig { n_experts: 16, d_ffn: 64, top_k: 4, ..base }),
            "deepseek_ish" => Some(ModelConfig {
                n_experts: 14,
                d_ffn: 64,
                top_k: 2,
                n_shared: 1,
                d_ffn_shared: 128,
                ..base
            }),
            _ => None,
        }
    }

    pub fn d_attn(&self) -> usize {
        self.n_heads * self.d_head
    }

    /// FLOPs of one expert FFN application per token (madd = 2 FLOPs).
    pub fn ffn_flops_per_token(&self, width: usize) -> u64 {
        (2 * 3 * self.d_model * width) as u64
    }

    pub fn validate(&self) -> Result<()> {
        if self.d_attn() != self.d_model {
            bail!("d_attn {} != d_model {}", self.d_attn(), self.d_model);
        }
        if self.top_k > self.n_experts {
            bail!("top_k {} > n_experts {}", self.top_k, self.n_experts);
        }
        if self.d_ffn % 2 != 0 {
            bail!("d_ffn must be even for major/minor reconstruction");
        }
        Ok(())
    }
}

/// Byte-level tokenizer (identity mapping, vocab = 256).
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn encode(text: &str) -> Vec<u8> {
        text.as_bytes().to_vec()
    }

    pub fn decode(tokens: &[u8]) -> String {
        tokens.iter().map(|&b| b as char).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 4,
            n_heads: 4,
            d_head: 16,
            vocab: 256,
            max_seq: 160,
            n_experts: 8,
            d_ffn: 128,
            top_k: 2,
            n_shared: 0,
            d_ffn_shared: 0,
            normalized_gating: false,
        }
    }

    #[test]
    fn config_validates() {
        cfg().validate().unwrap();
        let mut bad = cfg();
        bad.top_k = 99;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn config_parses_manifest_json() {
        let text = r#"{"name":"m","d_model":64,"n_layers":4,"n_heads":4,
            "d_head":16,"vocab":256,"max_seq":160,"n_experts":8,"d_ffn":128,
            "top_k":2,"n_shared":0,"d_ffn_shared":0,"normalized_gating":false}"#;
        let j = Json::parse(text).unwrap();
        let c = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c, cfg().clone_with_name("m"));
    }

    impl ModelConfig {
        fn clone_with_name(&self, n: &str) -> Self {
            let mut c = self.clone();
            c.name = n.into();
            c
        }
    }

    #[test]
    fn presets_mirror_python_configs() {
        let m = ModelConfig::preset("mixtral_ish").unwrap();
        assert_eq!((m.n_experts, m.d_ffn, m.top_k, m.n_shared), (8, 128, 2, 0));
        let o = ModelConfig::preset("olmoe_ish").unwrap();
        assert_eq!((o.n_experts, o.d_ffn, o.top_k), (16, 64, 4));
        let d = ModelConfig::preset("deepseek_ish").unwrap();
        assert_eq!((d.n_experts, d.d_ffn, d.n_shared, d.d_ffn_shared), (14, 64, 1, 128));
        for name in ModelConfig::PRESET_NAMES {
            ModelConfig::preset(name).unwrap().validate().unwrap();
        }
        assert!(ModelConfig::preset("gpt5_ish").is_none());
    }

    #[test]
    fn tokenizer_roundtrip() {
        let s = "add:3+4|7\n";
        assert_eq!(ByteTokenizer::decode(&ByteTokenizer::encode(s)), s);
    }

    #[test]
    fn ffn_flops() {
        let c = cfg();
        assert_eq!(c.ffn_flops_per_token(128), 2 * 3 * 64 * 128);
    }
}
