"""Synthetic corpus + benchmark task generators.

Stand-in for the paper's LM-Eval-Harness benchmarks and the Tulu-3
fine-tuning mixture (DESIGN.md §2). Nine byte-level tasks with a
difficulty spread; `add`/`srt`/`ind` play GSM8K's "most drop-sensitive"
role. Every sample is one ASCII line:

    {tag}:{input}|{answer}\n

The *evaluation* prompts are regenerated at run time by the Rust harness
(`rust/src/tasks/`), which mirrors these generators bit-for-bit on top of
the shared SplitMix64 stream — golden-stream tests on both sides keep
the two implementations locked together. Do not change a format here
without updating rust/src/tasks/mod.rs and the golden files.
"""

from .rng import SplitMix64

TASKS = ("cpy", "rev", "pat", "add", "bal", "ind", "srt", "maj", "lm")

LETTERS = "abcdefgh"
SHIFT_LETTERS = "ijklmnop"  # fine-tune distribution shift
SORT_POOL = "abcdef"
SHIFT_SORT_POOL = "cdefgh"
IND_KEYS = "abcd"

PHRASES = (
    "the cat sat on the mat",
    "a dog ran to the park",
    "we like to read books",
    "the sun is very warm",
    "birds fly over the sea",
    "she has a red ball",
    "rain falls on the roof",
    "the moon is out now",
)
SHIFT_PHRASES = (
    "the fox hid in the log",
    "he rows a boat at dawn",
    "cold wind blows all day",
    "a bee lands on the rose",
)


def _sample_cpy(rng, shift=False):
    pool = SHIFT_LETTERS if shift else LETTERS
    n = 3 + rng.below(4 if shift else 3)  # shift: longer strings
    s = "".join(rng.choice(pool) for _ in range(n))
    return s, s


def _sample_rev(rng, shift=False):
    pool = SHIFT_LETTERS if shift else LETTERS
    n = 3 + rng.below(4 if shift else 3)
    s = "".join(rng.choice(pool) for _ in range(n))
    return s, s[::-1]


def _sample_pat(rng, shift=False):
    period = 2 + rng.below(2)  # 2 or 3
    pool = SHIFT_LETTERS if shift else LETTERS
    unit = "".join(rng.choice(pool) for _ in range(period))
    reps = 6 // period + 1
    full = (unit * (reps + 2))
    return full[:6], full[6:9]


def _sample_add(rng, shift=False):
    if shift:
        a, b = rng.below(100), rng.below(100)
        return f"{a:02d}+{b:02d}", f"{(a + b) % 100:02d}"
    a, b = rng.below(10), rng.below(10)
    return f"{a}+{b}", f"{(a + b) % 10}"


def _gen_balanced(rng, pairs):
    """Random balanced bracket string with `pairs` pairs."""
    s, open_ = [], 0
    remaining_open = pairs
    remaining_close = pairs
    while remaining_open or remaining_close:
        if remaining_open and (open_ == 0 or rng.below(2) == 0):
            s.append("(")
            open_ += 1
            remaining_open -= 1
        else:
            s.append(")")
            open_ -= 1
            remaining_close -= 1
    return "".join(s)


def _sample_bal(rng, shift=False):
    pairs = 3 if shift else 2
    if rng.below(2) == 0:
        return _gen_balanced(rng, pairs), "Y"
    n = 2 * pairs
    s = "".join("(" if rng.below(2) == 0 else ")" for _ in range(n))
    bal, depth = True, 0
    for ch in s:
        depth += 1 if ch == "(" else -1
        if depth < 0:
            bal = False
    bal = bal and depth == 0
    return s, "Y" if bal else "N"


def _sample_ind(rng, shift=False):
    nkeys = 3
    keys = list(IND_KEYS)
    # Fisher-Yates with the shared stream.
    for i in range(len(keys) - 1, 0, -1):
        j = rng.below(i + 1)
        keys[i], keys[j] = keys[j], keys[i]
    keys = keys[:nkeys]
    vals = [str(rng.below(10)) for _ in range(nkeys)]
    q = rng.below(nkeys)
    inp = " ".join(k + v for k, v in zip(keys, vals)) + " " + keys[q]
    return inp, vals[q]


def _sample_srt(rng, shift=False):
    pool = list(SHIFT_SORT_POOL if shift else SORT_POOL)
    for i in range(len(pool) - 1, 0, -1):
        j = rng.below(i + 1)
        pool[i], pool[j] = pool[j], pool[i]
    s = "".join(pool[:4])
    return s, "".join(sorted(s))


def _sample_maj(rng, shift=False):
    s = "".join(rng.choice("ab") for _ in range(5))
    return s, "a" if s.count("a") >= 3 else "b"


def _sample_lm(rng, shift=False):
    phrase = rng.choice(SHIFT_PHRASES if shift else PHRASES)
    cut = 6 + rng.below(max(1, len(phrase) - 10))
    return phrase[:cut], phrase[cut : cut + 5]


_SAMPLERS = {
    "cpy": _sample_cpy,
    "rev": _sample_rev,
    "pat": _sample_pat,
    "add": _sample_add,
    "bal": _sample_bal,
    "ind": _sample_ind,
    "srt": _sample_srt,
    "maj": _sample_maj,
    "lm": _sample_lm,
}


def sample_line(task, rng, shift=False):
    """One full training/eval line for `task`: 'tag:input|answer\\n'."""
    inp, ans = _SAMPLERS[task](rng, shift)
    return f"{task}:{inp}|{ans}\n"


def eval_prompt(task, rng, shift=False):
    """(prompt_bytes, answer_str): prompt includes the trailing '|'."""
    inp, ans = _SAMPLERS[task](rng, shift)
    return f"{task}:{inp}|", ans


# Seed bases — shared with rust/src/tasks/mod.rs. Training, calibration
# and evaluation use disjoint streams.
TRAIN_SEED = 0x5EED_0001
FINETUNE_SEED = 0x5EED_0002
CALIB_SEED = 0x5EED_0003
EVAL_SEED_BASE = 0x5EED_1000  # + task index


def corpus_tokens(n_tokens, seed, shift=False, task_weights=None):
    """Byte token stream: a mixture of task lines (used for training).

    task_weights: optional list of per-task integer weights (default
    uniform). The fine-tune mixture upweights the hard tasks.
    """
    rng = SplitMix64(seed)
    weights = task_weights or [1] * len(TASKS)
    bag = [t for t, w in zip(TASKS, weights) for _ in range(w)]
    out = bytearray()
    while len(out) < n_tokens:
        out.extend(sample_line(rng.choice(bag), rng, shift).encode())
    return bytes(out[:n_tokens])


FINETUNE_WEIGHTS = [1, 1, 1, 3, 2, 3, 3, 1, 2]  # upweight add/ind/srt/bal/lm


def eval_set(task, n, shift=False):
    """Deterministic eval prompts for `task` (mirrored in Rust)."""
    rng = SplitMix64(EVAL_SEED_BASE + TASKS.index(task))
    return [eval_prompt(task, rng, shift) for _ in range(n)]


def calibration_tokens(n_tokens):
    """Calibration stream (paper uses MMLU; we use the mixed corpus)."""
    return corpus_tokens(n_tokens, CALIB_SEED)
