//! Arrival-driven scheduler tests: lifecycle conservation, fault
//! isolation, honest (arrival-anchored) latency accounting, and the
//! byte-for-byte pin of closed-loop mode against the legacy batch loop.
//!
//! Hermetic: CpuRef backend + synthetic SplitMix64 weights.

#![allow(clippy::needless_range_loop, clippy::manual_memcpy, clippy::type_complexity)]

use std::collections::VecDeque;
use std::path::PathBuf;

use dualsparse::engine::faults::{CancelSet, FaultPlan};
use dualsparse::engine::policy::Fcfs;
use dualsparse::engine::scheduler::{
    serve, serve_opts, serve_with, ArrivalMode, Phase, Request, SchedOptions,
};
use dualsparse::engine::{Engine, EngineOptions, EOS, MAX_SLOTS};
use dualsparse::moe::DropPolicy;
use dualsparse::server::workload;

fn artifacts() -> PathBuf {
    std::env::var("DUALSPARSE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn engine() -> Engine {
    Engine::new(&artifacts(), "mixtral_ish", DropPolicy::NoDrop, EngineOptions::default())
        .expect("hermetic engine (CpuRef + synthetic weights)")
}

/// The pre-scheduler `serve()` loop (admit-all into free sequence ids,
/// lockstep decode, retire on EOS/max_new) minus the timing fields —
/// spelled in stable-sequence-id form now that the paged cache has no
/// slot compaction. Per-row attention makes it text-equivalent to the
/// historical compacting loop, so this is still the reference the
/// closed-loop scheduler must match byte-for-byte on completion texts.
fn legacy_serve_texts(e: &mut Engine, reqs: &[Request]) -> Vec<(usize, String)> {
    e.kv.reset();
    e.reset_metrics();
    struct A {
        id: usize,
        seq: usize,
        out: Vec<u8>,
        next: u8,
        max_new: usize,
    }
    let mut queue: VecDeque<&Request> = reqs.iter().collect();
    let mut active: Vec<A> = Vec::new();
    let mut done: Vec<(usize, String)> = Vec::new();
    while !queue.is_empty() || !active.is_empty() {
        while e.kv.has_free() && active.len() < MAX_SLOTS {
            let Some(r) = queue.pop_front() else { break };
            let seq = e.kv.alloc();
            let first = e.prefill(seq, r.prompt.as_bytes()).unwrap();
            active.push(A { id: r.id, seq, out: vec![first], next: first, max_new: r.max_new });
        }
        if active.is_empty() {
            break;
        }
        let seqs: Vec<usize> = active.iter().map(|a| a.seq).collect();
        let toks: Vec<u8> = active.iter().map(|a| a.next).collect();
        let next = e.decode_step_seqs(&seqs, &toks).unwrap();
        for (a, &t) in active.iter_mut().zip(&next) {
            a.out.push(t);
            a.next = t;
        }
        let mut row = active.len();
        while row > 0 {
            row -= 1;
            let fin = active[row].next == EOS || active[row].out.len() >= active[row].max_new;
            if !fin {
                continue;
            }
            let a = active.swap_remove(row);
            e.kv.free(a.seq);
            let end = a.out.iter().position(|&c| c == EOS).unwrap_or(a.out.len());
            done.push((a.id, a.out[..end].iter().map(|&b| b as char).collect()));
        }
    }
    done.sort_by_key(|c| c.0);
    done
}

#[test]
fn closed_loop_reproduces_legacy_batcher_byte_for_byte() {
    let mut e = engine();
    // > MAX_SLOTS so both waves (initial fill + queued) are exercised.
    let reqs = workload(20, 5, 7);
    let legacy = legacy_serve_texts(&mut e, &reqs);
    let (done, stats) = serve(&mut e, &reqs).unwrap();
    assert_eq!(done.len(), legacy.len());
    assert_eq!(stats.requests, reqs.len());
    assert_eq!(stats.rejected, 0);
    for (c, (id, text)) in done.iter().zip(&legacy) {
        assert_eq!(c.id, *id);
        assert_eq!(&c.text, text, "request {id} diverged from the legacy loop");
        assert_eq!(c.new_tokens, c.text.len(), "new_tokens must match text.len()");
    }
}

#[test]
fn oversized_prompt_is_rejected_without_losing_completions() {
    let mut e = engine();
    // One 200-byte prompt (over the 128-token prefill ceiling) amid 10
    // good ones: exactly one rejection, zero lost completions, no leak.
    let good = workload(10, 5, 3);
    let mut reqs = good.clone();
    reqs.insert(
        4,
        Request { id: 10, prompt: "!".repeat(200), max_new: 5, priority: 0, deadline_secs: None },
    );
    let out = serve_with(&mut e, &reqs, ArrivalMode::Closed).unwrap();
    assert_eq!(out.rejections.len(), 1, "exactly one rejection");
    assert_eq!(out.rejections[0].id, 10);
    assert!(
        out.rejections[0].reason.contains("too long"),
        "reason: {}",
        out.rejections[0].reason
    );
    assert_eq!(out.completions.len(), 10, "zero lost completions");
    assert_eq!(e.kv.n_active, 0, "rejected request must not leak its KV slot");
    // The survivors are unaffected: same texts as a run without the bad
    // request at all.
    let clean = serve_with(&mut e, &good, ArrivalMode::Closed).unwrap();
    for (a, b) in out.completions.iter().zip(&clean.completions) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.text, b.text, "request {} was perturbed by the rejection", a.id);
    }
}

#[test]
fn every_request_resolves_exactly_once_in_both_modes() {
    let mut e = engine();
    let modes = [
        ArrivalMode::Closed,
        ArrivalMode::Open { rate: 200.0, seed: 3 },
        ArrivalMode::Open { rate: 30.0, seed: 9 },
    ];
    for mode in modes {
        let mut reqs = workload(14, 4, 5);
        reqs[7].prompt = "!".repeat(200); // one rejection per run
        let out = serve_with(&mut e, &reqs, mode).unwrap();
        let mut seen = vec![0usize; reqs.len()];
        for c in &out.completions {
            seen[c.id] += 1;
            assert_eq!(c.new_tokens, c.text.len());
            assert!(c.latency >= c.service_secs - 1e-12);
            assert!(c.ttft <= c.latency + 1e-12);
        }
        for r in &out.rejections {
            seen[r.id] += 1;
        }
        assert!(
            seen.iter().all(|&n| n == 1),
            "{mode:?}: completions ∪ rejections must cover every request exactly once: {seen:?}"
        );
        assert_eq!(out.stats.requests + out.stats.rejected, reqs.len());
        assert_eq!(e.kv.n_active, 0, "{mode:?}: slots must return to free");
    }
}

#[test]
fn latency_is_arrival_anchored_and_queue_inclusive() {
    let mut e = engine();
    // 24 > MAX_SLOTS: the second wave waits in the queue, which the old
    // admission-anchored numbers silently excluded.
    let reqs = workload(24, 4, 7);
    let out = serve_with(&mut e, &reqs, ArrivalMode::Closed).unwrap();
    let st = &out.stats;
    for c in &out.completions {
        assert!(
            (c.queue_secs + c.service_secs - c.latency).abs() < 1e-9,
            "latency must decompose into queue wait + service"
        );
        assert!(c.ttft >= c.queue_secs - 1e-12, "first token can't precede admission");
    }
    assert!(st.p50_latency >= st.p50_service - 1e-12, "queue-inclusive p50");
    assert!(st.p99_latency >= st.p99_service - 1e-12, "queue-inclusive p99");
    assert!(st.mean_ttft > 0.0, "TTFT populated");
    assert!(
        out.completions.iter().any(|c| c.queue_secs > 0.0),
        "a second-wave request must have waited"
    );
    assert!(st.max_queue_depth >= 1, "overflow wave must register queue depth");
}

#[test]
fn finished_at_prefill_requests_never_enter_the_decode_batch() {
    let mut e = engine();
    // max_new == 1: the prefill token is the whole completion; the old
    // loop still burned one full decode step per request on these.
    let reqs: Vec<Request> = workload(3, 1, 7);
    let out = serve_with(&mut e, &reqs, ArrivalMode::Closed).unwrap();
    assert_eq!(out.completions.len(), 3);
    assert_eq!(e.metrics.decode_steps, 0, "no decode step for max_new=1 requests");
    for c in &out.completions {
        assert!(c.new_tokens <= 1);
        assert_eq!(c.new_tokens, c.text.len());
        assert_eq!(c.decode_secs, 0.0);
    }

    // max_new == 0 honors the bound exactly: zero tokens, empty text.
    let mut zero = workload(2, 5, 7);
    for r in &mut zero {
        r.max_new = 0;
    }
    let out = serve_with(&mut e, &zero, ArrivalMode::Closed).unwrap();
    assert_eq!(e.metrics.decode_steps, 0);
    assert!(out.completions.iter().all(|c| c.new_tokens == 0 && c.text.is_empty()));

    // If any prompt yields EOS as its very first token, serving it alone
    // must also complete without a decode step and count zero new tokens.
    let candidates = workload(40, 4, 19);
    let mut eos_req = None;
    for r in &candidates {
        e.kv.reset();
        let slot = e.kv.alloc();
        if let Ok(first) = e.prefill(slot, r.prompt.as_bytes()) {
            if first == EOS {
                eos_req = Some(r.clone());
                break;
            }
        }
    }
    e.kv.reset();
    if let Some(r) = eos_req {
        let out = serve_with(&mut e, &[r], ArrivalMode::Closed).unwrap();
        assert_eq!(e.metrics.decode_steps, 0, "immediate EOS must skip decode");
        assert_eq!(out.completions[0].new_tokens, 0, "EOS terminator is not counted");
        assert_eq!(out.completions[0].text, "");
    }
}

#[test]
fn open_loop_arrivals_are_deterministic_and_respected() {
    let mut e = engine();
    let reqs = workload(6, 3, 7);
    let mode = ArrivalMode::Open { rate: 150.0, seed: 5 };
    let a = serve_with(&mut e, &reqs, mode).unwrap();
    let b = serve_with(&mut e, &reqs, mode).unwrap();
    let arrivals = |o: &dualsparse::engine::scheduler::ServeOutcome| -> Vec<f64> {
        o.completions.iter().map(|c| c.arrival).collect()
    };
    assert_eq!(arrivals(&a), arrivals(&b), "same seed ⇒ same arrival process");
    assert!(a.completions.iter().all(|c| c.arrival > 0.0));
    let last_arrival = a
        .completions
        .iter()
        .map(|c| c.arrival)
        .fold(0.0f64, f64::max);
    assert!(
        a.stats.wall_secs >= last_arrival,
        "the run cannot finish before its last request arrives \
         (wall={} last={last_arrival})",
        a.stats.wall_secs
    );
    // texts are unaffected by the arrival process
    let closed = serve_with(&mut e, &reqs, ArrivalMode::Closed).unwrap();
    for (x, y) in a.completions.iter().zip(&closed.completions) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.text, y.text, "arrival process leaked into generation");
    }
}

fn engine_with_pages(page_size: usize, kv_pages: Option<usize>) -> Engine {
    Engine::new(
        &artifacts(),
        "mixtral_ish",
        DropPolicy::NoDrop,
        EngineOptions { page_size: Some(page_size), kv_pages, ..Default::default() },
    )
    .expect("hermetic engine (CpuRef + synthetic weights)")
}

#[test]
fn page_granularity_is_invisible_to_completion_texts() {
    // With preemption off and page_size >= max_seq (160), every
    // sequence occupies exactly one page whose interior layout is the
    // old slot cache — the slot-scheduler reference configuration. Any
    // smaller page size must produce byte-identical completion texts:
    // attention walks positions in logical order regardless of where
    // page boundaries fall.
    let reqs = workload(20, 5, 7);
    let mut slotlike = engine_with_pages(160, None);
    let reference = serve_with(&mut slotlike, &reqs, ArrivalMode::Closed).unwrap();
    assert_eq!(reference.completions.len(), reqs.len());
    for page in [16usize, 3] {
        let mut paged = engine_with_pages(page, None);
        let got = serve_with(&mut paged, &reqs, ArrivalMode::Closed).unwrap();
        assert_eq!(got.completions.len(), reference.completions.len());
        for (x, y) in reference.completions.iter().zip(&got.completions) {
            assert_eq!(x.id, y.id);
            assert_eq!(
                x.text, y.text,
                "page size {page} leaked into request {}'s text",
                x.id
            );
        }
    }
}

#[test]
fn preemption_conserves_requests_and_reports_recompute() {
    // A starved page pool (20 pages × 4 positions, total demand ≈ 4×
    // that) with preemption on: decode growth must fault, evict and
    // re-admit with recompute-from-prompt — and still resolve every
    // request exactly once with no page or sequence leak.
    let mut e = engine_with_pages(4, Some(20));
    let reqs = workload(16, 8, 7);
    let out = serve_opts(
        &mut e,
        &reqs,
        ArrivalMode::Open { rate: 200.0, seed: 3 },
        &Fcfs,
        SchedOptions { preempt: true, ..Default::default() },
    )
    .unwrap();
    let mut seen = vec![0usize; reqs.len()];
    for c in &out.completions {
        seen[c.id] += 1;
    }
    for r in &out.rejections {
        seen[r.id] += 1;
    }
    assert!(
        seen.iter().all(|&n| n == 1),
        "completions ∪ rejections must cover every request exactly once: {seen:?}"
    );
    assert!(out.stats.preemptions > 0, "a 4× oversubscribed pool must evict");
    assert!(out.stats.recompute_tokens > 0, "evictions throw away cached positions");
    assert_eq!(e.kv.n_active, 0, "every sequence must retire");
    assert_eq!(e.kv.free_page_count(), e.kv.n_pages, "every page must come back");
    // Per-completion eviction counts are the stats total, distributed.
    let total: usize = out.completions.iter().map(|c| c.preemptions as usize).sum();
    assert_eq!(total, out.stats.preemptions, "preemption counts must reconcile");
}

/// Five-way exactly-once: Done ∪ Rejected ∪ Failed ∪ TimedOut ∪
/// Cancelled covers every submitted request exactly once.
fn assert_exactly_once(out: &dualsparse::engine::scheduler::ServeOutcome, n: usize) {
    let mut seen = vec![0usize; n];
    for c in &out.completions {
        seen[c.id] += 1;
    }
    for r in &out.rejections {
        seen[r.id] += 1;
    }
    for c in &out.casualties {
        seen[c.id] += 1;
    }
    assert!(
        seen.iter().all(|&k| k == 1),
        "completions ∪ rejections ∪ casualties must cover every request exactly once: {seen:?}"
    );
    assert_eq!(
        out.stats.requests
            + out.stats.rejected
            + out.stats.failed
            + out.stats.timed_out
            + out.stats.cancelled,
        n,
        "stats counters must reconcile with the five-way partition"
    );
}

#[test]
fn zero_fault_plan_is_byte_identical_to_a_run_without_the_subsystem() {
    // ISSUE-8 acceptance: `FaultPlan::none()` draws nothing and sweeps
    // nothing, so the chaos plumbing itself must be invisible — same
    // completion texts, same counts, no casualties.
    let mut e = engine();
    let reqs = workload(20, 5, 7);
    let plain = serve_opts(
        &mut e,
        &reqs,
        ArrivalMode::Closed,
        &Fcfs,
        SchedOptions::default(),
    )
    .unwrap();
    let chaos = serve_opts(
        &mut e,
        &reqs,
        ArrivalMode::Closed,
        &Fcfs,
        SchedOptions { faults: Some(FaultPlan::none()), ..Default::default() },
    )
    .unwrap();
    assert_eq!(plain.completions.len(), chaos.completions.len());
    for (a, b) in plain.completions.iter().zip(&chaos.completions) {
        assert_eq!((a.id, &a.text), (b.id, &b.text), "the zero plan perturbed generation");
    }
    assert_eq!(chaos.stats.faults_injected, 0);
    assert_eq!(chaos.stats.retries, 0);
    assert_eq!(chaos.stats.backoff_secs, 0.0);
    assert!(chaos.casualties.is_empty());
    assert_eq!(e.kv.free_page_count(), e.kv.n_pages);
}

#[test]
fn per_request_deadlines_time_out_without_leaking_pages() {
    let mut e = engine();
    let mut reqs = workload(12, 3, 7);
    // A deadline that is already expired by the first sweep: even ids
    // are reaped from Queued before any admission, odd ids complete.
    for r in reqs.iter_mut().filter(|r| r.id % 2 == 0) {
        r.deadline_secs = Some(1e-12);
    }
    let out = serve_opts(
        &mut e,
        &reqs,
        ArrivalMode::Closed,
        &Fcfs,
        SchedOptions::default(),
    )
    .unwrap();
    assert_eq!(out.stats.timed_out, 6);
    assert_eq!(out.stats.requests, 6);
    for c in &out.casualties {
        assert_eq!(c.id % 2, 0, "only the deadlined requests may time out");
        assert_eq!(c.phase, Phase::TimedOut);
        assert!(c.reason.contains("deadline"), "reason: {}", c.reason);
    }
    assert_exactly_once(&out, reqs.len());
    assert_eq!(e.kv.n_active, 0);
    assert_eq!(e.kv.free_page_count(), e.kv.n_pages, "timeouts must free pages immediately");

    // The run-wide `--deadline-ms` equivalent applies where the
    // per-request field is unset: everything times out.
    let reqs = workload(5, 3, 7);
    let out = serve_opts(
        &mut e,
        &reqs,
        ArrivalMode::Closed,
        &Fcfs,
        SchedOptions { deadline_secs: Some(1e-12), ..Default::default() },
    )
    .unwrap();
    assert_eq!(out.stats.timed_out, 5);
    assert!(out.completions.is_empty());
    assert_exactly_once(&out, reqs.len());
    assert_eq!(e.kv.free_page_count(), e.kv.n_pages);
}

#[test]
fn pre_cancelled_requests_resolve_exactly_once_as_cancelled() {
    // The external-cancellation hook: ids marked in a shared CancelSet
    // (the future network front end's side of the channel) are reaped
    // wherever the sweep finds them.
    let mut e = engine();
    let reqs = workload(10, 4, 7);
    let cs = CancelSet::new();
    cs.cancel(2);
    cs.cancel(7);
    let out = serve_opts(
        &mut e,
        &reqs,
        ArrivalMode::Closed,
        &Fcfs,
        SchedOptions { cancel: Some(cs), ..Default::default() },
    )
    .unwrap();
    assert_eq!(out.stats.cancelled, 2);
    assert_eq!(out.stats.requests, 8);
    assert_eq!(out.casualties.len(), 2);
    for c in &out.casualties {
        assert!([2usize, 7].contains(&c.id), "only marked ids cancel (got {})", c.id);
        assert_eq!(c.phase, Phase::Cancelled);
        assert!(c.reason.contains("cancel"), "reason: {}", c.reason);
    }
    assert_exactly_once(&out, reqs.len());
    assert_eq!(e.kv.n_active, 0);
    assert_eq!(e.kv.free_page_count(), e.kv.n_pages);
}

#[test]
fn retry_exhaustion_fails_requests_without_aborting_the_run() {
    // exec=1.0: every prefill attempt is injected. With max_retries = 1
    // each request burns its one retry, then fails — deterministically
    // two injected errors and one retry per request, and the run still
    // returns Ok instead of aborting.
    let mut e = engine();
    let reqs = workload(12, 3, 7);
    let plan = FaultPlan::parse("exec=1.0", 5).unwrap();
    let out = serve_opts(
        &mut e,
        &reqs,
        ArrivalMode::Closed,
        &Fcfs,
        SchedOptions { faults: Some(plan), max_retries: 1, ..Default::default() },
    )
    .unwrap();
    assert_eq!(out.stats.failed, 12);
    assert_eq!(out.stats.requests, 0);
    assert_eq!(out.stats.retries, 12);
    assert_eq!(out.stats.faults_injected, 24, "two injected errors per request");
    assert!(out.stats.backoff_secs > 0.0, "virtual backoff must be accounted");
    for c in &out.casualties {
        assert_eq!(c.phase, Phase::Failed);
        assert_eq!(c.retries, 1, "the whole budget was spent first");
        assert!(c.reason.contains("retries exhausted"), "reason: {}", c.reason);
    }
    assert_exactly_once(&out, reqs.len());
    assert_eq!(e.kv.n_active, 0);
    assert_eq!(e.kv.free_page_count(), e.kv.n_pages, "failures must free pages immediately");
}
