"""Generate cross-language golden fixtures for the Rust `CpuRef` backend.

Pure-Python (no jax/numpy) mirror of the oracle math in
`compile/kernels/ref.py` and the serving decomposition in
`compile/model.py`, seeded with the shared SplitMix64 stream so the
inputs are reproducible on both sides. The emitted JSON files live in
`rust/tests/fixtures/` and are asserted by `rust/tests/golden.rs` —
cross-language parity without running Python in CI.

Regenerate (only needed if the oracle math changes):

    python -m tools.gen_fixtures          # from python/
"""

import json
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from compile.rng import SplitMix64  # noqa: E402

EPS = 1e-6


# --------------------------------------------------------------------------
# Minimal f64 linear algebra over flat row-major lists
# --------------------------------------------------------------------------

def randn(rng, rows, cols, scale):
    """Box-Muller normals — the same formula as SplitMix64::gauss in Rust."""
    out = []
    for _ in range(rows * cols):
        u1 = max(rng.f64(), 1e-12)
        u2 = rng.f64()
        out.append(math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2) * scale)
    return out


def matmul(a, b, m, k, n):
    out = [0.0] * (m * n)
    for i in range(m):
        for p in range(k):
            av = a[i * k + p]
            if av == 0.0:
                continue
            for j in range(n):
                out[i * n + j] += av * b[p * n + j]
    return out


def swish(x):
    return x / (1.0 + math.exp(-x))


def softmax(row):
    mx = max(row)
    es = [math.exp(x - mx) for x in row]
    s = sum(es)
    return [e / s for e in es]


def rmsnorm(x, g, m, n):
    out = [0.0] * (m * n)
    for i in range(m):
        row = x[i * n:(i + 1) * n]
        ms = sum(v * v for v in row) / n
        scale = 1.0 / math.sqrt(ms + EPS)
        for j in range(n):
            out[i * n + j] = row[j] * scale * g[j]
    return out


def swiglu_ffn(x, w1, w3, w2, c, d, h):
    """f(x) = (Swish(x W1) * (x W3)) W2  — ref.swiglu_ffn_ref."""
    gate = matmul(x, w1, c, d, h)
    up = matmul(x, w3, c, d, h)
    hidden = [swish(g) * u for g, u in zip(gate, up)]
    return matmul(hidden, w2, c, h, d)


def probe(x, w1, w3, c, d, h):
    """ref.probe_ref: [4, h] accumulated importance rows."""
    gate = matmul(x, w1, c, d, h)
    up = matmul(x, w3, c, d, h)
    out = [0.0] * (4 * h)
    for i in range(c):
        for j in range(h):
            sw = swish(gate[i * h + j])
            gu = sw * up[i * h + j]
            out[j] += sw
            out[h + j] += abs(sw)
            out[2 * h + j] += gu
            out[3 * h + j] += abs(gu)
    return out


def attn_prefill(x, ln1, wq, wk, wv, wo, ln2, s, d, n_heads, d_head):
    """model.serve_attn_prefill: (y, ln2x, K [s,h,dh], V [s,h,dh])."""
    xn = rmsnorm(x, ln1, s, d)
    q = matmul(xn, wq, s, d, d)
    k = matmul(xn, wk, s, d, d)
    v = matmul(xn, wv, s, d, d)
    scale = 1.0 / math.sqrt(d_head)
    ctx = [0.0] * (s * d)
    for hi in range(n_heads):
        off = hi * d_head
        for qi in range(s):
            scores = []
            for ki in range(qi + 1):
                dot = sum(q[qi * d + off + e] * k[ki * d + off + e] for e in range(d_head))
                scores.append(dot * scale)
            attn = softmax(scores)
            for ki in range(qi + 1):
                for e in range(d_head):
                    ctx[qi * d + off + e] += attn[ki] * v[ki * d + off + e]
    proj = matmul(ctx, wo, s, d, d)
    y = [a + b for a, b in zip(x, proj)]
    return y, rmsnorm(y, ln2, s, d), k, v


def attn_step(x, ln1, wq, wk, wv, wo, ln2, kcache, vcache, pos, b, d,
              n_heads, t_max, d_head):
    """model.serve_attn_step: (y, ln2x, new_k [b,h,dh], new_v [b,h,dh])."""
    xn = rmsnorm(x, ln1, b, d)
    q = matmul(xn, wq, b, d, d)
    nk = matmul(xn, wk, b, d, d)
    nv = matmul(xn, wv, b, d, d)
    scale = 1.0 / math.sqrt(d_head)
    ctx = [0.0] * (b * d)
    for bi in range(b):
        p = pos[bi]
        for hi in range(n_heads):
            off = hi * d_head
            cbase = (bi * n_heads + hi) * t_max * d_head
            scores = []
            for ti in range(p):
                dot = sum(q[bi * d + off + e] * kcache[cbase + ti * d_head + e]
                          for e in range(d_head))
                scores.append(dot * scale)
            dot = sum(q[bi * d + off + e] * nk[bi * d + off + e] for e in range(d_head))
            scores.append(dot * scale)
            attn = softmax(scores)
            for ti in range(p):
                for e in range(d_head):
                    ctx[bi * d + off + e] += attn[ti] * vcache[cbase + ti * d_head + e]
            for e in range(d_head):
                ctx[bi * d + off + e] += attn[p] * nv[bi * d + off + e]
    proj = matmul(ctx, wo, b, d, d)
    y = [a + b_ for a, b_ in zip(x, proj)]
    return y, rmsnorm(y, ln2, b, d), nk, nv


# --------------------------------------------------------------------------
# Fixture emission
# --------------------------------------------------------------------------

def main():
    out_dir = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests", "fixtures")
    os.makedirs(out_dir, exist_ok=True)

    def dump(name, obj):
        path = os.path.join(out_dir, f"{name}.json")
        with open(path, "w") as f:
            json.dump(obj, f)
        print(f"wrote {path}")

    # ffn_h12_c4 — SwiGLU expert FFN (Eq. 4)
    rng = SplitMix64(0xF1C5_0001)
    c, d, h = 4, 16, 12
    x = randn(rng, c, d, 0.5)
    w1 = randn(rng, d, h, 0.3)
    w3 = randn(rng, d, h, 0.3)
    w2 = randn(rng, h, d, 0.3)
    dump("ffn_h12_c4", {
        "dims": {"c": c, "d": d, "h": h},
        "x": x, "w1": w1, "w3": w3, "w2": w2,
        "y": swiglu_ffn(x, w1, w3, w2, c, d, h),
    })

    # gate_b3_e8 — softmax gating (Eq. 1)
    rng = SplitMix64(0xF1C5_0002)
    b, d, e = 3, 16, 8
    x = randn(rng, b, d, 0.5)
    wg = randn(rng, d, e, 0.4)
    logits = matmul(x, wg, b, d, e)
    probs = []
    for i in range(b):
        probs.extend(softmax(logits[i * e:(i + 1) * e]))
    dump("gate_b3_e8", {
        "dims": {"b": b, "d": d, "e": e},
        "x": x, "wg": wg, "probs": probs,
    })

    # probe_h12 — neuron-importance accumulators (Eqs. 14-17)
    rng = SplitMix64(0xF1C5_0003)
    c, d, h = 5, 16, 12
    x = randn(rng, c, d, 0.5)
    w1 = randn(rng, d, h, 0.4)
    w3 = randn(rng, d, h, 0.4)
    dump("probe_h12", {
        "dims": {"c": c, "d": d, "h": h},
        "x": x, "w1": w1, "w3": w3, "imp": probe(x, w1, w3, c, d, h),
    })

    # lm_head_b2 — final norm + tied-embedding projection
    rng = SplitMix64(0xF1C5_0004)
    b, d, v = 2, 16, 20
    x = randn(rng, b, d, 0.5)
    lnf = [1.0] * d
    emb = randn(rng, v, d, 0.3)
    xn = rmsnorm(x, lnf, b, d)
    logits = [0.0] * (b * v)
    for i in range(b):
        for j in range(v):
            logits[i * v + j] = sum(xn[i * d + e] * emb[j * d + e] for e in range(d))
    dump("lm_head_b2", {
        "dims": {"b": b, "d": d, "v": v},
        "x": x, "lnf": lnf, "emb": emb, "logits": logits,
    })

    # attn_prefill_s4 — causal prefill, 2 heads x 8
    rng = SplitMix64(0xF1C5_0005)
    s, d, nh, dh = 4, 16, 2, 8
    x = randn(rng, s, d, 0.5)
    ln1 = [1.0] * d
    ln2 = [1.0] * d
    wq = randn(rng, d, d, 0.3)
    wk = randn(rng, d, d, 0.3)
    wv = randn(rng, d, d, 0.3)
    wo = randn(rng, d, d, 0.3)
    y, ln2x, kk, vv = attn_prefill(x, ln1, wq, wk, wv, wo, ln2, s, d, nh, dh)
    dump("attn_prefill_s4", {
        "dims": {"s": s, "d": d, "n_heads": nh, "d_head": dh},
        "x": x, "ln1": ln1, "wq": wq, "wk": wk, "wv": wv, "wo": wo, "ln2": ln2,
        "y": y, "ln2x": ln2x, "k": kk, "v": vv,
    })

    # attn_step_b2 — decode step over a partially-filled cache
    rng = SplitMix64(0xF1C5_0006)
    b, d, nh, dh, t_max = 2, 16, 2, 8, 6
    x = randn(rng, b, d, 0.5)
    ln1 = [1.0] * d
    ln2 = [1.0] * d
    wq = randn(rng, d, d, 0.3)
    wk = randn(rng, d, d, 0.3)
    wv = randn(rng, d, d, 0.3)
    wo = randn(rng, d, d, 0.3)
    pos = [3, 0]  # row 1 has an empty cache (pure self-attention)
    fill = pos[0]  # cache rows to populate for row 0
    kcache = [0.0] * (b * nh * t_max * dh)
    vcache = [0.0] * (b * nh * t_max * dh)
    fill_k = randn(rng, 1, nh * fill * dh, 0.3)
    fill_v = randn(rng, 1, nh * fill * dh, 0.3)
    for hi in range(nh):
        for ti in range(fill):
            for e_ in range(dh):
                src = (hi * fill + ti) * dh + e_
                dst = (0 * nh + hi) * t_max * dh + ti * dh + e_
                kcache[dst] = fill_k[src]
                vcache[dst] = fill_v[src]
    y, ln2x, nk, nv = attn_step(x, ln1, wq, wk, wv, wo, ln2, kcache, vcache,
                                pos, b, d, nh, t_max, dh)
    dump("attn_step_b2", {
        "dims": {"b": b, "d": d, "n_heads": nh, "d_head": dh, "t_max": t_max},
        "x": x, "ln1": ln1, "wq": wq, "wk": wk, "wv": wv, "wo": wo, "ln2": ln2,
        "kcache": kcache, "vcache": vcache, "pos": pos,
        "y": y, "ln2x": ln2x, "new_k": nk, "new_v": nv,
    })


if __name__ == "__main__":
    main()
