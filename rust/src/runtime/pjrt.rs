//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the serving hot path. Compiled only with the `pjrt` cargo feature
//! (requires the `xla` crate in the vendor set).
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` and
//! DESIGN.md §6). Every artifact was lowered with `return_tuple=True`,
//! so execution always yields a tuple literal which we decompose.
//!
//! Thread-safety: the `Backend` trait requires `Sync` (the engine
//! issues concurrent `exec` calls from its expert-dispatch workers).
//! PJRT's C++ client API is thread-safe for buffer upload, compilation
//! and execution; the `xla` crate's handle types are `!Sync` only
//! because they wrap raw pointers without a marker. All interior
//! mutability below is Mutex-guarded, and the `unsafe impl Sync`
//! documents that we rely on PJRT's own thread-safety contract.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{bail, Context, Result};

use crate::model::Tensor;

use super::{Arg, Backend, BufId, ExecCounters};

/// One compiled artifact.
pub struct Exec {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

/// Executable registry bound to one PJRT (CPU) client.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<Exec>>>,
    /// Device-resident weight buffers addressed by [`BufId`].
    bufs: RwLock<Vec<xla::PjRtBuffer>>,
    /// Serializes every touch of the raw-pointer xla handles (client,
    /// executables, buffers). Held for the whole of `platform`/`upload`
    /// /`load`/`exec` — the invariant that makes the `Sync` impl below
    /// sound without relying on PJRT's own (undeclared-in-Rust)
    /// thread-safety.
    call: Mutex<()>,
    counters: ExecCounters,
}

// SAFETY: all access to the raw-pointer xla handles goes through
// `call` (see the methods below — each acquires it before touching
// `client`/`bufs` contents), so cross-thread `&PjrtRuntime` usage is
// fully serialized; the remaining interior state (compile cache,
// buffer registry, counters) is independently lock-guarded. The
// `Backend: Sync` supertrait requires this impl; actual concurrency
// additionally stays disabled via the `supports_concurrent_exec()`
// default of `false`.
unsafe impl Sync for PjrtRuntime {}

impl PjrtRuntime {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime {
            client,
            artifacts_dir: artifacts_dir.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
            bufs: RwLock::new(Vec::new()),
            call: Mutex::new(()),
            counters: ExecCounters::default(),
        })
    }

    /// Load + compile an artifact by name (cached).
    pub fn load(&self, name: &str) -> Result<Arc<Exec>> {
        let _serial = self.call.lock().unwrap();
        self.load_locked(name)
    }

    /// [`Self::load`] body for callers already holding `call` (a plain
    /// Mutex is not reentrant — `exec` must not lock it twice).
    fn load_locked(&self, name: &str) -> Result<Arc<Exec>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!("artifact {name} not found at {path:?} — run `make artifacts`");
        }
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("parsing {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let e = Arc::new(Exec { name: name.to_string(), exe });
        self.cache.lock().unwrap().insert(name.to_string(), e.clone());
        Ok(e)
    }
}

impl Backend for PjrtRuntime {
    fn platform(&self) -> String {
        let _serial = self.call.lock().unwrap();
        self.client.platform_name()
    }

    /// An artifact is executable iff it is already compiled or its
    /// HLO text exists on disk. The scheduler probes
    /// `attn_prefill_chunk_s{S}` with this before a serving run so a
    /// missing chunk artifact fails fast instead of mid-run on the
    /// first long prompt.
    fn supports_artifact(&self, name: &str) -> bool {
        if self.cache.lock().unwrap().contains_key(name) {
            return true;
        }
        self.artifacts_dir.join(format!("{name}.hlo.txt")).exists()
    }

    /// Upload a host tensor to a device-resident buffer (weights path).
    fn upload(&self, t: &Tensor) -> Result<BufId> {
        let _serial = self.call.lock().unwrap();
        let buf = self.client.buffer_from_host_buffer(&t.data, &t.shape, None)?;
        let mut bufs = self.bufs.write().unwrap();
        bufs.push(buf);
        Ok(BufId(bufs.len() - 1))
    }

    /// Execute an artifact; host args are uploaded per call, `Arg::Buf`
    /// args are passed as-is. Returns the decomposed output tuple.
    fn exec(&self, name: &str, args: &[Arg]) -> Result<Vec<Tensor>> {
        let _serial = self.call.lock().unwrap();
        let exec = self.load_locked(name)?;
        let t0 = std::time::Instant::now();
        let persistent = self.bufs.read().unwrap();
        // Owned buffers for the host-side args (kept alive through the
        // execute call); `refs` mixes them with the persistent ones.
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        let mut slots: Vec<Option<usize>> = Vec::with_capacity(args.len());
        for a in args {
            match a {
                Arg::F32(t) => {
                    owned.push(self.client.buffer_from_host_buffer(&t.data, &t.shape, None)?);
                    slots.push(Some(owned.len() - 1));
                }
                Arg::F32Slices(slices, shape) => {
                    // PJRT uploads need contiguous host memory —
                    // materialize the zero-copy view here.
                    let n: usize = shape.iter().product();
                    let mut flat: Vec<f32> = Vec::with_capacity(n);
                    for s in slices.iter() {
                        flat.extend_from_slice(s);
                    }
                    if flat.len() != n {
                        // same contract CpuRef's kv_arg enforces
                        bail!("{name}: slice view holds {} elems, shape needs {n}", flat.len());
                    }
                    owned.push(self.client.buffer_from_host_buffer(&flat, shape, None)?);
                    slots.push(Some(owned.len() - 1));
                }
                Arg::F32Pages { pages, row_starts, n_heads, page, d_head, t_max } => {
                    // Gather the paged view into the contiguous
                    // [B, H, t_max, dh] layout the artifact was lowered
                    // against (unmapped positions read as zero).
                    let b = row_starts.len().saturating_sub(1);
                    let (h, dh, tm) = (*n_heads, *d_head, *t_max);
                    let stride = h * *page * dh;
                    let mut flat = vec![0.0f32; b * h * tm * dh];
                    for bi in 0..b {
                        for (pi, pg) in pages[row_starts[bi]..row_starts[bi + 1]]
                            .iter()
                            .enumerate()
                        {
                            if pg.len() != stride {
                                bail!(
                                    "{name}: page {pi} of row {bi} has {} elems, want {stride}",
                                    pg.len()
                                );
                            }
                            let t0 = pi * *page;
                            let run = (*page).min(tm.saturating_sub(t0));
                            for hi in 0..h {
                                let src = hi * *page * dh;
                                let dst = ((bi * h + hi) * tm + t0) * dh;
                                flat[dst..dst + run * dh]
                                    .copy_from_slice(&pg[src..src + run * dh]);
                            }
                        }
                    }
                    let shape = [b, h, tm, dh];
                    owned.push(self.client.buffer_from_host_buffer(&flat, &shape, None)?);
                    slots.push(Some(owned.len() - 1));
                }
                Arg::I32(v) => {
                    owned.push(self.client.buffer_from_host_buffer(v, &[v.len()], None)?);
                    slots.push(Some(owned.len() - 1));
                }
                Arg::Buf(_) => slots.push(None),
            }
        }
        let refs: Vec<&xla::PjRtBuffer> = args
            .iter()
            .zip(&slots)
            .map(|(a, s)| match (a, s) {
                (Arg::Buf(id), _) => &persistent[id.0],
                (_, Some(i)) => &owned[*i],
                _ => unreachable!(),
            })
            .collect();
        let result = exec.exe.execute_b::<&xla::PjRtBuffer>(&refs)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for lit in parts {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = lit.to_vec::<f32>()?;
            out.push(Tensor::new(dims, data));
        }
        self.counters.record(name, t0.elapsed().as_secs_f64());
        // decompose_tuple returns elements in declaration order already.
        Ok(out)
    }

    /// Number of distinct compiled artifacts held by this runtime.
    fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    fn reset_counters(&self) {
        self.counters.reset();
    }

    fn time_with_prefix(&self, prefix: &str) -> f64 {
        self.counters.time_with_prefix(prefix)
    }

    fn exec_counts(&self) -> HashMap<String, (u64, f64)> {
        self.counters.snapshot()
    }
}
