//! Expert partition and reconstruction (paper §3 + §4.2a/b), applied at
//! model-load time in the coordinator.
//!
//! * **Partial transformation** (Fig. 3c, Eq. 12): each original expert e
//!   is split into P contiguous sub-experts with ids e·P … e·P+P−1; the
//!   gating network is untouched; scores repeat at the router, no W2
//!   scaling. This is what the DualSparse serving path uses.
//! * **Complete transformation** (Fig. 3b, Eq. 11): gate columns repeat,
//!   W2 scales by P. The Python side performs it for fine-tuning
//!   (Fig. 4 / Table 1); the Rust mirror here exists so property tests
//!   can check consistency on the serving side too.
//! * **Reconstruction** (§4.2b): permute each expert's neurons by a
//!   calibration importance table so the *major* sub-expert holds the
//!   top half. A permutation of the FFN inner dim — output-invariant
//!   when both halves run.

use crate::model::{Tensor, Weights};
use anyhow::Result;

/// One sub-expert's weights (width = d_ffn / P).
#[derive(Debug, Clone)]
pub struct SubExpert {
    pub w1: Tensor,
    pub w3: Tensor,
    pub w2: Tensor,
    pub width: usize,
}

impl SubExpert {
    fn from_cols(w1: &Tensor, w3: &Tensor, w2: &Tensor, cols: &[usize]) -> SubExpert {
        SubExpert {
            w1: w1.gather_cols(cols),
            w3: w3.gather_cols(cols),
            w2: w2.gather_rows(cols),
            width: cols.len(),
        }
    }
}

/// An original expert prepared for dual-sparse serving: the full-width
/// weights plus the (major, minor) P=2 split.
#[derive(Debug, Clone)]
pub struct PartitionedExpert {
    pub full: SubExpert,
    pub major: SubExpert,
    pub minor: SubExpert,
}

/// Eq. 12: Top-K expert indices → K·P sub-expert indices, each original
/// expert placed contiguously, relative order preserved per repeat.
pub fn remap_indices(indices: &[usize], p: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(indices.len() * p);
    for rep in 0..p {
        for &i in indices {
            out.push(i * p + rep);
        }
    }
    out
}

/// Descending-importance permutation; ties break toward the lower
/// index, NaN importances order last (same total order as routing —
/// see [`crate::moe::gating::cmp_desc_nan_last`]).
pub fn importance_order(importance: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..importance.len()).collect();
    idx.sort_by(|&a, &b| {
        crate::moe::gating::cmp_desc_nan_last(a, importance[a], b, importance[b])
    });
    idx
}

/// Build the serving-side partitioned experts for one layer.
///
/// `importance`: per-expert `[d_ffn]` tables (§4.2b). When `Some`, the
/// split is by importance (reconstruction); when `None`, it is the
/// contiguous halves of the partial transformation (2T "partition" row
/// of Table 2).
pub fn build_layer(
    weights: &Weights,
    layer: usize,
    importance: Option<&[Vec<f32>]>,
) -> Result<Vec<PartitionedExpert>> {
    let e = weights.config.n_experts;
    let h = weights.config.d_ffn;
    let mut out = Vec::with_capacity(e);
    for ei in 0..e {
        let w1 = weights.expert(layer, "w1", ei)?;
        let w3 = weights.expert(layer, "w3", ei)?;
        let w2 = weights.expert(layer, "w2", ei)?;
        let order: Vec<usize> = match importance {
            Some(tables) => importance_order(&tables[ei]),
            None => (0..h).collect(),
        };
        let full_cols: Vec<usize> = (0..h).collect();
        let major_cols = &order[..h / 2];
        let minor_cols = &order[h / 2..];
        out.push(PartitionedExpert {
            full: SubExpert::from_cols(&w1, &w3, &w2, &full_cols),
            major: SubExpert::from_cols(&w1, &w3, &w2, major_cols),
            minor: SubExpert::from_cols(&w1, &w3, &w2, minor_cols),
        });
    }
    Ok(out)
}

/// Complete transformation of a gate matrix (Fig. 3b step 1): repeat
/// each expert column P times. Returns [d_model, E·P].
pub fn complete_transform_gate(wg: &Tensor, p: usize) -> Tensor {
    let (d, e) = (wg.shape[0], wg.shape[1]);
    let mut data = Vec::with_capacity(d * e * p);
    for r in 0..d {
        let row = wg.row(r);
        for c in 0..e {
            for _ in 0..p {
                data.push(row[c]);
            }
        }
    }
    Tensor::new(vec![d, e * p], data)
}

/// Complete transformation of one expert (Fig. 3b steps 2-3): contiguous
/// neuron split + W2 scaled by P. Returns P sub-experts.
pub fn complete_transform_expert(
    w1: &Tensor,
    w3: &Tensor,
    w2: &Tensor,
    p: usize,
) -> Vec<SubExpert> {
    let h = w1.shape[1];
    let hp = h / p;
    (0..p)
        .map(|pi| {
            let cols: Vec<usize> = (pi * hp..(pi + 1) * hp).collect();
            let mut se = SubExpert::from_cols(w1, w3, w2, &cols);
            se.w2 = se.w2.scale(p as f32);
            se
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn remap_matches_eq12() {
        // I = [i1, i2], P = 2 → [2 i1, 2 i2, 2 i1 + 1, 2 i2 + 1]
        assert_eq!(remap_indices(&[3, 1], 2), vec![6, 2, 7, 3]);
        // P = 3, single expert
        assert_eq!(remap_indices(&[2], 3), vec![6, 7, 8]);
    }

    #[test]
    fn importance_order_descending_stable() {
        let imp = [0.1, 0.9, 0.9, 0.2];
        assert_eq!(importance_order(&imp), vec![1, 2, 3, 0]);
    }

    #[test]
    fn gate_repeat_matches_eq7() {
        let wg = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let r = complete_transform_gate(&wg, 2);
        assert_eq!(r.shape, vec![2, 4]);
        assert_eq!(r.data, vec![1., 1., 2., 2., 3., 3., 4., 4.]);
    }

    #[test]
    fn complete_expert_scales_w2() {
        let w1 = Tensor::new(vec![2, 4], (0..8).map(|x| x as f32).collect());
        let w3 = w1.clone();
        let w2 = Tensor::new(vec![4, 2], (0..8).map(|x| x as f32).collect());
        let subs = complete_transform_expert(&w1, &w3, &w2, 2);
        assert_eq!(subs.len(), 2);
        assert_eq!(subs[0].w1.shape, vec![2, 2]);
        // W2 rows 0..2 scaled by 2
        assert_eq!(subs[0].w2.data, vec![0., 2., 4., 6.]);
        assert_eq!(subs[1].w2.data, vec![8., 10., 12., 14.]);
    }
}
