//! Chunked-prefill equivalence tests: a prompt longer than the largest
//! prefill bucket runs as several bucket-sized passes into the same KV
//! slot and must be **bit-identical** — logits, cached K/V, decode
//! continuation — to a single pass on an engine configured with a
//! large-enough bucket. Also pins the serving-level capacity policy:
//! long prompts complete (not Rejected) up to the KV window, and only
//! prompts that cannot fit `len + max_new ≤ max_seq` are rejected.
//!
//! Hermetic: CpuRef backend + synthetic SplitMix64 weights.

#![allow(clippy::needless_range_loop)]

use std::path::Path;

use dualsparse::engine::scheduler::{serve_with, ArrivalMode, Request};
use dualsparse::engine::{Engine, EngineOptions};
use dualsparse::model::{ModelConfig, Weights};
use dualsparse::moe::DropPolicy;

/// A mixtral_ish engine with a widened KV window and an optional
/// prefill-bucket override (None = the stock [16, 32, 64, 128] ladder).
fn engine_with(max_seq: usize, buckets: Option<Vec<usize>>) -> Engine {
    let mut cfg = ModelConfig::preset("mixtral_ish").unwrap();
    cfg.max_seq = max_seq;
    let weights = Weights::synthetic(&cfg);
    Engine::from_weights(
        Path::new("/nonexistent-artifacts"),
        weights,
        DropPolicy::NoDrop,
        EngineOptions { prefill_buckets: buckets, ..Default::default() },
    )
    .expect("hermetic engine (CpuRef + synthetic weights)")
}

/// 300 deterministic ASCII tokens — spans three stock prefill chunks
/// (128 + 128 + 44→bucket 64) and never contains EOS (`\n`).
fn long_prompt() -> String {
    (0..300).map(|i| (b'a' + (i % 17) as u8) as char).collect()
}

#[test]
fn three_bucket_prompt_is_bit_identical_to_single_pass() {
    let prompt = long_prompt();
    // Chunked: stock buckets, 3 passes. Single: one 300-wide bucket.
    let mut chunked = engine_with(400, None);
    let mut single = engine_with(400, Some(vec![16, 32, 64, 128, 300]));

    chunked.kv.reset();
    let sa = chunked.kv.alloc();
    let (ta, la) = chunked.prefill_logits(sa, prompt.as_bytes()).unwrap();
    single.kv.reset();
    let sb = single.kv.alloc();
    let (tb, lb) = single.prefill_logits(sb, prompt.as_bytes()).unwrap();

    assert!(!la.is_empty(), "logits row populated");
    assert_eq!(la, lb, "chunked logits must be bit-identical to a single pass");
    assert_eq!(ta, tb, "first generated token must agree");

    // KV positions line up after chunking: the decode cursor sits at
    // the prompt length and every cached position matches bitwise.
    // Compare in gathered (logical [H, max_seq, dh]) order — physical
    // page ids are an allocation detail; unmapped tail positions gather
    // as zero on both sides, so the whole-window compare is exact.
    assert_eq!(chunked.kv.pos[sa], 300);
    assert_eq!(single.kv.pos[sb], 300);
    for li in 0..chunked.cfg.n_layers {
        let (ka, va) = chunked.kv.gather_seq(li, sa);
        let (kb, vb) = single.kv.gather_seq(li, sb);
        assert_eq!(ka, kb, "layer {li} K cache diverged");
        assert_eq!(va, vb, "layer {li} V cache diverged");
    }

    // Decode continues identically over the chunk-written cache.
    let a = chunked.decode_step(&[ta]).unwrap();
    let b = single.decode_step(&[tb]).unwrap();
    assert_eq!(a, b, "decode over chunk-written KV diverged");
}

#[test]
fn three_bucket_prompt_completes_in_serving() {
    let prompt = long_prompt();
    let mut e = engine_with(400, None);
    let reqs = vec![
        Request { id: 0, prompt: "cpy:ab|".into(), max_new: 4, priority: 0, deadline_secs: None },
        Request { id: 1, prompt: prompt.clone(), max_new: 4, priority: 0, deadline_secs: None },
        Request { id: 2, prompt: "add:3+4|".into(), max_new: 4, priority: 0, deadline_secs: None },
    ];
    let out = serve_with(&mut e, &reqs, ArrivalMode::Closed).unwrap();
    assert!(
        out.rejections.is_empty(),
        "a 3-bucket prompt must complete, not Reject: {:?}",
        out.rejections
    );
    assert_eq!(out.completions.len(), 3);
    assert_eq!(e.kv.n_active, 0, "all slots returned");

    // The long request's completion matches an unchunked (single-pass
    // bucket) engine generating the same continuation.
    let mut single = engine_with(400, Some(vec![16, 32, 64, 128, 300]));
    let want = single.generate_batch(&[prompt.as_str()], 4).unwrap();
    let got = out.completions.iter().find(|c| c.id == 1).unwrap();
    assert_eq!(got.text, want[0], "chunked serving continuation diverged");
}

#[test]
fn stock_engine_accepts_up_to_the_kv_window_and_rejects_past_it() {
    // Stock mixtral_ish: max_seq 160, largest bucket 128. A 140-token
    // prompt (PR 4 would have rejected it) now chunks and completes;
    // 200 tokens cannot fit 200 + 5 ≤ 160 and is the true capacity
    // rejection.
    let mut e = Engine::new(
        Path::new("/nonexistent-artifacts"),
        "mixtral_ish",
        DropPolicy::NoDrop,
        EngineOptions::default(),
    )
    .unwrap();
    assert_eq!(e.prompt_capacity(5), 155);
    let reqs = vec![
        Request { id: 0, prompt: "?".repeat(140), max_new: 5, priority: 0, deadline_secs: None },
        Request { id: 1, prompt: "!".repeat(200), max_new: 5, priority: 0, deadline_secs: None },
    ];
    let out = serve_with(&mut e, &reqs, ArrivalMode::Closed).unwrap();
    assert_eq!(out.completions.len(), 1, "the 140-token prompt completes");
    assert_eq!(out.completions[0].id, 0);
    assert_eq!(out.rejections.len(), 1);
    assert_eq!(out.rejections[0].id, 1);
    assert!(
        out.rejections[0].reason.contains("too long"),
        "reason: {}",
        out.rejections[0].reason
    );
    assert_eq!(e.kv.n_active, 0);

    // Chunked prefill leaves the decode cursor at the prompt length.
    e.kv.reset();
    let slot = e.kv.alloc();
    e.prefill(slot, "?".repeat(140).as_bytes()).unwrap();
    assert_eq!(e.kv.pos[slot], 140);

    // Direct prefill past the KV window is an engine error, not UB.
    e.kv.reset();
    let slot = e.kv.alloc();
    assert!(e.prefill(slot, "!".repeat(200).as_bytes()).is_err());
}

#[test]
fn bad_bucket_overrides_are_rejected_at_construction() {
    let mut cfg = ModelConfig::preset("mixtral_ish").unwrap();
    cfg.max_seq = 100;
    for bad in [vec![], vec![16, 16], vec![32, 16], vec![16, 200]] {
        let weights = Weights::synthetic(&cfg);
        let r = Engine::from_weights(
            Path::new("/nonexistent-artifacts"),
            weights,
            DropPolicy::NoDrop,
            EngineOptions { prefill_buckets: Some(bad.clone()), ..Default::default() },
        );
        assert!(r.is_err(), "bucket override {bad:?} must be rejected");
    }
}
