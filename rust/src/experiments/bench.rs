//! `dualsparse bench` — the measured CPU perf sweep behind
//! `BENCH_cpu.json`.
//!
//! Sweeps drop policies × decode-batch sizes × worker thread counts on
//! a synthetic preset and records *measured* serving numbers
//! (tokens/sec, MoE-module busy seconds, wall seconds) plus the
//! speedup of each drop policy against the no-drop run of the same
//! (threads, batch) group. This seeds the repo's perf trajectory:
//! every future PR can diff its `BENCH_cpu.json` against the last one.
//!
//! A second phase (`sweep == "neuron"`, ISSUE-10) ladders the
//! neuron-level dimension: kept fraction of probe-ranked FFN neurons ×
//! int8 quantization, each row carrying a measured accuracy proxy
//! (max|Δlogit| vs the dense-f32 engine over fixed prompts). The
//! keep = 1.0 / quant-off row runs byte-identical kernels to the dense
//! engine, so its max_abs_dlogit is exactly 0.0 — CI pins that.
//!
//! Unlike the EP *simulation* (fig10/fig11), nothing here is modeled —
//! drop rate shrinks capacity buckets, which shrinks real GEMMs, which
//! moves real wall-clock time.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::engine::faults::{DegradeController, FaultPlan};
use crate::engine::policy::{AdmissionControl, PolicyKind};
use crate::engine::scheduler::{
    serve, serve_opts, serve_policy, ArrivalMode, SchedOptions, ServeStats,
};
use crate::engine::{Engine, EngineOptions, EpOptions};
use crate::moe::DropPolicy;
use crate::server;
use crate::util::json::{num, obj, s, Json};
use crate::util::threads;

/// CLI-facing bench options.
pub struct BenchConfig {
    /// Few-config smoke sweep (CI); full sweep otherwise.
    pub quick: bool,
    /// Output path for the JSON record.
    pub out: PathBuf,
    /// Synthetic preset (or serialized model) to bench.
    pub model: String,
}

/// One measured configuration.
pub struct BenchRow {
    /// Which sweep phase produced the row: `"policy"` (drop policies ×
    /// batches × threads) or `"neuron"` (neuron-keep × quant ladder).
    pub sweep: String,
    pub threads: usize,
    pub batch: usize,
    pub policy: String,
    /// Kept fraction of probe-ranked FFN neurons (1.0 on policy rows).
    pub neuron_keep: f64,
    /// Int8 quantized-weight kernels on (false on policy rows).
    pub quant: bool,
    pub drop_rate: f64,
    pub tokens_per_sec: f64,
    pub wall_secs: f64,
    /// Cumulative MoE (gate + FFN) busy seconds across workers.
    pub moe_secs: f64,
    /// tokens/sec vs the baseline row of the same group (the no-drop
    /// row of the same (threads, batch) on policy rows; the
    /// keep = 1.0 / quant-off row on neuron rows).
    pub speedup_vs_no_drop: f64,
    /// Accuracy proxy: max |Δlogit| vs the dense-f32 engine over a
    /// fixed drop-free prompt set. Exactly 0.0 on policy rows and on
    /// the neuron ladder's keep = 1.0 / quant-off baseline (those run
    /// byte-identical kernels).
    pub max_abs_dlogit: f64,
}

/// Run the sweep; rows are ordered (threads, batch, policy) with the
/// no-drop policy first in each group.
pub fn sweep(artifacts: &Path, model: &str, quick: bool) -> Result<Vec<BenchRow>> {
    // Thresholds sit around 0.5 on purpose: top-2 normalized gating
    // scores of the near-uniform synthetic gates cluster there, so this
    // ladder yields monotonically growing drop rates (cf. the 2T band
    // note in rust/tests/integration.rs).
    let policies: Vec<(&str, DropPolicy)> = if quick {
        vec![
            ("none", DropPolicy::NoDrop),
            ("2t:0.45", DropPolicy::two_t(0.45)),
        ]
    } else {
        vec![
            ("none", DropPolicy::NoDrop),
            ("2t:0.44", DropPolicy::two_t(0.44)),
            ("2t:0.48", DropPolicy::two_t(0.48)),
            ("1t:0.52", DropPolicy::OneT(0.52)),
        ]
    };
    let threads_sweep: Vec<usize> = if quick { vec![1, 2] } else { vec![1, 2, 4] };
    let batches: Vec<usize> = if quick { vec![8] } else { vec![4, 8, 16] };
    let (req_mult, max_new) = if quick { (1, 6) } else { (2, 10) };
    let mut engine =
        Engine::new(artifacts, model, DropPolicy::NoDrop, EngineOptions::default())?;
    let mut rows = Vec::new();
    for &t in &threads_sweep {
        for &batch in &batches {
            let reqs = server::workload(batch * req_mult, max_new, 7);
            let warm = server::workload(batch.min(4), 3, 13);
            let mut base_tps: Option<f64> = None;
            for (label, pol) in &policies {
                engine.policy = *pol;
                threads::set_thread_override(Some(t));
                // restore the process-global override even on error —
                // a leaked Some(t) would silently re-thread everything
                // that runs later in this process (paper_benches).
                let measured = (|| {
                    serve(&mut engine, &warm)?; // touch every artifact bucket
                    serve(&mut engine, &reqs)
                })();
                threads::set_thread_override(None);
                let (_, stats) = measured?;
                let speedup = match base_tps {
                    Some(b) if b > 0.0 && stats.tokens_per_sec > 0.0 => {
                        stats.tokens_per_sec / b
                    }
                    _ => 1.0,
                };
                if base_tps.is_none() {
                    base_tps = Some(stats.tokens_per_sec);
                }
                rows.push(BenchRow {
                    sweep: "policy".to_string(),
                    threads: t,
                    batch,
                    policy: label.to_string(),
                    neuron_keep: 1.0,
                    quant: false,
                    drop_rate: stats.drop_rate,
                    tokens_per_sec: stats.tokens_per_sec,
                    wall_secs: stats.wall_secs,
                    moe_secs: stats.moe_secs,
                    speedup_vs_no_drop: speedup,
                    max_abs_dlogit: 0.0,
                });
            }
        }
    }
    // --------------------------------------------------------------
    // Neuron-level ladder (ISSUE-10): neuron_keep × quant at the
    // heaviest thread count of the sweep, plus one combined row
    // stacking tensor-level dropping on a masked run. Importance comes
    // from an in-process calibration pass (hermetic — no prior
    // `dualsparse calibrate` needed); accuracy is measured drop-free
    // per row so max|Δlogit| isolates the neuron/quant error.
    // --------------------------------------------------------------
    let combined: (&str, DropPolicy) = if quick {
        ("2t:0.45", DropPolicy::two_t(0.45))
    } else {
        ("2t:0.44", DropPolicy::two_t(0.44))
    };
    let nodrop: (&str, DropPolicy) = ("none", DropPolicy::NoDrop);
    let ladder: Vec<(f32, bool, (&str, DropPolicy))> = if quick {
        vec![
            (1.0, false, nodrop),
            (0.75, false, nodrop),
            (0.5, false, nodrop),
            (1.0, true, nodrop),
            (0.75, true, nodrop),
            (0.75, false, combined),
        ]
    } else {
        let mut v: Vec<(f32, bool, (&str, DropPolicy))> = Vec::new();
        for &q in &[false, true] {
            for &k in &[1.0f32, 0.75, 0.5, 0.25] {
                v.push((k, q, nodrop));
            }
        }
        v.push((0.75, false, combined));
        v
    };
    engine.policy = DropPolicy::NoDrop;
    let n_tok = if quick { 256 } else { super::n_calib() };
    let tables = crate::calib::run_calibration(&mut engine, n_tok)?;
    let imp = tables.importance("abs_gate");
    let prompts: [&str; 4] = ["cpy:abcd|", "add:3+4|", "srt:dcba|", "maj:aabab|"];
    let ref_logits = prompt_logits(&mut engine, &prompts)?;
    let lt = *threads_sweep.last().unwrap();
    let lbatch = *batches.last().unwrap();
    let lreqs = server::workload(lbatch * req_mult, max_new, 7);
    let lwarm = server::workload(lbatch.min(4), 3, 13);
    let mut ladder_base: Option<f64> = None;
    for (keep, quant, (plabel, pol)) in ladder {
        let mut le = Engine::new(
            artifacts,
            model,
            DropPolicy::NoDrop,
            EngineOptions {
                neuron_keep: Some(keep),
                quant,
                importance: Some(imp.clone()),
                ..Default::default()
            },
        )?;
        let got = prompt_logits(&mut le, &prompts)?;
        let mut dmax = 0.0f64;
        for (a, b) in got.iter().zip(&ref_logits) {
            for (&x, &y) in a.iter().zip(b) {
                dmax = dmax.max((x as f64 - y as f64).abs());
            }
        }
        le.policy = pol;
        threads::set_thread_override(Some(lt));
        let measured = (|| {
            serve(&mut le, &lwarm)?; // touch every artifact bucket
            serve(&mut le, &lreqs)
        })();
        threads::set_thread_override(None);
        let (_, stats) = measured?;
        let speedup = match ladder_base {
            Some(b) if b > 0.0 && stats.tokens_per_sec > 0.0 => stats.tokens_per_sec / b,
            _ => 1.0,
        };
        if ladder_base.is_none() {
            ladder_base = Some(stats.tokens_per_sec);
        }
        rows.push(BenchRow {
            sweep: "neuron".to_string(),
            threads: lt,
            batch: lbatch,
            policy: plabel.to_string(),
            neuron_keep: keep as f64,
            quant,
            drop_rate: stats.drop_rate,
            tokens_per_sec: stats.tokens_per_sec,
            wall_secs: stats.wall_secs,
            moe_secs: stats.moe_secs,
            speedup_vs_no_drop: speedup,
            max_abs_dlogit: dmax,
        });
    }
    Ok(rows)
}

/// Last-position prefill logits for each prompt (KV reset between
/// prompts — deterministic, order-independent). The neuron ladder's
/// accuracy proxy compares these rows against the dense engine's.
fn prompt_logits(engine: &mut Engine, prompts: &[&str]) -> Result<Vec<Vec<f32>>> {
    let mut out = Vec::new();
    for p in prompts {
        engine.kv.reset();
        let slot = engine.kv.alloc();
        out.push(engine.prefill_logits(slot, p.as_bytes())?.1);
    }
    Ok(out)
}

/// Serialize sweep rows to the `BENCH_cpu.json` schema.
pub fn write_json(model: &str, quick: bool, rows: &[BenchRow], out: &Path) -> Result<()> {
    let runs = Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("sweep", s(&r.sweep)),
                    ("threads", num(r.threads as f64)),
                    ("batch", num(r.batch as f64)),
                    ("policy", s(&r.policy)),
                    ("neuron_keep", num(r.neuron_keep)),
                    ("quant", Json::Bool(r.quant)),
                    ("drop_rate", num(r.drop_rate)),
                    ("tokens_per_sec", num(r.tokens_per_sec)),
                    ("wall_secs", num(r.wall_secs)),
                    ("moe_secs", num(r.moe_secs)),
                    ("speedup_vs_no_drop", num(r.speedup_vs_no_drop)),
                    ("max_abs_dlogit", num(r.max_abs_dlogit)),
                ])
            })
            .collect(),
    );
    let ap = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let j = obj(vec![
        ("model", s(model)),
        ("quick", Json::Bool(quick)),
        ("available_parallelism", num(ap as f64)),
        ("runs", runs),
    ]);
    let text = j.to_string() + "\n";
    std::fs::write(out, text).with_context(|| format!("writing {out:?}"))?;
    Ok(())
}

/// Full CLI entry: sweep, print a table, write the JSON record.
pub fn run(artifacts: &Path, cfg: &BenchConfig) -> Result<()> {
    println!(
        "dualsparse bench — model {} ({} sweep, CpuRef measured)",
        cfg.model,
        if cfg.quick { "quick" } else { "full" }
    );
    let rows = sweep(artifacts, &cfg.model, cfg.quick)?;
    println!(
        "{:>7} {:>7} {:>6} {:>8} {:>5} {:>5} {:>7} {:>11} {:>9} {:>9} {:>10}",
        "sweep", "threads", "batch", "policy", "keep", "quant", "drop%", "tok/s", "moe_s",
        "vs-base", "max|dlog|"
    );
    for r in &rows {
        println!(
            "{:>7} {:>7} {:>6} {:>8} {:>5.2} {:>5} {:>6.1}% {:>11.1} {:>9.3} {:>8.2}x {:>10.2e}",
            r.sweep,
            r.threads,
            r.batch,
            r.policy,
            r.neuron_keep,
            if r.quant { "on" } else { "off" },
            100.0 * r.drop_rate,
            r.tokens_per_sec,
            r.moe_secs,
            r.speedup_vs_no_drop,
            r.max_abs_dlogit,
        );
    }
    write_json(&cfg.model, cfg.quick, &rows, &cfg.out)?;
    println!("wrote {:?}", cfg.out);
    Ok(())
}

// ---------------------------------------------------------------------
// Open-loop serving sweep (`dualsparse serve --sweep|--quick`)
// ---------------------------------------------------------------------

/// CLI-facing options for the open-loop serving sweep.
pub struct ServeSweepConfig {
    /// Few-config smoke sweep (CI); full sweep otherwise.
    pub quick: bool,
    /// Output path for the JSON record (next to BENCH_cpu.json).
    pub out: PathBuf,
    /// Synthetic preset (or serialized model) to serve.
    pub model: String,
    /// Restrict the scheduling-policy dimension to one policy (the CI
    /// smoke matrix runs one job per policy); `None` sweeps all three.
    pub sched: Option<PolicyKind>,
}

/// Waiting-queue bound applied to every sweep run: past the knee, the
/// scheduler rejects (`queue full`) instead of queueing unboundedly, so
/// `goodput_rps` vs `rate_rps` (offered load) is an honest saturation
/// curve. 1.5 × MAX_SLOTS: small enough to engage at the heaviest
/// arrival multiples of the full sweep, large enough that the quick
/// sweep (12 requests) never trips it.
pub const SWEEP_MAX_QUEUE: usize = 24;

/// One measured open-loop serving configuration.
pub struct ServeRow {
    /// Scheduling policy (`fcfs` | `spf` | `priority`).
    pub sched: String,
    /// Arrival rate as a multiple of the closed-loop service rate.
    pub arrival_mult: f64,
    /// Absolute arrival rate (requests/second) — the offered load.
    pub rate_rps: f64,
    pub policy: String,
    pub completed: usize,
    pub rejected: usize,
    /// Subset of `rejected` turned away by the queue bound.
    pub rejected_queue_full: usize,
    pub drop_rate: f64,
    pub tokens_per_sec: f64,
    /// Completed requests per second — plot against `rate_rps`.
    pub goodput_rps: f64,
    /// Queue-inclusive (arrival-anchored) latency percentiles.
    pub p50_latency: f64,
    pub p99_latency: f64,
    /// Admission-anchored service percentiles (the old metric, kept so
    /// the report shows what queue wait used to hide).
    pub p50_service: f64,
    pub p99_service: f64,
    pub p50_ttft: f64,
    pub p99_ttft: f64,
    pub mean_queue_depth: f64,
    pub max_queue_depth: usize,
    pub wall_secs: f64,
    /// Prefill/decode interleaving on. `false` rows are the
    /// drain-prefill-fully baseline recorded at overload multiples so
    /// the interleaved rows have an in-file p99-TTFT comparison point.
    pub interleave: bool,
    /// Evictions over the run (0 — the sweep runs preemption off).
    pub preemptions: usize,
    /// KV positions rebuilt by recompute-from-prompt re-admissions.
    pub recompute_tokens: u64,
    /// Time-weighted mean fraction of the KV page pool mapped.
    pub page_utilization: f64,
    /// Prefill chunks run inside the iteration loop (0 when
    /// `interleave` is off).
    pub interleaved_prefill_steps: u64,
    /// Per-priority-lane p50 TTFT, 0.0 when the lane saw no
    /// completions — the starvation-control report columns.
    pub ttft50_lane0: f64,
    pub ttft50_lane1: f64,
    pub ttft50_lane2: f64,
    /// Virtual EP workers simulated (0 = EP off; the other `ep_*`
    /// columns are zeros/empty then).
    pub ep_workers: usize,
    /// §4.3 load-aware per-worker thresholding on.
    pub ep_load_aware: bool,
    /// Per-worker attributed FFN busy seconds.
    pub ep_worker_busy_secs: Vec<f64>,
    /// Hottest worker's kept cost ÷ mean per-worker kept cost.
    pub ep_straggler_ratio: f64,
    /// Counterfactual ratio under the unscaled base policy on the
    /// identical routings (bounds `ep_straggler_ratio` from above).
    pub ep_straggler_ratio_static: f64,
    /// Hot-worker compute seconds avoided by dropping.
    pub ep_imbalance_saved_secs: f64,
    /// Simulated AlltoAll dispatch + return seconds.
    pub ep_comm_secs: f64,
    /// Drop rate over EP-routed pairs.
    pub ep_drop_rate: f64,
    /// Counterfactual drop rate under the unscaled base policy.
    pub ep_drop_rate_static: f64,
    /// Hot-expert replications over the run.
    pub ep_replications: u64,
    /// Injected-fault casualties (retry budget exhausted; 0 outside
    /// the chaos rows).
    pub failed: usize,
    /// Deadline casualties.
    pub timed_out: usize,
    /// External cancellations honored.
    pub cancelled: usize,
    /// Bounded retries of injected transient backend errors.
    pub retries: u64,
    /// Total fault events injected by the row's `FaultPlan`.
    pub faults_injected: u64,
    /// Highest degrade-ladder level the run reached.
    pub degrade_level_max: u32,
    /// `(iteration, level)` at every degrade-level change.
    pub degrade_timeline: Vec<(u64, u32)>,
    /// Experts re-hosted off injected EP worker failures.
    pub ep_failovers: u64,
}

/// Assemble one [`ServeRow`] from a measured run's [`ServeStats`].
fn serve_row(
    sched: &str,
    mult: f64,
    rate: f64,
    policy: &str,
    interleave: bool,
    st: &ServeStats,
) -> ServeRow {
    let lane =
        |l: u8| st.lane_ttft50.iter().find(|&&(k, _)| k == l).map(|&(_, v)| v).unwrap_or(0.0);
    ServeRow {
        sched: sched.to_string(),
        arrival_mult: mult,
        rate_rps: rate,
        policy: policy.to_string(),
        completed: st.requests,
        rejected: st.rejected,
        rejected_queue_full: st.rejected_queue_full,
        drop_rate: st.drop_rate,
        tokens_per_sec: st.tokens_per_sec,
        goodput_rps: st.goodput_rps,
        p50_latency: st.p50_latency,
        p99_latency: st.p99_latency,
        p50_service: st.p50_service,
        p99_service: st.p99_service,
        p50_ttft: st.p50_ttft,
        p99_ttft: st.p99_ttft,
        mean_queue_depth: st.mean_queue_depth,
        max_queue_depth: st.max_queue_depth,
        wall_secs: st.wall_secs,
        interleave,
        preemptions: st.preemptions,
        recompute_tokens: st.recompute_tokens,
        page_utilization: st.page_utilization,
        interleaved_prefill_steps: st.interleaved_prefill_steps,
        ttft50_lane0: lane(0),
        ttft50_lane1: lane(1),
        ttft50_lane2: lane(2),
        ep_workers: st.ep_workers,
        ep_load_aware: st.ep_load_aware,
        ep_worker_busy_secs: st.ep_worker_busy_secs.clone(),
        ep_straggler_ratio: st.ep_straggler_ratio,
        ep_straggler_ratio_static: st.ep_straggler_ratio_static,
        ep_imbalance_saved_secs: st.ep_imbalance_saved_secs,
        ep_comm_secs: st.ep_comm_secs,
        ep_drop_rate: st.ep_drop_rate,
        ep_drop_rate_static: st.ep_drop_rate_static,
        ep_replications: st.ep_replications,
        failed: st.failed,
        timed_out: st.timed_out,
        cancelled: st.cancelled,
        retries: st.retries,
        faults_injected: st.faults_injected,
        degrade_level_max: st.degrade_level_max,
        degrade_timeline: st.degrade_timeline.clone(),
        ep_failovers: st.ep_failovers,
    }
}

/// Sweep scheduling policy × arrival rate × drop policy in open-loop
/// mode under the [`SWEEP_MAX_QUEUE`] admission bound. Every run
/// carries one oversized prompt (fault isolation is part of the
/// measured path — it must cost exactly one rejection and zero lost
/// completions) and one 140-token prompt that exceeds the largest
/// prefill bucket, so chunked prefill is exercised on the measured
/// path too (and SPF has a long job to defer). The drop-policy ladder
/// runs under FCFS only; `spf` / `priority` run drop-free so the
/// scheduling comparison isn't confounded. Returns the calibrated
/// closed-loop service rate and the measured rows.
pub fn serve_sweep_rows(
    artifacts: &Path,
    model: &str,
    quick: bool,
    sched: Option<PolicyKind>,
) -> Result<(f64, Vec<ServeRow>)> {
    let (n, max_new) = if quick { (12, 5) } else { (48, 10) };
    let mults: Vec<f64> = if quick { vec![0.75, 2.0, 4.0] } else { vec![0.5, 1.0, 2.0, 4.0] };
    let drop_ladder: Vec<(&str, DropPolicy)> = if quick {
        vec![("none", DropPolicy::NoDrop), ("2t:0.45", DropPolicy::two_t(0.45))]
    } else {
        vec![
            ("none", DropPolicy::NoDrop),
            ("2t:0.44", DropPolicy::two_t(0.44)),
            ("2t:0.48", DropPolicy::two_t(0.48)),
            ("1t:0.52", DropPolicy::OneT(0.52)),
        ]
    };
    let scheds: Vec<PolicyKind> = match sched {
        Some(k) => vec![k],
        None => PolicyKind::ALL.to_vec(),
    };
    let mut reqs = server::workload(n, max_new, 7);
    reqs[n / 2].prompt = "!".repeat(200); // exceeds the KV window ⇒ rejected
    reqs[n / 3].prompt = "?".repeat(140); // > largest bucket ⇒ chunked prefill
    let mut engine =
        Engine::new(artifacts, model, DropPolicy::NoDrop, EngineOptions::default())?;
    // Warm under a 2T band so the half-width (major-only) artifacts are
    // loaded too — otherwise the first measured 2T row would pay their
    // lazy compilation inside its latency columns.
    engine.policy = DropPolicy::TwoT { major: 0.05, minor: 0.5 };
    serve(&mut engine, &server::workload(n.min(8), 3, 13))?;
    engine.policy = DropPolicy::NoDrop;
    // Closed-loop calibration run: measures this machine's service
    // throughput so the arrival-rate axis sweeps *relative* load.
    let (done, base) = serve(&mut engine, &reqs)?;
    if done.is_empty() {
        bail!("calibration run completed zero requests — cannot derive an arrival rate");
    }
    let base_rps = done.len() as f64 / base.wall_secs.max(1e-3);
    let admission = AdmissionControl::bounded(SWEEP_MAX_QUEUE);
    let mut rows = Vec::new();
    for &sk in &scheds {
        for &mult in &mults {
            let rate = base_rps * mult;
            let drops: &[(&str, DropPolicy)] = if sk == PolicyKind::Fcfs {
                &drop_ladder
            } else {
                &drop_ladder[..1] // drop-free scheduling comparison
            };
            for (label, pol) in drops {
                engine.policy = *pol;
                let out = serve_policy(
                    &mut engine,
                    &reqs,
                    ArrivalMode::Open { rate, seed: 11 },
                    sk.policy(),
                    admission,
                )?;
                rows.push(serve_row(sk.label(), mult, rate, label, true, &out.stats));
            }
            // Non-interleaved baseline at overload: drain each prefill
            // fully before the decode batch runs. Recorded so the
            // report can compare overload p99 TTFT against the
            // interleaved rows above; deliberately not asserted — the
            // inequality is a measured wall-clock property and flakes
            // on loaded CI machines.
            if mult >= 2.0 {
                engine.policy = DropPolicy::NoDrop;
                let out = serve_opts(
                    &mut engine,
                    &reqs,
                    ArrivalMode::Open { rate, seed: 11 },
                    sk.policy(),
                    SchedOptions { admission, interleave: false, ..Default::default() },
                )?;
                rows.push(serve_row(sk.label(), mult, rate, "none", false, &out.stats));
            }
        }
    }
    // EP dimension (§4.3): virtual-worker count × load-aware
    // thresholding, under FCFS at the 2× overload multiple on the
    // ladder's first 2T policy. Runs only when FCFS is in the sched
    // filter — EP rows ride the drop ladder, which is FCFS-only above.
    if scheds.contains(&PolicyKind::Fcfs) {
        let (ep_label, ep_pol) = drop_ladder[1];
        let ep_configs: &[(usize, bool)] = if quick {
            &[(1, false), (4, false), (4, true)]
        } else {
            &[(1, false), (2, false), (2, true), (4, false), (4, true), (8, false), (8, true)]
        };
        let mult = 2.0;
        let rate = base_rps * mult;
        for &(workers, aware) in ep_configs {
            engine.policy = ep_pol;
            engine.set_ep(Some(EpOptions::new(workers, aware)));
            let out = serve_policy(
                &mut engine,
                &reqs,
                ArrivalMode::Open { rate, seed: 11 },
                PolicyKind::Fcfs.policy(),
                admission,
            )?;
            rows.push(serve_row("fcfs", mult, rate, ep_label, true, &out.stats));
        }
        engine.set_ep(None);
    }
    // Chaos dimension: the failure-domain subsystem on the measured
    // path, under FCFS at the heaviest multiple. One row closes the
    // SLO → drop-policy loop (a DegradeController over the ladder's 2T
    // policy — the paper's drop-rate→speedup curve as a runtime
    // controller, with a deliberately unmeetable TTFT SLO so the
    // escalation is exercised); one row injects deterministic backend
    // faults and page-pool pressure and must still resolve every
    // request exactly once.
    if scheds.contains(&PolicyKind::Fcfs) {
        let mult = *mults.last().expect("mults non-empty");
        let rate = base_rps * mult;
        let (deg_label, deg_pol) = drop_ladder[1];
        engine.policy = deg_pol;
        let degrade = DegradeController::new(1e-6, SWEEP_MAX_QUEUE);
        let out = serve_opts(
            &mut engine,
            &reqs,
            ArrivalMode::Open { rate, seed: 11 },
            PolicyKind::Fcfs.policy(),
            SchedOptions { admission, degrade: Some(degrade), ..Default::default() },
        )?;
        let label = format!("degrade:{deg_label}");
        rows.push(serve_row("fcfs", mult, rate, &label, true, &out.stats));
        engine.policy = DropPolicy::NoDrop;
        let plan = FaultPlan::parse("exec=0.4,spike=0.2:2,pressure=0.3:8:4", 11)?;
        let out = serve_opts(
            &mut engine,
            &reqs,
            ArrivalMode::Open { rate, seed: 11 },
            PolicyKind::Fcfs.policy(),
            SchedOptions { admission, faults: Some(plan), ..Default::default() },
        )?;
        rows.push(serve_row("fcfs", mult, rate, "chaos", true, &out.stats));
    }
    Ok((base_rps, rows))
}

/// Serialize serve-sweep rows to the `SERVE_cpu.json` schema.
pub fn write_serve_json(
    model: &str,
    quick: bool,
    base_rps: f64,
    rows: &[ServeRow],
    out: &Path,
) -> Result<()> {
    let runs = Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("sched", s(&r.sched)),
                    ("arrival_mult", num(r.arrival_mult)),
                    ("rate_rps", num(r.rate_rps)),
                    ("policy", s(&r.policy)),
                    ("completed", num(r.completed as f64)),
                    ("rejected", num(r.rejected as f64)),
                    ("rejected_queue_full", num(r.rejected_queue_full as f64)),
                    ("drop_rate", num(r.drop_rate)),
                    ("tokens_per_sec", num(r.tokens_per_sec)),
                    ("goodput_rps", num(r.goodput_rps)),
                    ("p50_latency", num(r.p50_latency)),
                    ("p99_latency", num(r.p99_latency)),
                    ("p50_service", num(r.p50_service)),
                    ("p99_service", num(r.p99_service)),
                    ("p50_ttft", num(r.p50_ttft)),
                    ("p99_ttft", num(r.p99_ttft)),
                    ("mean_queue_depth", num(r.mean_queue_depth)),
                    ("max_queue_depth", num(r.max_queue_depth as f64)),
                    ("wall_secs", num(r.wall_secs)),
                    ("interleave", Json::Bool(r.interleave)),
                    ("preemptions", num(r.preemptions as f64)),
                    ("recompute_tokens", num(r.recompute_tokens as f64)),
                    ("page_utilization", num(r.page_utilization)),
                    ("interleaved_prefill_steps", num(r.interleaved_prefill_steps as f64)),
                    ("ttft50_lane0", num(r.ttft50_lane0)),
                    ("ttft50_lane1", num(r.ttft50_lane1)),
                    ("ttft50_lane2", num(r.ttft50_lane2)),
                    ("ep_workers", num(r.ep_workers as f64)),
                    ("ep_load_aware", Json::Bool(r.ep_load_aware)),
                    (
                        "ep_worker_busy_secs",
                        Json::Arr(r.ep_worker_busy_secs.iter().map(|&b| num(b)).collect()),
                    ),
                    ("ep_straggler_ratio", num(r.ep_straggler_ratio)),
                    ("ep_straggler_ratio_static", num(r.ep_straggler_ratio_static)),
                    ("ep_imbalance_saved_secs", num(r.ep_imbalance_saved_secs)),
                    ("ep_comm_secs", num(r.ep_comm_secs)),
                    ("ep_drop_rate", num(r.ep_drop_rate)),
                    ("ep_drop_rate_static", num(r.ep_drop_rate_static)),
                    ("ep_replications", num(r.ep_replications as f64)),
                    ("failed", num(r.failed as f64)),
                    ("timed_out", num(r.timed_out as f64)),
                    ("cancelled", num(r.cancelled as f64)),
                    ("retries", num(r.retries as f64)),
                    ("faults_injected", num(r.faults_injected as f64)),
                    ("degrade_level_max", num(r.degrade_level_max as f64)),
                    (
                        "degrade_timeline",
                        Json::Arr(
                            r.degrade_timeline
                                .iter()
                                .map(|&(it, lvl)| {
                                    Json::Arr(vec![num(it as f64), num(lvl as f64)])
                                })
                                .collect(),
                        ),
                    ),
                    ("ep_failovers", num(r.ep_failovers as f64)),
                ])
            })
            .collect(),
    );
    let j = obj(vec![
        ("model", s(model)),
        ("quick", Json::Bool(quick)),
        ("mode", s("open-loop poisson")),
        ("closed_loop_rps", num(base_rps)),
        ("max_queue_depth", num(SWEEP_MAX_QUEUE as f64)),
        ("runs", runs),
    ]);
    let text = j.to_string() + "\n";
    std::fs::write(out, text).with_context(|| format!("writing {out:?}"))?;
    Ok(())
}

/// Full CLI entry for the serving sweep: measure, print, write JSON.
pub fn serve_sweep(artifacts: &Path, cfg: &ServeSweepConfig) -> Result<()> {
    println!(
        "dualsparse serve — model {} ({} open-loop sweep, Poisson arrivals, \
         sched {}, max queue {SWEEP_MAX_QUEUE})",
        cfg.model,
        if cfg.quick { "quick" } else { "full" },
        match cfg.sched {
            Some(k) => k.label(),
            None => "fcfs+spf+priority",
        },
    );
    let (base_rps, rows) = serve_sweep_rows(artifacts, &cfg.model, cfg.quick, cfg.sched)?;
    println!("closed-loop service rate: {base_rps:.2} req/s");
    println!(
        "{:>8} {:>5} {:>8} {:>3} {:>8} {:>7} {:>4} {:>4} {:>9} {:>9} {:>9} {:>9} {:>6}",
        "sched", "load", "policy", "il", "tok/s", "gp(r/s)", "done", "rej", "p50(ms)",
        "p99(ms)", "ttft50", "ttft99", "qdep"
    );
    for r in &rows {
        println!(
            "{:>8} {:>4.2}x {:>8} {:>3} {:>8.1} {:>7.2} {:>4} {:>4} {:>9.0} {:>9.0} {:>9.0} \
             {:>9.0} {:>6.1}",
            r.sched,
            r.arrival_mult,
            r.policy,
            if r.interleave { "on" } else { "off" },
            r.tokens_per_sec,
            r.goodput_rps,
            r.completed,
            r.rejected,
            r.p50_latency * 1e3,
            r.p99_latency * 1e3,
            r.p50_ttft * 1e3,
            r.p99_ttft * 1e3,
            r.mean_queue_depth,
        );
    }
    for r in rows.iter().filter(|r| r.ep_workers > 0) {
        println!(
            "ep: workers={} load_aware={} straggler_ratio={:.3} static={:.3} \
             drop={:.3} drop_static={:.3} saved_s={:.4} comm_s={:.4} repl={}",
            r.ep_workers,
            r.ep_load_aware,
            r.ep_straggler_ratio,
            r.ep_straggler_ratio_static,
            r.ep_drop_rate,
            r.ep_drop_rate_static,
            r.ep_imbalance_saved_secs,
            r.ep_comm_secs,
            r.ep_replications,
        );
    }
    for r in rows.iter().filter(|r| {
        r.faults_injected > 0 || r.degrade_level_max > 0 || r.failed + r.timed_out + r.cancelled > 0
    }) {
        println!(
            "chaos[{}/{}]: faults_injected={} retries={} failed={} timed_out={} cancelled={} \
             degrade_max={} ep_failovers={}",
            r.sched,
            r.policy,
            r.faults_injected,
            r.retries,
            r.failed,
            r.timed_out,
            r.cancelled,
            r.degrade_level_max,
            r.ep_failovers,
        );
    }
    write_serve_json(&cfg.model, cfg.quick, base_rps, &rows, &cfg.out)?;
    println!("wrote {:?}", cfg.out);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_writes_valid_json() {
        let rows = sweep(Path::new("/nonexistent-artifacts"), "mixtral_ish", true)
            .expect("hermetic sweep on synthetic weights");
        let policy_rows: Vec<&BenchRow> =
            rows.iter().filter(|r| r.sweep == "policy").collect();
        let neuron_rows: Vec<&BenchRow> =
            rows.iter().filter(|r| r.sweep == "neuron").collect();
        assert_eq!(policy_rows.len(), 2 * 1 * 2, "threads × batches × policies");
        assert_eq!(neuron_rows.len(), 6, "quick neuron_keep × quant ladder");
        assert_eq!(rows.len(), policy_rows.len() + neuron_rows.len());
        for r in &policy_rows {
            assert!(r.tokens_per_sec > 0.0, "measured, not simulated");
            assert_eq!(r.neuron_keep, 1.0);
            assert!(!r.quant);
            assert_eq!(r.max_abs_dlogit, 0.0);
            if r.policy == "none" {
                assert!((r.speedup_vs_no_drop - 1.0).abs() < 1e-9);
            } else {
                assert!(r.drop_rate > 0.0, "drop ladder must actually drop");
            }
        }
        // The ladder baseline runs byte-identical kernels to the dense
        // engine: its accuracy proxy must be *exactly* zero, not small.
        let base = &neuron_rows[0];
        assert_eq!(base.neuron_keep, 1.0);
        assert!(!base.quant);
        assert_eq!(base.policy, "none");
        assert_eq!(base.max_abs_dlogit, 0.0, "keep=1.0/quant-off is byte-identical");
        assert!((base.speedup_vs_no_drop - 1.0).abs() < 1e-9);
        for r in &neuron_rows {
            assert!(r.tokens_per_sec > 0.0, "measured, not simulated");
            assert!(r.max_abs_dlogit.is_finite());
            if r.policy != "none" {
                assert!(r.drop_rate > 0.0, "combined row stacks tensor dropping");
            }
        }
        // Quantization is a real approximation on this model: the int8
        // rows must move the logits (a 0.0 here would mean the quant
        // kernels silently ran dense weights).
        assert!(
            neuron_rows.iter().filter(|r| r.quant).all(|r| r.max_abs_dlogit > 0.0),
            "quant rows must show nonzero logit error"
        );
        let out = std::env::temp_dir().join("dualsparse_bench_selftest.json");
        write_json("mixtral_ish", true, &rows, &out).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(j.get("model").unwrap().as_str().unwrap(), "mixtral_ish");
        assert_eq!(j.get("runs").unwrap().as_arr().unwrap().len(), rows.len());
        let run0 = &j.get("runs").unwrap().as_arr().unwrap()[0];
        for field in ["sweep", "neuron_keep", "quant", "max_abs_dlogit"] {
            assert!(run0.get(field).is_ok(), "BENCH_cpu.json runs must carry {field}");
        }
        let _ = std::fs::remove_file(&out);
    }

    /// The ISSUE-4 acceptance smoke, extended with the ISSUE-5 policy
    /// dimension: open-loop rows must show honest (queue-inclusive)
    /// latency ≥ the admission-anchored service time, populated
    /// TTFT/goodput, exactly one rejection (the injected oversized
    /// prompt — the quick workload never trips the queue bound) with
    /// zero lost completions (including the 140-token chunked-prefill
    /// prompt), per-scheduling-policy rows, and goodput that does not
    /// grow past the saturation knee.
    #[test]
    fn quick_serve_sweep_is_honest_fault_isolated_and_policy_tagged() {
        let (base_rps, rows) =
            serve_sweep_rows(Path::new("/nonexistent-artifacts"), "mixtral_ish", true, None)
                .expect("hermetic open-loop sweep");
        assert!(base_rps > 0.0);
        // fcfs: 3 mults × 2 drop policies; spf/priority: 3 mults ×
        // drop-free; plus one non-interleaved baseline per sched at
        // each overload mult (2×, 4×); plus the 3-config EP dimension
        // (1 worker, 4 static, 4 load-aware) under fcfs at 2×; plus
        // the 2-row chaos dimension (degrade controller, fault plan)
        // under fcfs at the heaviest mult.
        assert_eq!(
            rows.len(),
            3 * 2 + 3 + 3 + 3 * 2 + 3 + 2,
            "sched × rates × drops + baselines + EP dimension + chaos dimension"
        );
        assert_eq!(
            rows.iter().filter(|r| !r.interleave).count(),
            3 * 2,
            "one drain-prefill baseline per sched per overload mult"
        );
        for r in &rows {
            assert_eq!(r.rejected, 1, "exactly the oversized prompt ({})", r.sched);
            assert_eq!(r.rejected_queue_full, 0, "quick load can't fill 24 slots");
            if r.policy == "chaos" {
                // Injected faults may exhaust a request's retry budget;
                // the run must still resolve every request exactly once.
                assert_eq!(
                    r.completed + r.failed,
                    11,
                    "chaos row resolves every admitted request"
                );
            } else {
                assert_eq!(
                    r.completed, 11,
                    "zero lost completions incl. the chunked 140-token prompt ({})",
                    r.sched
                );
            }
            assert!(r.p50_latency >= r.p50_service - 1e-12, "queue-inclusive p50");
            assert!(r.p99_latency >= r.p99_service - 1e-12, "queue-inclusive p99");
            assert!(r.p99_ttft >= r.p50_ttft - 1e-12, "TTFT percentiles ordered");
            assert!(r.p50_ttft > 0.0, "TTFT populated");
            assert!(r.tokens_per_sec > 0.0);
            assert!(r.goodput_rps > 0.0, "goodput populated");
            assert_eq!(r.preemptions, 0, "sweep runs preemption off");
            assert_eq!(r.recompute_tokens, 0, "no evictions ⇒ nothing recomputed");
            assert!(r.page_utilization > 0.0, "page pool was sampled");
            if r.interleave {
                assert!(r.interleaved_prefill_steps > 0, "iteration loop ran prefill chunks");
            } else {
                assert_eq!(r.interleaved_prefill_steps, 0, "baseline drains prefill fully");
            }
            assert!(
                r.ttft50_lane0 > 0.0 || r.ttft50_lane1 > 0.0 || r.ttft50_lane2 > 0.0,
                "per-lane TTFT populated"
            );
        }
        for kind in crate::engine::policy::PolicyKind::ALL {
            assert!(
                rows.iter().any(|r| r.sched == kind.label()),
                "policy dimension must include {}",
                kind.label()
            );
        }
        // The EP dimension: 1-worker is EP-identity (ratio exactly 1,
        // no comm); 4-worker static exposes routing skew as a straggler
        // ratio > 1; load-aware never exceeds its in-run static
        // counterfactual on either straggler ratio or drop rate (the
        // shadow accounting makes both exact, not statistical).
        let ep_one = rows.iter().find(|r| r.ep_workers == 1).expect("1-worker EP row");
        assert_eq!(ep_one.ep_straggler_ratio, 1.0, "single worker is its own mean");
        assert_eq!(ep_one.ep_comm_secs, 0.0, "no AlltoAll inside one worker");
        let ep_static =
            rows.iter().find(|r| r.ep_workers == 4 && !r.ep_load_aware).expect("static EP row");
        let ep_aware =
            rows.iter().find(|r| r.ep_workers == 4 && r.ep_load_aware).expect("aware EP row");
        assert!(
            ep_static.ep_straggler_ratio > 1.0,
            "4-worker round-robin on skewed routing must straggle: {}",
            ep_static.ep_straggler_ratio
        );
        assert!(
            (ep_static.ep_straggler_ratio - ep_static.ep_straggler_ratio_static).abs() < 1e-12,
            "static run IS its own counterfactual"
        );
        assert!(
            ep_aware.ep_straggler_ratio <= ep_aware.ep_straggler_ratio_static + 1e-12,
            "load-aware must not worsen the straggler ratio: {} vs {}",
            ep_aware.ep_straggler_ratio,
            ep_aware.ep_straggler_ratio_static
        );
        assert!(
            ep_aware.ep_drop_rate <= ep_aware.ep_drop_rate_static + 1e-12,
            "load-aware only relaxes thresholds ⇒ drop rate ≤ static: {} vs {}",
            ep_aware.ep_drop_rate,
            ep_aware.ep_drop_rate_static
        );
        for r in &rows {
            if r.ep_workers > 0 {
                assert_eq!(r.ep_worker_busy_secs.len(), r.ep_workers);
                assert!(r.ep_worker_busy_secs.iter().all(|&b| b >= 0.0));
            } else {
                assert!(r.ep_worker_busy_secs.is_empty(), "EP columns zeroed when EP off");
                assert_eq!(r.ep_straggler_ratio, 0.0);
            }
        }
        // The chaos dimension: the fault row deterministically injects
        // (seeded plan, exec_p = 0.4 over dozens of draws) yet resolves
        // every request with a drained page pool (the conservation law
        // itself is asserted inside serve_opts); the degrade row's
        // unmeetable TTFT SLO must push the controller off level 0 and
        // the timeline must record the escalation.
        let chaos = rows.iter().find(|r| r.policy == "chaos").expect("chaos row");
        assert!(chaos.faults_injected > 0, "seeded plan must actually inject");
        assert!(
            chaos.faults_injected >= chaos.retries,
            "every retry answers an injected exec error ({} vs {})",
            chaos.faults_injected,
            chaos.retries
        );
        assert!(
            chaos.retries >= 2 * chaos.failed as u64,
            "a failed request first burned its whole retry budget"
        );
        assert_eq!(chaos.timed_out, 0, "no deadline configured on the chaos row");
        assert_eq!(chaos.cancelled, 0, "no cancellation configured on the chaos row");
        assert_eq!(chaos.degrade_level_max, 0, "no controller on the fault row");
        let deg = rows
            .iter()
            .find(|r| r.policy.starts_with("degrade:"))
            .expect("degrade row");
        assert!(deg.degrade_level_max >= 1, "unmeetable SLO must escalate the ladder");
        assert!(!deg.degrade_timeline.is_empty(), "level changes are timestamped");
        assert!(
            deg.degrade_timeline.iter().any(|&(_, lvl)| lvl == deg.degrade_level_max),
            "timeline reaches the recorded max level"
        );
        assert_eq!(deg.faults_injected, 0, "degrade row runs fault-free");
        // Past the knee (arrival ≥ 2× service rate) goodput is pinned at
        // service capacity: offering 4× instead of 2× must not raise it
        // (generous tolerance — these are measured wall-clock numbers).
        for kind in crate::engine::policy::PolicyKind::ALL {
            let gp = |mult: f64| -> f64 {
                rows.iter()
                    .find(|r| {
                        r.sched == kind.label()
                            && r.policy == "none"
                            && r.interleave
                            && (r.arrival_mult - mult).abs() < 1e-9
                    })
                    .expect("row present")
                    .goodput_rps
            };
            assert!(
                gp(4.0) <= gp(2.0) * 1.25,
                "{}: goodput grew past the knee: {} → {}",
                kind.label(),
                gp(2.0),
                gp(4.0)
            );
        }
        let out = std::env::temp_dir().join("dualsparse_serve_selftest.json");
        write_serve_json("mixtral_ish", true, base_rps, &rows, &out).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(j.get("runs").unwrap().as_arr().unwrap().len(), rows.len());
        let run0 = &j.get("runs").unwrap().as_arr().unwrap()[0];
        for field in [
            "sched",
            "goodput_rps",
            "p99_ttft",
            "rejected_queue_full",
            "interleave",
            "preemptions",
            "recompute_tokens",
            "page_utilization",
            "interleaved_prefill_steps",
            "ttft50_lane0",
            "ttft50_lane1",
            "ttft50_lane2",
            "ep_workers",
            "ep_load_aware",
            "ep_worker_busy_secs",
            "ep_straggler_ratio",
            "ep_straggler_ratio_static",
            "ep_imbalance_saved_secs",
            "ep_comm_secs",
            "ep_drop_rate",
            "ep_drop_rate_static",
            "ep_replications",
            "failed",
            "timed_out",
            "cancelled",
            "retries",
            "faults_injected",
            "degrade_level_max",
            "degrade_timeline",
            "ep_failovers",
        ] {
            assert!(run0.get(field).is_ok(), "SERVE_cpu.json runs must carry {field}");
        }
        assert!(j.get("max_queue_depth").is_ok());
        let _ = std::fs::remove_file(&out);
    }
}
