//! Pluggable scheduling policies + admission control for the
//! arrival-driven scheduler ([`crate::engine::scheduler`]).
//!
//! PR 4's scheduler admitted strictly FCFS and queued open-loop traffic
//! without bound. This module factors both decisions out of the serving
//! loop:
//!
//! * **Ordering** — a [`SchedulingPolicy`] picks which queued request is
//!   admitted into the next free KV slot. Three built-ins:
//!   [`Fcfs`] (arrival order — byte-for-byte the PR 4 behavior, pinned
//!   by `rust/tests/scheduler.rs`), [`ShortestPromptFirst`] (SJF on
//!   prompt length: short prefills stop head-of-line blocking under
//!   backlog, the dominant p99-TTFT lever the MoE-serving surveys
//!   identify), and [`PriorityLanes`] (strict priority lanes over the
//!   per-request [`crate::engine::scheduler::Request::priority`] field,
//!   arrival order within a lane).
//! * **Admission** — an [`AdmissionControl`] bound on the waiting
//!   queue. With `max_queue_depth = Some(k)`, a request arriving while
//!   `k` requests already wait is Rejected (`reason` = "queue full…")
//!   instead of queueing unboundedly, so open-loop overload reports
//!   **goodput vs offered load** (the knee of the SERVE_cpu.json
//!   curves) rather than an ever-growing queue.
//!
//! Policies see only a [`QueuedRequest`] snapshot per waiting request —
//! they cannot touch engine state — and return a *position in the
//! queue*, which keeps every implementation trivially correct: the
//! scheduler owns admission validation, slot accounting and the
//! lifecycle state machine regardless of pick order.
//!
//! The CLI face is [`PolicyKind`] (`--policy fcfs | spf | priority`);
//! library users can pass any `&dyn SchedulingPolicy` to
//! [`crate::engine::scheduler::serve_policy`].

use std::fmt;

use anyhow::{bail, Result};

/// What a [`SchedulingPolicy`] sees about one waiting request: an
/// immutable snapshot, not the request itself.
#[derive(Debug, Clone, Copy)]
pub struct QueuedRequest {
    /// Caller-assigned request id.
    pub id: usize,
    /// Prompt length in tokens (bytes, under the byte tokenizer).
    pub prompt_len: usize,
    /// Scheduling lane; higher = more urgent. 0 for legacy requests.
    pub priority: u8,
    /// Arrival time (seconds from run start; 0 in closed-loop mode).
    pub arrival: f64,
}

/// Admission-ordering policy: given the waiting queue (front = earliest
/// arrival), choose which request the scheduler admits into the next
/// free KV slot.
///
/// Implementations must be pure functions of the queue snapshot — the
/// scheduler may call `pick` any number of times per loop iteration and
/// relies on it for ordering only, never for admission validation
/// (oversized-prompt rejection and queue bounds stay in the scheduler).
pub trait SchedulingPolicy {
    /// Short stable name, used for report rows and JSON tags.
    fn name(&self) -> &'static str;

    /// Position in `queue` of the request to admit next. `queue` is
    /// never empty; an out-of-range return is clamped to the last
    /// element by the scheduler.
    fn pick(&self, queue: &[QueuedRequest]) -> usize;
}

/// First-come-first-served: admit the front of the queue. This is
/// exactly the PR 4 scheduler order — `serve_with` runs it, and the
/// legacy byte-for-byte pin tests in `rust/tests/scheduler.rs` hold
/// under it unchanged.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fcfs;

impl SchedulingPolicy for Fcfs {
    fn name(&self) -> &'static str {
        "fcfs"
    }

    fn pick(&self, _queue: &[QueuedRequest]) -> usize {
        0
    }
}

/// Shortest-prompt-first (SJF on prefill cost): admit the waiting
/// request with the smallest prompt; ties break toward the earliest
/// arrival. Long prompts can be deferred indefinitely under sustained
/// overload — pair with [`AdmissionControl`] or accept the starvation
/// tail (it is what buys the p99-TTFT win for everyone else).
#[derive(Debug, Clone, Copy, Default)]
pub struct ShortestPromptFirst;

impl SchedulingPolicy for ShortestPromptFirst {
    fn name(&self) -> &'static str {
        "spf"
    }

    fn pick(&self, queue: &[QueuedRequest]) -> usize {
        let mut best = 0usize;
        for (i, q) in queue.iter().enumerate().skip(1) {
            // strict `<` keeps the earliest arrival among equals (the
            // queue is arrival-ordered front to back).
            if q.prompt_len < queue[best].prompt_len {
                best = i;
            }
        }
        best
    }
}

/// Strict priority lanes: admit the highest-`priority` waiting request;
/// ties break toward the earliest arrival (FCFS within a lane). Lane
/// values come from [`crate::engine::scheduler::Request::priority`]
/// (higher = more urgent).
#[derive(Debug, Clone, Copy, Default)]
pub struct PriorityLanes;

impl SchedulingPolicy for PriorityLanes {
    fn name(&self) -> &'static str {
        "priority"
    }

    fn pick(&self, queue: &[QueuedRequest]) -> usize {
        let mut best = 0usize;
        for (i, q) in queue.iter().enumerate().skip(1) {
            // strict `>` keeps the earliest arrival within a lane.
            if q.priority > queue[best].priority {
                best = i;
            }
        }
        best
    }
}

/// The built-in policies as a CLI-facing enum (`--policy` on
/// `dualsparse serve`, the `sched` column of SERVE_cpu.json).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PolicyKind {
    /// [`Fcfs`] — the legacy order and the default.
    #[default]
    Fcfs,
    /// [`ShortestPromptFirst`].
    ShortestPromptFirst,
    /// [`PriorityLanes`].
    PriorityLanes,
}

impl PolicyKind {
    /// Every built-in, in report order.
    pub const ALL: [PolicyKind; 3] =
        [PolicyKind::Fcfs, PolicyKind::ShortestPromptFirst, PolicyKind::PriorityLanes];

    /// Parse a CLI spelling (`fcfs` | `spf` | `priority`).
    pub fn parse(spec: &str) -> Result<PolicyKind> {
        match spec {
            "fcfs" => Ok(PolicyKind::Fcfs),
            "spf" => Ok(PolicyKind::ShortestPromptFirst),
            "priority" => Ok(PolicyKind::PriorityLanes),
            _ => bail!("unknown scheduling policy {spec:?}; use fcfs | spf | priority"),
        }
    }

    /// The policy object behind this kind (all built-ins are stateless
    /// unit structs, so a `'static` borrow suffices).
    pub fn policy(&self) -> &'static dyn SchedulingPolicy {
        match self {
            PolicyKind::Fcfs => &Fcfs,
            PolicyKind::ShortestPromptFirst => &ShortestPromptFirst,
            PolicyKind::PriorityLanes => &PriorityLanes,
        }
    }

    /// Stable label (same string [`SchedulingPolicy::name`] returns).
    pub fn label(&self) -> &'static str {
        self.policy().name()
    }
}

impl fmt::Display for PolicyKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// Queue-bound admission control: how many requests may wait for a KV
/// slot before new arrivals are rejected.
///
/// The bound counts the *waiting* queue only — requests already holding
/// a slot (Prefill/Decode) are not counted. A request that arrives
/// while the queue holds `max_queue_depth` entries transitions
/// Queued → Rejected immediately (`reason` = "queue full…"), consumes
/// no KV slot, and shows up in
/// [`crate::engine::scheduler::ServeStats::rejected_queue_full`]. Note
/// the closed-loop corner: every request "arrives" at t = 0 in one
/// burst, before any admission, so a bounded closed-loop run completes
/// exactly `max_queue_depth` requests and rejects the rest — which is
/// what makes the overflow count exactly testable.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdmissionControl {
    /// Maximum waiting-queue depth; `None` = unbounded (the legacy PR 4
    /// behavior and the default).
    pub max_queue_depth: Option<usize>,
}

impl AdmissionControl {
    /// No queue bound (legacy behavior).
    pub fn unbounded() -> AdmissionControl {
        AdmissionControl { max_queue_depth: None }
    }

    /// Reject arrivals once `k` requests are already waiting.
    pub fn bounded(k: usize) -> AdmissionControl {
        AdmissionControl { max_queue_depth: Some(k) }
    }

    /// May a request enter a queue currently `depth` deep?
    pub fn admits(&self, depth: usize) -> bool {
        match self.max_queue_depth {
            Some(k) => depth < k,
            None => true,
        }
    }
}

/// One serving run's scheduling configuration: ordering policy +
/// admission control. `Default` is FCFS, unbounded — exactly PR 4.
#[derive(Debug, Clone, Copy, Default)]
pub struct SchedConfig {
    pub policy: PolicyKind,
    pub admission: AdmissionControl,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(entries: &[(usize, u8)]) -> Vec<QueuedRequest> {
        entries
            .iter()
            .enumerate()
            .map(|(i, &(len, pri))| QueuedRequest {
                id: i,
                prompt_len: len,
                priority: pri,
                arrival: i as f64,
            })
            .collect()
    }

    #[test]
    fn fcfs_always_picks_the_front() {
        let queue = q(&[(50, 0), (1, 9), (2, 3)]);
        assert_eq!(Fcfs.pick(&queue), 0);
        assert_eq!(Fcfs.name(), "fcfs");
    }

    #[test]
    fn spf_picks_shortest_with_fcfs_ties() {
        let queue = q(&[(50, 0), (4, 0), (90, 0), (4, 0)]);
        // two length-4 prompts: the earlier one (index 1) wins.
        assert_eq!(ShortestPromptFirst.pick(&queue), 1);
        let queue = q(&[(3, 0)]);
        assert_eq!(ShortestPromptFirst.pick(&queue), 0);
    }

    #[test]
    fn priority_lanes_pick_highest_with_fcfs_ties() {
        let queue = q(&[(10, 1), (10, 2), (10, 0), (10, 2)]);
        // two lane-2 requests: the earlier one (index 1) wins.
        assert_eq!(PriorityLanes.pick(&queue), 1);
        // all-equal lanes degenerate to FCFS.
        let queue = q(&[(10, 1), (9, 1), (8, 1)]);
        assert_eq!(PriorityLanes.pick(&queue), 0);
    }

    #[test]
    fn policy_kind_parses_and_labels() {
        assert_eq!(PolicyKind::parse("fcfs").unwrap(), PolicyKind::Fcfs);
        assert_eq!(PolicyKind::parse("spf").unwrap(), PolicyKind::ShortestPromptFirst);
        assert_eq!(PolicyKind::parse("priority").unwrap(), PolicyKind::PriorityLanes);
        assert!(PolicyKind::parse("lifo").is_err());
        for k in PolicyKind::ALL {
            assert_eq!(k.label(), k.policy().name());
            assert_eq!(format!("{k}"), k.label());
        }
        assert_eq!(PolicyKind::default(), PolicyKind::Fcfs);
    }

    #[test]
    fn admission_control_bounds_the_queue() {
        let open = AdmissionControl::unbounded();
        assert!(open.admits(0));
        assert!(open.admits(1_000_000));
        let tight = AdmissionControl::bounded(2);
        assert!(tight.admits(0));
        assert!(tight.admits(1));
        assert!(!tight.admits(2));
        assert!(!tight.admits(3));
        assert_eq!(AdmissionControl::default(), AdmissionControl::unbounded());
    }
}
