"""Layer-1 Pallas kernel: the dual-sparse SwiGLU expert FFN.

This is the paper's compute hot-spot (the grouped-GEMM the authors
optimize in Triton, §4.2 "we optimize the corresponding Triton kernel").
TPU adaptation (see DESIGN.md §Hardware-Adaptation): one Pallas program
instance per FFN tile; the token block [C, d_model] stays resident in
VMEM across the grid while W1/W3/W2 tiles stream HBM→VMEM; the partial
down-projection products are accumulated into the output block.

Dropping happens *outside* the kernel at tensor granularity: the Rust
coordinator packs kept token-expert pairs into capacity buckets and
invokes the (C, width) variant whose whole problem is smaller — so saved
work is a smaller GEMM, never a masked one. The "major-only" neuron-level
path is the same kernel with d_ffn halved.

Lowered with interpret=True: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO that runs anywhere.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# FFN tile width (lane dimension of one grid step). 128 matches the MXU
# systolic array edge; every artifact's d_ffn is a multiple of 64 and we
# shrink the tile for the narrow variants.
FFN_TILE = 128


def _ffn_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref):
    """One grid step: one [d_model, FT] slice of the hidden dimension.

    x_ref:  [C, d_model]   (whole token block, revisited every step)
    w1_ref: [d_model, FT]  gate-projection tile
    w3_ref: [d_model, FT]  up-projection tile
    w2_ref: [FT, d_model]  down-projection tile
    o_ref:  [C, d_model]   output accumulator (revisited every step)
    """
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    h = x @ w1_ref[...]
    gate = h * (1.0 / (1.0 + jnp.exp(-h)))  # Swish
    up = x @ w3_ref[...]
    o_ref[...] += (gate * up) @ w2_ref[...]


@functools.partial(jax.jit, static_argnames=("ffn_tile",))
def swiglu_ffn(x, w1, w3, w2, ffn_tile=None):
    """Pallas dual-sparse expert FFN. Shapes as in ref.swiglu_ffn_ref.

    The grid runs over d_ffn tiles; d_ffn must divide evenly by the tile.
    """
    c, d_model = x.shape
    d_ffn = w1.shape[1]
    ft = ffn_tile or min(FFN_TILE, d_ffn)
    assert d_ffn % ft == 0, f"d_ffn={d_ffn} not a multiple of tile {ft}"
    grid = (d_ffn // ft,)
    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, d_model), lambda j: (0, 0)),
            pl.BlockSpec((d_model, ft), lambda j: (0, j)),
            pl.BlockSpec((d_model, ft), lambda j: (0, j)),
            pl.BlockSpec((ft, d_model), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((c, d_model), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, d_model), x.dtype),
        interpret=True,
    )(x, w1, w3, w2)


def _ffn_kernel_tokens(x_ref, w1_ref, w3_ref, w2_ref, o_ref):
    """Variant with a 2-D grid (token tile × FFN tile) for large C.

    Token tiles are the *parallel* dimension, FFN tiles the accumulation
    dimension; on real TPU hardware this is the double-bufferable
    schedule (weights stream while the MXU chews the previous tile).
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    h = x @ w1_ref[...]
    gate = h * (1.0 / (1.0 + jnp.exp(-h)))
    up = x @ w3_ref[...]
    o_ref[...] += (gate * up) @ w2_ref[...]


@functools.partial(jax.jit, static_argnames=("token_tile", "ffn_tile"))
def swiglu_ffn_tiled(x, w1, w3, w2, token_tile=32, ffn_tile=None):
    """2-D-grid version used for the large capacity buckets (C >= 64)."""
    c, d_model = x.shape
    d_ffn = w1.shape[1]
    ft = ffn_tile or min(FFN_TILE, d_ffn)
    tt = min(token_tile, c)
    assert c % tt == 0 and d_ffn % ft == 0
    grid = (c // tt, d_ffn // ft)
    return pl.pallas_call(
        _ffn_kernel_tokens,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tt, d_model), lambda i, j: (i, 0)),
            pl.BlockSpec((d_model, ft), lambda i, j: (0, j)),
            pl.BlockSpec((d_model, ft), lambda i, j: (0, j)),
            pl.BlockSpec((ft, d_model), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tt, d_model), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, d_model), x.dtype),
        interpret=True,
    )(x, w1, w3, w2)


def ffn_for_capacity(c):
    """Pick the kernel variant for a capacity bucket (see DESIGN.md §6)."""
    if c >= 64:
        return swiglu_ffn_tiled
    return swiglu_ffn
