//! Pure-Rust reference math over host tensors.
//!
//! These are the shared kernels behind the `CpuRef` backend
//! (`runtime::cpu`) — the hermetic serving hot path when no AOT
//! artifacts exist — and are also used by property tests
//! (partition/reconstruction invariants), baseline weight surgery
//! (Wanda 2:4), and cross-checking artifact outputs without a Python
//! round trip.

use crate::model::Tensor;

/// C = A[m,k] @ B[k,n] (naive; test-scale sizes only).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape.len(), 2);
    assert_eq!(b.shape.len(), 2);
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "matmul shape mismatch");
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a.data[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    Tensor::new(vec![m, n], out)
}

/// C = A[m,k] @ B[n,k]ᵀ (B is accessed row-wise — the tied-embedding
/// LM head projects onto `emb` rows without materializing a transpose).
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape.len(), 2);
    assert_eq!(b.shape.len(), 2);
    let (m, k) = (a.shape[0], a.shape[1]);
    let (n, k2) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "matmul_bt shape mismatch");
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b.data[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            out[i * n + j] = acc;
        }
    }
    Tensor::new(vec![m, n], out)
}

pub fn swish(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// SwiGLU FFN (paper Eq. 4) over host tensors.
pub fn swiglu_ffn(x: &Tensor, w1: &Tensor, w3: &Tensor, w2: &Tensor) -> Tensor {
    let gate = matmul(x, w1);
    let up = matmul(x, w3);
    let h: Vec<f32> = gate
        .data
        .iter()
        .zip(&up.data)
        .map(|(&g, &u)| swish(g) * u)
        .collect();
    matmul(&Tensor::new(gate.shape.clone(), h), w2)
}

/// Row-wise softmax of a 2-D tensor.
pub fn softmax_rows(x: &Tensor) -> Tensor {
    let (m, n) = (x.shape[0], x.shape[1]);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let row = &x.data[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for j in 0..n {
            let e = (row[j] - mx).exp();
            out[i * n + j] = e;
            sum += e;
        }
        for j in 0..n {
            out[i * n + j] /= sum;
        }
    }
    Tensor::new(vec![m, n], out)
}

/// RMSNorm with gain g (matches `python/compile/model.py::rmsnorm`).
pub fn rmsnorm_rows(x: &Tensor, g: &[f32]) -> Tensor {
    let (m, n) = (x.shape[0], x.shape[1]);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let row = &x.data[i * n..(i + 1) * n];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / n as f32;
        let scale = 1.0 / (ms + 1e-6).sqrt();
        for j in 0..n {
            out[i * n + j] = row[j] * scale * g[j];
        }
    }
    Tensor::new(vec![m, n], out)
}

/// Elementwise a + b.
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape, b.shape);
    Tensor::new(
        a.shape.clone(),
        a.data.iter().zip(&b.data).map(|(x, y)| x + y).collect(),
    )
}

/// a + k * b (scaled accumulate, used for gating-weighted expert sums).
pub fn add_scaled(a: &mut Tensor, b: &Tensor, k: f32) {
    assert_eq!(a.shape, b.shape);
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x += k * y;
    }
}

/// Max absolute difference between two tensors.
pub fn max_abs_diff(a: &Tensor, b: &Tensor) -> f32 {
    assert_eq!(a.shape, b.shape);
    a.data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(vec![2, 2], vec![1., 0., 0., 1.]);
        assert_eq!(matmul(&a, &b).data, a.data);
        let c = matmul(&a, &a);
        assert_eq!(c.data, vec![7., 10., 15., 22.]);
    }

    #[test]
    fn matmul_bt_matches_explicit_transpose() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(vec![2, 3], vec![1., 0., 1., 0., 1., 0.]);
        // bᵀ is [[1,0],[0,1],[1,0]] → a@bᵀ = [[4,2],[10,5]]
        assert_eq!(matmul_bt(&a, &b).data, vec![4., 2., 10., 5.]);
        assert_eq!(matmul_bt(&a, &b).shape, vec![2, 2]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = Tensor::new(vec![2, 3], vec![1., 2., 3., -1., 0., 1.]);
        let s = softmax_rows(&x);
        for i in 0..2 {
            let sum: f32 = s.data[i * 3..(i + 1) * 3].iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn swish_values() {
        assert!((swish(0.0) - 0.0).abs() < 1e-9);
        assert!((swish(10.0) - 10.0 / (1.0 + (-10.0f32).exp())).abs() < 1e-6);
    }

    #[test]
    fn rmsnorm_unit_gain() {
        let x = Tensor::new(vec![1, 2], vec![3.0, 4.0]);
        let y = rmsnorm_rows(&x, &[1.0, 1.0]);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        let rms = (12.5f32 + 1e-6).sqrt();
        assert!((y.data[0] - 3.0 / rms).abs() < 1e-5);
    }

    #[test]
    fn add_scaled_accumulates() {
        let mut a = Tensor::new(vec![2], vec![1.0, 1.0]);
        let b = Tensor::new(vec![2], vec![2.0, 4.0]);
        add_scaled(&mut a, &b, 0.5);
        assert_eq!(a.data, vec![2.0, 3.0]);
    }
}
