//! Accuracy experiments: Fig. 7, Table 1, Table 2, Table 3.

use std::path::Path;

use anyhow::Result;

use super::{
    acc_json, eval_with_rate, eval_with_rate_shift, find_threshold, mk_engine,
    mk_engine_reconstructed, save_result,
};
use crate::baselines;
use crate::engine::{Engine, EngineOptions, RouterMode};
use crate::moe::DropPolicy;
use crate::server::{run_once, workload};
use crate::tasks::eval::{avg_accuracy, format_row};
use crate::util::json::{num, obj, s, Json};

/// Fig. 7 — 1T-Drop threshold sweep on the OLMoE stand-in: accuracy per
/// task + computation drop rate.
pub fn fig7(artifacts: &Path) -> Result<()> {
    let model = "olmoe_ish";
    let thresholds = [0.0f32, 0.02, 0.05, 0.08, 0.12, 0.16, 0.20, 0.25, 0.30];
    println!("Fig.7 — 1T-Drop threshold sweep ({model})");
    let mut records = Vec::new();
    let mut engine = mk_engine(artifacts, model, DropPolicy::NoDrop)?;
    for &t in &thresholds {
        engine.policy = if t == 0.0 {
            DropPolicy::NoDrop
        } else {
            DropPolicy::OneT(t)
        };
        let (res, rate) = eval_with_rate(&mut engine)?;
        println!(
            "T={t:.2} drop={:>5.1}%  {}",
            100.0 * rate,
            format_row(&format!("1T@{t:.2}"), &res)
        );
        records.push(acc_json(&format!("T={t:.2}"), rate, &res));
    }
    save_result(artifacts, "fig7", Json::Arr(records))?;
    println!("(paper: small thresholds can improve accuracy; large ones degrade,\n\
              with the math-reasoning task most sensitive)");
    Ok(())
}

/// Table 1 — expert partition consistency + fine-tuned model quality.
pub fn table1(artifacts: &Path) -> Result<()> {
    println!("Table 1 — expert partition (complete transformation) on mixtral_ish");
    let mut records = Vec::new();

    // Pre-trained model, original routing.
    let mut e0 = mk_engine(artifacts, "mixtral_ish", DropPolicy::NoDrop)?;
    let (r0, _) = eval_with_rate(&mut e0)?;
    println!("{}", format_row("pretrained 2/8", &r0));
    records.push(acc_json("pretrained 2/8", 0.0, &r0));

    // Same weights served through the partial-transformation split
    // (every expert executed as major+minor sub-experts with repeated
    // scores) — Eq. 13 says accuracy must match the row above.
    let mut e_split = mk_engine(artifacts, "mixtral_ish", DropPolicy::NoDrop)?;
    e_split.force_split = true;
    let (r1, _) = eval_with_rate(&mut e_split)?;
    println!("{}", format_row("partitioned 4/16 (P=2)", &r1));
    records.push(acc_json("partitioned 4/16 (P=2)", 0.0, &r1));
    let diff = (avg_accuracy(&r0) - avg_accuracy(&r1)).abs();
    println!("  consistency |Δavg| = {diff:.2} (paper: ~0, fp noise only)");

    // Fine-tuned originals vs fine-tuned partitioned models (Fig. 4 runs).
    for (name, label) in [
        ("mixtral_ish_p1_ft", "fine-tuned 2/8"),
        ("mixtral_ish_p2_ft", "fine-tuned 4/16 (P=2)"),
        ("mixtral_ish_p4_ft", "fine-tuned 8/32 (P=4)"),
    ] {
        let mut e = mk_engine(artifacts, name, DropPolicy::NoDrop)?;
        // fine-tuned models are benchmarked on their fine-tuning
        // (shifted) distribution — see eval_with_rate_shift docs.
        let (r, _) = eval_with_rate_shift(&mut e, true)?;
        println!("{}", format_row(label, &r));
        records.push(acc_json(label, 0.0, &r));
    }

    // 1T-Drop on the fine-tuned partitioned models (paper's last block).
    for (name, label, target) in [
        ("mixtral_ish_p1_ft", "ft 2/8 + 1T", 0.20),
        ("mixtral_ish_p2_ft", "ft 4/16 + 1T", 0.21),
        ("mixtral_ish_p4_ft", "ft 8/32 + 1T", 0.24),
    ] {
        let t = find_threshold(artifacts, name, target)?;
        let mut e = mk_engine(artifacts, name, DropPolicy::OneT(t))?;
        let (r, rate) = eval_with_rate_shift(&mut e, true)?;
        println!(
            "{}  (T¹={t:.3}, drop={:.1}%)",
            format_row(label, &r),
            100.0 * rate
        );
        records.push(acc_json(label, rate, &r));
    }
    save_result(artifacts, "table1", Json::Arr(records))?;
    Ok(())
}

/// Table 2 — drop-method comparison on the three models.
pub fn table2(artifacts: &Path) -> Result<()> {
    println!("Table 2 — No-drop / 1T / 2T(partition) / 2T(reconstruct)");
    let mut records = Vec::new();
    // The paper's Mixtral rows use the fine-tuned 8/32 (P=4) variant —
    // finer tensor-level granularity is what makes ~24% dropping cheap.
    for (model, target, metric) in [
        ("mixtral_ish_p4_ft", 0.24, "abs_gate"),
        ("olmoe_ish", 0.22, "abs_gate"),
        ("deepseek_ish", 0.27, "abs_gate_up"),
    ] {
        println!("--- {model} ---");
        // fine-tuned models evaluate on their fine-tuning distribution
        let shift = model.ends_with("_ft");
        let t1 = find_threshold(artifacts, model, target)?;

        let mut e = mk_engine(artifacts, model, DropPolicy::NoDrop)?;
        let (r, rate) = eval_with_rate_shift(&mut e, shift)?;
        let base_avg = avg_accuracy(&r);
        println!("{}", format_row("No Drop", &r));
        records.push(acc_json(&format!("{model}/no_drop"), rate, &r));

        e.policy = DropPolicy::OneT(t1);
        let (r, rate) = eval_with_rate_shift(&mut e, shift)?;
        println!("{} (T¹={t1:.3}, drop={:.1}%)", format_row("1T-Drop", &r), 100.0 * rate);
        records.push(acc_json(&format!("{model}/1t"), rate, &r));

        // 2T with contiguous partition halves (no reconstruction).
        e.policy = DropPolicy::two_t(t1);
        let (r, rate) = eval_with_rate_shift(&mut e, shift)?;
        println!("{} (drop={:.1}%)", format_row("2T (partition)", &r), 100.0 * rate);
        records.push(acc_json(&format!("{model}/2t_partition"), rate, &r));

        // 2T with importance reconstruction.
        let mut er = mk_engine_reconstructed(
            artifacts, model, DropPolicy::two_t(t1), metric,
        )?;
        let (r, rate) = eval_with_rate_shift(&mut er, shift)?;
        let rec_avg = avg_accuracy(&r);
        println!("{} (drop={:.1}%)", format_row("2T (reconstruct)", &r), 100.0 * rate);
        records.push(acc_json(&format!("{model}/2t_reconstruct"), rate, &r));
        println!(
            "  Δavg vs no-drop: {:+.2} (paper: −0.08…−0.28 at ~25% drop)",
            rec_avg - base_avg
        );
    }
    save_result(artifacts, "table2", Json::Arr(records))?;
    Ok(())
}

/// Table 3 — comparison with EES / EEP / Wanda on the Mixtral stand-in.
pub fn table3(artifacts: &Path) -> Result<()> {
    let model = "mixtral_ish";
    println!("Table 3 — vs prior work ({model}; 'add' task = GSM8K stand-in)");
    let reqs = workload(60, 12, 42);
    let mut records = Vec::new();

    // helper: evaluate accuracy on the math task + measure speedup.
    let run_row = |label: &str,
                       engine: &mut Engine,
                       memory_saving: f64,
                       records: &mut Vec<Json>|
     -> Result<(f64, f64, f64)> {
        let (res, _) = eval_with_rate(engine)?;
        let math = res.iter().find(|r| r.task == "add").unwrap().accuracy;
        let avg = avg_accuracy(&res);
        let rep = run_once(engine, &reqs, engine.policy, label)?;
        records.push(obj(vec![
            ("label", s(label)),
            ("memory_saving", num(memory_saving)),
            ("math_acc", num(math)),
            ("avg_acc", num(avg)),
            ("moe_secs", num(rep.stats.moe_secs)),
            ("e2e_secs", num(rep.stats.artifact_secs)),
        ]));
        Ok((math, avg, rep.stats.moe_secs))
    };

    let t1 = find_threshold(artifacts, model, 0.24)?;

    let mut base = mk_engine(artifacts, model, DropPolicy::NoDrop)?;
    let (math0, avg0, moe0) = run_row("No Drop (baseline)", &mut base, 0.0, &mut records)?;

    let mut rows = Vec::new();
    // 2T partition + reconstruct
    let mut e = mk_engine(artifacts, model, DropPolicy::two_t(t1))?;
    let (m, a, t) = run_row("2T-Drop (partition)", &mut e, 0.0, &mut records)?;
    rows.push(("2T-Drop (partition)", 0.0, m, a, t));
    let mut e = mk_engine_reconstructed(artifacts, model, DropPolicy::two_t(t1), "abs_gate")?;
    let (m, a, t) = run_row("2T-Drop (reconstruct)", &mut e, 0.0, &mut records)?;
    rows.push(("2T-Drop (reconstruct)", 0.0, m, a, t));

    // EES
    let mut e = mk_engine(artifacts, model, DropPolicy::NoDrop)?;
    let beta = baselines::calibrate_ees_beta(&mut e, 1024)?;
    e.router_mode = RouterMode::Ees { beta };
    let (m, a, t) = run_row("EES", &mut e, 0.0, &mut records)?;
    rows.push(("EES", 0.0, m, a, t));

    // EEP r=6 and r=4, each alone and + EES
    for r_kept in [6usize, 4] {
        let mut e = mk_engine(artifacts, model, DropPolicy::NoDrop)?;
        let kept = baselines::calibrate_eep_kept(&mut e, 1024, r_kept)?;
        let mem = baselines::eep_memory_saving(e.cfg.n_experts, r_kept);
        e.router_mode = RouterMode::Eep { kept: kept.clone() };
        let label = format!("EEP (r={r_kept})");
        let (m, a, t) = run_row(&label, &mut e, mem, &mut records)?;
        rows.push((Box::leak(label.into_boxed_str()), mem, m, a, t));

        let mut e2 = mk_engine(artifacts, model, DropPolicy::NoDrop)?;
        e2.router_mode = RouterMode::Eep { kept };
        let beta2 = baselines::calibrate_ees_beta(&mut e2, 1024)?;
        e2.router_mode = match &e2.router_mode {
            RouterMode::Eep { kept } => RouterMode::EepEes {
                kept: kept.clone(),
                beta: beta2,
            },
            _ => unreachable!(),
        };
        let label = format!("EEP (r={r_kept}) + EES");
        let (m, a, t) = run_row(&label, &mut e2, mem, &mut records)?;
        rows.push((Box::leak(label.into_boxed_str()), mem, m, a, t));
    }

    // Wanda 2:4 (accuracy impact only — dense kernels gain nothing).
    let mut w = crate::model::Weights::load(&artifacts.join("models"), model)?;
    baselines::apply_wanda_2_4(&mut w)?;
    let mut e = Engine::from_weights(
        artifacts, w, DropPolicy::NoDrop, EngineOptions::default(),
    )?;
    let (m, a, t) = run_row("Wanda 2:4", &mut e, 0.0, &mut records)?;
    rows.push(("Wanda 2:4", 0.5, m, a, t));

    println!(
        "\n{:<24} {:>7} {:>9} {:>12} {:>10}",
        "method", "mem", "speedup", "math Δacc", "avg Δacc"
    );
    for (label, mem, math, avg, moe_t) in rows {
        println!(
            "{label:<24} {:>6.0}% {:>8.2}x {:>+11.1}% {:>+9.1}%",
            100.0 * mem,
            crate::util::stats::speedup_ratio(moe0, moe_t),
            math - math0,
            avg - avg0,
        );
    }
    save_result(artifacts, "table3", Json::Arr(records))?;
    Ok(())
}
