"""L2 model consistency: the per-artifact serving decomposition must
reproduce the dense training forward token-for-token — this is what
makes the Rust engine's accuracy meaningful."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model
from compile.kernels import ref

CFG = configs.ModelConfig(name="t", n_layers=2, n_experts=4, d_ffn=32, top_k=2)
DS_CFG = configs.ModelConfig(
    name="ds", n_layers=2, n_experts=4, d_ffn=32, top_k=2,
    n_shared=1, d_ffn_shared=64,
)


def serving_forward(params, tokens, cfg):
    """Mirror of the Rust engine's layer loop, built from the serve_*
    functions (prefill path, one request)."""
    s = len(tokens)
    x = params["emb"][jnp.asarray(tokens)] + params["pos"][:s]
    for layer in params["layers"]:
        y, ln2x, _, _ = model.serve_attn_prefill(
            x, layer["ln1"], layer["wq"], layer["wk"], layer["wv"],
            layer["wo"], layer["ln2"], n_heads=cfg.n_heads, d_head=cfg.d_head,
        )
        probs = model.serve_gate(ln2x, layer["wg"])
        moe = jnp.zeros_like(x)
        mask = ref.topk_mask_ref(probs, cfg.top_k)
        g = probs * mask
        for e in range(cfg.n_experts):
            fe = model.serve_ffn(ln2x, layer["w1"][e], layer["w3"][e], layer["w2"][e])
            moe = moe + g[:, e:e + 1] * fe
        if cfg.n_shared:
            moe = moe + model.serve_ffn(ln2x, layer["sw1"], layer["sw3"], layer["sw2"])
        x = y + moe
    return model.serve_lm_head(x, params["lnf"], params["emb"])


@pytest.mark.parametrize("cfg", [CFG, DS_CFG], ids=["plain", "shared"])
def test_serving_matches_dense_forward(cfg):
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    tokens = [104, 105, 33, 97, 98, 99]
    dense_logits, _ = model.forward_train(
        params, jnp.asarray([tokens]), cfg
    )
    serve_logits = serving_forward(params, tokens, cfg)
    np.testing.assert_allclose(
        serve_logits, dense_logits[0], rtol=2e-4, atol=2e-4
    )


def test_decode_step_matches_prefill():
    """attn_step with a cache must agree with attn_prefill at the last
    position (the KV-cache correctness property)."""
    cfg = CFG
    params = model.init_params(jax.random.PRNGKey(1), cfg)
    layer = params["layers"][0]
    s = 5
    x = jax.random.normal(jax.random.PRNGKey(2), (s, cfg.d_model)) * 0.5
    y_all, ln2_all, ks, vs = model.serve_attn_prefill(
        x, layer["ln1"], layer["wq"], layer["wk"], layer["wv"], layer["wo"],
        layer["ln2"], n_heads=cfg.n_heads, d_head=cfg.d_head,
    )
    # decode path: cache holds positions 0..s-1, current token is row s-1
    t = cfg.max_seq
    kc = jnp.zeros((1, cfg.n_heads, t, cfg.d_head))
    vc = jnp.zeros((1, cfg.n_heads, t, cfg.d_head))
    kc = kc.at[0, :, : s - 1].set(jnp.transpose(ks[: s - 1], (1, 0, 2)))
    vc = vc.at[0, :, : s - 1].set(jnp.transpose(vs[: s - 1], (1, 0, 2)))
    y1, ln21, nk, nv = model.serve_attn_step(
        x[s - 1: s], layer["ln1"], layer["wq"], layer["wk"], layer["wv"],
        layer["wo"], layer["ln2"], kc, vc, jnp.asarray([s - 1], jnp.int32),
        n_heads=cfg.n_heads, d_head=cfg.d_head,
    )
    np.testing.assert_allclose(y1[0], y_all[s - 1], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(ln21[0], ln2_all[s - 1], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(nk[0], ks[s - 1], rtol=2e-4, atol=2e-4)


def test_attn_step_padding_rows_are_safe():
    """Rows with pos=0 over a zero cache must produce finite output
    (the engine pads decode batches to the bucket size)."""
    cfg = CFG
    params = model.init_params(jax.random.PRNGKey(3), cfg)
    layer = params["layers"][0]
    t = cfg.max_seq
    x = jnp.zeros((2, cfg.d_model))
    kc = jnp.zeros((2, cfg.n_heads, t, cfg.d_head))
    vc = jnp.zeros((2, cfg.n_heads, t, cfg.d_head))
    y, ln2x, _, _ = model.serve_attn_step(
        x, layer["ln1"], layer["wq"], layer["wk"], layer["wv"], layer["wo"],
        layer["ln2"], kc, vc, jnp.asarray([0, 0], jnp.int32),
        n_heads=cfg.n_heads, d_head=cfg.d_head,
    )
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(ln2x).all())


def test_gate_rows_sum_to_one():
    x = jax.random.normal(jax.random.PRNGKey(4), (8, CFG.d_model))
    wg = jax.random.normal(jax.random.PRNGKey(5), (CFG.d_model, CFG.n_experts))
    probs = model.serve_gate(x, wg)
    np.testing.assert_allclose(probs.sum(-1), jnp.ones(8), rtol=1e-5)


def test_loss_decreases_quickly():
    """Three Adam steps on a repeating batch must reduce the loss —
    smoke test for the gradient path (incl. the one-hot CE and the
    stop-gradient top-k mask)."""
    from compile import train as trainer

    cfg = CFG
    params = model.init_params(jax.random.PRNGKey(6), cfg)
    batch = jnp.tile(jnp.arange(16, dtype=jnp.int32)[None] % 255, (4, 1))
    l0 = float(model.loss_fn(params, batch, cfg, 0.01)[0])
    opt = trainer._adam_init(params)
    for _ in range(5):
        (loss, _), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch, cfg, 0.01
        )
        params, opt = trainer._adam_update(params, grads, opt, 1e-2)
    l1 = float(model.loss_fn(params, batch, cfg, 0.01)[0])
    assert l1 < l0


def test_aux_loss_balanced_value():
    """For near-uniform routing the Switch aux ≈ top_k."""
    cfg = CFG
    params = model.init_params(jax.random.PRNGKey(8), cfg)
    toks = (jnp.arange(64, dtype=jnp.int32) * 7 % 255).reshape(2, 32)
    _, aux = model.forward_train(params, toks, cfg)
    # fresh random gates route nearly uniformly
    assert 0.5 * cfg.top_k < float(aux) < 2.0 * cfg.top_k
