//! Scoped worker threads for the CPU hot path.
//!
//! The engine parallelizes *independent* units of work (per-expert
//! sub-expert calls, per-head prefill attention, row blocks of large
//! GEMMs) with [`parallel_map`]: each index is computed exactly as in
//! the serial path and results are merged in index order, so outputs
//! are **bit-identical for every thread count** — `DUALSPARSE_THREADS=1`
//! and `=8` produce byte-identical generations.
//!
//! Thread-count resolution (first match wins):
//! 1. [`set_thread_override`] (programmatic; the bench harness sweeps it),
//! 2. the `DUALSPARSE_THREADS` env var,
//! 3. `std::thread::available_parallelism()`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Programmatic thread-count override; 0 means "not set".
static OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Env/auto default, resolved once per process — `num_threads()` sits
/// on the per-GEMM hot path and must not take the env lock each call.
static DEFAULT: OnceLock<usize> = OnceLock::new();

/// Override the worker thread count for subsequent [`parallel_map`]
/// calls (`None` restores env-var / auto detection). Used by the bench
/// harness to sweep thread counts inside one process.
pub fn set_thread_override(n: Option<usize>) {
    OVERRIDE.store(n.unwrap_or(0), Ordering::SeqCst);
}

/// Worker thread count for the CPU hot path (always ≥ 1). The
/// `DUALSPARSE_THREADS` env var is read once per process.
pub fn num_threads() -> usize {
    let o = OVERRIDE.load(Ordering::SeqCst);
    if o > 0 {
        return o;
    }
    *DEFAULT.get_or_init(|| {
        if let Ok(v) = std::env::var("DUALSPARSE_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n > 0 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// Compute `f(0), f(1), …, f(n-1)` on a scoped worker pool and return
/// the results in index order.
///
/// Work is distributed dynamically (an atomic next-index counter), the
/// calling thread participates as a worker, and every `f(i)` is
/// computed exactly once — so the result is independent of the thread
/// count and identical to the serial `(0..n).map(f)`.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
    for _ in 0..n {
        slots.push(None);
    }
    let worker = |local: &mut Vec<(usize, T)>| loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            break;
        }
        local.push((i, f(i)));
    };
    std::thread::scope(|scope| {
        // threads - 1 spawned workers; the calling thread pulls too.
        let handles: Vec<_> = (1..threads)
            .map(|_| {
                scope.spawn(|| {
                    let mut local = Vec::new();
                    worker(&mut local);
                    local
                })
            })
            .collect();
        let mut local = Vec::new();
        worker(&mut local);
        for (i, v) in local {
            slots[i] = Some(v);
        }
        for h in handles {
            for (i, v) in h.join().expect("worker thread panicked") {
                slots[i] = Some(v);
            }
        }
    });
    slots
        .into_iter()
        .map(|s| s.expect("every index computed exactly once"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn num_threads_is_positive() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn parallel_map_matches_serial_at_any_thread_count() {
        let want: Vec<usize> = (0..97).map(|i| i * i).collect();
        for t in [1usize, 2, 4, 8] {
            set_thread_override(Some(t));
            let got = parallel_map(97, |i| i * i);
            assert_eq!(got, want, "threads={t}");
        }
        set_thread_override(None);
    }

    #[test]
    fn parallel_map_handles_edge_sizes() {
        set_thread_override(Some(4));
        assert_eq!(parallel_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(1, |i| i + 10), vec![10]);
        set_thread_override(None);
    }
}
