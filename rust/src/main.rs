//! `dualsparse` — CLI for the DualSparse-MoE serving stack.
//!
//! Subcommands:
//!   serve [model] [--policy fcfs|spf|priority] [--drop none|1t:<T>|2t:<T>]
//!         [--neuron-keep F] [--quant]          neuron-level sparsity: keep
//!                                            the top-F probe-ranked FFN
//!                                            neurons (needs `calibrate`
//!                                            tables when F < 1.0) / int8
//!                                            quantized kernels (CpuRef)
//!         [--max-queue N] [--reqs N] [--max-new N]
//!         [--mode closed|open] [--rate R] [--seed S]
//!         [--page-size P] [--kv-pages N] [--preempt]
//!         [--age-boost SECS] [--no-interleave]
//!         [--ep-workers N] [--ep-load-aware]
//!         [--ep-replicate-after K]
//!         [--faults SPEC] [--retries N] [--deadline-ms MS]
//!         [--slo-ttft-ms MS [--slo-queue-depth N]]       one measured run
//!         (SPEC grammar: exec=P,spike=P:MS,pressure=P:PAGES[:HOLD],
//!          ep-fail=W@STEP,ep-slow=W@FACTOR,cancel=P — seeded by --seed)
//!         [--sweep | --quick] [--out PATH]   arrival-rate × drop × sched
//!                                            sweep → SERVE_cpu.json
//!         (--policy also filters --sweep/--quick to one scheduling
//!          policy; legacy `--policy none|1t:<T>|2t:<T>` still parses
//!          as a drop policy for back-compat)
//!         [--listen HOST:PORT [--conn-queue N]
//!          [--max-frame-bytes B]]             network front end: NDJSON
//!                                            `generate` frames in,
//!                                            per-token frames out; runs
//!                                            until a `shutdown` frame
//!                                            (excludes --sweep/--quick
//!                                            and --mode/--rate/--reqs)
//!   client --connect HOST:PORT [--reqs N] [--max-new N] [--seed S]
//!          [--shutdown]                       loopback NDJSON client
//!                                            driver (the net-smoke CI
//!                                            counterpart of --listen)
//!   eval <model> [--policy …] [--reconstruct] [--n N]
//!        [--neuron-keep F] [--quant]
//!   calibrate <model> [--tokens N]
//!   bench [--quick] [--model M] [--out PATH]   (writes BENCH_cpu.json:
//!                                            policy sweep + neuron-keep ×
//!                                            quant ladder)
//!   exp <fig1|fig4|fig6|fig7|fig9|fig10|fig11|fig12|fig13|table1|table2|table3|all>
//!   info
//!
//! Artifacts are resolved from ./artifacts (override: DUALSPARSE_ARTIFACTS).
//! Worker threads for the CPU hot path: DUALSPARSE_THREADS (default:
//! available parallelism).
//! Serving architecture and report schemas: docs/ARCHITECTURE.md and
//! docs/REPORTS.md.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use dualsparse::engine::faults::{DegradeController, FaultPlan};
use dualsparse::engine::policy::{AdmissionControl, AgingConfig, PolicyKind, SchedConfig};
use dualsparse::engine::scheduler::ArrivalMode;
use dualsparse::engine::{artifacts_dir, EngineOptions, EpOptions};
use dualsparse::moe::DropPolicy;
use dualsparse::runtime::Backend as _;
use dualsparse::tasks::eval::{evaluate, format_row};
use dualsparse::{calib, experiments, server, Engine};

fn parse_policy(spec: &str) -> Result<DropPolicy> {
    if spec == "none" {
        return Ok(DropPolicy::NoDrop);
    }
    if let Some(t) = spec.strip_prefix("1t:") {
        return Ok(DropPolicy::OneT(t.parse().context("bad 1t threshold")?));
    }
    if let Some(t) = spec.strip_prefix("2t:") {
        return Ok(DropPolicy::two_t(t.parse().context("bad 2t threshold")?));
    }
    bail!("unknown policy {spec:?}; use none | 1t:<T> | 2t:<T>")
}

/// Split `serve`'s flags into (scheduling policy, drop policy):
/// `--policy` takes the scheduling spelling (`fcfs|spf|priority`) but
/// still accepts the legacy drop grammar (`none|1t:<T>|2t:<T>`) it
/// meant before PR 5; `--drop` is the explicit drop-policy flag and
/// wins over a legacy `--policy` value.
fn parse_serve_policies(
    policy_flag: Option<&str>,
    drop_flag: Option<&str>,
) -> Result<(Option<PolicyKind>, DropPolicy)> {
    let mut drop = match drop_flag {
        Some(spec) => Some(parse_policy(spec)?),
        None => None,
    };
    let mut sched = None;
    if let Some(spec) = policy_flag {
        match PolicyKind::parse(spec) {
            Ok(k) => sched = Some(k),
            Err(_) if parse_policy(spec).is_ok() => {
                // legacy spelling: `--policy 2t:0.15` etc.
                if drop.is_none() {
                    drop = Some(parse_policy(spec)?);
                }
            }
            Err(e) => {
                return Err(e.context(
                    "--policy takes fcfs | spf | priority (or a legacy \
                     drop spec none | 1t:<T> | 2t:<T>)",
                ))
            }
        }
    }
    Ok((sched, drop.unwrap_or(DropPolicy::NoDrop)))
}

/// Parse the neuron-level sparsity flags shared by `serve` and `eval`:
/// `--neuron-keep F` (kept fraction of probe-ranked FFN neurons,
/// strictly validated to `0.0..=1.0` — a typo'd fraction must not
/// silently serve dense) and the bare `--quant` switch.
fn parse_neuron_flags(args: &Args) -> Result<(Option<f32>, bool)> {
    let keep = match args.flag("neuron-keep") {
        Some(v) => {
            let f: f32 = v.parse().with_context(|| {
                format!("--neuron-keep must be a fraction in 0.0..=1.0, got {v:?}")
            })?;
            if !(0.0..=1.0).contains(&f) {
                bail!("--neuron-keep must be in 0.0..=1.0 (got {f})");
            }
            Some(f)
        }
        None => None,
    };
    Ok((keep, args.flag("quant").is_some()))
}

/// Fold the neuron-level flags into `base` engine options, loading the
/// model's calibration tables when a keep < 1.0 actually needs them
/// (and the caller didn't already supply importance, as `eval
/// --reconstruct` does).
fn neuron_engine_opts(
    artifacts: &Path,
    model: &str,
    keep: Option<f32>,
    quant: bool,
    base: EngineOptions,
) -> Result<EngineOptions> {
    let mut opts = base;
    opts.neuron_keep = keep;
    opts.quant = quant;
    if keep.is_some_and(|k| k < 1.0) && opts.importance.is_none() {
        let tables = calib::ProbeTables::load(&calib::tables_path(artifacts, model))?;
        opts.importance = Some(tables.importance("abs_gate"));
    }
    Ok(opts)
}

/// Tiny flag parser: positional args + --key value pairs.
struct Args {
    pos: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse() -> Args {
        Args::from_vec(std::env::args().skip(1).collect())
    }

    fn from_vec(argv: Vec<String>) -> Args {
        let mut pos = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(k) = a.strip_prefix("--") {
                let v = if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    it.next().unwrap()
                } else {
                    "true".to_string()
                };
                flags.insert(k.to_string(), v);
            } else {
                pos.push(a);
            }
        }
        Args { pos, flags }
    }

    fn flag(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    fn flag_usize(&self, k: &str, default: usize) -> usize {
        self.flag(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Strict numeric flag: absent → default; present but unparseable
    /// (including overflow of the target type) → error, never a silent
    /// fallback.
    fn flag_f64_strict(&self, k: &str, default: f64) -> Result<f64> {
        match self.flag(k) {
            Some(v) => v
                .parse()
                .with_context(|| format!("--{k} must be a number, got {v:?}")),
            None => Ok(default),
        }
    }

    /// Strict u32 flag: a value that overflows u32 (or is negative /
    /// non-numeric) is an error instead of silently using the default.
    fn flag_u32_strict(&self, k: &str, default: u32) -> Result<u32> {
        match self.flag(k) {
            Some(v) => v.parse().with_context(|| {
                format!("--{k} must be a count that fits u32, got {v:?}")
            }),
            None => Ok(default),
        }
    }
}

/// Parse `--deadline-ms`: positive finite milliseconds → seconds.
/// Zero is rejected loudly — it would time every request out before its
/// first sweep, which is never what the caller meant.
fn parse_deadline_ms(v: Option<&str>) -> Result<Option<f64>> {
    match v {
        Some(s) => {
            let ms: f64 = s
                .parse()
                .with_context(|| format!("--deadline-ms must be milliseconds, got {s:?}"))?;
            if !(ms > 0.0 && ms.is_finite()) {
                bail!("--deadline-ms must be positive, finite milliseconds (got {s:?})");
            }
            Ok(Some(ms / 1e3))
        }
        None => Ok(None),
    }
}

/// Parse the network-front-end flags (`--listen`, `--conn-queue`,
/// `--max-frame-bytes`) into [`server::net::NetOptions`]. All the
/// refusals are loud: a bad socket address, net flags without
/// `--listen`, and `--listen` combined with flags that synthesize a
/// workload (`--sweep`/`--quick`, `--mode`/`--rate`/`--reqs`) — a live
/// server takes its requests off the wire, so silently ignoring either
/// side would misrepresent the run.
fn parse_net_options(args: &Args) -> Result<Option<(String, server::net::NetOptions)>> {
    let Some(addr) = args.flag("listen") else {
        for k in ["conn-queue", "max-frame-bytes"] {
            if args.flag(k).is_some() {
                bail!("--{k} configures the network front end; it requires --listen HOST:PORT");
            }
        }
        return Ok(None);
    };
    // `--listen` with no value parses as the bare-flag sentinel "true",
    // which this rejects like any other non-address.
    addr.parse::<std::net::SocketAddr>()
        .with_context(|| format!("--listen {addr:?} is not a HOST:PORT socket address"))?;
    if args.flag("sweep").is_some() || args.flag("quick").is_some() {
        bail!("--listen runs a live server; it cannot combine with --sweep/--quick");
    }
    for k in ["mode", "rate", "reqs"] {
        if args.flag(k).is_some() {
            bail!(
                "--{k} shapes a synthetic workload; a --listen server takes its \
                 requests off the wire (drive it with `dualsparse client`)"
            );
        }
    }
    let mut opts = server::net::NetOptions::default();
    if let Some(v) = args.flag("conn-queue") {
        let q: usize = v
            .parse()
            .with_context(|| format!("--conn-queue must be a request count, got {v:?}"))?;
        if q == 0 {
            bail!("--conn-queue must be ≥ 1 (0 would refuse every generate frame)");
        }
        opts.conn_queue = q;
    }
    if let Some(v) = args.flag("max-frame-bytes") {
        let b: usize = v
            .parse()
            .with_context(|| format!("--max-frame-bytes must be a byte count, got {v:?}"))?;
        if b < 64 {
            bail!("--max-frame-bytes must be ≥ 64 (a minimal generate frame is bigger)");
        }
        opts.max_frame_bytes = b;
    }
    // In net mode `--max-new` is the per-request default for frames
    // that omit the field (the synthetic-workload meaning is rejected
    // above alongside --reqs).
    opts.default_max_new = args.flag_usize("max-new", opts.default_max_new);
    Ok(Some((addr.to_string(), opts)))
}

fn main() -> Result<()> {
    let args = Args::parse();
    let artifacts: PathBuf = args
        .flag("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(artifacts_dir);
    let cmd = args.pos.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "serve" => {
            // `dualsparse serve --quick` (the CI smoke) takes no
            // positional model; the preset default serves hermetically.
            let model = args
                .pos
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("mixtral_ish")
                .to_string();
            let (sched_kind, policy) =
                parse_serve_policies(args.flag("policy"), args.flag("drop"))?;
            let (neuron_keep, quant) = parse_neuron_flags(&args)?;
            let max_queue = match args.flag("max-queue") {
                Some(v) => Some(v.parse::<usize>().with_context(|| {
                    format!("--max-queue must be a request count, got {v:?}")
                })?),
                None => None,
            };
            let page_size = match args.flag("page-size") {
                Some(v) => Some(v.parse::<usize>().with_context(|| {
                    format!("--page-size must be a token count, got {v:?}")
                })?),
                None => None,
            };
            let kv_pages = match args.flag("kv-pages") {
                Some(v) => Some(v.parse::<usize>().with_context(|| {
                    format!("--kv-pages must be a page count, got {v:?}")
                })?),
                None => None,
            };
            let preempt = args.flag("preempt").is_some();
            let aging = match args.flag("age-boost") {
                Some(v) => {
                    let step_secs = v.parse::<f64>().with_context(|| {
                        format!("--age-boost must be seconds per boost step, got {v:?}")
                    })?;
                    if !(step_secs > 0.0 && step_secs.is_finite()) {
                        bail!("--age-boost must be positive, finite seconds (got {step_secs})");
                    }
                    Some(AgingConfig { step_secs })
                }
                None => None,
            };
            let interleave = args.flag("no-interleave").is_none();
            let ep_workers = match args.flag("ep-workers") {
                Some(v) => {
                    let n = v.parse::<usize>().with_context(|| {
                        format!("--ep-workers must be a worker count, got {v:?}")
                    })?;
                    if n == 0 {
                        bail!("--ep-workers must be ≥ 1 (omit the flag to turn EP off)");
                    }
                    Some(n)
                }
                None => None,
            };
            let ep_load_aware = args.flag("ep-load-aware").is_some();
            let ep_replicate_after = match args.flag("ep-replicate-after") {
                Some(v) => {
                    let k = v.parse::<u64>().with_context(|| {
                        format!("--ep-replicate-after must be an invocation count, got {v:?}")
                    })?;
                    if k == 0 {
                        bail!("--ep-replicate-after must be ≥ 1");
                    }
                    Some(k)
                }
                None => None,
            };
            if ep_workers.is_none() && (ep_load_aware || ep_replicate_after.is_some()) {
                bail!("--ep-load-aware/--ep-replicate-after require --ep-workers N");
            }
            let seed = args.flag_usize("seed", 11) as u64;
            let faults = match args.flag("faults") {
                Some(spec) => Some(
                    FaultPlan::parse(spec, seed)
                        .context("--faults spec (grammar: exec=P,spike=P:MS,\
                                  pressure=P:PAGES[:HOLD],ep-fail=W@STEP,\
                                  ep-slow=W@FACTOR,cancel=P)")?,
                ),
                None => None,
            };
            let max_retries = args.flag_u32_strict("retries", 2)?;
            let deadline_secs = parse_deadline_ms(args.flag("deadline-ms"))?;
            let degrade = match args.flag("slo-ttft-ms") {
                Some(v) => {
                    let ms: f64 = v.parse().with_context(|| {
                        format!("--slo-ttft-ms must be milliseconds, got {v:?}")
                    })?;
                    if !(ms > 0.0 && ms.is_finite()) {
                        bail!("--slo-ttft-ms must be positive, finite milliseconds (got {v:?})");
                    }
                    let qd = args.flag_usize("slo-queue-depth", usize::MAX);
                    Some(DegradeController::new(ms / 1e3, qd))
                }
                None => {
                    if args.flag("slo-queue-depth").is_some() {
                        bail!("--slo-queue-depth requires --slo-ttft-ms MS");
                    }
                    None
                }
            };
            if faults.as_ref().is_some_and(|p| p.spec.ep_fail.is_some() || p.spec.ep_slow.is_some())
                && ep_workers.is_none()
            {
                bail!("--faults ep-fail/ep-slow require --ep-workers N");
            }
            let listen = parse_net_options(&args)?;
            if args.flag("sweep").is_some() || args.flag("quick").is_some() {
                // The sweep fixes its own queue bound, drop ladder and
                // scheduler knobs; refusing beats silently writing a
                // JSON the user's flags did not shape (--policy does
                // apply: it restricts the scheduling dimension).
                let legacy_drop_spelling =
                    sched_kind.is_none() && args.flag("policy").is_some();
                let paging_flags =
                    page_size.is_some() || kv_pages.is_some() || preempt || aging.is_some()
                        || !interleave;
                let chaos_flags = faults.is_some()
                    || deadline_secs.is_some()
                    || degrade.is_some()
                    || args.flag("retries").is_some();
                if max_queue.is_some()
                    || args.flag("drop").is_some()
                    || legacy_drop_spelling
                    || paging_flags
                    || ep_workers.is_some()
                    || chaos_flags
                    || neuron_keep.is_some()
                    || quant
                {
                    bail!(
                        "--max-queue, drop-policy, paging/preemption, EP, chaos \
                         and neuron-level flags have no effect with \
                         --sweep/--quick (the sweep uses max queue {}, its own \
                         drop ladder, default paging, its own interleave-off \
                         baselines and its own EP + chaos dimensions; the \
                         neuron-keep × quant ladder lives in `dualsparse \
                         bench`); use --policy fcfs|spf|priority to restrict \
                         the sweep",
                        experiments::bench::SWEEP_MAX_QUEUE
                    );
                }
                let cfg = experiments::bench::ServeSweepConfig {
                    quick: args.flag("quick").is_some(),
                    out: args
                        .flag("out")
                        .map(PathBuf::from)
                        .unwrap_or_else(|| PathBuf::from("SERVE_cpu.json")),
                    model,
                    sched: sched_kind,
                };
                experiments::bench::serve_sweep(&artifacts, &cfg)?;
                return Ok(());
            }
            let sched = SchedConfig {
                policy: sched_kind.unwrap_or_default(),
                admission: match max_queue {
                    Some(k) => AdmissionControl::bounded(k),
                    None => AdmissionControl::unbounded(),
                },
                preempt,
                aging,
                interleave,
                faults,
                max_retries,
                deadline_secs,
                cancel: None,
                degrade,
            };
            if let Some((addr, net_opts)) = listen {
                let ep = ep_workers.map(|n| {
                    let mut o = EpOptions::new(n, ep_load_aware);
                    o.replicate_after = ep_replicate_after;
                    o
                });
                let opts = neuron_engine_opts(
                    &artifacts,
                    &model,
                    neuron_keep,
                    quant,
                    EngineOptions { page_size, kv_pages, ep, ..Default::default() },
                )?;
                let mut engine = Engine::new(&artifacts, &model, policy, opts)?;
                server::warmup(&mut engine)?;
                let srv = server::net::NetServer::bind(&addr, net_opts)?;
                let bound = srv.local_addr();
                println!(
                    "serving {model} on {} (sched {}, drop {policy:?}, pages {}×{} tok, \
                     preempt={}, interleave={}, ep={:?})",
                    engine.rt.platform(),
                    sched.policy,
                    engine.kv.n_pages,
                    engine.kv.page_size,
                    sched.preempt,
                    sched.interleave,
                    ep_workers,
                );
                // CI discovers the ephemeral port from this line; keep
                // the spelling stable.
                println!("listening on {bound}");
                let (outcome, net) =
                    srv.serve(&mut engine, sched.policy.policy(), sched.options())?;
                let st = &outcome.stats;
                println!(
                    "latency p50={:.0}ms p99={:.0}ms | ttft mean={:.0}ms p99={:.0}ms | \
                     completed={} goodput={:.2} req/s rejected={} (queue-full {})",
                    st.p50_latency * 1e3,
                    st.p99_latency * 1e3,
                    st.mean_ttft * 1e3,
                    st.p99_ttft * 1e3,
                    st.requests,
                    st.goodput_rps,
                    st.rejected,
                    st.rejected_queue_full,
                );
                let leaked = engine.kv.n_pages - engine.kv.free_page_count();
                println!("{}", server::net::format_net_report(&net, leaked));
                let chaos_line = server::format_chaos_report(st, leaked);
                if !chaos_line.is_empty() {
                    println!("{chaos_line}");
                }
                // Same conservation law as the offline path, with the
                // submitted count taken off the wire: every request the
                // scheduler accepted must end in exactly one terminal
                // state, and the page pool must drain to full.
                let resolved =
                    st.requests + st.rejected + st.failed + st.timed_out + st.cancelled;
                if resolved != net.accepted_requests || leaked != 0 {
                    bail!(
                        "lifecycle violation: {} completed + {} rejected + {} failed + \
                         {} timed-out + {} cancelled != {} accepted off the wire \
                         (leaked pages: {})",
                        st.requests,
                        st.rejected,
                        st.failed,
                        st.timed_out,
                        st.cancelled,
                        net.accepted_requests,
                        leaked
                    );
                }
                println!(
                    "lifecycle: exactly-once ({} completed + {} rejected + {} failed + \
                     {} timed-out + {} cancelled = {} submitted)",
                    st.requests,
                    st.rejected,
                    st.failed,
                    st.timed_out,
                    st.cancelled,
                    net.accepted_requests
                );
                let out = args
                    .flag("out")
                    .map(PathBuf::from)
                    .unwrap_or_else(|| PathBuf::from("SERVE_cpu.json"));
                server::net::write_net_serve_json(&model, &bound, st, &net, &out)?;
                return Ok(());
            }
            let n = args.flag_usize("reqs", 100);
            let max_new = args.flag_usize("max-new", 12);
            let mode = match args.flag("mode").unwrap_or("closed") {
                "closed" => ArrivalMode::Closed,
                "open" => {
                    // Strict parse: a typo'd --rate must not silently
                    // serve at the default (the open-loop Poisson gap
                    // is 1/rate, so a wrong rate poisons every number).
                    let rate = args.flag_f64_strict("rate", 4.0)?;
                    if !(rate > 0.0 && rate.is_finite()) {
                        bail!("--rate must be a positive, finite req/s (got {rate})");
                    }
                    ArrivalMode::Open { rate, seed }
                }
                other => bail!("unknown --mode {other:?}; use closed | open"),
            };
            let ep = ep_workers.map(|n| {
                let mut o = EpOptions::new(n, ep_load_aware);
                o.replicate_after = ep_replicate_after;
                o
            });
            let opts = neuron_engine_opts(
                &artifacts,
                &model,
                neuron_keep,
                quant,
                EngineOptions { page_size, kv_pages, ep, ..Default::default() },
            )?;
            let mut engine = Engine::new(&artifacts, &model, policy, opts)?;
            println!(
                "serving {model} on {} ({} requests, sched {} max-queue {:?}, \
                 drop {policy:?}, {mode:?}, pages {}×{} tok, preempt={}, \
                 interleave={}, ep={:?})",
                engine.rt.platform(),
                n,
                sched.policy,
                sched.admission.max_queue_depth,
                engine.kv.n_pages,
                engine.kv.page_size,
                sched.preempt,
                sched.interleave,
                ep_workers,
            );
            let reqs = server::workload(n, max_new, 7);
            let report =
                server::run_once_mode(&mut engine, &reqs, policy, "serve", mode, sched)?;
            let st = &report.stats;
            println!("{}", server::format_report(&report));
            println!(
                "wall={:.2}s prefill={} gen={} moe={:.2}s artifacts={:.2}s",
                st.wall_secs, st.prefill_tokens, st.generated_tokens, st.moe_secs,
                st.artifact_secs,
            );
            println!(
                "latency (arrival-anchored) p50={:.0}ms p99={:.0}ms | \
                 service (admission-anchored) p50={:.0}ms p99={:.0}ms",
                st.p50_latency * 1e3,
                st.p99_latency * 1e3,
                st.p50_service * 1e3,
                st.p99_service * 1e3,
            );
            println!(
                "ttft mean={:.0}ms p99={:.0}ms | queue wait={:.0}ms depth mean={:.1} \
                 max={} | completed={} goodput={:.2} req/s rejected={} \
                 (queue-full {})",
                st.mean_ttft * 1e3,
                st.p99_ttft * 1e3,
                st.mean_queue_secs * 1e3,
                st.mean_queue_depth,
                st.max_queue_depth,
                st.requests,
                st.goodput_rps,
                st.rejected,
                st.rejected_queue_full,
            );
            println!(
                "pages: util={:.2} | preemptions={} recompute={} interleaved_chunks={}",
                st.page_utilization,
                st.preemptions,
                st.recompute_tokens,
                st.interleaved_prefill_steps,
            );
            let ep_line = server::format_ep_report(st);
            if !ep_line.is_empty() {
                println!("{ep_line}");
            }
            // Leaked pages = page-pool deficit after the run; must be 0
            // even when chaos freed pages mid-lifecycle. CI greps the
            // chaos line's counters.
            let leaked = engine.kv.n_pages - engine.kv.free_page_count();
            let chaos_line = server::format_chaos_report(st, leaked);
            if !chaos_line.is_empty() {
                println!("{chaos_line}");
            }
            if !st.degrade_timeline.is_empty() {
                let steps: Vec<String> = st
                    .degrade_timeline
                    .iter()
                    .map(|&(it, lvl)| format!("{it}:{lvl}"))
                    .collect();
                println!("degrade timeline (iter:level): {}", steps.join(" "));
            }
            if !st.lane_ttft50.is_empty() {
                let lanes: Vec<String> = st
                    .lane_ttft50
                    .iter()
                    .map(|&(l, t)| format!("{l}:{:.0}ms", t * 1e3))
                    .collect();
                println!("ttft50 by lane: {}", lanes.join(" "));
            }
            // Binary-enforced lifecycle conservation: every submitted
            // request must end in exactly one terminal state — completed,
            // rejected, failed, timed-out or cancelled — even across
            // preemption/re-admission and chaos. CI greps the line.
            let resolved =
                st.requests + st.rejected + st.failed + st.timed_out + st.cancelled;
            if resolved != n || leaked != 0 {
                bail!(
                    "lifecycle violation: {} completed + {} rejected + {} failed + \
                     {} timed-out + {} cancelled != {} submitted (leaked pages: {})",
                    st.requests,
                    st.rejected,
                    st.failed,
                    st.timed_out,
                    st.cancelled,
                    n,
                    leaked
                );
            }
            println!(
                "lifecycle: exactly-once ({} completed + {} rejected + {} failed + \
                 {} timed-out + {} cancelled = {} submitted)",
                st.requests, st.rejected, st.failed, st.timed_out, st.cancelled, n
            );
        }
        "eval" => {
            let model = args.pos.get(1).context("eval <model>")?;
            let policy = parse_policy(args.flag("policy").unwrap_or("none"))?;
            let n = args.flag_usize("n", 24);
            let (neuron_keep, quant) = parse_neuron_flags(&args)?;
            let base = if args.flag("reconstruct").is_some() {
                let tables = calib::ProbeTables::load(&calib::tables_path(&artifacts, model))?;
                EngineOptions {
                    reconstructed: true,
                    importance: Some(tables.importance(
                        args.flag("metric").unwrap_or("abs_gate"),
                    )),
                    ..Default::default()
                }
            } else {
                EngineOptions::default()
            };
            let opts = neuron_engine_opts(&artifacts, model, neuron_keep, quant, base)?;
            let mut engine = Engine::new(&artifacts, model, policy, opts)?;
            let res = evaluate(&mut engine, n, false)?;
            println!("{}", format_row(model, &res));
            println!("drop rate: {:.1}%", 100.0 * engine.metrics.drop_rate());
        }
        "calibrate" => {
            let model = args.pos.get(1).context("calibrate <model>")?;
            let tokens = args.flag_usize("tokens", 2048);
            let mut engine =
                Engine::new(&artifacts, model, DropPolicy::NoDrop, EngineOptions::default())?;
            let tables = calib::run_calibration(&mut engine, tokens)?;
            let path = calib::tables_path(&artifacts, model);
            tables.save(&path)?;
            println!("calibrated {model} on {tokens} tokens → {path:?}");
        }
        "bench" => {
            let cfg = experiments::bench::BenchConfig {
                quick: args.flag("quick").is_some(),
                out: args
                    .flag("out")
                    .map(PathBuf::from)
                    .unwrap_or_else(|| PathBuf::from("BENCH_cpu.json")),
                model: args.flag("model").unwrap_or("mixtral_ish").to_string(),
            };
            experiments::bench::run(&artifacts, &cfg)?;
        }
        "client" => {
            // Loopback driver for `serve --listen`: replays the built-in
            // task workload over NDJSON and reports wire-level streaming
            // accounting (CI's net-smoke counterpart of the server).
            let addr = args.flag("connect").context(
                "client --connect HOST:PORT [--reqs N] [--max-new N] [--seed S] [--shutdown]",
            )?;
            let sock: std::net::SocketAddr = addr
                .parse()
                .with_context(|| format!("--connect {addr:?} is not HOST:PORT"))?;
            let n = args.flag_usize("reqs", 12);
            let max_new = args.flag_usize("max-new", 6);
            let seed = args.flag_usize("seed", 7) as u64;
            let reqs: Vec<server::net::ClientRequest> = server::workload(n, max_new, seed)
                .into_iter()
                .map(|r| server::net::ClientRequest {
                    tag: r.id.to_string(),
                    prompt: r.prompt,
                    max_new: r.max_new,
                })
                .collect();
            let rep = server::net::run_client(&sock, &reqs, args.flag("shutdown").is_some())?;
            // Streaming must be real: each completion's token frames
            // arrive before its done frame and concatenate to its text.
            let stream_matches_done = rep
                .outcomes
                .iter()
                .filter(|(_, o)| o.terminal == "done")
                .all(|(_, o)| {
                    (o.token_frames == 0 || o.token_before_done)
                        && o.done_text.as_deref() == Some(o.streamed.as_str())
                });
            println!(
                "client: sent={n} completions={} token_frames={} errors={} \
                 stream_matches_done={} shutdown_acked={}",
                rep.completions(),
                rep.token_frames(),
                rep.errors,
                stream_matches_done,
                rep.shutdown_acked,
            );
            if !stream_matches_done {
                bail!("streamed token frames do not reconstruct the done text");
            }
        }
        "exp" => {
            let id = args.pos.get(1).context("exp <id|all>")?;
            experiments::run(id, &artifacts)?;
        }
        "info" => {
            use dualsparse::runtime::{make_backend, BackendKind};
            let rt = make_backend(BackendKind::Auto, &artifacts)?;
            println!("backend: {}", rt.platform());
            let models = match std::fs::read_dir(artifacts.join("models")) {
                Ok(rd) => rd
                    .filter_map(|e| e.ok())
                    .filter(|e| e.path().extension().map(|x| x == "json").unwrap_or(false))
                    .map(|e| e.path().file_stem().unwrap().to_string_lossy().into_owned())
                    .collect::<Vec<_>>(),
                Err(_) => Vec::new(),
            };
            if models.is_empty() {
                println!(
                    "models: none serialized — synthetic presets available: {:?}",
                    dualsparse::model::ModelConfig::PRESET_NAMES
                );
            } else {
                println!("models: {models:?}");
            }
            let n_artifacts = match std::fs::read_dir(&artifacts) {
                Ok(rd) => rd
                    .filter_map(|e| e.ok())
                    .filter(|e| e.path().to_string_lossy().ends_with(".hlo.txt"))
                    .count(),
                Err(_) => 0,
            };
            println!("artifacts: {n_artifacts} HLO modules");
        }
        _ => {
            println!(
                "dualsparse — DualSparse-MoE inference system\n\
                 usage: dualsparse <serve|client|eval|calibrate|bench|exp|info> …\n\
                 see `rust/src/main.rs` header or README.md"
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Args {
        Args::from_vec(s.split_whitespace().map(String::from).collect())
    }

    #[test]
    fn args_split_positionals_and_flags() {
        let a = argv("serve mixtral_ish --reqs 32 --preempt --rate 6.5");
        assert_eq!(a.pos, vec!["serve", "mixtral_ish"]);
        assert_eq!(a.flag("reqs"), Some("32"));
        assert_eq!(a.flag("preempt"), Some("true"), "bare flag gets a truthy value");
        assert_eq!(a.flag_usize("reqs", 0), 32);
        assert_eq!(a.flag_f64_strict("rate", 4.0).unwrap(), 6.5);
        assert_eq!(a.flag_f64_strict("absent", 4.0).unwrap(), 4.0);
    }

    #[test]
    fn strict_flags_reject_garbage_instead_of_defaulting() {
        let a = argv("serve --rate zero --retries many");
        assert!(a.flag_f64_strict("rate", 4.0).is_err(), "--rate zero must not become 4.0");
        assert!(a.flag_u32_strict("retries", 2).is_err());
    }

    #[test]
    fn retry_counts_that_overflow_u32_are_errors() {
        let a = argv("serve --retries 4294967296"); // u32::MAX + 1
        assert!(a.flag_u32_strict("retries", 2).is_err());
        let a = argv("serve --retries -1");
        assert!(a.flag_u32_strict("retries", 2).is_err());
        let a = argv("serve --retries 3");
        assert_eq!(a.flag_u32_strict("retries", 2).unwrap(), 3);
    }

    #[test]
    fn deadline_ms_rejects_zero_and_nonsense() {
        assert_eq!(parse_deadline_ms(None).unwrap(), None);
        assert_eq!(parse_deadline_ms(Some("250")).unwrap(), Some(0.25));
        assert!(parse_deadline_ms(Some("0")).is_err(), "a zero deadline kills every request");
        assert!(parse_deadline_ms(Some("-5")).is_err());
        assert!(parse_deadline_ms(Some("inf")).is_err());
        assert!(parse_deadline_ms(Some("soon")).is_err());
    }

    #[test]
    fn net_flags_parse_and_default() {
        let got = parse_net_options(&argv("serve --listen 127.0.0.1:0")).unwrap();
        let (addr, opts) = got.expect("--listen present");
        assert_eq!(addr, "127.0.0.1:0");
        assert_eq!(opts.conn_queue, server::net::NetOptions::default().conn_queue);
        let got = parse_net_options(
            &argv("serve --listen 127.0.0.1:0 --conn-queue 4 --max-frame-bytes 4096 --max-new 9"),
        )
        .unwrap()
        .expect("--listen present");
        assert_eq!(got.1.conn_queue, 4);
        assert_eq!(got.1.max_frame_bytes, 4096);
        assert_eq!(got.1.default_max_new, 9);
        assert!(parse_net_options(&argv("serve --reqs 32")).unwrap().is_none());
    }

    #[test]
    fn net_flags_reject_bad_addresses_and_orphans() {
        assert!(
            parse_net_options(&argv("serve --listen nonsense")).is_err(),
            "a non-address must not bind"
        );
        assert!(
            parse_net_options(&argv("serve --listen --preempt")).is_err(),
            "valueless --listen parses as the bare-flag sentinel and must be rejected"
        );
        assert!(
            parse_net_options(&argv("serve --conn-queue 8")).is_err(),
            "net flags without --listen are a misconfiguration, not a no-op"
        );
        assert!(parse_net_options(&argv("serve --max-frame-bytes 4096")).is_err());
    }

    #[test]
    fn listen_excludes_synthetic_workload_flags() {
        for flags in [
            "serve --listen 127.0.0.1:0 --sweep",
            "serve --listen 127.0.0.1:0 --quick",
            "serve --listen 127.0.0.1:0 --mode open --rate 4",
            "serve --listen 127.0.0.1:0 --reqs 32",
        ] {
            assert!(parse_net_options(&argv(flags)).is_err(), "{flags:?} must be rejected");
        }
    }

    #[test]
    fn net_bounds_reject_zero_and_garbage() {
        assert!(parse_net_options(&argv("serve --listen 127.0.0.1:0 --conn-queue 0")).is_err());
        assert!(parse_net_options(&argv("serve --listen 127.0.0.1:0 --conn-queue many")).is_err());
        assert!(
            parse_net_options(&argv("serve --listen 127.0.0.1:0 --max-frame-bytes 8")).is_err(),
            "a frame cap below any valid generate frame refuses everything"
        );
    }

    #[test]
    fn neuron_flags_parse_and_validate() {
        assert_eq!(parse_neuron_flags(&argv("serve")).unwrap(), (None, false));
        assert_eq!(
            parse_neuron_flags(&argv("serve --neuron-keep 0.75 --quant")).unwrap(),
            (Some(0.75), true)
        );
        assert_eq!(parse_neuron_flags(&argv("eval m --quant")).unwrap(), (None, true));
        assert!(
            parse_neuron_flags(&argv("serve --neuron-keep 1.5")).is_err(),
            "out-of-range keep must not silently serve dense"
        );
        assert!(parse_neuron_flags(&argv("serve --neuron-keep -0.1")).is_err());
        assert!(parse_neuron_flags(&argv("serve --neuron-keep most")).is_err());
        assert!(
            parse_neuron_flags(&argv("serve --neuron-keep")).is_err(),
            "bare --neuron-keep parses as the sentinel \"true\" and must be rejected"
        );
    }

    #[test]
    fn serve_policy_split_keeps_legacy_drop_spelling() {
        let (sched, drop) = parse_serve_policies(Some("spf"), None).unwrap();
        assert_eq!(sched, Some(PolicyKind::ShortestPromptFirst));
        assert_eq!(drop, DropPolicy::NoDrop);
        let (sched, drop) = parse_serve_policies(Some("1t:0.2"), None).unwrap();
        assert_eq!(sched, None);
        assert_eq!(drop, DropPolicy::OneT(0.2));
        assert!(parse_serve_policies(Some("lifo"), None).is_err());
    }
}
