//! Gating: Top-K selection over softmax scores + activated-set
//! normalization (paper §2.1.1 Eqs. 1-3 and §4.1).
//!
//! The gate *scores* come from the AOT `gate_b{B}_e{E}` artifact
//! (softmax over all experts); everything downstream — Top-K, the
//! normalization used by the drop thresholds, the drop decisions — is
//! coordinator logic and lives here in Rust.

/// One token's routing decision before drop policies are applied.
#[derive(Debug, Clone)]
pub struct TokenRouting {
    /// (expert index, original gating score, normalized gating score),
    /// sorted by descending score. The *original* score is the
    /// combination weight (Eq. 3); the *normalized* score feeds the
    /// drop thresholds (§4.1).
    pub experts: Vec<(usize, f32, f32)>,
}

/// Top-K indices + scores, descending, ties toward the lower index.
pub fn top_k(scores: &[f32], k: usize) -> Vec<(usize, f32)> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    idx.into_iter().map(|i| (i, scores[i])).collect()
}

/// Route one token: Top-K + normalization over the activated set.
///
/// `already_normalized` models architectures (DeepSeek-V3 / Qwen3-style)
/// whose gate normalizes activated scores itself — then the normalized
/// score *is* the original score (paper §4.1 note).
pub fn route_token(scores: &[f32], k: usize, already_normalized: bool) -> TokenRouting {
    let sel = top_k(scores, k);
    let sum: f32 = sel.iter().map(|(_, s)| *s).sum();
    let experts = sel
        .into_iter()
        .map(|(e, s)| {
            let norm = if already_normalized {
                s
            } else if sum > 0.0 {
                s / sum
            } else {
                1.0 / k as f32
            };
            (e, s, norm)
        })
        .collect();
    TokenRouting { experts }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_orders_descending() {
        let s = [0.1, 0.5, 0.2, 0.2];
        let t = top_k(&s, 3);
        assert_eq!(t[0], (1, 0.5));
        assert_eq!(t[1].0, 2); // tie 0.2/0.2 → lower index first
        assert_eq!(t[2].0, 3);
    }

    #[test]
    fn normalization_sums_to_one() {
        let s = [0.05, 0.6, 0.15, 0.2];
        let r = route_token(&s, 2, false);
        let total: f32 = r.experts.iter().map(|(_, _, n)| n).sum();
        assert!((total - 1.0).abs() < 1e-6);
        // original scores preserved as combination weights
        assert_eq!(r.experts[0].1, 0.6);
    }

    #[test]
    fn already_normalized_passthrough() {
        let s = [0.1, 0.6, 0.3];
        let r = route_token(&s, 2, true);
        assert_eq!(r.experts[0].2, 0.6);
        assert_eq!(r.experts[1].2, 0.3);
    }

    #[test]
    fn top1_is_argmax() {
        let s = [0.2, 0.1, 0.7];
        let r = route_token(&s, 1, false);
        assert_eq!(r.experts.len(), 1);
        assert_eq!(r.experts[0].0, 2);
        assert_eq!(r.experts[0].2, 1.0);
    }

    #[test]
    fn zero_scores_fall_back_uniform() {
        let s = [0.0, 0.0, 0.0, 0.0];
        let r = route_token(&s, 2, false);
        assert!((r.experts[0].2 - 0.5).abs() < 1e-6);
    }
}
