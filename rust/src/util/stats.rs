//! Small statistics helpers shared by metrics, benches and experiments.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on a sorted copy. p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    // total_cmp: a NaN sample must not panic the reporting path.
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Guarded speedup ratio for timing columns. Sub-microsecond phase
/// times (instant `CpuRef` runs, or a phase that never executed) carry
/// no signal — dividing them inflates speedup columns with noise, so
/// both operands must be measurable or the ratio reports a neutral 1.0.
pub fn speedup_ratio(base_secs: f64, new_secs: f64) -> f64 {
    const MIN_MEASURABLE_SECS: f64 = 1e-6;
    if !base_secs.is_finite()
        || !new_secs.is_finite()
        || base_secs < MIN_MEASURABLE_SECS
        || new_secs < MIN_MEASURABLE_SECS
    {
        1.0
    } else {
        base_secs / new_secs
    }
}

/// Histogram with fixed-width bins over [lo, hi); counts outliers in the
/// edge bins. Used for the Fig. 6 gating-score distributions.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let mut b = ((x - lo) / w) as isize;
        b = b.clamp(0, bins as isize - 1);
        h[b as usize] += 1;
    }
    h
}

/// Online mean/max accumulator for per-device load tracking.
#[derive(Debug, Default, Clone)]
pub struct Acc {
    pub n: u64,
    pub sum: f64,
    pub max: f64,
}

impl Acc {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        if x > self.max {
            self.max = x;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_clamps_outliers() {
        let h = histogram(&[-1.0, 0.05, 0.15, 2.0], 0.0, 1.0, 10);
        assert_eq!(h[0], 2); // -1.0 clamped + 0.05
        assert_eq!(h[1], 1);
        assert_eq!(h[9], 1); // 2.0 clamped
    }

    #[test]
    fn speedup_ratio_guards_instant_runs() {
        assert_eq!(speedup_ratio(2.0, 1.0), 2.0);
        assert_eq!(speedup_ratio(0.0, 1.0), 1.0);
        assert_eq!(speedup_ratio(1.0, 0.0), 1.0);
        assert_eq!(speedup_ratio(1e-9, 1e-12), 1.0); // both unmeasurable
        assert_eq!(speedup_ratio(f64::NAN, 1.0), 1.0);
    }

    #[test]
    fn acc_tracks_max() {
        let mut a = Acc::default();
        for x in [1.0, 5.0, 3.0] {
            a.push(x);
        }
        assert_eq!(a.max, 5.0);
        assert!((a.mean() - 3.0).abs() < 1e-12);
    }
}
