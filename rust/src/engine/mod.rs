//! The DualSparse-MoE serving engine: layer loop, capacity-bucket MoE
//! dispatch, KV cache, greedy generation.
//!
//! All heavy math executes through a pluggable [`Backend`] (the AOT
//! PJRT runtime when artifacts exist, the pure-Rust `CpuRef` reference
//! executor otherwise); this module owns routing, drop decisions,
//! packing, the KV cache and batching — the coordination the paper
//! contributes. The engine is backend-agnostic: it holds weight
//! buffers as opaque [`BufId`] handles and never names a runtime type.

pub mod ep;
pub mod faults;
pub mod kv;
pub mod policy;
pub mod scheduler;

pub use ep::{EpOptions, EpReport, EpSim};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::model::{ModelConfig, Tensor, Weights};
use crate::moe::{
    plan_dispatch, route_token, DispatchPlan, DropPolicy, DropStats,
    PartitionedExpert, SubExpert, TokenRouting,
};
use crate::runtime::{make_backend, Arg, Backend, BackendKind, BufId};
use crate::util::round_up_bucket;

pub const BATCH_BUCKETS: [usize; 5] = [1, 2, 4, 8, 16];
pub const PREFILL_BUCKETS: [usize; 4] = [16, 32, 64, 128];
/// ~1.4× spacing so a ~25% drop in kept pairs usually lands in a smaller
/// bucket — the mechanism that turns drop rate into real speedup (Fig. 10).
pub const CAPACITY_BUCKETS: [usize; 12] =
    [2, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128];
pub const MAX_SLOTS: usize = 16;
pub const EOS: u8 = b'\n';

/// How the router selects experts (baselines reuse the same engine).
#[derive(Debug, Clone)]
pub enum RouterMode {
    /// Paper's router: Top-K + normalization + drop policy.
    Standard,
    /// Efficient Expert Skipping (Lu et al.): skip the 2nd..Kth expert
    /// when its score < β × top-1 score.
    Ees { beta: f32 },
    /// Efficient Expert Pruning: only `kept[layer]` experts exist;
    /// scores are renormalized over the kept set.
    Eep { kept: Vec<Vec<usize>> },
    /// EEP + EES stacked (Table 3's combined rows).
    EepEes { kept: Vec<Vec<usize>>, beta: f32 },
}

#[derive(Debug, Clone, Default)]
pub struct EngineOptions {
    /// Use the 2-sub-expert reconstruction split (requires importance
    /// tables from `calib`); false ⇒ contiguous partition halves.
    pub reconstructed: bool,
    /// Importance tables `[layer][expert][neuron]` (from calibration).
    pub importance: Option<Vec<Vec<Vec<f32>>>>,
    /// Collect gating-score distributions + per-layer drop stats.
    pub collect_stats: bool,
    pub ep: Option<EpOptions>,
    /// Execution backend; `Auto` prefers PJRT artifacts when available
    /// and falls back to `CpuRef`. The `DUALSPARSE_BACKEND` env var
    /// (auto | cpu | pjrt) overrides this at engine construction.
    pub backend: BackendKind,
    /// Override the prefill bucket ladder ([`PREFILL_BUCKETS`] when
    /// `None`). Must be strictly increasing; the largest bucket is the
    /// chunk size of chunked prefill (prompts longer than it run as
    /// several bucket-sized passes into the same KV slot), so it must
    /// not exceed `max_seq`. Mostly a test hook: the chunked-prefill
    /// equivalence suite compares a default-bucket engine against one
    /// whose largest bucket covers the whole prompt in a single pass.
    pub prefill_buckets: Option<Vec<usize>>,
    /// Positions per KV page ([`kv::DEFAULT_PAGE_SIZE`] when `None`).
    /// With `page_size >= max_seq` every sequence occupies one page and
    /// the cache degenerates to the old slot-granularity layout.
    pub page_size: Option<usize>,
    /// Total physical KV pages per layer. Defaults to
    /// `MAX_SLOTS · ceil(max_seq / page_size)` — exactly the old
    /// slot-world capacity. Smaller budgets make admission
    /// page-bound (and preemption reachable) before it is slot-bound.
    pub kv_pages: Option<usize>,
    /// Neuron-level sparsity: kept fraction of probe-ranked neurons per
    /// routed sub-expert (paper §4.2b). `None` (or `Some(1.0)`) runs
    /// the dense kernels byte-identically to an engine built without
    /// this option. Any value `< 1.0` requires importance tables and
    /// the `CpuRef` backend (the masked FFN artifacts are
    /// CpuRef-only). The shared expert is never masked — there is no
    /// probe table for it.
    pub neuron_keep: Option<f32>,
    /// Run expert FFNs through the int8 quantized-weight kernels
    /// (symmetric per-tensor scales, dequantize-in-register). CpuRef
    /// only. The `DUALSPARSE_QUANT` env var (1/0, true/false, on/off,
    /// yes/no) overrides this at engine construction.
    pub quant: bool,
}

/// Aggregated engine metrics (fig6/fig10/fig11/fig12 inputs).
#[derive(Debug, Default, Clone)]
pub struct EngineMetrics {
    pub per_layer_drop: Vec<DropStats>,
    pub shared_pairs: u64,
    pub raw_scores: Vec<f32>,
    pub norm_scores: Vec<f32>,
    pub expert_counts: Vec<Vec<u64>>,
    pub decode_steps: u64,
    pub prefill_tokens: u64,
    pub generated_tokens: u64,
    /// Per-EP-device accumulated FFN busy time (seconds).
    pub device_time: Vec<f64>,
    /// Per-EP-device routed token-expert pairs before dropping.
    pub device_load: Vec<u64>,
}

impl EngineMetrics {
    pub fn total_drop(&self) -> DropStats {
        let mut s = DropStats::default();
        for d in &self.per_layer_drop {
            s.merge(d);
        }
        s
    }

    /// Paper's drop-rate definition; includes shared-expert compute in
    /// the denominator for shared-expert models (§5.3.1).
    pub fn drop_rate(&self) -> f64 {
        let t = self.total_drop();
        let denom = t.total() as f64 + self.shared_pairs as f64;
        if denom == 0.0 {
            return 0.0;
        }
        (t.dropped as f64 + 0.5 * t.major_only as f64) / denom
    }

    /// Simulated EP MoE makespan: max per-device busy time. Returns a
    /// clean 0.0 for empty / all-zero / non-finite device times (the
    /// instant-run CpuRef case) so downstream speedup columns never
    /// divide by garbage.
    pub fn makespan(&self) -> f64 {
        self.device_time
            .iter()
            .cloned()
            .filter(|t| t.is_finite())
            .fold(0.0, f64::max)
    }
}

/// Backend-resident buffers for one weight-bearing executable argument
/// set (uploaded once at load; the hot path never re-copies weights).
/// With quantization on, `w1/w3/w2` hold the int8 codes (as
/// integer-valued f32 through the unchanged upload ABI) and `scales`
/// carries the `[s_w1, s_w3, s_w2]` dequantization scales.
struct VariantBufs {
    w1: BufId,
    w3: BufId,
    w2: BufId,
    width: usize,
    /// Probe-ranked kept-neuron mask (variant-local indices) when
    /// neuron-level sparsity is on; `None` ⇒ dense. A full mask
    /// normalizes to `None` so keep = 1.0 is *structurally* identical
    /// to dense (same artifact names, same args — byte-identity for
    /// free).
    kept: Option<Vec<i32>>,
    /// `[3]` host tensor of per-matrix int8 scales when quantized.
    scales: Option<Tensor>,
}

struct LayerBufs {
    ln1: BufId,
    wq: BufId,
    wk: BufId,
    wv: BufId,
    wo: BufId,
    ln2: BufId,
    wg: BufId,
}

struct ExpertBufs {
    full: VariantBufs,
    major: VariantBufs,
    minor: VariantBufs,
}

pub struct Engine {
    /// The pluggable execution backend (PJRT / CpuRef / future GPU).
    pub rt: Box<dyn Backend>,
    pub cfg: ModelConfig,
    weights: Weights,
    /// `[layer][original expert]` partitioned weights.
    experts: Vec<Vec<PartitionedExpert>>,
    /// `[layer]` shared expert (DeepSeek-style), full width.
    shared: Vec<Option<SubExpert>>,
    /// Persistent backend buffers mirroring the above.
    lbufs: Vec<LayerBufs>,
    ebufs: Vec<Vec<ExpertBufs>>,
    sbufs: Vec<Option<VariantBufs>>,
    lnf_buf: BufId,
    emb_buf: BufId,
    pub kv: kv::KvCache,
    /// Prefill bucket ladder (strictly increasing; last = the chunked-
    /// prefill chunk size). [`PREFILL_BUCKETS`] unless overridden via
    /// [`EngineOptions::prefill_buckets`].
    prefill_buckets: Vec<usize>,
    pub policy: DropPolicy,
    pub router_mode: RouterMode,
    pub opts: EngineOptions,
    pub metrics: EngineMetrics,
    /// Virtual expert-parallel deployment (placement, load accounting,
    /// load-aware thresholding, replication) when EP is on.
    ep_sim: Option<EpSim>,
    /// When set, every routed (token, expert) pair is also run through
    /// the probe artifact and accumulated (calibration mode, §4.2b).
    pub probe: Option<crate::calib::ProbeTables>,
    /// Serve through the partial-transformation split: every kept FULL
    /// pair executes as two sub-expert calls (major + minor) with the
    /// repeated original score — the runtime face of Eq. 13. Used by the
    /// Table 1 consistency row and the S-ETP-style deployments.
    pub force_split: bool,
}

impl Engine {
    /// Build an engine for `model_name`. Loads serialized weights when
    /// `make artifacts` has produced them; otherwise materializes
    /// deterministic synthetic weights for the built-in preset of that
    /// name, so the stack runs hermetically on the `CpuRef` backend.
    pub fn new(
        artifacts_dir: &Path,
        model_name: &str,
        policy: DropPolicy,
        opts: EngineOptions,
    ) -> Result<Self> {
        let weights = Weights::load_or_synthetic(&artifacts_dir.join("models"), model_name)?;
        Self::from_weights(artifacts_dir, weights, policy, opts)
    }

    /// Build an engine around already-loaded (possibly surgically
    /// modified — see `baselines::apply_wanda_2_4`) weights.
    pub fn from_weights(
        artifacts_dir: &Path,
        weights: Weights,
        policy: DropPolicy,
        mut opts: EngineOptions,
    ) -> Result<Self> {
        let rt = make_backend(opts.backend, artifacts_dir)?;
        let cfg = weights.config.clone();
        rt.set_model(&cfg);
        // Resolve neuron-level sparsity + quantization up front: both
        // change which FFN artifacts the hot path names, and only the
        // CpuRef backend synthesizes those artifacts.
        opts.quant = match std::env::var("DUALSPARSE_QUANT") {
            Ok(v) if !v.is_empty() => parse_bool_env("DUALSPARSE_QUANT", &v)?,
            _ => opts.quant,
        };
        let keep = opts.neuron_keep.unwrap_or(1.0);
        if !(0.0..=1.0).contains(&keep) {
            bail!("neuron_keep must be in 0.0..=1.0, got {keep}");
        }
        let neuron_on = keep < 1.0;
        if neuron_on && opts.importance.is_none() {
            bail!(
                "neuron_keep < 1.0 requires importance tables — run \
                 `dualsparse calibrate {}` first",
                cfg.name
            );
        }
        if (neuron_on || opts.quant) && rt.platform() != "cpu-ref" {
            bail!(
                "neuron-level sparsity / quantized kernels are CpuRef-only \
                 (backend platform is {})",
                rt.platform()
            );
        }
        let mut experts = Vec::with_capacity(cfg.n_layers);
        for li in 0..cfg.n_layers {
            let imp = match (&opts.importance, opts.reconstructed) {
                (Some(tables), true) => Some(tables[li].as_slice()),
                (None, true) => bail!(
                    "reconstructed=true requires importance tables — run \
                     `dualsparse calibrate {}` first",
                    cfg.name
                ),
                _ => None,
            };
            experts.push(crate::moe::build_layer(&weights, li, imp)?);
        }
        let shared = (0..cfg.n_layers)
            .map(|li| -> Result<Option<SubExpert>> {
                if cfg.n_shared == 0 {
                    return Ok(None);
                }
                Ok(Some(SubExpert {
                    w1: weights.layer(li, "sw1")?.clone(),
                    w3: weights.layer(li, "sw3")?.clone(),
                    w2: weights.layer(li, "sw2")?.clone(),
                    width: cfg.d_ffn_shared,
                    cols: (0..cfg.d_ffn_shared).collect(),
                }))
            })
            .collect::<Result<Vec<_>>>()?;
        // Upload every weight tensor to a persistent device buffer.
        // `imp` is the owning expert's full-width importance row (None
        // for the shared expert and whenever neuron sparsity is off);
        // keep masks rank it through the variant's `cols` mapping.
        let up = |t: &Tensor| rt.upload(t);
        let up3 = |se: &SubExpert, imp: Option<&[f32]>| -> Result<VariantBufs> {
            let kept = match imp {
                Some(imp) if neuron_on => {
                    let m = crate::moe::partition::keep_mask(&se.cols, imp, keep);
                    // Full mask ⇒ dense: same artifact, same args.
                    if m.len() == se.width { None } else { Some(m) }
                }
                _ => None,
            };
            let (w1, w3, w2, scales) = if opts.quant {
                let q = crate::moe::partition::QuantizedWeights::from_sub_expert(se);
                (
                    rt.upload(&q.w1)?,
                    rt.upload(&q.w3)?,
                    rt.upload(&q.w2)?,
                    Some(Tensor::new(vec![3], q.scales.to_vec())),
                )
            } else {
                (rt.upload(&se.w1)?, rt.upload(&se.w3)?, rt.upload(&se.w2)?, None)
            };
            Ok(VariantBufs { w1, w3, w2, width: se.width, kept, scales })
        };
        let mut lbufs = Vec::with_capacity(cfg.n_layers);
        let mut ebufs = Vec::with_capacity(cfg.n_layers);
        let mut sbufs = Vec::with_capacity(cfg.n_layers);
        for li in 0..cfg.n_layers {
            lbufs.push(LayerBufs {
                ln1: up(weights.layer(li, "ln1")?)?,
                wq: up(weights.layer(li, "wq")?)?,
                wk: up(weights.layer(li, "wk")?)?,
                wv: up(weights.layer(li, "wv")?)?,
                wo: up(weights.layer(li, "wo")?)?,
                ln2: up(weights.layer(li, "ln2")?)?,
                wg: up(weights.layer(li, "wg")?)?,
            });
            ebufs.push(
                experts[li]
                    .iter()
                    .enumerate()
                    .map(|(ei, pe)| -> Result<ExpertBufs> {
                        let imp_e = if neuron_on {
                            opts.importance.as_ref().map(|t| t[li][ei].as_slice())
                        } else {
                            None
                        };
                        Ok(ExpertBufs {
                            full: up3(&pe.full, imp_e)?,
                            major: up3(&pe.major, imp_e)?,
                            minor: up3(&pe.minor, imp_e)?,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?,
            );
            sbufs.push(match &shared[li] {
                Some(se) => Some(up3(se, None)?),
                None => None,
            });
        }
        let lnf_buf = up(weights.get("lnf")?)?;
        let emb_buf = up(weights.get("emb")?)?;
        let page_size = opts.page_size.unwrap_or(kv::DEFAULT_PAGE_SIZE);
        if page_size == 0 {
            bail!("page_size must be positive");
        }
        // Default physical budget reproduces the slot world exactly:
        // every admitted sequence can always grow to max_seq.
        let n_pages = opts
            .kv_pages
            .unwrap_or(MAX_SLOTS * cfg.max_seq.div_ceil(page_size));
        if n_pages == 0 {
            bail!("kv_pages must be positive");
        }
        let kv = kv::KvCache::new(
            cfg.n_layers,
            cfg.n_heads,
            cfg.max_seq,
            cfg.d_head,
            MAX_SLOTS,
            page_size,
            n_pages,
        );
        let prefill_buckets = match &opts.prefill_buckets {
            Some(b) => {
                if b.is_empty() || b.windows(2).any(|w| w[0] >= w[1]) {
                    bail!("prefill_buckets must be non-empty and strictly increasing: {b:?}");
                }
                if *b.last().unwrap() > cfg.max_seq {
                    bail!(
                        "largest prefill bucket {} exceeds max_seq {}",
                        b.last().unwrap(),
                        cfg.max_seq
                    );
                }
                b.clone()
            }
            None => PREFILL_BUCKETS.to_vec(),
        };
        let ep_sim = opts.ep.clone().map(|o| EpSim::new(o, cfg.n_experts));
        let n_dev = ep_sim.as_ref().map(EpSim::n_workers).unwrap_or(1);
        let metrics = EngineMetrics {
            per_layer_drop: vec![DropStats::default(); cfg.n_layers],
            expert_counts: vec![vec![0; cfg.n_experts]; cfg.n_layers],
            device_time: vec![0.0; n_dev],
            device_load: vec![0; n_dev],
            ..Default::default()
        };
        Ok(Engine {
            rt,
            cfg,
            weights,
            experts,
            shared,
            lbufs,
            ebufs,
            sbufs,
            lnf_buf,
            emb_buf,
            kv,
            prefill_buckets,
            policy,
            router_mode: RouterMode::Standard,
            opts,
            metrics,
            ep_sim,
            probe: None,
            force_split: false,
        })
    }

    /// Reset all accumulated metrics AND the EP simulator (fresh
    /// round-robin placement, zeroed accumulators) — a serve run starts
    /// from a clean deployment.
    pub fn reset_metrics(&mut self) {
        self.ep_sim = self.opts.ep.clone().map(|o| EpSim::new(o, self.cfg.n_experts));
        let n_dev = self.ep_sim.as_ref().map(EpSim::n_workers).unwrap_or(1);
        self.metrics = EngineMetrics {
            per_layer_drop: vec![DropStats::default(); self.cfg.n_layers],
            expert_counts: vec![vec![0; self.cfg.n_experts]; self.cfg.n_layers],
            device_time: vec![0.0; n_dev],
            device_load: vec![0; n_dev],
            ..Default::default()
        };
        self.rt.reset_counters();
    }

    /// Swap the EP configuration on a live engine (the serve sweep's EP
    /// dimension reuses one engine instead of re-uploading weights).
    /// Resets metrics and the simulated deployment.
    pub fn set_ep(&mut self, ep: Option<EpOptions>) {
        self.opts.ep = ep;
        self.reset_metrics();
    }

    /// Aggregated EP observables for the run since the last
    /// [`Engine::reset_metrics`], when EP is on.
    pub fn ep_report(&self) -> Option<EpReport> {
        self.ep_sim.as_ref().map(EpSim::report)
    }

    /// Injected EP worker failure ([`EpSim::fail_worker`]): re-host its
    /// experts onto survivors. Returns the number of experts re-hosted
    /// (0 when EP is off or the failure is refused).
    pub fn fail_ep_worker(&mut self, w: usize) -> u64 {
        self.ep_sim.as_mut().map(|s| s.fail_worker(w)).unwrap_or(0)
    }

    /// Injected EP worker slow-down ([`EpSim::slow_worker`]). No-op
    /// when EP is off.
    pub fn slow_ep_worker(&mut self, w: usize, factor: f64) {
        if let Some(s) = self.ep_sim.as_mut() {
            s.slow_worker(w, factor);
        }
    }

    // ------------------------------------------------------------------
    // Embedding
    // ------------------------------------------------------------------

    /// `x = emb[token] + pos_emb[position]`, one row per (token, pos).
    fn embed(&self, tokens: &[u8], positions: &[usize]) -> Result<Tensor> {
        let d = self.cfg.d_model;
        let emb = self.weights.get("emb")?;
        let pos = self.weights.get("pos")?;
        let mut data = vec![0.0f32; tokens.len() * d];
        for (i, (&t, &p)) in tokens.iter().zip(positions).enumerate() {
            let er = emb.row(t as usize);
            let pr = pos.row(p);
            for j in 0..d {
                data[i * d + j] = er[j] + pr[j];
            }
        }
        Ok(Tensor::new(vec![tokens.len(), d], data))
    }

    // ------------------------------------------------------------------
    // Routing
    // ------------------------------------------------------------------

    /// Route one token's gate-score row according to the router mode.
    fn route(&self, scores: &[f32], li: usize) -> TokenRouting {
        match &self.router_mode {
            RouterMode::Standard => route_token(
                scores, self.cfg.top_k, self.cfg.normalized_gating,
            ),
            RouterMode::Ees { beta } => {
                let mut r = route_token(
                    scores, self.cfg.top_k, self.cfg.normalized_gating,
                );
                // Empty selection (top_k == 0): the token simply
                // contributes zero MoE output — nothing to skip.
                if r.experts.is_empty() {
                    return r;
                }
                let top = r.experts[0].1;
                r.experts = r
                    .experts
                    .iter()
                    .enumerate()
                    .filter(|&(i, &(_, s, _))| i == 0 || s >= beta * top)
                    .map(|(_, &e)| e)
                    .collect();
                r
            }
            RouterMode::Eep { kept } => self.route_eep(scores, &kept[li], None),
            RouterMode::EepEes { kept, beta } => {
                self.route_eep(scores, &kept[li], Some(*beta))
            }
        }
    }

    /// EEP routing: renormalize over the kept set, Top-K, and optionally
    /// stack EES's β-ratio skipping on top.
    fn route_eep(&self, scores: &[f32], kept: &[usize], ees_beta: Option<f32>) -> TokenRouting {
        let sum: f32 = kept.iter().map(|&e| scores[e]).sum();
        let mut kept_scores: Vec<(usize, f32)> = kept
            .iter()
            .map(|&e| (e, if sum > 0.0 { scores[e] / sum } else { 0.0 }))
            .collect();
        // total order with NaN-last: degenerate weights can renormalize
        // to NaN (e.g. inf/inf), which panicked the old partial_cmp sort.
        kept_scores.sort_by(|a, b| crate::moe::cmp_desc_nan_last(a.0, a.1, b.0, b.1));
        let k = self.cfg.top_k.min(kept_scores.len());
        let mut sel: Vec<(usize, f32)> = kept_scores[..k].to_vec();
        // An empty kept list (fully-pruned layer) or top_k == 0 selects
        // nothing: return an empty routing instead of indexing sel[0].
        if sel.is_empty() {
            return TokenRouting { experts: Vec::new() };
        }
        if let Some(beta) = ees_beta {
            let top = sel[0].1;
            sel = sel
                .into_iter()
                .enumerate()
                .filter(|&(i, (_, s))| i == 0 || s >= beta * top)
                .map(|(_, e)| e)
                .collect();
        }
        let ssum: f32 = sel.iter().map(|(_, s)| s).sum();
        TokenRouting {
            experts: sel
                .iter()
                .map(|&(e, s)| (e, s, if ssum > 0.0 { s / ssum } else { 0.0 }))
                .collect(),
        }
    }

    // ------------------------------------------------------------------
    // MoE layer
    // ------------------------------------------------------------------

    /// Run the MoE block for `n_rows` valid rows of `ln2x` ([R, d], rows
    /// ≥ n_rows are padding). Returns the MoE output [R, d] (padding
    /// rows zero).
    fn moe_layer(&mut self, li: usize, ln2x: &Tensor, n_rows: usize) -> Result<Tensor> {
        let d = self.cfg.d_model;
        let e_count = self.cfg.n_experts;
        // 1. gate scores via artifact (bucketed on the row count)
        let rb = round_up_bucket(
            ln2x.shape[0],
            if ln2x.shape[0] > 16 { &self.prefill_buckets } else { &BATCH_BUCKETS },
        );
        debug_assert_eq!(ln2x.shape[0], rb, "caller pads to a bucket");
        let gate_out = self.rt.exec(
            &format!("gate_b{}_e{}", ln2x.shape[0], e_count),
            &[Arg::F32(ln2x), Arg::Buf(self.lbufs[li].wg)],
        )?;
        let probs = &gate_out[0]; // [R, E]

        // 2. route real rows
        let routings: Vec<TokenRouting> = (0..n_rows)
            .map(|r| self.route(probs.row(r), li))
            .collect();
        if self.opts.collect_stats {
            for r in &routings {
                for &(e, s, n) in &r.experts {
                    self.metrics.expert_counts[li][e] += 1;
                    self.metrics.raw_scores.push(s);
                    self.metrics.norm_scores.push(n);
                }
            }
        }

        // 3. drop decisions (load-aware per-worker scaling under EP
        // §4.3): the EP simulator assigns every routed pair to a
        // virtual worker; when load-aware, each worker's policy is the
        // base scaled by its routed load relative to the hottest
        // worker (the hottest keeps the base policy unchanged).
        let ep_inv = self.ep_sim.as_ref().map(|sim| sim.observe(&routings, self.policy));
        if let Some(inv) = &ep_inv {
            for (w, &l) in inv.routed.iter().enumerate() {
                self.metrics.device_load[w] += l;
            }
        }
        let plan = match (&self.ep_sim, &ep_inv) {
            (Some(sim), Some(inv)) => match sim.policies(inv, self.policy) {
                Some(pols) => {
                    let f = |row: usize, e: usize| pols[inv.worker(row, e)];
                    plan_dispatch(&routings, e_count, self.policy, Some(&f))
                }
                None => plan_dispatch(&routings, e_count, self.policy, None),
            },
            _ => plan_dispatch(&routings, e_count, self.policy, None),
        };
        self.metrics.per_layer_drop[li].merge(&plan.stats);

        // 3b. calibration probing: accumulate the four importance rows
        // for every routed pair (original, un-permuted expert weights).
        if self.probe.is_some() {
            let mut probe = self.probe.take();
            if let Some(tables) = &mut probe {
                for e in 0..e_count {
                    if plan.full[e].is_empty() {
                        continue;
                    }
                    let w1 = self.weights.layer(li, "w1")?.index0(e);
                    let w3 = self.weights.layer(li, "w3")?.index0(e);
                    for chunk in plan.full[e].chunks(32) {
                        let mut x = vec![0.0f32; 32 * d];
                        for (i, &(r, _)) in chunk.iter().enumerate() {
                            x[i * d..(i + 1) * d]
                                .copy_from_slice(&ln2x.data[r * d..(r + 1) * d]);
                        }
                        let xt = Tensor::new(vec![32, d], x);
                        let imp = self.rt.exec(
                            &format!("probe_h{}", self.cfg.d_ffn),
                            &[Arg::F32(&xt), Arg::F32(&w1), Arg::F32(&w3)],
                        )?;
                        let it = &imp[0]; // [4, width]
                        let w = tables.width;
                        for m in 0..4 {
                            let dst = &mut tables.t[li][e][m];
                            for j in 0..w {
                                dst[j] += it.data[m * w + j];
                            }
                        }
                    }
                }
            }
            self.probe = probe;
        }

        // 4. execute kept work through capacity-bucketed FFN artifacts,
        // one worker task per expert.
        //
        // Sub-expert-granular execution (paper §4.2's grouped-GEMM): when
        // anything runs at reduced width (2T bands, or force_split), the
        // MAJOR sub-expert serves full-band ∪ major-only rows in ONE
        // packed call and the MINOR sub-expert serves the full band —
        // at most two calls per expert, maximally packed.
        //
        // Each expert task scatters into its OWN buffer; buffers are
        // merged serially in ascending expert order afterwards, so the
        // result is bit-identical for every thread count (fixed
        // reduction order). Within a task the packing scratch is reused
        // between the major and minor calls.
        let rb_rows = ln2x.shape[0];
        let ep_on = self.ep_sim.is_some();
        let work: Vec<usize> = (0..e_count)
            .filter(|&e| !plan.full[e].is_empty() || !plan.major_only[e].is_empty())
            .collect();
        let force_split = self.force_split;
        let ebufs = &self.ebufs[li];
        let rt: &dyn Backend = self.rt.as_ref();
        // Threaded dispatch only when the backend allows concurrent
        // exec AND the layer is worth it: below ~1M madds the
        // scoped-thread spawn dominates the GEMMs (single-token
        // decode). The fallback is an in-order serial walk of the SAME
        // per-expert-buffer structure, so the numbers are identical
        // either way.
        let kept_pairs: usize =
            work.iter().map(|&e| plan.full[e].len() + plan.major_only[e].len()).sum();
        let parallel_worthwhile = rt.supports_concurrent_exec()
            && kept_pairs * d * self.cfg.d_ffn * 6 >= (1 << 20);
        let expert_task = |wi: usize| -> Result<(Tensor, f64)> {
            let e = work[wi];
            let full_rows = &plan.full[e];
            let major_rows = &plan.major_only[e];
            let mut buf = Tensor::zeros(vec![rb_rows, d]);
            let mut scratch: Vec<f32> = Vec::new();
            let mut dt = 0.0;
            let split = force_split || !major_rows.is_empty();
            if split {
                if major_rows.is_empty() {
                    dt += run_sub_expert(
                        rt, d, ln2x, full_rows, &ebufs[e].major, &mut buf, &mut scratch,
                    )?;
                } else {
                    let mut both = full_rows.clone();
                    both.extend_from_slice(major_rows);
                    dt += run_sub_expert(
                        rt, d, ln2x, &both, &ebufs[e].major, &mut buf, &mut scratch,
                    )?;
                }
                if !full_rows.is_empty() {
                    dt += run_sub_expert(
                        rt, d, ln2x, full_rows, &ebufs[e].minor, &mut buf, &mut scratch,
                    )?;
                }
            } else {
                dt += run_sub_expert(
                    rt, d, ln2x, full_rows, &ebufs[e].full, &mut buf, &mut scratch,
                )?;
            }
            Ok((buf, dt))
        };
        let mut out = Tensor::zeros(vec![rb_rows, d]);
        // Per-expert measured exec seconds, collected in ascending
        // expert order in both branches; the EP simulator attributes
        // them to workers after the merge.
        let mut expert_secs: Vec<(usize, f64)> = Vec::new();
        if parallel_worthwhile {
            let results = crate::util::threads::parallel_map(work.len(), &expert_task);
            for (wi, res) in results.into_iter().enumerate() {
                let e = work[wi];
                let (buf, dt) = res?;
                merge_expert_rows(&plan, e, d, &buf, &mut out);
                if ep_on {
                    expert_secs.push((e, dt));
                }
            }
        } else {
            // Serial: merge each expert as it finishes — one live
            // buffer at a time. The buffer+merge structure is kept
            // DELIBERATELY (not scatter-straight-into-out): it makes
            // every row's reduction tree identical in both branches,
            // so the same token produces bit-identical output whether
            // its layer call lands above or below the parallel
            // threshold (e.g. alone vs inside a big batch — the
            // `batched_equals_single_generation` invariant).
            for (wi, &e) in work.iter().enumerate() {
                let (buf, dt) = expert_task(wi)?;
                merge_expert_rows(&plan, e, d, &buf, &mut out);
                if ep_on {
                    expert_secs.push((e, dt));
                }
            }
        }
        // EP accounting: straggler/comm charging, per-worker busy
        // attribution, and (if configured) hot-expert replication.
        if let (Some(sim), Some(inv)) = (self.ep_sim.as_mut(), &ep_inv) {
            let busy = sim.charge(inv, &plan, &expert_secs, d);
            for (w, s) in busy.into_iter().enumerate() {
                self.metrics.device_time[w] += s;
            }
        }

        // 5. shared expert (always-on, DeepSeek-style)
        if self.shared[li].is_some() {
            self.metrics.shared_pairs += n_rows as u64;
        }
        if let Some(sb) = &self.sbufs[li] {
            let rows: Vec<(usize, f32)> = (0..n_rows).map(|r| (r, 1.0)).collect();
            let mut scratch: Vec<f32> = Vec::new();
            run_sub_expert(self.rt.as_ref(), d, ln2x, &rows, sb, &mut out, &mut scratch)?;
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    // Prefill / decode
    // ------------------------------------------------------------------

    /// Longest admissible prompt for a request allowed up to `max_new`
    /// generated tokens. Prefill writes `prompt.len()` KV positions and
    /// every decode step appends one more, so admission requires
    /// `prompt.len() + max_new ≤ max_seq` — and, since paged KV, that a
    /// single sequence can even be granted that many positions out of
    /// the physical page pool (`n_pages · page_size`). The largest
    /// prefill bucket is just the chunk size, not a length limit.
    pub fn prompt_capacity(&self, max_new: usize) -> usize {
        self.cfg
            .max_seq
            .min(self.kv.n_pages.saturating_mul(self.kv.page_size))
            .saturating_sub(max_new)
    }

    /// The chunked-prefill chunk size (largest prefill bucket). Prompts
    /// longer than this need one `attn_prefill_chunk_s{S}` pass per
    /// extra chunk.
    pub fn max_prefill_chunk(&self) -> usize {
        *self.prefill_buckets.last().unwrap()
    }

    /// Fail fast if serving `prompt_len` would need a chunked-prefill
    /// continuation artifact the backend cannot execute. CpuRef
    /// synthesizes every artifact so this never fires there; on AOT
    /// backends (PJRT) a missing `attn_prefill_chunk_s{S}` otherwise
    /// surfaces mid-run, on the first long prompt.
    pub fn check_chunked_prefill_support(&self, prompt_len: usize) -> Result<()> {
        let max_chunk = self.max_prefill_chunk();
        let mut base = max_chunk;
        while base < prompt_len {
            let take = (prompt_len - base).min(max_chunk);
            let sb = round_up_bucket(take, &self.prefill_buckets);
            let name = format!("attn_prefill_chunk_s{sb}");
            if !self.rt.supports_artifact(&name) {
                bail!("chunked prefill requires CpuRef (missing {name} artifact)");
            }
            base += take;
        }
        Ok(())
    }

    /// Prefill one request into `slot`; returns the first generated token.
    ///
    /// **Chunked prefill**: a prompt longer than the largest prefill
    /// bucket is split into successive bucket-sized passes over the
    /// same KV slot. The first chunk runs the classic
    /// `attn_prefill_s{S}` artifact; each later chunk runs
    /// `attn_prefill_chunk_s{S}`, whose queries attend over the slot's
    /// cached K/V (positions `0..base`) before the in-chunk causal
    /// window. Every per-token computation (projections, scores in
    /// cached-then-in-chunk order, softmax, FFN rows) matches a single
    /// pass with a large-enough bucket operation-for-operation, so
    /// chunked logits are **bit-identical** to unchunked ones (pinned
    /// by `rust/tests/chunked_prefill.rs`).
    pub fn prefill(&mut self, slot: usize, prompt: &[u8]) -> Result<u8> {
        Ok(self.prefill_logits(slot, prompt)?.0)
    }

    /// [`Engine::prefill`] variant that also returns the logits row of
    /// the last prompt position (the distribution the first token is
    /// argmaxed from) — the chunked-prefill equivalence tests pin on it.
    pub fn prefill_logits(&mut self, seq: usize, prompt: &[u8]) -> Result<(u8, Vec<f32>)> {
        let mut base = 0usize;
        loop {
            let (next, fin) = self.prefill_chunk_inner(seq, prompt, base)?;
            if let Some(out) = fin {
                return Ok(out);
            }
            base = next;
        }
    }

    /// Run exactly **one** prefill chunk of `prompt` into sequence
    /// `seq`, starting at cached position `base` (0 for the first
    /// chunk; thereafter the value returned by the previous call).
    /// Returns `(next_base, Some(first_token))` when the prompt is
    /// fully prefilled, `(next_base, None)` otherwise. The scheduler's
    /// interleaved iteration loop drives this so one prefill chunk can
    /// ride alongside each decode batch instead of monopolizing the
    /// engine for the whole prompt.
    pub fn prefill_chunk(
        &mut self,
        seq: usize,
        prompt: &[u8],
        base: usize,
    ) -> Result<(usize, Option<u8>)> {
        let (next, fin) = self.prefill_chunk_inner(seq, prompt, base)?;
        Ok((next, fin.map(|(t, _)| t)))
    }

    fn prefill_chunk_inner(
        &mut self,
        seq: usize,
        prompt: &[u8],
        base: usize,
    ) -> Result<(usize, Option<(u8, Vec<f32>)>)> {
        let d = self.cfg.d_model;
        let s_len = prompt.len();
        if s_len == 0 {
            bail!("empty prompt");
        }
        if s_len > self.cfg.max_seq {
            bail!("prompt too long: {s_len} > max_seq {}", self.cfg.max_seq);
        }
        debug_assert!(base < s_len, "prefill chunk past end of prompt");
        let max_chunk = self.max_prefill_chunk();
        let take = (s_len - base).min(max_chunk);
        if !self.kv.ensure(seq, base + take) {
            bail!(
                "out of KV pages: sequence {seq} needs positions 0..{} \
                 ({} pages) but only {} pages are free",
                base + take,
                self.kv.pages_for(base + take),
                self.kv.free_page_count()
            );
        }
        let sb = round_up_bucket(take, &self.prefill_buckets);
        let mut toks = prompt[base..base + take].to_vec();
        toks.resize(sb, 0);
        // Padding rows clamp to a valid position-embedding row:
        // their outputs are discarded, their K/V never written, and
        // no real query attends to them, so the clamp cannot leak.
        let positions: Vec<usize> =
            (0..sb).map(|i| (base + i).min(self.cfg.max_seq - 1)).collect();
        let mut x = self.embed(&toks, &positions)?;
        for li in 0..self.cfg.n_layers {
            let outs = if base == 0 {
                let lb = &self.lbufs[li];
                self.rt.exec(
                    &format!("attn_prefill_s{sb}"),
                    &[
                        Arg::F32(&x),
                        Arg::Buf(lb.ln1),
                        Arg::Buf(lb.wq),
                        Arg::Buf(lb.wk),
                        Arg::Buf(lb.wv),
                        Arg::Buf(lb.wo),
                        Arg::Buf(lb.ln2),
                    ],
                )?
            } else {
                // Continuation chunk: lend the sequence's cached K/V
                // pages as a zero-copy paged view (same mechanism as
                // decode) plus the number of cached positions.
                let pstride = self.kv.page_stride();
                let kdata = &self.kv.k[li].data;
                let vdata = &self.kv.v[li].data;
                let kpages: Vec<&[f32]> = self
                    .kv
                    .seq_pages(seq)
                    .iter()
                    .map(|&pg| &kdata[pg * pstride..(pg + 1) * pstride])
                    .collect();
                let vpages: Vec<&[f32]> = self
                    .kv
                    .seq_pages(seq)
                    .iter()
                    .map(|&pg| &vdata[pg * pstride..(pg + 1) * pstride])
                    .collect();
                let row_starts = [0usize, kpages.len()];
                let base_i32 = [base as i32];
                let lb = &self.lbufs[li];
                self.rt.exec(
                    &format!("attn_prefill_chunk_s{sb}"),
                    &[
                        Arg::F32(&x),
                        Arg::Buf(lb.ln1),
                        Arg::Buf(lb.wq),
                        Arg::Buf(lb.wk),
                        Arg::Buf(lb.wv),
                        Arg::Buf(lb.wo),
                        Arg::Buf(lb.ln2),
                        Arg::F32Pages {
                            pages: &kpages,
                            row_starts: &row_starts,
                            n_heads: self.cfg.n_heads,
                            page: self.kv.page_size,
                            d_head: self.cfg.d_head,
                            t_max: self.cfg.max_seq,
                        },
                        Arg::F32Pages {
                            pages: &vpages,
                            row_starts: &row_starts,
                            n_heads: self.cfg.n_heads,
                            page: self.kv.page_size,
                            d_head: self.cfg.d_head,
                            t_max: self.cfg.max_seq,
                        },
                        Arg::I32(&base_i32),
                    ],
                )?
            };
            let (y, ln2x, ks, vs) = (&outs[0], &outs[1], &outs[2], &outs[3]);
            self.kv.write_prefill(li, seq, base, take, &ks.data, &vs.data);
            let moe = self.moe_layer(li, ln2x, take)?;
            x = Tensor::new(
                y.shape.clone(),
                y.data.iter().zip(&moe.data).map(|(a, b)| a + b).collect(),
            );
        }
        self.metrics.prefill_tokens += take as u64;
        if base + take < s_len {
            return Ok((base + take, None));
        }
        // logits for the last real position only
        let last = Tensor::new(vec![1, d], x.data[(take - 1) * d..take * d].to_vec());
        let logits = self.rt.exec(
            "lm_head_b1",
            &[
                Arg::F32(&last),
                Arg::Buf(self.lnf_buf),
                Arg::Buf(self.emb_buf),
            ],
        )?;
        let logits_row = logits[0].row(0).to_vec();
        let first = argmax_u8(&logits_row);
        Ok((base + take, Some((first, logits_row))))
    }

    /// One decode step for the active sequences `0..tokens.len()`
    /// (sequence i consumes `tokens[i]`); returns the next token per
    /// sequence. Convenience wrapper over [`Engine::decode_step_seqs`]
    /// for callers (eval, baselines) that allocate sequences densely
    /// from 0.
    pub fn decode_step(&mut self, tokens: &[u8]) -> Result<Vec<u8>> {
        let seqs: Vec<usize> = (0..tokens.len()).collect();
        self.decode_step_seqs(&seqs, tokens)
    }

    /// One decode step for an arbitrary set of sequence ids (`seqs[i]`
    /// consumes `tokens[i]`); returns the next token per sequence.
    ///
    /// Pages for the appended position are granted up front for every
    /// sequence (all-or-nothing per sequence); a grant failure is an
    /// error here — the scheduler resolves page faults by preempting a
    /// victim *before* calling this.
    pub fn decode_step_seqs(&mut self, seqs: &[usize], tokens: &[u8]) -> Result<Vec<u8>> {
        let b = tokens.len();
        assert_eq!(seqs.len(), b, "one token per sequence");
        for &seq in seqs {
            let upto = self.kv.pos[seq] + 1;
            if !self.kv.ensure(seq, upto) {
                bail!(
                    "out of KV pages: sequence {seq} needs position {} but \
                     only {} pages are free",
                    upto - 1,
                    self.kv.free_page_count()
                );
            }
        }
        let bb = round_up_bucket(b, &BATCH_BUCKETS);
        let mut toks = tokens.to_vec();
        toks.resize(bb, 0);
        let positions: Vec<usize> = (0..bb)
            .map(|i| if i < b { self.kv.pos[seqs[i]] } else { 0 })
            .collect();
        let mut x = self.embed(&toks, &positions)?;
        let pos_i32: Vec<i32> = positions.iter().map(|&p| p as i32).collect();
        for li in 0..self.cfg.n_layers {
            // Zero-copy KV: borrowed per-page slices of this layer's
            // cache in CSR layout (padding rows own an empty page range
            // and attend to nothing). The old path cloned the full
            // [bb, H, T, dh] cache pair here on every layer of every
            // step.
            let outs = {
                let pstride = self.kv.page_stride();
                let kdata = &self.kv.k[li].data;
                let vdata = &self.kv.v[li].data;
                let mut kpages: Vec<&[f32]> = Vec::new();
                let mut vpages: Vec<&[f32]> = Vec::new();
                let mut row_starts: Vec<usize> = Vec::with_capacity(bb + 1);
                row_starts.push(0);
                for &seq in seqs {
                    for &pg in self.kv.seq_pages(seq) {
                        kpages.push(&kdata[pg * pstride..(pg + 1) * pstride]);
                        vpages.push(&vdata[pg * pstride..(pg + 1) * pstride]);
                    }
                    row_starts.push(kpages.len());
                }
                for _ in b..bb {
                    row_starts.push(kpages.len());
                }
                let lb = &self.lbufs[li];
                self.rt.exec(
                    &format!("attn_step_b{bb}"),
                    &[
                        Arg::F32(&x),
                        Arg::Buf(lb.ln1),
                        Arg::Buf(lb.wq),
                        Arg::Buf(lb.wk),
                        Arg::Buf(lb.wv),
                        Arg::Buf(lb.wo),
                        Arg::Buf(lb.ln2),
                        Arg::F32Pages {
                            pages: &kpages,
                            row_starts: &row_starts,
                            n_heads: self.cfg.n_heads,
                            page: self.kv.page_size,
                            d_head: self.cfg.d_head,
                            t_max: self.cfg.max_seq,
                        },
                        Arg::F32Pages {
                            pages: &vpages,
                            row_starts: &row_starts,
                            n_heads: self.cfg.n_heads,
                            page: self.kv.page_size,
                            d_head: self.cfg.d_head,
                            t_max: self.cfg.max_seq,
                        },
                        Arg::I32(&pos_i32),
                    ],
                )?
            };
            let (y, ln2x, nk, nv) = (&outs[0], &outs[1], &outs[2], &outs[3]);
            let hd = self.cfg.n_heads * self.cfg.d_head;
            for (i, &seq) in seqs.iter().enumerate() {
                self.kv.append(
                    li,
                    seq,
                    &nk.data[i * hd..(i + 1) * hd],
                    &nv.data[i * hd..(i + 1) * hd],
                );
            }
            let moe = self.moe_layer(li, ln2x, b)?;
            x = Tensor::new(
                y.shape.clone(),
                y.data.iter().zip(&moe.data).map(|(a, b)| a + b).collect(),
            );
        }
        self.metrics.decode_steps += 1;
        self.metrics.generated_tokens += b as u64;
        let logits = self.rt.exec(
            &format!("lm_head_b{bb}"),
            &[
                Arg::F32(&x),
                Arg::Buf(self.lnf_buf),
                Arg::Buf(self.emb_buf),
            ],
        )?;
        Ok((0..b).map(|i| argmax_u8(logits[0].row(i))).collect())
    }

    // ------------------------------------------------------------------
    // Generation + evaluation
    // ------------------------------------------------------------------

    /// Greedy-generate completions for a batch of prompts (lockstep
    /// decode; finished rows keep decoding but their output is frozen —
    /// simple and deterministic for eval).
    pub fn generate_batch(&mut self, prompts: &[&str], max_new: usize) -> Result<Vec<String>> {
        assert!(prompts.len() <= MAX_SLOTS);
        self.kv.reset();
        let mut next: Vec<u8> = Vec::new();
        for p in prompts {
            let slot = self.kv.alloc();
            next.push(self.prefill(slot, p.as_bytes())?);
        }
        let mut outs: Vec<Vec<u8>> = next.iter().map(|&t| vec![t]).collect();
        let mut done: Vec<bool> = next.iter().map(|&t| t == EOS).collect();
        for _ in 1..max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            let step = self.decode_step(&next)?;
            for i in 0..prompts.len() {
                if !done[i] {
                    outs[i].push(step[i]);
                    if step[i] == EOS {
                        done[i] = true;
                    }
                }
                next[i] = step[i];
            }
        }
        Ok(outs
            .into_iter()
            .map(|o| {
                let end = o.iter().position(|&c| c == EOS).unwrap_or(o.len());
                o[..end].iter().map(|&b| b as char).collect()
            })
            .collect())
    }

    /// Per-artifact exec statistics snapshot (name → (count, secs)).
    pub fn exec_stats(&self) -> HashMap<String, (u64, f64)> {
        self.rt.exec_counts()
    }

    /// Seconds spent in the MoE module (gate + expert FFNs).
    pub fn moe_time(&self) -> f64 {
        self.rt.time_with_prefix("ffn_") + self.rt.time_with_prefix("gate_")
    }

    /// Seconds of end-to-end artifact compute.
    pub fn total_artifact_time(&self) -> f64 {
        self.rt.time_with_prefix("")
    }
}

/// Add expert `e`'s scatter buffer into `out`, touching only the rows
/// the expert actually served (full ∪ major-only are disjoint row
/// sets). Untouched rows of `buf` are exact zeros, so skipping them is
/// value-identical to a full-buffer add — and the per-row, ascending-
/// expert order is what makes the output independent of thread count.
fn merge_expert_rows(plan: &DispatchPlan, e: usize, d: usize, buf: &Tensor, out: &mut Tensor) {
    for &(r, _) in plan.full[e].iter().chain(plan.major_only[e].iter()) {
        let src = &buf.data[r * d..(r + 1) * d];
        let dst = &mut out.data[r * d..(r + 1) * d];
        for j in 0..d {
            dst[j] += src[j];
        }
    }
}

/// Pack `rows` of ln2x into capacity buckets, run the FFN artifact,
/// scatter-add score-weighted outputs into `out`. `scratch` is the
/// packing buffer, reused across calls (major + minor of one expert
/// share it; each worker task owns its own). Row sets larger than the
/// biggest capacity bucket (possible only with an oversized prefill
/// bucket override routing one chunk's worth of tokens to one expert)
/// are split across several maximally-packed calls; the FFN is
/// row-independent, so the split leaves every row's value bit-identical
/// to a hypothetical single call.
///
/// Returns **backend exec seconds only** — host-side packing and
/// scatter are excluded, so EP `device_time` attributes exactly the
/// per-device kernel busy time (not coordinator overhead).
fn run_sub_expert(
    rt: &dyn Backend,
    d: usize,
    ln2x: &Tensor,
    rows: &[(usize, f32)],
    se: &VariantBufs,
    out: &mut Tensor,
    scratch: &mut Vec<f32>,
) -> Result<f64> {
    let max_c = *CAPACITY_BUCKETS.last().unwrap();
    let mut secs = 0.0f64;
    for rows_chunk in rows.chunks(max_c) {
        let c = round_up_bucket(rows_chunk.len(), &CAPACITY_BUCKETS);
        scratch.clear();
        scratch.resize(c * d, 0.0);
        for (i, &(r, _)) in rows_chunk.iter().enumerate() {
            scratch[i * d..(i + 1) * d].copy_from_slice(&ln2x.data[r * d..(r + 1) * d]);
        }
        let xt = Tensor::new(vec![c, d], std::mem::take(scratch));
        // Dense / masked / quantized variants share one dispatch: the
        // artifact name encodes the kernel family and the optional
        // scales (arg 4) and kept-mask (last arg) ride behind the
        // always-present x/w1/w3/w2 quartet. With `kept == None` and
        // `scales == None` this is byte-for-byte the historical dense
        // call — names, args and timing identical.
        let name = match (&se.kept, &se.scales) {
            (None, None) => format!("ffn_h{}_c{}", se.width, c),
            (Some(k), None) => format!("ffn_mask_h{}k{}_c{}", se.width, k.len(), c),
            (None, Some(_)) => format!("ffn_q8_h{}_c{}", se.width, c),
            (Some(k), Some(_)) => {
                format!("ffn_q8_mask_h{}k{}_c{}", se.width, k.len(), c)
            }
        };
        let mut args =
            vec![Arg::F32(&xt), Arg::Buf(se.w1), Arg::Buf(se.w3), Arg::Buf(se.w2)];
        if let Some(s) = &se.scales {
            args.push(Arg::F32(s));
        }
        if let Some(k) = &se.kept {
            args.push(Arg::I32(k));
        }
        let t0 = std::time::Instant::now();
        let y = rt.exec(&name, &args)?;
        secs += t0.elapsed().as_secs_f64();
        // hand the packing buffer back for the next call
        *scratch = xt.data;
        let yt = &y[0];
        for (i, &(r, w)) in rows_chunk.iter().enumerate() {
            let src = &yt.data[i * d..(i + 1) * d];
            let dst = &mut out.data[r * d..(r + 1) * d];
            for j in 0..d {
                dst[j] += w * src[j];
            }
        }
    }
    Ok(secs)
}

fn argmax_u8(row: &[f32]) -> u8 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in row.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as u8
}

/// Parse a boolean env-var value (`DUALSPARSE_QUANT` et al.): accepts
/// 1/0, true/false, on/off, yes/no (case-insensitive); anything else
/// is an error naming the variable.
fn parse_bool_env(var: &str, v: &str) -> Result<bool> {
    match v.to_ascii_lowercase().as_str() {
        "1" | "true" | "on" | "yes" => Ok(true),
        "0" | "false" | "off" | "no" => Ok(false),
        _ => bail!("unrecognized {var} value {v:?}; use 1/0, true/false, on/off, yes/no"),
    }
}

/// Standard artifact base dir resolution (env override for tests).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("DUALSPARSE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax_u8(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax_u8(&[-5.0, -2.0]), 1);
    }

    fn hermetic_engine() -> Engine {
        Engine::new(
            Path::new("/nonexistent-artifacts"),
            "mixtral_ish",
            DropPolicy::NoDrop,
            EngineOptions::default(),
        )
        .expect("hermetic engine (CpuRef + synthetic weights)")
    }

    /// Every router mode must return an empty TokenRouting — not panic —
    /// when nothing is selectable (top_k == 0 or an empty kept list).
    #[test]
    fn empty_selection_returns_empty_routing_in_all_modes() {
        let mut e = hermetic_engine();
        let nl = e.cfg.n_layers;
        let scores = vec![1.0 / e.cfg.n_experts as f32; e.cfg.n_experts];

        e.cfg.top_k = 0;
        for mode in [
            RouterMode::Standard,
            RouterMode::Ees { beta: 0.5 },
            RouterMode::Eep { kept: vec![vec![0, 1]; nl] },
            RouterMode::EepEes { kept: vec![vec![0, 1]; nl], beta: 0.5 },
        ] {
            e.router_mode = mode;
            let r = e.route(&scores, 0);
            assert!(r.experts.is_empty(), "{:?}", e.router_mode);
        }

        // Fully-pruned layer: kept list empty even with top_k > 0.
        e.cfg.top_k = 2;
        for mode in [
            RouterMode::Eep { kept: vec![Vec::new(); nl] },
            RouterMode::EepEes { kept: vec![Vec::new(); nl], beta: 0.5 },
        ] {
            e.router_mode = mode;
            let r = e.route(&scores, 0);
            assert!(r.experts.is_empty(), "{:?}", e.router_mode);
        }
    }

    /// Degenerate gate scores can renormalize to NaN (inf / inf); the
    /// routing sort must order them deterministically NaN-last instead
    /// of panicking (the old `partial_cmp().unwrap()`).
    #[test]
    fn eep_routing_survives_nan_normalized_scores() {
        let mut e = hermetic_engine();
        let nl = e.cfg.n_layers;
        e.router_mode = RouterMode::Eep { kept: vec![vec![0, 1, 2]; nl] };
        let mut scores = vec![0.0f32; e.cfg.n_experts];
        scores[0] = f32::INFINITY; // kept-set sum = inf ⇒ inf/inf = NaN
        scores[1] = 1.0;
        let r = e.route(&scores, 0);
        assert!(!r.experts.is_empty());
        // The NaN-scored expert 0 sorts behind the finite scores.
        assert_eq!(r.experts[0].0, 1);
        // A NaN *input* score poisons the sum; the sum>0 guard zeroes
        // the kept scores and routing stays index-ordered — no panic.
        scores[0] = f32::NAN;
        let r2 = e.route(&scores, 0);
        assert_eq!(r2.experts.len(), e.cfg.top_k.min(3));
    }

    #[test]
    fn parse_bool_env_accepts_common_spellings() {
        for v in ["1", "true", "ON", "Yes"] {
            assert!(parse_bool_env("X", v).unwrap());
        }
        for v in ["0", "false", "OFF", "no"] {
            assert!(!parse_bool_env("X", v).unwrap());
        }
        let err = parse_bool_env("DUALSPARSE_QUANT", "maybe").unwrap_err();
        assert!(err.to_string().contains("DUALSPARSE_QUANT"));
    }

    /// neuron_keep < 1.0 without importance tables must fail at build
    /// time (not mid-serve), and out-of-range fractions are rejected.
    #[test]
    fn neuron_keep_validation_fails_fast() {
        let opts = EngineOptions { neuron_keep: Some(0.5), ..Default::default() };
        let err = Engine::new(
            Path::new("/nonexistent-artifacts"),
            "mixtral_ish",
            DropPolicy::NoDrop,
            opts,
        )
        .unwrap_err();
        assert!(err.to_string().contains("importance"), "{err}");

        let opts = EngineOptions { neuron_keep: Some(1.5), ..Default::default() };
        let err = Engine::new(
            Path::new("/nonexistent-artifacts"),
            "mixtral_ish",
            DropPolicy::NoDrop,
            opts,
        )
        .unwrap_err();
        assert!(err.to_string().contains("0.0..=1.0"), "{err}");
    }

    /// An empty routing flows through the full MoE layer: the token
    /// contributes zero MoE output and generation still completes.
    #[test]
    fn top_k_zero_generates_with_zero_moe_output() {
        let mut e = hermetic_engine();
        e.cfg.top_k = 0;
        let outs = e.generate_batch(&["cpy:ab|"], 4).expect("no panic");
        assert_eq!(outs.len(), 1);
        assert_eq!(e.metrics.total_drop().total(), 0, "no pairs routed at all");
    }
}
