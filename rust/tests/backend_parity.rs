//! Backend-parity and mathematical-consistency fuzz tests (SplitMix64-
//! seeded, hermetic on `CpuRef`).
//!
//! The paper's §3 claim — expert partition/reconstruction is output-
//! preserving, and 2T dropping removes exactly the dropped terms of a
//! linear combination — stated as executable properties:
//!
//! 1. full-expert output ≈ major + minor reconstructed sub-expert sum
//!    (any importance permutation, any split point), within 1e-4;
//! 2. a 2T-drop plan's output plus the explicitly-reconstructed dropped
//!    terms equals the NoDrop reference (linearity identity, Eq. 3);
//! 3. the `CpuRef` backend executes sub-experts exactly like the shared
//!    `util::linalg` kernels it is built from.

#![allow(clippy::needless_range_loop, clippy::manual_memcpy, clippy::type_complexity)]

use dualsparse::model::Tensor;
use dualsparse::moe::{
    importance_order, plan_dispatch, route_token, DropPolicy, TokenRouting,
};
use dualsparse::runtime::{Arg, Backend, CpuRef};
use dualsparse::util::linalg::{add_scaled, matmul, max_abs_diff, softmax_rows, swiglu_ffn};
use dualsparse::util::rng::SplitMix64;

fn randn(rng: &mut SplitMix64, shape: Vec<usize>, scale: f32) -> Tensor {
    let n = shape.iter().product();
    Tensor::new(shape, (0..n).map(|_| rng.gauss() as f32 * scale).collect())
}

/// Split (w1, w3, w2) into the (major, minor) halves given a neuron
/// order — the serving-side reconstruction of `moe::partition`.
fn split_expert(
    w1: &Tensor,
    w3: &Tensor,
    w2: &Tensor,
    order: &[usize],
    cut: usize,
) -> ((Tensor, Tensor, Tensor), (Tensor, Tensor, Tensor)) {
    let (maj, min_) = order.split_at(cut);
    (
        (w1.gather_cols(maj), w3.gather_cols(maj), w2.gather_rows(maj)),
        (w1.gather_cols(min_), w3.gather_cols(min_), w2.gather_rows(min_)),
    )
}

#[test]
fn full_expert_equals_major_plus_minor_fuzz() {
    // Acceptance property: fuzzed full-expert output vs reconstructed
    // major+minor sum within 1e-4, across random shapes, permutations
    // and split points.
    let mut rng = SplitMix64::new(0x9A817);
    for case in 0..40 {
        let d = 4 + 4 * rng.below(4); // 4..16
        let h = 2 * (1 + rng.below(8)); // even 2..16
        let c = 1 + rng.below(6);
        let x = randn(&mut rng, vec![c, d], 0.5);
        let w1 = randn(&mut rng, vec![d, h], 0.4);
        let w3 = randn(&mut rng, vec![d, h], 0.4);
        let w2 = randn(&mut rng, vec![h, d], 0.4);
        // random importance table → descending permutation
        let imp: Vec<f32> = (0..h).map(|_| rng.f64() as f32).collect();
        let order = importance_order(&imp);
        let cut = 1 + rng.below(h - 1); // any interior split, not only h/2
        let ((m1, m3, m2), (n1, n3, n2)) = split_expert(&w1, &w3, &w2, &order, cut);
        let full = swiglu_ffn(&x, &w1, &w3, &w2);
        let major = swiglu_ffn(&x, &m1, &m3, &m2);
        let minor = swiglu_ffn(&x, &n1, &n3, &n2);
        let mut recon = major.clone();
        add_scaled(&mut recon, &minor, 1.0);
        let err = max_abs_diff(&full, &recon);
        assert!(
            err < 1e-4,
            "case {case}: full vs major+minor |Δ|={err} (d={d} h={h} cut={cut})"
        );
    }
}

#[test]
fn cpu_backend_matches_shared_kernels_on_sub_experts_fuzz() {
    // The engine hot path calls the backend; property tests call
    // util::linalg. Pin the two together on fuzzed sub-expert shapes.
    let be = CpuRef::new();
    let mut rng = SplitMix64::new(0xBACCE);
    for _ in 0..20 {
        let d = 8;
        let h = 2 * (1 + rng.below(6));
        let c = 1 + rng.below(5);
        let x = randn(&mut rng, vec![c, d], 0.5);
        let w1 = randn(&mut rng, vec![d, h], 0.4);
        let w3 = randn(&mut rng, vec![d, h], 0.4);
        let w2 = randn(&mut rng, vec![h, d], 0.4);
        let out = be
            .exec(
                &format!("ffn_h{h}_c{c}"),
                &[Arg::F32(&x), Arg::F32(&w1), Arg::F32(&w3), Arg::F32(&w2)],
            )
            .unwrap();
        assert_eq!(out[0].data, swiglu_ffn(&x, &w1, &w3, &w2).data);
    }
}

/// Dense NoDrop MoE reference for a routed batch: Σ score · f_e(x).
fn moe_reference(
    x: &Tensor,
    routings: &[TokenRouting],
    experts: &[(Tensor, Tensor, Tensor)],
) -> Tensor {
    let d = x.shape[1];
    let mut out = Tensor::zeros(vec![x.shape[0], d]);
    for (row, r) in routings.iter().enumerate() {
        let xr = x.row_slice(row, row + 1);
        for &(e, score, _) in &r.experts {
            let (w1, w3, w2) = &experts[e];
            let y = swiglu_ffn(&xr, w1, w3, w2);
            for j in 0..d {
                out.data[row * d + j] += score * y.data[j];
            }
        }
    }
    out
}

#[test]
fn two_t_drop_output_is_bounded_by_no_drop_reference_fuzz() {
    // Linearity identity (Eq. 3 + §4.2): y_nodrop − y_2T is *exactly*
    // the sum of the dropped terms — score·f_e(x) for dropped pairs and
    // score·minor_e(x) for major-only pairs. Reconstructing those terms
    // and adding them back must close the gap to f32 round-off; in
    // particular the 2T output error is bounded by the dropped mass.
    let mut rng = SplitMix64::new(0x2217D);
    for case in 0..15 {
        let (d, h, n_exp, top_k) = (8usize, 8usize, 6usize, 2usize);
        let n_tok = 2 + rng.below(5);
        let x = randn(&mut rng, vec![n_tok, d], 0.5);
        let experts: Vec<(Tensor, Tensor, Tensor)> = (0..n_exp)
            .map(|_| {
                (
                    randn(&mut rng, vec![d, h], 0.4),
                    randn(&mut rng, vec![d, h], 0.4),
                    randn(&mut rng, vec![h, d], 0.4),
                )
            })
            .collect();
        let wg = randn(&mut rng, vec![d, n_exp], 0.6);
        let probs = softmax_rows(&matmul(&x, &wg));
        let routings: Vec<TokenRouting> = (0..n_tok)
            .map(|r| route_token(probs.row(r), top_k, false))
            .collect();
        // reconstruction split of every expert at h/2 by random importance
        let splits: Vec<_> = experts
            .iter()
            .map(|(w1, w3, w2)| {
                let imp: Vec<f32> = (0..h).map(|_| rng.f64() as f32).collect();
                split_expert(w1, w3, w2, &importance_order(&imp), h / 2)
            })
            .collect();

        let t = 0.2 + (rng.f64() as f32) * 0.4;
        let plan = plan_dispatch(&routings, n_exp, DropPolicy::two_t(t), None);

        // 2T output: full pairs run the full expert, major-only pairs
        // run the major half.
        let mut y2t = Tensor::zeros(vec![n_tok, d]);
        for e in 0..n_exp {
            let (w1, w3, w2) = &experts[e];
            for &(row, score) in &plan.full[e] {
                let y = swiglu_ffn(&x.row_slice(row, row + 1), w1, w3, w2);
                for j in 0..d {
                    y2t.data[row * d + j] += score * y.data[j];
                }
            }
            let ((m1, m3, m2), _) = &splits[e];
            for &(row, score) in &plan.major_only[e] {
                let y = swiglu_ffn(&x.row_slice(row, row + 1), m1, m3, m2);
                for j in 0..d {
                    y2t.data[row * d + j] += score * y.data[j];
                }
            }
        }

        // Explicitly reconstruct the dropped terms.
        let mut missing = Tensor::zeros(vec![n_tok, d]);
        for (row, r) in routings.iter().enumerate() {
            for &(e, score, norm) in &r.experts {
                let dec = DropPolicy::two_t(t).decide(norm);
                let xr = x.row_slice(row, row + 1);
                let y = match dec {
                    dualsparse::moe::Decision::Full => continue,
                    dualsparse::moe::Decision::MajorOnly => {
                        let (_, (n1, n3, n2)) = &splits[e];
                        swiglu_ffn(&xr, n1, n3, n2)
                    }
                    dualsparse::moe::Decision::Drop => {
                        let (w1, w3, w2) = &experts[e];
                        swiglu_ffn(&xr, w1, w3, w2)
                    }
                };
                for j in 0..d {
                    missing.data[row * d + j] += score * y.data[j];
                }
            }
        }

        let y_ref = moe_reference(&x, &routings, &experts);
        let mut closed = y2t.clone();
        add_scaled(&mut closed, &missing, 1.0);
        let gap = max_abs_diff(&closed, &y_ref);
        assert!(gap < 1e-4, "case {case}: identity gap {gap} at T={t}");

        // …and therefore the raw 2T error is bounded by the dropped mass.
        let err = max_abs_diff(&y2t, &y_ref);
        let bound: f32 = missing.data.iter().map(|v| v.abs()).fold(0.0, f32::max);
        assert!(
            err <= bound + 1e-4,
            "case {case}: 2T error {err} exceeds dropped-mass bound {bound}"
        );
    }
}

#[test]
fn no_drop_plan_reproduces_reference_exactly_fuzz() {
    // Degenerate policy check: a NoDrop dispatch plan executed through
    // the plan structure equals the dense reference bit-for-bit (same
    // accumulation order), so the planner adds no numeric drift.
    let mut rng = SplitMix64::new(0x0DD0);
    for _ in 0..10 {
        let (d, h, n_exp, top_k) = (8usize, 6usize, 5usize, 2usize);
        let n_tok = 2 + rng.below(4);
        let x = randn(&mut rng, vec![n_tok, d], 0.5);
        let experts: Vec<(Tensor, Tensor, Tensor)> = (0..n_exp)
            .map(|_| {
                (
                    randn(&mut rng, vec![d, h], 0.4),
                    randn(&mut rng, vec![d, h], 0.4),
                    randn(&mut rng, vec![h, d], 0.4),
                )
            })
            .collect();
        let wg = randn(&mut rng, vec![d, n_exp], 0.6);
        let probs = softmax_rows(&matmul(&x, &wg));
        let routings: Vec<TokenRouting> = (0..n_tok)
            .map(|r| route_token(probs.row(r), top_k, false))
            .collect();
        let plan = plan_dispatch(&routings, n_exp, DropPolicy::NoDrop, None);
        assert_eq!(plan.stats.dropped, 0);
        assert_eq!(plan.stats.major_only, 0);
        assert_eq!(plan.kept_pairs(), n_tok * top_k);
        let mut y = Tensor::zeros(vec![n_tok, d]);
        for e in 0..n_exp {
            let (w1, w3, w2) = &experts[e];
            for &(row, score) in &plan.full[e] {
                let out = swiglu_ffn(&x.row_slice(row, row + 1), w1, w3, w2);
                for j in 0..d {
                    y.data[row * d + j] += score * out.data[j];
                }
            }
        }
        let y_ref = moe_reference(&x, &routings, &experts);
        // identical term sets per row; only the f32 accumulation order
        // differs (expert-index vs score-descending) → round-off only.
        assert!(max_abs_diff(&y, &y_ref) < 1e-5);
    }
}
