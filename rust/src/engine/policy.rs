//! Pluggable scheduling policies + admission control for the
//! arrival-driven scheduler ([`crate::engine::scheduler`]).
//!
//! PR 4's scheduler admitted strictly FCFS and queued open-loop traffic
//! without bound. This module factors both decisions out of the serving
//! loop:
//!
//! * **Ordering** — a [`SchedulingPolicy`] picks which queued request is
//!   admitted into the next free KV slot. Three built-ins:
//!   [`Fcfs`] (arrival order — byte-for-byte the PR 4 behavior, pinned
//!   by `rust/tests/scheduler.rs`), [`ShortestPromptFirst`] (SJF on
//!   prompt length: short prefills stop head-of-line blocking under
//!   backlog, the dominant p99-TTFT lever the MoE-serving surveys
//!   identify), and [`PriorityLanes`] (strict priority lanes over the
//!   per-request [`crate::engine::scheduler::Request::priority`] field,
//!   arrival order within a lane).
//! * **Admission** — an [`AdmissionControl`] bound on the waiting
//!   queue. With `max_queue_depth = Some(k)`, a request arriving while
//!   `k` requests already wait is Rejected (`reason` = "queue full…")
//!   instead of queueing unboundedly, so open-loop overload reports
//!   **goodput vs offered load** (the knee of the SERVE_cpu.json
//!   curves) rather than an ever-growing queue.
//!
//! Policies see only a [`QueuedRequest`] snapshot per waiting request —
//! they cannot touch engine state — and return a *position in the
//! queue*, which keeps every implementation trivially correct: the
//! scheduler owns admission validation, slot accounting and the
//! lifecycle state machine regardless of pick order.
//!
//! The CLI face is [`PolicyKind`] (`--policy fcfs | spf | priority`);
//! library users can pass any `&dyn SchedulingPolicy` to
//! [`crate::engine::scheduler::serve_policy`].

use std::fmt;

use anyhow::{bail, Result};

/// What a [`SchedulingPolicy`] sees about one waiting request: an
/// immutable snapshot, not the request itself.
#[derive(Debug, Clone, Copy)]
pub struct QueuedRequest {
    /// Caller-assigned request id.
    pub id: usize,
    /// Prompt length in tokens (bytes, under the byte tokenizer).
    pub prompt_len: usize,
    /// Scheduling lane; higher = more urgent. 0 for legacy requests.
    pub priority: u8,
    /// Arrival time (seconds from run start; 0 in closed-loop mode).
    pub arrival: f64,
    /// Starvation-control boost, computed by the scheduler from queue
    /// time (`floor(waited / aging.step_secs)`, see [`AgingConfig`]).
    /// 0 when aging is off. SPF halves the *effective* prompt length
    /// per boost step; priority lanes add it to the effective lane, so
    /// any queued request eventually outranks fresh arrivals.
    pub age_boost: u8,
}

/// What a [`SchedulingPolicy`] sees about one *admitted* sequence when
/// choosing a preemption victim: an immutable snapshot of scheduling-
/// relevant state (never engine internals).
#[derive(Debug, Clone, Copy)]
pub struct ActiveSeq {
    /// Caller-assigned request id.
    pub id: usize,
    /// Scheduling lane; higher = more urgent.
    pub priority: u8,
    /// Prompt length in tokens.
    pub prompt_len: usize,
    /// Arrival time (seconds from run start).
    pub arrival: f64,
    /// When the request was (first) admitted out of the queue.
    pub admitted_at: f64,
    /// Tokens generated so far — what a preemption throws away
    /// (recompute-from-prompt re-derives them on re-admission).
    pub generated: usize,
}

/// Admission-ordering policy: given the waiting queue (front = earliest
/// arrival), choose which request the scheduler admits into the next
/// free KV slot.
///
/// Implementations must be pure functions of the queue snapshot — the
/// scheduler may call `pick` any number of times per loop iteration and
/// relies on it for ordering only, never for admission validation
/// (oversized-prompt rejection and queue bounds stay in the scheduler).
pub trait SchedulingPolicy {
    /// Short stable name, used for report rows and JSON tags.
    fn name(&self) -> &'static str;

    /// Position in `queue` of the request to admit next. `queue` is
    /// never empty; an out-of-range return is clamped to the last
    /// element by the scheduler.
    fn pick(&self, queue: &[QueuedRequest]) -> usize;

    /// Position in `active` of the sequence to evict when a page fault
    /// (no free KV pages) must be resolved by preemption. `active` is
    /// never empty; an out-of-range return is clamped by the scheduler.
    ///
    /// The default — evict the **latest arrival** (ties: latest
    /// admission) — matches FCFS's contract: the requests that have
    /// waited longest keep their pages.
    fn victim(&self, active: &[ActiveSeq]) -> usize {
        let mut best = 0usize;
        for (i, a) in active.iter().enumerate().skip(1) {
            let b = &active[best];
            if a.arrival > b.arrival || (a.arrival == b.arrival && a.admitted_at >= b.admitted_at)
            {
                best = i;
            }
        }
        best
    }

    /// May queued request `cand` preempt admitted sequence `victim` at
    /// **admission** time (as opposed to resolving a decode-time page
    /// fault, which any policy does via [`SchedulingPolicy::victim`])?
    /// Default: never — only [`PriorityLanes`] lets a more urgent lane
    /// displace a running request outright.
    fn preempts(&self, _cand: &QueuedRequest, _victim: &ActiveSeq) -> bool {
        false
    }
}

/// First-come-first-served: admit the front of the queue. This is
/// exactly the PR 4 scheduler order — `serve_with` runs it, and the
/// legacy byte-for-byte pin tests in `rust/tests/scheduler.rs` hold
/// under it unchanged.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fcfs;

impl SchedulingPolicy for Fcfs {
    fn name(&self) -> &'static str {
        "fcfs"
    }

    fn pick(&self, _queue: &[QueuedRequest]) -> usize {
        0
    }
}

/// Shortest-prompt-first (SJF on prefill cost): admit the waiting
/// request with the smallest prompt; ties break toward the earliest
/// arrival. Long prompts can be deferred indefinitely under sustained
/// overload — pair with [`AdmissionControl`], turn on aging
/// ([`AgingConfig`] halves a request's effective length per waited
/// step), or accept the starvation tail (it is what buys the p99-TTFT
/// win for everyone else).
#[derive(Debug, Clone, Copy, Default)]
pub struct ShortestPromptFirst;

/// SPF's aged sort key: each boost step halves the effective length, so
/// a long prompt that has waited long enough competes with short ones.
fn spf_effective_len(q: &QueuedRequest) -> usize {
    q.prompt_len >> q.age_boost.min(usize::BITS as u8 - 1)
}

impl SchedulingPolicy for ShortestPromptFirst {
    fn name(&self) -> &'static str {
        "spf"
    }

    fn pick(&self, queue: &[QueuedRequest]) -> usize {
        let mut best = 0usize;
        for (i, q) in queue.iter().enumerate().skip(1) {
            // strict `<` keeps the earliest arrival among equals (the
            // queue is arrival-ordered front to back).
            if spf_effective_len(q) < spf_effective_len(&queue[best]) {
                best = i;
            }
        }
        best
    }

    /// SPF evicts the **longest** prompt (ties: latest arrival) — the
    /// mirror image of its admission order.
    fn victim(&self, active: &[ActiveSeq]) -> usize {
        let mut best = 0usize;
        for (i, a) in active.iter().enumerate().skip(1) {
            let b = &active[best];
            if a.prompt_len > b.prompt_len
                || (a.prompt_len == b.prompt_len && a.arrival >= b.arrival)
            {
                best = i;
            }
        }
        best
    }
}

/// Strict priority lanes: admit the highest-`priority` waiting request;
/// ties break toward the earliest arrival (FCFS within a lane). Lane
/// values come from [`crate::engine::scheduler::Request::priority`]
/// (higher = more urgent).
#[derive(Debug, Clone, Copy, Default)]
pub struct PriorityLanes;

/// A lane-request's aged lane: aging lifts the effective priority one
/// lane per waited step, so lane-0 traffic cannot starve forever.
fn effective_priority(q: &QueuedRequest) -> u8 {
    q.priority.saturating_add(q.age_boost)
}

impl SchedulingPolicy for PriorityLanes {
    fn name(&self) -> &'static str {
        "priority"
    }

    fn pick(&self, queue: &[QueuedRequest]) -> usize {
        let mut best = 0usize;
        for (i, q) in queue.iter().enumerate().skip(1) {
            // strict `>` keeps the earliest arrival within a lane.
            if effective_priority(q) > effective_priority(&queue[best]) {
                best = i;
            }
        }
        best
    }

    /// Priority evicts the **lowest lane** (ties: latest arrival).
    fn victim(&self, active: &[ActiveSeq]) -> usize {
        let mut best = 0usize;
        for (i, a) in active.iter().enumerate().skip(1) {
            let b = &active[best];
            if a.priority < b.priority || (a.priority == b.priority && a.arrival >= b.arrival) {
                best = i;
            }
        }
        best
    }

    /// A strictly more urgent arrival may displace a running lower-lane
    /// sequence even when no decode-time page fault forces it.
    fn preempts(&self, cand: &QueuedRequest, victim: &ActiveSeq) -> bool {
        effective_priority(cand) > victim.priority
    }
}

/// The built-in policies as a CLI-facing enum (`--policy` on
/// `dualsparse serve`, the `sched` column of SERVE_cpu.json).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PolicyKind {
    /// [`Fcfs`] — the legacy order and the default.
    #[default]
    Fcfs,
    /// [`ShortestPromptFirst`].
    ShortestPromptFirst,
    /// [`PriorityLanes`].
    PriorityLanes,
}

impl PolicyKind {
    /// Every built-in, in report order.
    pub const ALL: [PolicyKind; 3] =
        [PolicyKind::Fcfs, PolicyKind::ShortestPromptFirst, PolicyKind::PriorityLanes];

    /// Parse a CLI spelling (`fcfs` | `spf` | `priority`).
    pub fn parse(spec: &str) -> Result<PolicyKind> {
        match spec {
            "fcfs" => Ok(PolicyKind::Fcfs),
            "spf" => Ok(PolicyKind::ShortestPromptFirst),
            "priority" => Ok(PolicyKind::PriorityLanes),
            _ => bail!("unknown scheduling policy {spec:?}; use fcfs | spf | priority"),
        }
    }

    /// The policy object behind this kind (all built-ins are stateless
    /// unit structs, so a `'static` borrow suffices).
    pub fn policy(&self) -> &'static dyn SchedulingPolicy {
        match self {
            PolicyKind::Fcfs => &Fcfs,
            PolicyKind::ShortestPromptFirst => &ShortestPromptFirst,
            PolicyKind::PriorityLanes => &PriorityLanes,
        }
    }

    /// Stable label (same string [`SchedulingPolicy::name`] returns).
    pub fn label(&self) -> &'static str {
        self.policy().name()
    }
}

impl fmt::Display for PolicyKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// Queue-bound admission control: how many requests may wait for a KV
/// slot before new arrivals are rejected.
///
/// The bound counts the *waiting* queue only — requests already holding
/// a slot (Prefill/Decode) are not counted. A request that arrives
/// while the queue holds `max_queue_depth` entries transitions
/// Queued → Rejected immediately (`reason` = "queue full…"), consumes
/// no KV slot, and shows up in
/// [`crate::engine::scheduler::ServeStats::rejected_queue_full`]. Note
/// the closed-loop corner: every request "arrives" at t = 0 in one
/// burst, before any admission, so a bounded closed-loop run completes
/// exactly `max_queue_depth` requests and rejects the rest — which is
/// what makes the overflow count exactly testable.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdmissionControl {
    /// Maximum waiting-queue depth; `None` = unbounded (the legacy PR 4
    /// behavior and the default).
    pub max_queue_depth: Option<usize>,
}

impl AdmissionControl {
    /// No queue bound (legacy behavior).
    pub fn unbounded() -> AdmissionControl {
        AdmissionControl { max_queue_depth: None }
    }

    /// Reject arrivals once `k` requests are already waiting.
    pub fn bounded(k: usize) -> AdmissionControl {
        AdmissionControl { max_queue_depth: Some(k) }
    }

    /// May a request enter a queue currently `depth` deep?
    pub fn admits(&self, depth: usize) -> bool {
        match self.max_queue_depth {
            Some(k) => depth < k,
            None => true,
        }
    }
}

/// Starvation control: queued requests gain one `age_boost` step per
/// `step_secs` waited, lifting their effective rank under SPF (length
/// halves per step) and priority lanes (lane +1 per step). FCFS ignores
/// boosts — arrival order already starves nobody.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AgingConfig {
    /// Seconds of queue time per boost step (> 0).
    pub step_secs: f64,
}

impl Default for AgingConfig {
    fn default() -> Self {
        AgingConfig { step_secs: 0.5 }
    }
}

impl AgingConfig {
    /// The boost a request that has waited `waited` seconds carries.
    pub fn boost(&self, waited: f64) -> u8 {
        if self.step_secs <= 0.0 || waited <= 0.0 {
            return 0;
        }
        (waited / self.step_secs).floor().min(u8::MAX as f64) as u8
    }
}

/// One serving run's scheduling configuration: ordering policy +
/// admission control + the paged-KV knobs (preemption, aging,
/// prefill/decode interleaving). `Default` is FCFS, unbounded, no
/// preemption, no aging, interleaving **on** — the PR 4 ordering with
/// iteration-level prefill chunks.
#[derive(Debug, Clone)]
pub struct SchedConfig {
    pub policy: PolicyKind,
    pub admission: AdmissionControl,
    /// Resolve page faults by evicting a victim (recompute-from-prompt
    /// on re-admission) instead of erroring; also enables
    /// admission-time preemption for policies whose
    /// [`SchedulingPolicy::preempts`] allows it.
    pub preempt: bool,
    /// Starvation control for SPF / priority lanes; `None` = off.
    pub aging: Option<AgingConfig>,
    /// Run at most one prefill chunk per scheduler iteration alongside
    /// the decode batch (`false` = legacy whole-prompt prefill at
    /// admission, the non-interleaved baseline the sweep compares
    /// against).
    pub interleave: bool,
    /// Deterministic chaos plan (`--faults`); `None` = no injection.
    pub faults: Option<crate::engine::faults::FaultPlan>,
    /// Retry budget per request for injected transient backend errors.
    pub max_retries: u32,
    /// Run-default per-request deadline (`--deadline-ms`), seconds.
    pub deadline_secs: Option<f64>,
    /// External-cancellation hook for the future network front end.
    pub cancel: Option<crate::engine::faults::CancelSet>,
    /// SLO feedback → drop-policy degradation controller.
    pub degrade: Option<crate::engine::faults::DegradeController>,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            policy: PolicyKind::default(),
            admission: AdmissionControl::default(),
            preempt: false,
            aging: None,
            interleave: true,
            faults: None,
            max_retries: 2,
            deadline_secs: None,
            cancel: None,
            degrade: None,
        }
    }
}

impl SchedConfig {
    /// The scheduler-facing slice of this config (everything except the
    /// ordering policy object).
    pub fn options(&self) -> super::scheduler::SchedOptions {
        super::scheduler::SchedOptions {
            admission: self.admission,
            preempt: self.preempt,
            aging: self.aging,
            interleave: self.interleave,
            faults: self.faults.clone(),
            max_retries: self.max_retries,
            deadline_secs: self.deadline_secs,
            cancel: self.cancel.clone(),
            degrade: self.degrade.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(entries: &[(usize, u8)]) -> Vec<QueuedRequest> {
        entries
            .iter()
            .enumerate()
            .map(|(i, &(len, pri))| QueuedRequest {
                id: i,
                prompt_len: len,
                priority: pri,
                arrival: i as f64,
                age_boost: 0,
            })
            .collect()
    }

    fn active(entries: &[(u8, usize, f64)]) -> Vec<ActiveSeq> {
        entries
            .iter()
            .enumerate()
            .map(|(i, &(pri, len, arrival))| ActiveSeq {
                id: i,
                priority: pri,
                prompt_len: len,
                arrival,
                admitted_at: arrival,
                generated: 0,
            })
            .collect()
    }

    #[test]
    fn fcfs_always_picks_the_front() {
        let queue = q(&[(50, 0), (1, 9), (2, 3)]);
        assert_eq!(Fcfs.pick(&queue), 0);
        assert_eq!(Fcfs.name(), "fcfs");
    }

    #[test]
    fn spf_picks_shortest_with_fcfs_ties() {
        let queue = q(&[(50, 0), (4, 0), (90, 0), (4, 0)]);
        // two length-4 prompts: the earlier one (index 1) wins.
        assert_eq!(ShortestPromptFirst.pick(&queue), 1);
        let queue = q(&[(3, 0)]);
        assert_eq!(ShortestPromptFirst.pick(&queue), 0);
    }

    #[test]
    fn priority_lanes_pick_highest_with_fcfs_ties() {
        let queue = q(&[(10, 1), (10, 2), (10, 0), (10, 2)]);
        // two lane-2 requests: the earlier one (index 1) wins.
        assert_eq!(PriorityLanes.pick(&queue), 1);
        // all-equal lanes degenerate to FCFS.
        let queue = q(&[(10, 1), (9, 1), (8, 1)]);
        assert_eq!(PriorityLanes.pick(&queue), 0);
    }

    #[test]
    fn policy_kind_parses_and_labels() {
        assert_eq!(PolicyKind::parse("fcfs").unwrap(), PolicyKind::Fcfs);
        assert_eq!(PolicyKind::parse("spf").unwrap(), PolicyKind::ShortestPromptFirst);
        assert_eq!(PolicyKind::parse("priority").unwrap(), PolicyKind::PriorityLanes);
        assert!(PolicyKind::parse("lifo").is_err());
        for k in PolicyKind::ALL {
            assert_eq!(k.label(), k.policy().name());
            assert_eq!(format!("{k}"), k.label());
        }
        assert_eq!(PolicyKind::default(), PolicyKind::Fcfs);
    }

    #[test]
    fn aging_boost_counts_whole_steps() {
        let aging = AgingConfig { step_secs: 0.5 };
        assert_eq!(aging.boost(0.0), 0);
        assert_eq!(aging.boost(0.49), 0);
        assert_eq!(aging.boost(0.5), 1);
        assert_eq!(aging.boost(1.7), 3);
        assert_eq!(aging.boost(1e9), u8::MAX);
        assert_eq!(AgingConfig { step_secs: 0.0 }.boost(10.0), 0);
    }

    #[test]
    fn spf_aging_halves_effective_length() {
        // 64-byte prompt with 4 boost steps → effective 4: beats the
        // fresh 5-byte prompt behind it.
        let mut queue = q(&[(64, 0), (5, 0)]);
        assert_eq!(ShortestPromptFirst.pick(&queue), 1);
        queue[0].age_boost = 4;
        assert_eq!(ShortestPromptFirst.pick(&queue), 0);
        // absurd boosts must not overflow the shift
        queue[0].age_boost = u8::MAX;
        assert_eq!(ShortestPromptFirst.pick(&queue), 0);
    }

    #[test]
    fn priority_aging_lifts_the_lane() {
        let mut queue = q(&[(10, 0), (10, 2)]);
        assert_eq!(PriorityLanes.pick(&queue), 1);
        queue[0].age_boost = 2;
        // equal effective lanes → earliest arrival wins.
        assert_eq!(PriorityLanes.pick(&queue), 0);
        queue[0].age_boost = 3;
        assert_eq!(PriorityLanes.pick(&queue), 0);
    }

    #[test]
    fn victim_selection_per_policy() {
        let a = active(&[(0, 10, 0.0), (0, 90, 1.0), (0, 40, 2.0)]);
        // FCFS default: latest arrival loses its pages.
        assert_eq!(Fcfs.victim(&a), 2);
        // SPF: longest prompt loses.
        assert_eq!(ShortestPromptFirst.victim(&a), 1);
        let a = active(&[(2, 10, 0.0), (0, 10, 1.0), (1, 10, 2.0)]);
        // priority: lowest lane loses.
        assert_eq!(PriorityLanes.victim(&a), 1);
    }

    #[test]
    fn only_priority_preempts_at_admission() {
        let cand = q(&[(10, 2)])[0];
        let low = active(&[(0, 10, 0.0)])[0];
        let high = active(&[(2, 10, 0.0)])[0];
        assert!(!Fcfs.preempts(&cand, &low));
        assert!(!ShortestPromptFirst.preempts(&cand, &low));
        assert!(PriorityLanes.preempts(&cand, &low));
        assert!(!PriorityLanes.preempts(&cand, &high));
        // aging makes a starved lane-0 request eventually able to
        // displace lane-1 traffic.
        let mut old = q(&[(10, 0)])[0];
        let mid = active(&[(1, 10, 0.0)])[0];
        assert!(!PriorityLanes.preempts(&old, &mid));
        old.age_boost = 2;
        assert!(PriorityLanes.preempts(&old, &mid));
    }

    #[test]
    fn sched_config_default_matches_legacy_plus_interleave() {
        let c = SchedConfig::default();
        assert_eq!(c.policy, PolicyKind::Fcfs);
        assert_eq!(c.admission, AdmissionControl::unbounded());
        assert!(!c.preempt);
        assert!(c.aging.is_none());
        assert!(c.interleave);
        assert!(c.faults.is_none() && c.cancel.is_none() && c.degrade.is_none());
        assert!(c.deadline_secs.is_none());
        assert_eq!(c.max_retries, 2);
        let o = c.options();
        assert!(o.interleave && !o.preempt && o.aging.is_none());
        assert!(o.faults.is_none() && o.cancel.is_none() && o.degrade.is_none());
    }

    #[test]
    fn admission_control_bounds_the_queue() {
        let open = AdmissionControl::unbounded();
        assert!(open.admits(0));
        assert!(open.admits(1_000_000));
        let tight = AdmissionControl::bounded(2);
        assert!(tight.admits(0));
        assert!(tight.admits(1));
        assert!(!tight.admits(2));
        assert!(!tight.admits(3));
        assert_eq!(AdmissionControl::default(), AdmissionControl::unbounded());
    }
}
