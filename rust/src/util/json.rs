//! Minimal JSON parser/emitter (serde is not in the offline vendor set).
//!
//! Supports the full JSON grammar we produce: objects, arrays, strings
//! with standard escapes, f64 numbers, bools, null. Used for model
//! manifests, golden vectors, and experiment result files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    /// Flatten a numeric array (arbitrary nesting is not needed — one level).
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        Ok(self
            .as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect::<Result<Vec<_>>>()?)
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        Ok(self
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<Vec<_>>>()?)
    }

    // An inherent `to_string` (not Display) is deliberate: this is the
    // only serialization entry point and a Display impl would invite
    // formatting-machinery overhead on large tensors.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for result emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
}

/// One event surfaced by [`FrameDecoder::feed`].
#[derive(Debug)]
pub enum FrameEvent {
    /// A complete, well-formed frame.
    Frame(Json),
    /// A syntactically broken frame (or a line that never opened one).
    /// The connection survives: the decoder resynchronizes on the next
    /// newline / balanced brace and keeps going.
    Malformed(String),
    /// A frame that exceeded the size bound. Its bytes were discarded
    /// as they streamed in (never buffered); the payload is the total
    /// size observed.
    Oversized(usize),
}

/// Incremental NDJSON frame decoder for the wire protocol.
///
/// Bytes are fed in whatever chunks the socket delivers; complete
/// frames come out as they close. Only the *current* frame is ever
/// buffered — a frame that grows past `max_frame` flips the decoder
/// into a counting discard state until the braces balance, so a
/// hostile connection cannot make the server hold its body in memory.
/// Framing is brace-depth based (strings and escapes tracked), so
/// frames may contain raw newlines even though well-behaved clients
/// write one frame per line.
pub struct FrameDecoder {
    buf: Vec<u8>,
    depth: usize,
    in_str: bool,
    esc: bool,
    max_frame: usize,
    /// Oversized frame being discarded: bytes seen so far.
    discarding: Option<usize>,
    /// Garbage outside any frame: skip until the next newline.
    skip_line: bool,
}

impl FrameDecoder {
    pub fn new(max_frame: usize) -> Self {
        FrameDecoder {
            buf: Vec::new(),
            depth: 0,
            in_str: false,
            esc: false,
            max_frame,
            discarding: None,
            skip_line: false,
        }
    }

    /// Consume one chunk off the wire, returning every event it
    /// completes (possibly none — a frame can span many chunks — or
    /// several, when one chunk carries several frames).
    pub fn feed(&mut self, bytes: &[u8]) -> Vec<FrameEvent> {
        let mut out = Vec::new();
        for &b in bytes {
            if self.skip_line {
                if b == b'\n' {
                    self.skip_line = false;
                }
                continue;
            }
            if let Some(n) = self.discarding.as_mut() {
                *n += 1;
                if Self::track(&mut self.depth, &mut self.in_str, &mut self.esc, b)
                    && self.depth == 0
                {
                    out.push(FrameEvent::Oversized(*n));
                    self.discarding = None;
                }
                continue;
            }
            if self.depth == 0 {
                // Between frames: tolerate whitespace, demand a frame
                // opener for anything else.
                match b {
                    b' ' | b'\t' | b'\r' | b'\n' => continue,
                    b'{' | b'[' => {}
                    _ => {
                        out.push(FrameEvent::Malformed(format!(
                            "frame must open with '{{' or '[', got {:?}",
                            b as char
                        )));
                        self.skip_line = true;
                        continue;
                    }
                }
            }
            self.buf.push(b);
            if Self::track(&mut self.depth, &mut self.in_str, &mut self.esc, b) && self.depth == 0 {
                let ev = match std::str::from_utf8(&self.buf)
                    .map_err(|e| anyhow!(e))
                    .and_then(Json::parse)
                {
                    Ok(v) => FrameEvent::Frame(v),
                    Err(e) => FrameEvent::Malformed(e.to_string()),
                };
                out.push(ev);
                self.buf.clear();
            } else if self.buf.len() > self.max_frame {
                self.discarding = Some(self.buf.len());
                self.buf.clear();
                self.buf.shrink_to_fit();
            }
        }
        out
    }

    /// Advance the brace/string state machine by one byte. Returns
    /// whether the byte could have closed the frame (i.e. it was a
    /// structural close outside a string).
    fn track(depth: &mut usize, in_str: &mut bool, esc: &mut bool, b: u8) -> bool {
        if *in_str {
            if *esc {
                *esc = false;
            } else if b == b'\\' {
                *esc = true;
            } else if b == b'"' {
                *in_str = false;
            }
            return false;
        }
        match b {
            b'"' => *in_str = true,
            b'{' | b'[' => *depth += 1,
            b'}' | b']' => {
                *depth = depth.saturating_sub(1);
                return true;
            }
            _ => {}
        }
        false
    }
}

/// Write `v` as one NDJSON frame (single line + `\n`) and flush, so
/// the peer observes it immediately — the per-token streaming path
/// depends on the flush. The serializer escapes control characters,
/// so the payload can never contain a raw newline.
pub fn write_ndjson<W: std::io::Write>(w: &mut W, v: &Json) -> std::io::Result<()> {
    let mut line = v.to_string();
    line.push('\n');
    w.write_all(line.as_bytes())?;
    w.flush()
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Reassemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for t in ["null", "true", "false", "3.5", "-2", "\"hi\""] {
            let v = Json::parse(t).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_float_arrays() {
        let v = Json::parse("[1.5e-3, -2.25, 0]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![0.0015, -2.25, 0.0]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn emit_escapes() {
        let v = Json::Str("a\"b\\c\n".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse("\"caf\\u00e9 — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café — ok");
    }

    #[test]
    fn decoder_frame_split_across_arbitrary_chunks() {
        let wire = b"{\"op\":\"generate\",\"prompt\":\"a}b{\\\"c\"}\n{\"op\":\"shutdown\"}\n";
        // Byte-at-a-time is the worst case; every split must agree.
        let mut d = FrameDecoder::new(1024);
        let mut evs = Vec::new();
        for b in wire.iter() {
            evs.extend(d.feed(std::slice::from_ref(b)));
        }
        assert_eq!(evs.len(), 2, "{evs:?}");
        match &evs[0] {
            FrameEvent::Frame(v) => {
                assert_eq!(v.get("prompt").unwrap().as_str().unwrap(), "a}b{\"c");
            }
            other => panic!("expected frame, got {other:?}"),
        }
        // One big chunk must produce the identical events.
        let mut d = FrameDecoder::new(1024);
        assert_eq!(d.feed(wire).len(), 2);
    }

    #[test]
    fn decoder_resyncs_after_malformed_line() {
        let mut d = FrameDecoder::new(1024);
        let evs = d.feed(b"not json at all\n{\"op\":1}\n{\"x\":\n\"unterminated\n");
        // garbage line -> Malformed; good frame -> Frame; the last
        // frame is still open (raw newlines are legal inside frames).
        assert_eq!(evs.len(), 2, "{evs:?}");
        assert!(matches!(evs[0], FrameEvent::Malformed(_)));
        assert!(matches!(evs[1], FrameEvent::Frame(_)));
        // broken-syntax-but-balanced frames also come back Malformed
        // without poisoning the stream.
        let mut d = FrameDecoder::new(1024);
        let evs = d.feed(b"{\"a\" 1}\n{\"a\":2}\n");
        assert_eq!(evs.len(), 2, "{evs:?}");
        assert!(matches!(evs[0], FrameEvent::Malformed(_)));
        assert!(matches!(evs[1], FrameEvent::Frame(_)));
    }

    #[test]
    fn decoder_discards_oversized_without_buffering() {
        let mut d = FrameDecoder::new(32);
        let huge = format!("{{\"p\":\"{}\"}}\n", "x".repeat(1000));
        let mut evs = d.feed(huge.as_bytes());
        evs.extend(d.feed(b"{\"ok\":true}\n"));
        assert_eq!(evs.len(), 2, "{evs:?}");
        match evs[0] {
            FrameEvent::Oversized(n) => assert!(n >= 1000, "observed {n}"),
            ref other => panic!("expected oversized, got {other:?}"),
        }
        assert!(matches!(evs[1], FrameEvent::Frame(_)));
    }

    #[test]
    fn ndjson_writer_one_flushed_line_per_frame() {
        let mut buf = Vec::new();
        let v = obj(vec![("frame", s("token")), ("text", s("a\nb"))]);
        write_ndjson(&mut buf, &v).unwrap();
        write_ndjson(&mut buf, &obj(vec![("frame", s("done"))])).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        // the embedded newline was escaped, not emitted raw
        assert_eq!(
            Json::parse(lines[0]).unwrap().get("text").unwrap().as_str().unwrap(),
            "a\nb"
        );
    }
}
