"""Expert partition (complete / partial transformation) + reconstruction.

Weight-space implementations of §3 of the paper:

* `complete_transform` (Fig. 3b / Eqs. 7-11): repeat the gating columns
  P times, split every expert's FFN neurons into P contiguous groups,
  scale W2 by P, and bump top_k → top_k·P. The result is a *standard*
  MoE model with E·P finer experts whose output equals the original
  (property-tested to f.p. tolerance).
* `partial_transform` (Fig. 3c / Eqs. 12-13): split the neurons the same
  way but keep the gating network and W2 untouched; the *router* repeats
  scores and remaps indices at run time (Rust owns that logic —
  `rust/src/moe/partition.rs`; the reference router here exists for
  cross-checking).
* `reconstruct` (§4.2b): per expert, sort neurons by a calibration
  importance table so the **major** sub-expert (p = 0) holds the top
  half. A pure permutation of the FFN inner dimension — a mathematical
  no-op when all sub-experts run.
"""

import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from dataclasses import replace


def _split_expert(w1, w3, w2, P, scale_w2):
    """[E,d,h]/[E,h,d] → [E*P,d,h/P]/[E*P,h/P,d], contiguous neuron groups."""
    e, d, h = w1.shape
    assert h % P == 0, f"d_ffn={h} not divisible by P={P}"
    hp = h // P
    w1p = w1.reshape(e, d, P, hp).transpose(0, 2, 1, 3).reshape(e * P, d, hp)
    w3p = w3.reshape(e, d, P, hp).transpose(0, 2, 1, 3).reshape(e * P, d, hp)
    w2p = w2.reshape(e, P, hp, d).reshape(e * P, hp, d)
    if scale_w2:
        w2p = w2p * float(P)
    return w1p, w3p, w2p


def complete_transform(params, cfg: ModelConfig, P: int):
    """Complete transformation. Returns (new_params, new_cfg)."""
    new_layers = []
    for layer in params["layers"]:
        nl = dict(layer)
        nl["wg"] = jnp.repeat(layer["wg"], P, axis=1)  # [d, E*P]
        nl["w1"], nl["w3"], nl["w2"] = _split_expert(
            layer["w1"], layer["w3"], layer["w2"], P, scale_w2=True
        )
        new_layers.append(nl)
    new_params = dict(params)
    new_params["layers"] = new_layers
    new_cfg = replace(
        cfg,
        name=f"{cfg.name}_p{P}",
        n_experts=cfg.n_experts * P,
        d_ffn=cfg.d_ffn // P,
        top_k=cfg.top_k * P,
    )
    return new_params, new_cfg


def partial_transform_weights(params, cfg: ModelConfig, P: int):
    """Neuron split only (no gating change, no W2 scaling).

    The gating network stays [d, E]; the router repeats scores / remaps
    indices per Eq. 12 at run time.
    """
    new_layers = []
    for layer in params["layers"]:
        nl = dict(layer)
        nl["w1"], nl["w3"], nl["w2"] = _split_expert(
            layer["w1"], layer["w3"], layer["w2"], P, scale_w2=False
        )
        new_layers.append(nl)
    new_params = dict(params)
    new_params["layers"] = new_layers
    return new_params


def remap_indices(indices, P):
    """Eq. 12: original Top-K indices → K·P sub-expert indices.

    indices: [K] original expert ids. Sub-expert p of original expert i
    has id i·P + p (contiguous placement).
    """
    return [i * P + p for p in range(P) for i in indices]


def reconstruct_permutation(importance_eh):
    """Per-expert neuron permutation from an importance table [E, h].

    Returns perm [E, h] such that perm[e, :h//2] are the indices of the
    *most* important neurons (major sub-expert) in descending order.
    Ties break toward the lower index (stable sort on -importance).
    """
    imp = np.asarray(importance_eh)
    order = np.argsort(-imp, axis=1, kind="stable")
    return order


def reconstruct(params, importance_leh):
    """Apply reconstruction permutations; returns (params', perms).

    importance_leh: [n_layers][E, h] importance tables (any of Eqs. 14-17).
    The permutation reorders W1/W3 columns and W2 rows per expert —
    output-invariant; partition into (major, minor) is then the contiguous
    P=2 split of `partial_transform_weights`.
    """
    new_layers, perms = [], []
    for layer, imp in zip(params["layers"], importance_leh):
        order = reconstruct_permutation(imp)  # [E, h]
        w1 = np.asarray(layer["w1"]).copy()
        w3 = np.asarray(layer["w3"]).copy()
        w2 = np.asarray(layer["w2"]).copy()
        for e in range(w1.shape[0]):
            w1[e] = w1[e][:, order[e]]
            w3[e] = w3[e][:, order[e]]
            w2[e] = w2[e][order[e], :]
        nl = dict(layer)
        nl["w1"], nl["w3"], nl["w2"] = jnp.asarray(w1), jnp.asarray(w3), jnp.asarray(w2)
        new_layers.append(nl)
        perms.append(order)
    new_params = dict(params)
    new_params["layers"] = new_layers
    return new_params, perms


def profile_importance(params, cfg: ModelConfig, tokens, metric="abs_gate"):
    """Build-time importance profiling (reference path; the runtime path
    streams the probe artifact from Rust — `rust/src/calib/`).

    tokens: [B, S] calibration batch. Returns [L, E, h] numpy table.
    """
    from .model import rmsnorm, _attn_dense  # local import to avoid cycle
    import jax

    b, s = tokens.shape
    x = params["emb"][tokens] + params["pos"][:s][None]
    tables = []
    for layer in params["layers"]:
        x = _attn_dense(x, layer, cfg)
        ln2x = rmsnorm(x, layer["ln2"])
        flat = ln2x.reshape(b * s, cfg.d_model)
        h = jnp.einsum("td,edf->tef", flat, layer["w1"])
        gate = h * jax.nn.sigmoid(h)
        up = jnp.einsum("td,edf->tef", flat, layer["w3"])
        gu = gate * up
        if metric == "gate":
            imp = jnp.sum(gate, axis=0)
        elif metric == "abs_gate":
            imp = jnp.sum(jnp.abs(gate), axis=0)
        elif metric == "gate_up":
            imp = jnp.sum(gu, axis=0)
        elif metric == "abs_gate_up":
            imp = jnp.sum(jnp.abs(gu), axis=0)
        else:
            raise ValueError(f"unknown metric {metric}")
        tables.append(np.asarray(imp))
        # continue the forward with the true MoE output
        from .model import _moe_dense
        moe_out, _ = _moe_dense(flat, layer, cfg)
        x = x + moe_out.reshape(b, s, cfg.d_model)
    return np.stack(tables)
