"""Analytic VMEM-footprint and MXU-utilization model for the L1 kernels.

interpret=True gives CPU-numpy timings, which are *not* a TPU proxy —
so per DESIGN.md §Perf we optimize kernel *structure* and estimate
real-TPU behaviour analytically from the BlockSpec shapes:

* VMEM footprint: every block resident during one grid step, double-
  buffered on the streamed (weight) operands.
* MXU utilization: fraction of each 128x128 systolic pass carrying
  useful lanes, times the arithmetic-intensity roofline factor.

These numbers feed EXPERIMENTS.md §Perf and the `cost` tests assert the
invariants (footprint < VMEM budget, utilization within [0, 1], wider
tiles never decrease utilization).
"""

from dataclasses import dataclass

VMEM_BYTES = 16 * 1024 * 1024  # v4/v5-class core budget
MXU_EDGE = 128
F32 = 4
# HBM bandwidth / peak-FLOPs ratio for a v5p-class core (bf16 ~459 TFLOPs,
# ~2.7 TB/s) expressed as FLOPs needed per byte to be compute bound.
ROOFLINE_FLOPS_PER_BYTE = 170.0


@dataclass
class KernelCost:
    vmem_bytes: int
    vmem_frac: float
    flops: int
    hbm_bytes: int
    arithmetic_intensity: float
    mxu_utilization: float
    compute_bound: bool


def _ceil_div(a, b):
    return -(-a // b)


def ffn_cost(c, d_model, d_ffn, token_tile=None, ffn_tile=128, double_buffer=True):
    """Cost model for one swiglu_ffn(_tiled) invocation.

    Mirrors the BlockSpecs in moe_ffn.py: the x/o blocks are resident,
    the three weight tiles stream (2x buffered when double_buffer).
    """
    tt = min(token_tile or c, c)
    ft = min(ffn_tile, d_ffn)
    buf = 2 if double_buffer else 1
    x_block = tt * d_model * F32
    o_block = tt * d_model * F32
    w_tiles = (2 * d_model * ft + ft * d_model) * F32  # w1, w3, w2
    vmem = x_block + o_block + buf * w_tiles
    # FLOPs: 3 GEMMs of [C, d] x [d, h] (x2 madd) + elementwise swish/mul.
    flops = 2 * c * d_model * d_ffn * 3 + 6 * c * d_ffn
    # HBM traffic: x once per FFN-tile column pass, weights once, out once.
    col_passes = _ceil_div(d_ffn, ft)
    hbm = (
        c * d_model * F32 * (1 if tt == c else col_passes)
        + 3 * d_model * d_ffn * F32
        + c * d_model * F32
    )
    ai = flops / max(hbm, 1)
    # MXU lane occupancy: each GEMM pass uses min(dim,128)/128 of the array
    # in each of its two systolic dimensions.
    occ_rows = min(tt, MXU_EDGE) / MXU_EDGE
    occ_cols = min(ft, MXU_EDGE) / MXU_EDGE
    occ_depth = min(d_model, MXU_EDGE) / MXU_EDGE
    lane_occ = occ_rows * occ_cols * occ_depth
    bandwidth_factor = min(1.0, ai / ROOFLINE_FLOPS_PER_BYTE)
    util = lane_occ * bandwidth_factor
    return KernelCost(
        vmem_bytes=vmem,
        vmem_frac=vmem / VMEM_BYTES,
        flops=flops,
        hbm_bytes=hbm,
        arithmetic_intensity=ai,
        mxu_utilization=util,
        compute_bound=ai >= ROOFLINE_FLOPS_PER_BYTE,
    )


def probe_cost(c, d_model, d_ffn, ffn_tile=128):
    """Cost model for one probe() invocation."""
    ft = min(ffn_tile, d_ffn)
    vmem = (c * d_model + 2 * 2 * d_model * ft + 4 * ft) * F32
    flops = 2 * c * d_model * d_ffn * 2 + 8 * c * d_ffn
    hbm = (c * d_model + 2 * d_model * d_ffn + 4 * d_ffn) * F32
    ai = flops / max(hbm, 1)
    occ = (min(c, MXU_EDGE) / MXU_EDGE) * (min(ft, MXU_EDGE) / MXU_EDGE) * (
        min(d_model, MXU_EDGE) / MXU_EDGE
    )
    return KernelCost(
        vmem_bytes=vmem,
        vmem_frac=vmem / VMEM_BYTES,
        flops=flops,
        hbm_bytes=hbm,
        arithmetic_intensity=ai,
        mxu_utilization=occ * min(1.0, ai / ROOFLINE_FLOPS_PER_BYTE),
        compute_bound=ai >= ROOFLINE_FLOPS_PER_BYTE,
    )


def report(capacities=(4, 8, 16, 32, 64, 128), widths=(128, 64, 32), d_model=64):
    """Text table used by `make perf-l1` and EXPERIMENTS.md §Perf.

    Defaults mirror the TinyMoE family's actual artifact shapes; the
    second block evaluates the *same kernel structure* at Mixtral-8×7B
    scale (d_model 4096, d_ffn 14336) to show the schedule reaches the
    compute-bound regime on production shapes.
    """
    lines = ["-- TinyMoE artifact shapes --",
             "C    d_ffn  VMEM(KiB)  frac     AI      MXU-util  bound"]
    for h in widths:
        for c in capacities:
            k = ffn_cost(c, d_model, h, token_tile=32 if c >= 64 else None)
            lines.append(
                f"{c:<4} {h:<6} {k.vmem_bytes / 1024:<10.1f} {k.vmem_frac:<8.4f} "
                f"{k.arithmetic_intensity:<7.2f} {k.mxu_utilization:<9.3f} "
                f"{'compute' if k.compute_bound else 'memory'}"
            )
    lines.append("-- same kernel at Mixtral-8x7B expert scale --")
    for c in (128, 256, 512, 1024):
        k = ffn_cost(c, 4096, 14336, token_tile=64, ffn_tile=128)
        lines.append(
            f"{c:<4} {14336:<6} {k.vmem_bytes / 1024:<10.1f} {k.vmem_frac:<8.4f} "
            f"{k.arithmetic_intensity:<7.2f} {k.mxu_utilization:<9.3f} "
            f"{'compute' if k.compute_bound else 'memory'}"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
