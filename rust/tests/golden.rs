//! Artifact-level golden tests: load each AOT HLO artifact through the
//! PJRT runtime and compare against input/output pairs generated from
//! the pure-jnp oracle at build time (artifacts/golden/*.json).
//!
//! Requires `make artifacts`.

use std::path::PathBuf;

use dualsparse::model::Tensor;
use dualsparse::runtime::{Arg, Runtime};
use dualsparse::util::json::Json;

fn artifacts() -> PathBuf {
    std::env::var("DUALSPARSE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn golden(name: &str) -> Json {
    let path = artifacts().join("golden").join(format!("{name}.json"));
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|_| panic!("{path:?} missing — run `make artifacts`"));
    Json::parse(&text).unwrap()
}

fn tensor(j: &Json, key: &str, shape: Vec<usize>) -> Tensor {
    Tensor::new(shape, j.get(key).unwrap().as_f32_vec().unwrap())
}

fn assert_close(got: &Tensor, want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.data.len(), want.len(), "{what}: length");
    let mut worst = 0.0f32;
    for (g, w) in got.data.iter().zip(want) {
        worst = worst.max((g - w).abs());
    }
    assert!(worst < tol, "{what}: max |Δ| = {worst} > {tol}");
}

#[test]
fn ffn_artifact_matches_oracle() {
    let rt = Runtime::new(&artifacts()).unwrap();
    let g = golden("ffn_h64_c4");
    let x = tensor(&g, "x", vec![4, 64]);
    let w1 = tensor(&g, "w1", vec![64, 64]);
    let w3 = tensor(&g, "w3", vec![64, 64]);
    let w2 = tensor(&g, "w2", vec![64, 64]);
    let out = rt
        .exec("ffn_h64_c4", &[Arg::F32(&x), Arg::F32(&w1), Arg::F32(&w3), Arg::F32(&w2)])
        .unwrap();
    let want = g.get("y").unwrap().as_f32_vec().unwrap();
    assert_close(&out[0], &want, 1e-4, "ffn_h64_c4");
}

#[test]
fn ffn_artifact_matches_rust_reference() {
    // Pallas artifact vs the in-crate naive implementation: ties the
    // three layers together without Python in the loop.
    let rt = Runtime::new(&artifacts()).unwrap();
    let g = golden("ffn_h64_c4");
    let x = tensor(&g, "x", vec![4, 64]);
    let w1 = tensor(&g, "w1", vec![64, 64]);
    let w3 = tensor(&g, "w3", vec![64, 64]);
    let w2 = tensor(&g, "w2", vec![64, 64]);
    let out = rt
        .exec("ffn_h64_c4", &[Arg::F32(&x), Arg::F32(&w1), Arg::F32(&w3), Arg::F32(&w2)])
        .unwrap();
    let rust_ref = dualsparse::util::linalg::swiglu_ffn(&x, &w1, &w3, &w2);
    assert_close(&out[0], &rust_ref.data, 1e-4, "ffn vs rust ref");
}

#[test]
fn gate_artifact_matches_oracle() {
    let rt = Runtime::new(&artifacts()).unwrap();
    let g = golden("gate_b2_e8");
    let x = tensor(&g, "x", vec![2, 64]);
    let wg = tensor(&g, "wg", vec![64, 8]);
    let out = rt.exec("gate_b2_e8", &[Arg::F32(&x), Arg::F32(&wg)]).unwrap();
    let want = g.get("probs").unwrap().as_f32_vec().unwrap();
    assert_close(&out[0], &want, 1e-5, "gate_b2_e8");
    // rows are probability distributions
    for r in 0..2 {
        let s: f32 = out[0].row(r).iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }
}

#[test]
fn probe_artifact_matches_oracle() {
    let rt = Runtime::new(&artifacts()).unwrap();
    let g = golden("probe_h64");
    let x = tensor(&g, "x", vec![32, 64]);
    let w1 = tensor(&g, "w1", vec![64, 64]);
    let w3 = tensor(&g, "w3", vec![64, 64]);
    let out = rt
        .exec("probe_h64", &[Arg::F32(&x), Arg::F32(&w1), Arg::F32(&w3)])
        .unwrap();
    let want = g.get("imp").unwrap().as_f32_vec().unwrap();
    assert_close(&out[0], &want, 2e-3, "probe_h64");
}

#[test]
fn attn_step_artifact_matches_oracle() {
    let rt = Runtime::new(&artifacts()).unwrap();
    let g = golden("attn_step_b1");
    let d = 64;
    let x = tensor(&g, "x", vec![1, d]);
    let ln1 = tensor(&g, "ln1", vec![d]);
    let wq = tensor(&g, "wq", vec![d, d]);
    let wk = tensor(&g, "wk", vec![d, d]);
    let wv = tensor(&g, "wv", vec![d, d]);
    let wo = tensor(&g, "wo", vec![d, d]);
    let ln2 = tensor(&g, "ln2", vec![d]);
    let kc = tensor(&g, "kcache", vec![1, 4, 160, 16]);
    let vc = tensor(&g, "vcache", vec![1, 4, 160, 16]);
    let pos_f = g.get("pos_f").unwrap().as_f32_vec().unwrap();
    let pos: Vec<i32> = pos_f.iter().map(|&x| x as i32).collect();
    let out = rt
        .exec(
            "attn_step_b1",
            &[
                Arg::F32(&x), Arg::F32(&ln1), Arg::F32(&wq), Arg::F32(&wk),
                Arg::F32(&wv), Arg::F32(&wo), Arg::F32(&ln2), Arg::F32(&kc),
                Arg::F32(&vc), Arg::I32(&pos),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 4);
    assert_close(&out[0], &g.get("y").unwrap().as_f32_vec().unwrap(), 1e-4, "y");
    assert_close(&out[1], &g.get("ln2x").unwrap().as_f32_vec().unwrap(), 1e-4, "ln2x");
    assert_close(&out[2], &g.get("new_k").unwrap().as_f32_vec().unwrap(), 1e-4, "new_k");
    assert_close(&out[3], &g.get("new_v").unwrap().as_f32_vec().unwrap(), 1e-4, "new_v");
}

#[test]
fn capacity_buckets_are_consistent() {
    // The same rows fed through different capacity buckets (padded with
    // zeros) must produce the same outputs for the real rows.
    let rt = Runtime::new(&artifacts()).unwrap();
    let g = golden("ffn_h64_c4");
    let x4 = tensor(&g, "x", vec![4, 64]);
    let w1 = tensor(&g, "w1", vec![64, 64]);
    let w3 = tensor(&g, "w3", vec![64, 64]);
    let w2 = tensor(&g, "w2", vec![64, 64]);
    let mut x8 = x4.data.clone();
    x8.resize(8 * 64, 0.0);
    let x8 = Tensor::new(vec![8, 64], x8);
    let y4 = rt
        .exec("ffn_h64_c4", &[Arg::F32(&x4), Arg::F32(&w1), Arg::F32(&w3), Arg::F32(&w2)])
        .unwrap();
    let y8 = rt
        .exec("ffn_h64_c8", &[Arg::F32(&x8), Arg::F32(&w1), Arg::F32(&w3), Arg::F32(&w2)])
        .unwrap();
    assert_close(
        &Tensor::new(vec![4, 64], y8[0].data[..4 * 64].to_vec()),
        &y4[0].data,
        1e-5,
        "bucket consistency",
    );
}
