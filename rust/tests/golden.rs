//! Kernel-level golden tests, hermetic by construction.
//!
//! Checked-in fixtures (`rust/tests/fixtures/*.json`, generated once by
//! `python/tools/gen_fixtures.py` from the pure-Python mirror of the
//! jnp oracles) pin the **`CpuRef`** numerics — cross-language parity
//! without running Python in CI. The fixture tests construct `CpuRef`
//! directly (not via `make_backend`): their tensors use tiny test dims
//! that no AOT artifact was ever lowered for, and the point is to
//! assert the reference executor against the Python oracle regardless
//! of env overrides.
//!
//! The legacy artifact goldens (`artifacts/golden/*.json`) run through
//! `make_backend(Auto)` — PJRT when compiled in, `CpuRef` otherwise —
//! and are asserted when present instead of panicking when absent.

#![allow(clippy::needless_range_loop, clippy::manual_memcpy, clippy::type_complexity)]

use std::path::{Path, PathBuf};

use dualsparse::model::{ModelConfig, Tensor};
use dualsparse::runtime::{make_backend, Arg, Backend, BackendKind, CpuRef};
use dualsparse::util::json::Json;

fn artifacts() -> PathBuf {
    std::env::var("DUALSPARSE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Backend under test for the fixture goldens: always the reference
/// executor (see module docs).
fn backend() -> Box<dyn Backend> {
    Box::new(CpuRef::new())
}

fn fixture(name: &str) -> Json {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures")
        .join(format!("{name}.json"));
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|_| panic!("{path:?} missing — run python/tools/gen_fixtures.py"));
    Json::parse(&text).unwrap()
}

fn dim(j: &Json, key: &str) -> usize {
    j.get("dims").unwrap().get(key).unwrap().as_usize().unwrap()
}

fn tensor(j: &Json, key: &str, shape: Vec<usize>) -> Tensor {
    Tensor::new(shape, j.get(key).unwrap().as_f32_vec().unwrap())
}

fn assert_close(got: &Tensor, want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.data.len(), want.len(), "{what}: length");
    let mut worst = 0.0f32;
    for (g, w) in got.data.iter().zip(want) {
        worst = worst.max((g - w).abs());
    }
    assert!(worst < tol, "{what}: max |Δ| = {worst} > {tol}");
}

#[test]
fn ffn_matches_python_fixture() {
    let be = backend();
    let g = fixture("ffn_h12_c4");
    let (c, d, h) = (dim(&g, "c"), dim(&g, "d"), dim(&g, "h"));
    let x = tensor(&g, "x", vec![c, d]);
    let w1 = tensor(&g, "w1", vec![d, h]);
    let w3 = tensor(&g, "w3", vec![d, h]);
    let w2 = tensor(&g, "w2", vec![h, d]);
    let out = be
        .exec(
            &format!("ffn_h{h}_c{c}"),
            &[Arg::F32(&x), Arg::F32(&w1), Arg::F32(&w3), Arg::F32(&w2)],
        )
        .unwrap();
    let want = g.get("y").unwrap().as_f32_vec().unwrap();
    assert_close(&out[0], &want, 1e-4, "ffn_h12_c4");
}

#[test]
fn ffn_fixture_matches_rust_reference() {
    // Fixture vs the in-crate shared kernel: ties the checked-in Python
    // oracle values and util::linalg together without a backend.
    let g = fixture("ffn_h12_c4");
    let (c, d, h) = (dim(&g, "c"), dim(&g, "d"), dim(&g, "h"));
    let x = tensor(&g, "x", vec![c, d]);
    let w1 = tensor(&g, "w1", vec![d, h]);
    let w3 = tensor(&g, "w3", vec![d, h]);
    let w2 = tensor(&g, "w2", vec![h, d]);
    let rust_ref = dualsparse::util::linalg::swiglu_ffn(&x, &w1, &w3, &w2);
    let want = g.get("y").unwrap().as_f32_vec().unwrap();
    assert_close(&rust_ref, &want, 1e-4, "ffn vs rust ref");
}

#[test]
fn gate_matches_python_fixture() {
    let be = backend();
    let g = fixture("gate_b3_e8");
    let (b, d, e) = (dim(&g, "b"), dim(&g, "d"), dim(&g, "e"));
    let x = tensor(&g, "x", vec![b, d]);
    let wg = tensor(&g, "wg", vec![d, e]);
    let out = be
        .exec(&format!("gate_b{b}_e{e}"), &[Arg::F32(&x), Arg::F32(&wg)])
        .unwrap();
    let want = g.get("probs").unwrap().as_f32_vec().unwrap();
    assert_close(&out[0], &want, 1e-5, "gate_b3_e8");
    // rows are probability distributions
    for r in 0..b {
        let s: f32 = out[0].row(r).iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }
}

#[test]
fn probe_matches_python_fixture() {
    let be = backend();
    let g = fixture("probe_h12");
    let (c, d, h) = (dim(&g, "c"), dim(&g, "d"), dim(&g, "h"));
    let x = tensor(&g, "x", vec![c, d]);
    let w1 = tensor(&g, "w1", vec![d, h]);
    let w3 = tensor(&g, "w3", vec![d, h]);
    let out = be
        .exec(&format!("probe_h{h}"), &[Arg::F32(&x), Arg::F32(&w1), Arg::F32(&w3)])
        .unwrap();
    let want = g.get("imp").unwrap().as_f32_vec().unwrap();
    assert_close(&out[0], &want, 2e-3, "probe_h12");
}

#[test]
fn lm_head_matches_python_fixture() {
    let be = backend();
    let g = fixture("lm_head_b2");
    let (b, d, v) = (dim(&g, "b"), dim(&g, "d"), dim(&g, "v"));
    let x = tensor(&g, "x", vec![b, d]);
    let lnf = tensor(&g, "lnf", vec![d]);
    let emb = tensor(&g, "emb", vec![v, d]);
    let out = be
        .exec(
            &format!("lm_head_b{b}"),
            &[Arg::F32(&x), Arg::F32(&lnf), Arg::F32(&emb)],
        )
        .unwrap();
    let want = g.get("logits").unwrap().as_f32_vec().unwrap();
    assert_close(&out[0], &want, 1e-4, "lm_head_b2");
}

fn fixture_cfg(n_heads: usize, d_head: usize) -> ModelConfig {
    ModelConfig {
        name: "fixture".into(),
        d_model: n_heads * d_head,
        n_layers: 1,
        n_heads,
        d_head,
        vocab: 256,
        max_seq: 16,
        n_experts: 2,
        d_ffn: 4,
        top_k: 1,
        n_shared: 0,
        d_ffn_shared: 0,
        normalized_gating: false,
    }
}

#[test]
fn attn_prefill_matches_python_fixture() {
    let be = backend();
    let g = fixture("attn_prefill_s4");
    let (s, d) = (dim(&g, "s"), dim(&g, "d"));
    let (nh, dh) = (dim(&g, "n_heads"), dim(&g, "d_head"));
    be.set_model(&fixture_cfg(nh, dh));
    let x = tensor(&g, "x", vec![s, d]);
    let ln1 = tensor(&g, "ln1", vec![d]);
    let wq = tensor(&g, "wq", vec![d, d]);
    let wk = tensor(&g, "wk", vec![d, d]);
    let wv = tensor(&g, "wv", vec![d, d]);
    let wo = tensor(&g, "wo", vec![d, d]);
    let ln2 = tensor(&g, "ln2", vec![d]);
    let out = be
        .exec(
            &format!("attn_prefill_s{s}"),
            &[
                Arg::F32(&x),
                Arg::F32(&ln1),
                Arg::F32(&wq),
                Arg::F32(&wk),
                Arg::F32(&wv),
                Arg::F32(&wo),
                Arg::F32(&ln2),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 4);
    assert_close(&out[0], &g.get("y").unwrap().as_f32_vec().unwrap(), 1e-4, "y");
    assert_close(&out[1], &g.get("ln2x").unwrap().as_f32_vec().unwrap(), 1e-4, "ln2x");
    assert_close(&out[2], &g.get("k").unwrap().as_f32_vec().unwrap(), 1e-4, "k");
    assert_close(&out[3], &g.get("v").unwrap().as_f32_vec().unwrap(), 1e-4, "v");
    assert_eq!(out[2].shape, vec![s, nh, dh]);
}

#[test]
fn attn_step_matches_python_fixture() {
    let be = backend();
    let g = fixture("attn_step_b2");
    let (b, d) = (dim(&g, "b"), dim(&g, "d"));
    let (nh, dh, t) = (dim(&g, "n_heads"), dim(&g, "d_head"), dim(&g, "t_max"));
    be.set_model(&fixture_cfg(nh, dh));
    let x = tensor(&g, "x", vec![b, d]);
    let ln1 = tensor(&g, "ln1", vec![d]);
    let wq = tensor(&g, "wq", vec![d, d]);
    let wk = tensor(&g, "wk", vec![d, d]);
    let wv = tensor(&g, "wv", vec![d, d]);
    let wo = tensor(&g, "wo", vec![d, d]);
    let ln2 = tensor(&g, "ln2", vec![d]);
    let kc = tensor(&g, "kcache", vec![b, nh, t, dh]);
    let vc = tensor(&g, "vcache", vec![b, nh, t, dh]);
    let pos: Vec<i32> = g
        .get("pos")
        .unwrap()
        .as_f32_vec()
        .unwrap()
        .iter()
        .map(|&x| x as i32)
        .collect();
    let out = be
        .exec(
            &format!("attn_step_b{b}"),
            &[
                Arg::F32(&x),
                Arg::F32(&ln1),
                Arg::F32(&wq),
                Arg::F32(&wk),
                Arg::F32(&wv),
                Arg::F32(&wo),
                Arg::F32(&ln2),
                Arg::F32(&kc),
                Arg::F32(&vc),
                Arg::I32(&pos),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 4);
    assert_close(&out[0], &g.get("y").unwrap().as_f32_vec().unwrap(), 1e-4, "y");
    assert_close(&out[1], &g.get("ln2x").unwrap().as_f32_vec().unwrap(), 1e-4, "ln2x");
    assert_close(&out[2], &g.get("new_k").unwrap().as_f32_vec().unwrap(), 1e-4, "new_k");
    assert_close(&out[3], &g.get("new_v").unwrap().as_f32_vec().unwrap(), 1e-4, "new_v");
}

#[test]
fn capacity_buckets_are_consistent() {
    // The same rows fed through different capacity buckets (padded with
    // zeros) must produce the same outputs for the real rows.
    let be = backend();
    let g = fixture("ffn_h12_c4");
    let (c, d, h) = (dim(&g, "c"), dim(&g, "d"), dim(&g, "h"));
    let x4 = tensor(&g, "x", vec![c, d]);
    let w1 = tensor(&g, "w1", vec![d, h]);
    let w3 = tensor(&g, "w3", vec![d, h]);
    let w2 = tensor(&g, "w2", vec![h, d]);
    let mut x8 = x4.data.clone();
    x8.resize(2 * c * d, 0.0);
    let x8 = Tensor::new(vec![2 * c, d], x8);
    let y4 = be
        .exec(
            &format!("ffn_h{h}_c{c}"),
            &[Arg::F32(&x4), Arg::F32(&w1), Arg::F32(&w3), Arg::F32(&w2)],
        )
        .unwrap();
    let y8 = be
        .exec(
            &format!("ffn_h{h}_c{}", 2 * c),
            &[Arg::F32(&x8), Arg::F32(&w1), Arg::F32(&w3), Arg::F32(&w2)],
        )
        .unwrap();
    assert_close(
        &Tensor::new(vec![c, d], y8[0].data[..c * d].to_vec()),
        &y4[0].data,
        1e-5,
        "bucket consistency",
    );
}

// ---------------------------------------------------------------------
// Legacy artifact goldens — asserted only when a `make artifacts` tree
// is actually present (they used to panic when it was not).
// ---------------------------------------------------------------------

/// Backend for the legacy artifact goldens: whatever `Auto` resolves
/// to (PJRT with artifacts + feature, `CpuRef` otherwise).
fn auto_backend() -> Box<dyn Backend> {
    make_backend(BackendKind::Auto, &artifacts()).expect("backend")
}

fn artifact_golden(name: &str) -> Option<Json> {
    let path = artifacts().join("golden").join(format!("{name}.json"));
    let text = std::fs::read_to_string(&path).ok()?;
    Some(Json::parse(&text).unwrap())
}

#[test]
fn artifact_ffn_golden_when_present() {
    let Some(g) = artifact_golden("ffn_h64_c4") else {
        eprintln!("(no artifacts tree — skipping PJRT-era golden check)");
        return;
    };
    let be = auto_backend();
    let x = tensor(&g, "x", vec![4, 64]);
    let w1 = tensor(&g, "w1", vec![64, 64]);
    let w3 = tensor(&g, "w3", vec![64, 64]);
    let w2 = tensor(&g, "w2", vec![64, 64]);
    let out = be
        .exec(
            "ffn_h64_c4",
            &[Arg::F32(&x), Arg::F32(&w1), Arg::F32(&w3), Arg::F32(&w2)],
        )
        .unwrap();
    let want = g.get("y").unwrap().as_f32_vec().unwrap();
    assert_close(&out[0], &want, 1e-4, "ffn_h64_c4");
}

#[test]
fn artifact_gate_golden_when_present() {
    let Some(g) = artifact_golden("gate_b2_e8") else {
        eprintln!("(no artifacts tree — skipping PJRT-era golden check)");
        return;
    };
    let be = auto_backend();
    let x = tensor(&g, "x", vec![2, 64]);
    let wg = tensor(&g, "wg", vec![64, 8]);
    let out = be.exec("gate_b2_e8", &[Arg::F32(&x), Arg::F32(&wg)]).unwrap();
    let want = g.get("probs").unwrap().as_f32_vec().unwrap();
    assert_close(&out[0], &want, 1e-5, "gate_b2_e8");
}

#[test]
fn artifact_probe_golden_when_present() {
    let Some(g) = artifact_golden("probe_h64") else {
        eprintln!("(no artifacts tree — skipping PJRT-era golden check)");
        return;
    };
    let be = auto_backend();
    let x = tensor(&g, "x", vec![32, 64]);
    let w1 = tensor(&g, "w1", vec![64, 64]);
    let w3 = tensor(&g, "w3", vec![64, 64]);
    let out = be
        .exec("probe_h64", &[Arg::F32(&x), Arg::F32(&w1), Arg::F32(&w3)])
        .unwrap();
    let want = g.get("imp").unwrap().as_f32_vec().unwrap();
    assert_close(&out[0], &want, 2e-3, "probe_h64");
}
