//! Deterministic fault injection and SLO-driven degradation for the
//! serve loop — the chaos half of the scheduler's failure-domain
//! contract (the survival half lives in [`crate::engine::scheduler`]).
//!
//! Three cooperating pieces:
//!
//! * [`FaultPlan`] — a seeded, fully deterministic schedule of injected
//!   failures: per-attempt backend execution errors, per-decode-step
//!   latency spikes, KV page-pool pressure (pages sequestered from the
//!   free list for a bounded hold), EP worker failure/slow-down, and
//!   client disconnects. Every draw comes from one [`SplitMix64`]
//!   stream, so in closed-loop mode the same seed replays the same
//!   faults at the same loop positions. A zero plan draws nothing and
//!   injects nothing — the scheduler is byte-identical with
//!   `Some(zero plan)`, and with `None`.
//! * [`CancelSet`] — the external-cancellation hook: a thread-safe id
//!   set a network front end (or a fault plan simulating disconnects)
//!   marks; the scheduler sweeps it every iteration and retires marked
//!   requests as `Cancelled`, freeing their pages immediately.
//! * [`DegradeController`] — closes the loop from observed TTFT /
//!   queue depth to the active [`DropPolicy`](crate::moe::DropPolicy)
//!   via `DropPolicy::scaled`: the configured policy is the *ceiling*,
//!   level 0 scales it to keep-everything, and each SLO breach climbs
//!   one rung of the ladder (the paper's drop-rate→speedup curve run
//!   as a feedback controller); healthy evaluations relax it back down
//!   with hysteresis so the level does not flap.

use std::collections::HashSet;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::util::rng::SplitMix64;
use crate::util::stats::percentile;

/// Parsed `--faults` specification: rates and magnitudes only, no
/// state. `Default` is the zero spec (inject nothing).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FaultSpec {
    /// Per-attempt probability of an injected backend execution error
    /// (one draw per prefill-chunk attempt and per decode step).
    pub exec_p: f64,
    /// Per-decode-step probability of a latency spike…
    pub spike_p: f64,
    /// …of this many milliseconds (a real stall, so TTFT/latency
    /// percentiles — and the [`DegradeController`] — feel it).
    pub spike_ms: f64,
    /// Per-iteration probability of page-pool pressure…
    pub pressure_p: f64,
    /// …sequestering up to this many free pages…
    pub pressure_pages: usize,
    /// …for this many scheduler iterations (an equal cool-down window
    /// follows each release, so admission is guaranteed forward
    /// progress between pressure episodes).
    pub pressure_hold: u64,
    /// Fail EP worker `.0` once the run's decode-step count reaches
    /// `.1` (its experts re-host onto survivors).
    pub ep_fail: Option<(usize, u64)>,
    /// Slow EP worker `.0` by factor `.1` (≥ 1.0) for the whole run.
    pub ep_slow: Option<(usize, f64)>,
    /// Per-arrival probability that the client disconnects immediately
    /// (marks the request in the run's [`CancelSet`]).
    pub cancel_p: f64,
}

impl FaultSpec {
    /// True when nothing can ever be injected.
    pub fn is_zero(&self) -> bool {
        self.exec_p <= 0.0
            && self.spike_p <= 0.0
            && self.pressure_p <= 0.0
            && self.ep_fail.is_none()
            && self.ep_slow.is_none()
            && self.cancel_p <= 0.0
    }
}

fn parse_prob(kind: &str, raw: &str) -> Result<f64> {
    let p: f64 = raw.parse().map_err(|_| {
        anyhow::anyhow!("--faults {kind}: probability `{raw}` is not a number")
    })?;
    if !(0.0..=1.0).contains(&p) {
        bail!("--faults {kind}: probability {p} outside [0, 1]");
    }
    Ok(p)
}

/// Deterministic fault schedule: a [`FaultSpec`] plus the seeded draw
/// stream and an injected-event counter. Cloning clones the stream
/// state, so two clones replay identical faults.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub spec: FaultSpec,
    rng: SplitMix64,
    injected: u64,
    ep_fail_armed: bool,
}

impl FaultPlan {
    pub fn new(spec: FaultSpec, seed: u64) -> Self {
        FaultPlan { spec, rng: SplitMix64::new(seed), injected: 0, ep_fail_armed: true }
    }

    /// The zero plan: draws nothing, injects nothing. A serve run with
    /// this plan is byte-identical to one with no plan at all.
    pub fn none() -> Self {
        FaultPlan::new(FaultSpec::default(), 0)
    }

    /// Parse a comma-separated `--faults` spec. Components:
    ///
    /// * `exec=P` — backend execution errors at probability P/attempt
    /// * `spike=P:MS` — P/decode-step latency spikes of MS milliseconds
    /// * `pressure=P:PAGES[:HOLD]` — P/iteration sequestration of PAGES
    ///   free KV pages for HOLD iterations (default 3)
    /// * `ep-fail=W@STEP` — fail EP worker W at decode step STEP
    /// * `ep-slow=W@FACTOR` — slow EP worker W by FACTOR (≥ 1.0)
    /// * `cancel=P` — P/arrival immediate client disconnects
    ///
    /// The empty string parses to the zero plan.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan> {
        let mut out = FaultSpec::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("--faults component `{part}` is not key=value"))?;
            match key {
                "exec" => out.exec_p = parse_prob("exec", val)?,
                "cancel" => out.cancel_p = parse_prob("cancel", val)?,
                "spike" => {
                    let (p, ms) = val.split_once(':').ok_or_else(|| {
                        anyhow::anyhow!("--faults spike wants P:MS, got `{val}`")
                    })?;
                    out.spike_p = parse_prob("spike", p)?;
                    out.spike_ms = ms
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--faults spike: `{ms}` ms is not a number"))?;
                    if !(out.spike_ms > 0.0 && out.spike_ms.is_finite()) {
                        bail!("--faults spike: milliseconds must be positive and finite");
                    }
                }
                "pressure" => {
                    let mut it = val.split(':');
                    let p = it.next().unwrap_or_default();
                    let pages = it.next().ok_or_else(|| {
                        anyhow::anyhow!("--faults pressure wants P:PAGES[:HOLD], got `{val}`")
                    })?;
                    out.pressure_p = parse_prob("pressure", p)?;
                    out.pressure_pages = pages.parse().map_err(|_| {
                        anyhow::anyhow!("--faults pressure: `{pages}` pages is not an integer")
                    })?;
                    if out.pressure_pages == 0 {
                        bail!("--faults pressure: page count must be positive");
                    }
                    out.pressure_hold = match it.next() {
                        Some(h) => h.parse().map_err(|_| {
                            anyhow::anyhow!("--faults pressure: hold `{h}` is not an integer")
                        })?,
                        None => 3,
                    };
                    if out.pressure_hold == 0 {
                        bail!("--faults pressure: hold must be at least one iteration");
                    }
                }
                "ep-fail" => {
                    let (w, step) = val.split_once('@').ok_or_else(|| {
                        anyhow::anyhow!("--faults ep-fail wants W@STEP, got `{val}`")
                    })?;
                    let w: usize = w.parse().map_err(|_| {
                        anyhow::anyhow!("--faults ep-fail: worker `{w}` is not an integer")
                    })?;
                    let step: u64 = step.parse().map_err(|_| {
                        anyhow::anyhow!("--faults ep-fail: step `{step}` is not an integer")
                    })?;
                    out.ep_fail = Some((w, step));
                }
                "ep-slow" => {
                    let (w, f) = val.split_once('@').ok_or_else(|| {
                        anyhow::anyhow!("--faults ep-slow wants W@FACTOR, got `{val}`")
                    })?;
                    let w: usize = w.parse().map_err(|_| {
                        anyhow::anyhow!("--faults ep-slow: worker `{w}` is not an integer")
                    })?;
                    let f: f64 = f.parse().map_err(|_| {
                        anyhow::anyhow!("--faults ep-slow: factor `{f}` is not a number")
                    })?;
                    if !(f >= 1.0 && f.is_finite()) {
                        bail!("--faults ep-slow: factor must be ≥ 1.0 and finite");
                    }
                    out.ep_slow = Some((w, f));
                }
                other => bail!(
                    "--faults: unknown component `{other}` \
                     (want exec/spike/pressure/ep-fail/ep-slow/cancel)"
                ),
            }
        }
        Ok(FaultPlan::new(out, seed))
    }

    fn draw(&mut self, p: f64) -> bool {
        if p <= 0.0 {
            return false;
        }
        let hit = self.rng.f64() < p;
        if hit {
            self.injected += 1;
        }
        hit
    }

    /// One draw per backend-op attempt (prefill chunk / decode step):
    /// should this attempt fail with an injected execution error? The
    /// error is injected *before* the engine runs, so no partial state
    /// ever needs unwinding — retrying the attempt is always safe.
    pub fn inject_exec_error(&mut self) -> bool {
        self.draw(self.spec.exec_p)
    }

    /// One draw per decode step: a latency spike of `Some(ms)` to
    /// stall for, or `None`.
    pub fn spike_ms(&mut self) -> Option<f64> {
        if self.draw(self.spec.spike_p) {
            Some(self.spec.spike_ms)
        } else {
            None
        }
    }

    /// One draw per eligible scheduler iteration: `Some((pages, hold))`
    /// to sequester, or `None`.
    pub fn pressure(&mut self) -> Option<(usize, u64)> {
        if self.draw(self.spec.pressure_p) {
            Some((self.spec.pressure_pages, self.spec.pressure_hold.max(1)))
        } else {
            None
        }
    }

    /// One draw per arrival: does this client disconnect immediately?
    pub fn cancel_on_arrival(&mut self) -> bool {
        self.draw(self.spec.cancel_p)
    }

    /// Deterministic victim pick among `n` active decode rows.
    pub fn pick(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        self.rng.below(n)
    }

    /// Fire the one-shot EP worker failure once the run's decode-step
    /// count reaches the configured trigger. Consumes the trigger.
    pub fn take_ep_fail(&mut self, decode_steps: u64) -> Option<usize> {
        let (w, at) = self.spec.ep_fail?;
        if !self.ep_fail_armed || decode_steps < at {
            return None;
        }
        self.ep_fail_armed = false;
        self.injected += 1;
        Some(w)
    }

    /// Record the whole-run EP slow-down as one injected event (called
    /// by the scheduler when it applies `spec.ep_slow`).
    pub fn note_injected(&mut self) {
        self.injected += 1;
    }

    /// Total injected events so far (exec errors + spikes + pressure
    /// episodes + disconnects + EP failures/slow-downs).
    pub fn injected(&self) -> u64 {
        self.injected
    }
}

/// External-cancellation hook: the serve loop sweeps this set every
/// iteration and retires marked requests (by
/// [`Request::id`](crate::engine::scheduler::Request)) as `Cancelled`,
/// freeing their KV pages immediately. Clones share the underlying
/// set, so a network front end can hold one clone and cancel from
/// another thread mid-run — real client disconnects
/// ([`crate::server::net`] hangups and dead-sink token writes) land in
/// the same set as `cancel=P`-injected chaos, so both take the one
/// audited path through the sweep.
#[derive(Debug, Clone, Default)]
pub struct CancelSet {
    inner: Arc<Mutex<HashSet<usize>>>,
}

impl CancelSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mark `id` for cancellation (idempotent).
    pub fn cancel(&self, id: usize) {
        self.inner.lock().expect("cancel set poisoned").insert(id);
    }

    pub fn is_cancelled(&self, id: usize) -> bool {
        self.inner.lock().expect("cancel set poisoned").contains(&id)
    }

    /// Fast emptiness probe so the per-iteration sweep is free when no
    /// cancellation was ever requested.
    pub fn is_empty(&self) -> bool {
        self.inner.lock().expect("cancel set poisoned").is_empty()
    }
}

/// SLO feedback controller over the
/// [`DropPolicy::scaled`](crate::moe::DropPolicy::scaled) ladder.
///
/// The configured drop policy is the **ceiling**: the controller holds
/// a level in `0..=levels` and the scheduler runs
/// `base.scaled(level / levels)` — level 0 keeps everything (thresholds
/// scaled to zero), the top level is the full configured policy. Every
/// `eval_every` iterations the controller compares the windowed p99
/// TTFT and the instantaneous queue depth against the SLOs: a breach
/// escalates one level immediately; only `hysteresis` *consecutive*
/// healthy evaluations relax one level, so the ladder ratchets up fast
/// under overload and climbs down slowly when the queue drains.
#[derive(Debug, Clone)]
pub struct DegradeController {
    /// Windowed p99 TTFT above this breaches the SLO.
    pub ttft_slo_secs: f64,
    /// Instantaneous queue depth above this breaches the SLO.
    pub queue_depth_slo: usize,
    /// Ladder rungs (level ∈ 0..=levels).
    pub levels: u32,
    /// Iterations between evaluations.
    pub eval_every: u64,
    /// Consecutive healthy evaluations required to relax one level.
    pub hysteresis: u32,
    level: u32,
    healthy_streak: u32,
    window: Vec<f64>,
    timeline: Vec<(u64, u32)>,
    max_level: u32,
}

impl DegradeController {
    pub fn new(ttft_slo_secs: f64, queue_depth_slo: usize) -> Self {
        DegradeController {
            ttft_slo_secs,
            queue_depth_slo,
            levels: 4,
            eval_every: 8,
            hysteresis: 2,
            level: 0,
            healthy_streak: 0,
            window: Vec::new(),
            timeline: Vec::new(),
            max_level: 0,
        }
    }

    /// Current ladder position as the `DropPolicy::scaled` ratio.
    pub fn scale(&self) -> f64 {
        f64::from(self.level) / f64::from(self.levels.max(1))
    }

    pub fn level(&self) -> u32 {
        self.level
    }

    /// Highest level the run reached.
    pub fn max_level(&self) -> u32 {
        self.max_level
    }

    /// `(iteration, new_level)` for every level change, in order.
    pub fn timeline(&self) -> &[(u64, u32)] {
        &self.timeline
    }

    /// Feed one observed TTFT (seconds, arrival-anchored) into the
    /// current evaluation window.
    pub fn observe_ttft(&mut self, secs: f64) {
        self.window.push(secs);
    }

    /// Called once per scheduler iteration; on evaluation boundaries
    /// returns `Some(new scale)` iff the level changed.
    pub fn tick(&mut self, iter: u64, queue_depth: usize) -> Option<f64> {
        if iter == 0 || !iter.is_multiple_of(self.eval_every.max(1)) {
            return None;
        }
        let ttft_p99 = if self.window.is_empty() {
            0.0
        } else {
            percentile(&self.window, 99.0)
        };
        self.window.clear();
        let breach = ttft_p99 > self.ttft_slo_secs || queue_depth > self.queue_depth_slo;
        let before = self.level;
        if breach {
            self.healthy_streak = 0;
            self.level = (self.level + 1).min(self.levels);
        } else {
            self.healthy_streak += 1;
            if self.healthy_streak >= self.hysteresis && self.level > 0 {
                self.healthy_streak = 0;
                self.level -= 1;
            }
        }
        if self.level == before {
            return None;
        }
        self.max_level = self.max_level.max(self.level);
        self.timeline.push((iter, self.level));
        Some(self.scale())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_every_component() {
        let p = FaultPlan::parse(
            "exec=0.3, spike=0.25:30, pressure=0.2:4:5, ep-fail=1@40, ep-slow=2@1.5, cancel=0.1",
            7,
        )
        .unwrap();
        assert_eq!(p.spec.exec_p, 0.3);
        assert_eq!(p.spec.spike_p, 0.25);
        assert_eq!(p.spec.spike_ms, 30.0);
        assert_eq!(p.spec.pressure_p, 0.2);
        assert_eq!(p.spec.pressure_pages, 4);
        assert_eq!(p.spec.pressure_hold, 5);
        assert_eq!(p.spec.ep_fail, Some((1, 40)));
        assert_eq!(p.spec.ep_slow, Some((2, 1.5)));
        assert_eq!(p.spec.cancel_p, 0.1);
        assert!(!p.spec.is_zero());
        // pressure hold defaults to 3 when omitted
        let q = FaultPlan::parse("pressure=0.5:2", 0).unwrap();
        assert_eq!(q.spec.pressure_hold, 3);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "exec",           // no value
            "exec=1.5",       // p out of range
            "exec=-0.1",      // negative p
            "spike=0.5",      // missing ms
            "spike=0.5:0",    // non-positive ms
            "pressure=0.5",   // missing pages
            "pressure=0.5:0", // zero pages
            "pressure=0.5:2:0", // zero hold
            "ep-fail=1",      // missing @step
            "ep-slow=1@0.5",  // factor < 1
            "warp=0.5",       // unknown component
        ] {
            assert!(FaultPlan::parse(bad, 0).is_err(), "`{bad}` must not parse");
        }
    }

    #[test]
    fn empty_spec_is_the_zero_plan_and_draws_nothing() {
        let mut p = FaultPlan::parse("", 99).unwrap();
        assert!(p.spec.is_zero());
        for _ in 0..100 {
            assert!(!p.inject_exec_error());
            assert!(p.spike_ms().is_none());
            assert!(p.pressure().is_none());
            assert!(!p.cancel_on_arrival());
            assert!(p.take_ep_fail(u64::MAX).is_none());
        }
        assert_eq!(p.injected(), 0);
    }

    #[test]
    fn draws_are_seed_deterministic_and_counted() {
        let mk = || FaultPlan::parse("exec=0.4,spike=0.3:5", 42).unwrap();
        let (mut a, mut b) = (mk(), mk());
        let sa: Vec<(bool, Option<u64>)> = (0..200)
            .map(|_| (a.inject_exec_error(), a.spike_ms().map(|m| m as u64)))
            .collect();
        let sb: Vec<(bool, Option<u64>)> = (0..200)
            .map(|_| (b.inject_exec_error(), b.spike_ms().map(|m| m as u64)))
            .collect();
        assert_eq!(sa, sb, "same seed ⇒ same fault schedule");
        let hits = sa.iter().map(|(e, s)| u64::from(*e) + u64::from(s.is_some())).sum::<u64>();
        assert!(hits > 0, "p=0.4 over 200 draws must fire");
        assert_eq!(a.injected(), hits, "every injected event is counted");
        let mut c = FaultPlan::parse("exec=0.4,spike=0.3:5", 43).unwrap();
        let sc: Vec<(bool, Option<u64>)> = (0..200)
            .map(|_| (c.inject_exec_error(), c.spike_ms().map(|m| m as u64)))
            .collect();
        assert_ne!(sa, sc, "seed must matter");
    }

    #[test]
    fn ep_fail_trigger_is_one_shot() {
        let mut p = FaultPlan::parse("ep-fail=2@10", 0).unwrap();
        assert_eq!(p.take_ep_fail(9), None, "before the trigger step");
        assert_eq!(p.take_ep_fail(10), Some(2));
        assert_eq!(p.take_ep_fail(11), None, "consumed");
        assert_eq!(p.injected(), 1);
    }

    #[test]
    fn cancel_set_is_shared_across_clones() {
        let cs = CancelSet::new();
        assert!(cs.is_empty());
        let other = cs.clone();
        other.cancel(7);
        assert!(cs.is_cancelled(7), "clones share the set");
        assert!(!cs.is_cancelled(8));
        assert!(!cs.is_empty());
    }

    #[test]
    fn degrade_escalates_on_breach_and_relaxes_with_hysteresis() {
        let mut d = DegradeController::new(0.010, 4);
        assert_eq!(d.level(), 0);
        assert_eq!(d.scale(), 0.0, "healthy start keeps everything");
        // Breach via TTFT: escalate one level per evaluation.
        d.observe_ttft(0.050);
        assert_eq!(d.tick(8, 0), Some(0.25));
        d.observe_ttft(0.050);
        assert_eq!(d.tick(16, 0), Some(0.5));
        // Breach via queue depth alone (empty TTFT window).
        assert_eq!(d.tick(24, 9), Some(0.75));
        assert_eq!(d.max_level(), 3);
        // One healthy eval is not enough (hysteresis = 2)…
        assert_eq!(d.tick(32, 0), None);
        // …the second relaxes one level.
        assert_eq!(d.tick(40, 0), Some(0.5));
        // Non-boundary iterations never evaluate.
        d.observe_ttft(9.0);
        assert_eq!(d.tick(41, 99), None);
        assert_eq!(
            d.timeline(),
            &[(8, 1), (16, 2), (24, 3), (40, 2)],
            "every level change is on the timeline"
        );
    }

    #[test]
    fn degrade_saturates_at_the_ceiling_and_the_floor() {
        let mut d = DegradeController::new(1e-9, 0);
        for k in 1..=10u64 {
            d.observe_ttft(1.0);
            d.tick(k * 8, 100);
        }
        assert_eq!(d.level(), d.levels, "escalation saturates at the configured policy");
        assert_eq!(d.scale(), 1.0);
        let mut h = DegradeController::new(1e9, usize::MAX);
        for k in 1..=10u64 {
            h.tick(k * 8, 0);
        }
        assert_eq!(h.level(), 0, "healthy runs stay at keep-everything");
        assert!(h.timeline().is_empty());
    }
}
