//! # DualSparse-MoE
//!
//! A Rust + JAX + Pallas reproduction of **"DualSparse-MoE: Coordinating
//! Tensor/Neuron-Level Sparsity with Expert Partition and
//! Reconstruction"** (Cai et al., 2025).
//!
//! Three layers (see DESIGN.md):
//!
//! * **L1** — Pallas SwiGLU expert-FFN + probe kernels
//!   (`python/compile/kernels/`), AOT-lowered to HLO text.
//! * **L2** — the TinyMoE model family, expert partition
//!   (complete/partial transformation) and reconstruction in JAX
//!   (`python/compile/`), build-time only.
//! * **L3** — this crate: pluggable execution backends, the DualSparse
//!   router (Top-K + normalization + 1T/2T drop + load-aware
//!   thresholding), the serving engine with KV cache, chunked prefill
//!   (prompts beyond the largest prefill bucket split bit-identically
//!   across bucket-sized passes), continuous batching and an
//!   arrival-driven request scheduler ([`engine::scheduler`]:
//!   closed-loop batch or open-loop Poisson arrivals, per-request
//!   fault isolation, arrival-anchored latency) with pluggable
//!   scheduling policies and admission control ([`engine::policy`]:
//!   FCFS / shortest-prompt-first / priority lanes, bounded queues
//!   reporting goodput vs offered load), the expert-parallel
//!   simulation, the ETP/S-ETP communication simulator, the
//!   EES/EEP/Wanda baselines, and the per-figure/table experiment
//!   drivers. The serving architecture — lifecycle, policy surface,
//!   latency decomposition — is documented in `docs/ARCHITECTURE.md`;
//!   the measured-report schemas in `docs/REPORTS.md`.
//!
//! ## Execution backends
//!
//! Heavy math runs through the [`runtime::Backend`] trait:
//!
//! * **`CpuRef`** (always available) — a pure-Rust reference executor,
//!   numerically equivalent to the jnp oracles in
//!   `python/compile/kernels/ref.py`. When no serialized model exists
//!   the engine materializes deterministic SplitMix64 synthetic weights
//!   ([`model::Weights::synthetic`]), so the entire stack — engine,
//!   scheduler, server, network front end, experiments, tests — runs
//!   **hermetically**:
//!   `cargo test -q` needs no `make artifacts`, no Python, no PJRT.
//! * **PJRT** (`pjrt` cargo feature) — loads the AOT HLO-text artifacts
//!   for trained weights; Python still never runs on the request path.
//!
//! Selection: `EngineOptions::backend` (`Auto` | `CpuRef` | `Pjrt`),
//! overridable with the `DUALSPARSE_BACKEND` env var (`auto` | `cpu` |
//! `pjrt`). `Auto` prefers PJRT when compiled in and artifacts exist.
//!
//! ## Threaded CPU hot path
//!
//! `Backend` is `Sync`; the engine runs per-expert sub-expert calls on
//! a scoped worker pool ([`util::threads`], sized by
//! `DUALSPARSE_THREADS`, default = available parallelism), and the
//! blocked kernels in [`util::linalg`] tile large GEMMs and prefill
//! attention heads across the same pool. Every parallel unit computes
//! exactly what the serial path computes and merges in a fixed order,
//! so generations and metrics are byte-identical for every thread
//! count (pinned by `rust/tests/parallel.rs`). `dualsparse bench`
//! measures the resulting tokens/sec surface into `BENCH_cpu.json`.

// The numeric kernels and scatter/gather loops index several parallel
// arrays in lockstep; clippy's iterator rewrites obscure them without
// changing codegen.
#![allow(clippy::needless_range_loop, clippy::manual_memcpy, clippy::too_many_arguments)]

pub mod baselines;
pub mod calib;
pub mod commsim;
pub mod engine;
pub mod experiments;
pub mod model;
pub mod moe;
pub mod runtime;
pub mod server;
pub mod tasks;
pub mod util;

pub use engine::{Engine, EngineOptions};
pub use moe::{DropPolicy, DropStats};
