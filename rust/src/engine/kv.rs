//! KV-cache manager: fixed-slot paged storage for continuous batching.
//!
//! Layout: one tensor per layer, `[B_MAX, H, T, dh]`, plus a free-slot
//! list. Decode batches always occupy a contiguous slot prefix
//! (`compact` moves the tail slot into a hole when a request retires),
//! so the batch cache fed to `attn_step_b{B}` is simply the first
//! `B` rows — no per-step gather.
//!
//! Writers come in three flavors, all appending behind `pos[slot]`'s
//! invariant (tokens cached == next write position):
//!
//! * [`KvCache::write_prefill`] — bulk chunk write at an explicit
//!   `base`; chunked prefill calls it once per chunk so a long prompt's
//!   positions land exactly where a single-pass prefill would put them.
//! * [`KvCache::append`] — one decode-step (k, v) head-vector set.
//! * [`KvCache::reset`] / [`KvCache::alloc`] — slot recycling between
//!   runs; `alloc` re-zeroes contents so a stale sequence can never
//!   widen a later request's attention window.

use crate::model::Tensor;

pub struct KvCache {
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_seq: usize,
    pub d_head: usize,
    pub max_slots: usize,
    /// Per-layer K / V tensors, shape [B_MAX, H, T, dh].
    pub k: Vec<Tensor>,
    pub v: Vec<Tensor>,
    /// Tokens cached per slot (== next write position).
    pub pos: Vec<usize>,
    /// Slots currently in use (always a prefix 0..n_active).
    pub n_active: usize,
}

impl KvCache {
    pub fn new(n_layers: usize, n_heads: usize, max_seq: usize, d_head: usize,
               max_slots: usize) -> Self {
        let shape = vec![max_slots, n_heads, max_seq, d_head];
        KvCache {
            n_layers,
            n_heads,
            max_seq,
            d_head,
            max_slots,
            k: (0..n_layers).map(|_| Tensor::zeros(shape.clone())).collect(),
            v: (0..n_layers).map(|_| Tensor::zeros(shape.clone())).collect(),
            pos: vec![0; max_slots],
            n_active: 0,
        }
    }

    /// Claim the next slot; returns its index. Panics if full (the
    /// batcher checks `has_free` first).
    pub fn alloc(&mut self) -> usize {
        assert!(self.n_active < self.max_slots, "KV cache full");
        let slot = self.n_active;
        self.n_active += 1;
        self.pos[slot] = 0;
        self.zero_slot(slot);
        slot
    }

    pub fn has_free(&self) -> bool {
        self.n_active < self.max_slots
    }

    /// Drop every active slot (start of a fresh serving run). Positions
    /// are cleared too, so a stale sequence length can never widen a
    /// later run's attention window (`alloc` re-zeroes slot contents).
    pub fn reset(&mut self) {
        self.n_active = 0;
        self.pos.fill(0);
    }

    /// Floats per slot per layer (`H · T · dh`) — the row stride of the
    /// zero-copy per-slot views the engine feeds to `attn_step_*`.
    pub fn slot_stride(&self) -> usize {
        self.n_heads * self.max_seq * self.d_head
    }

    fn zero_slot(&mut self, slot: usize) {
        let stride = self.slot_stride();
        for li in 0..self.n_layers {
            self.k[li].data[slot * stride..(slot + 1) * stride].fill(0.0);
            self.v[li].data[slot * stride..(slot + 1) * stride].fill(0.0);
        }
    }

    /// Retire `slot`, moving the last active slot into the hole so active
    /// slots stay a contiguous prefix. Returns Some(moved_from) when a
    /// slot was relocated (the batcher must remap its request).
    pub fn free(&mut self, slot: usize) -> Option<usize> {
        assert!(slot < self.n_active);
        let last = self.n_active - 1;
        self.n_active -= 1;
        if slot == last {
            return None;
        }
        let stride = self.slot_stride();
        for li in 0..self.n_layers {
            let (a, b) = (slot * stride, last * stride);
            // copy within one buffer: split_at_mut around the later range
            let data = &mut self.k[li].data;
            data.copy_within(b..b + stride, a);
            let data = &mut self.v[li].data;
            data.copy_within(b..b + stride, a);
        }
        self.pos[slot] = self.pos[last];
        self.pos[last] = 0;
        Some(last)
    }

    /// Write one new (k, v) head-vector set for `slot` at its current
    /// position and advance it. `new_k`/`new_v`: `[H, dh]` row-major.
    pub fn append(&mut self, layer: usize, slot: usize, new_k: &[f32], new_v: &[f32]) {
        let t = self.pos[slot];
        assert!(t < self.max_seq, "sequence overflow in slot {slot}");
        let (h, dh, tt) = (self.n_heads, self.d_head, self.max_seq);
        for hi in 0..h {
            let dst = ((slot * h + hi) * tt + t) * dh;
            let src = hi * dh;
            self.k[layer].data[dst..dst + dh].copy_from_slice(&new_k[src..src + dh]);
            self.v[layer].data[dst..dst + dh].copy_from_slice(&new_v[src..src + dh]);
        }
        if layer == self.n_layers - 1 {
            self.pos[slot] = t + 1;
        }
    }

    /// Bulk-write prefill K/V for `slot` at positions
    /// `base..base + s_len`: `ks`/`vs` are `[S, H, dh]` chunk-local.
    /// `base = 0` is a whole-prompt (or first-chunk) prefill; `base > 0`
    /// is a chunked-prefill continuation appending behind the positions
    /// already cached. Advances `pos[slot]` to `base + s_len` on the
    /// last layer, so after the final chunk the slot's decode position
    /// is exactly the prompt length.
    pub fn write_prefill(&mut self, layer: usize, slot: usize, base: usize,
                         s_len: usize, ks: &[f32], vs: &[f32]) {
        debug_assert!(base + s_len <= self.max_seq, "prefill overflows the KV window");
        let (h, dh, tt) = (self.n_heads, self.d_head, self.max_seq);
        for t in 0..s_len {
            for hi in 0..h {
                let dst = ((slot * h + hi) * tt + base + t) * dh;
                let src = (t * h + hi) * dh;
                self.k[layer].data[dst..dst + dh].copy_from_slice(&ks[src..src + dh]);
                self.v[layer].data[dst..dst + dh].copy_from_slice(&vs[src..src + dh]);
            }
        }
        if layer == self.n_layers - 1 {
            self.pos[slot] = base + s_len;
        }
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache() -> KvCache {
        KvCache::new(2, 2, 8, 4, 3)
    }

    #[test]
    fn alloc_free_compacts() {
        let mut c = cache();
        let a = c.alloc();
        let b = c.alloc();
        let d = c.alloc();
        assert_eq!((a, b, d), (0, 1, 2));
        assert!(!c.has_free());
        // free middle: slot 2 moves into 1
        assert_eq!(c.free(1), Some(2));
        assert_eq!(c.n_active, 2);
        // free last: no move
        assert_eq!(c.free(1), None);
    }

    #[test]
    fn append_advances_on_last_layer_only() {
        let mut c = cache();
        let s = c.alloc();
        let k = vec![1.0; 8];
        let v = vec![2.0; 8];
        c.append(0, s, &k, &v);
        assert_eq!(c.pos[s], 0); // not the last layer yet
        c.append(1, s, &k, &v);
        assert_eq!(c.pos[s], 1);
    }

    #[test]
    fn append_lands_in_layout() {
        let mut c = cache();
        let s = c.alloc();
        let k: Vec<f32> = (0..8).map(|x| x as f32).collect();
        c.append(0, s, &k, &k);
        c.append(1, s, &k, &k);
        // head 1, t=0, dh=4 → offset ((0*2+1)*8+0)*4 = 32
        assert_eq!(c.k[0].data[32..36], [4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn prefill_sets_pos() {
        let mut c = cache();
        let s = c.alloc();
        let ks = vec![0.5; 3 * 2 * 4];
        for li in 0..2 {
            c.write_prefill(li, s, 0, 3, &ks, &ks);
        }
        assert_eq!(c.pos[s], 3);
        // slot 0's K landed at the head of the layer-0 cache, which is
        // exactly the zero-copy slice the engine lends to attn_step
        assert_eq!(c.k[0].data[0], 0.5);
        assert_eq!(c.k[0].shape, vec![3, 2, 8, 4]);
    }

    #[test]
    fn chunked_prefill_continuation_appends_behind_base() {
        // Two chunks into one slot must equal one whole-prompt write:
        // positions line up and pos[slot] ends at the prompt length.
        let mut whole = cache();
        let mut chunked = cache();
        let sw = whole.alloc();
        let sc = chunked.alloc();
        let (h, dh) = (2usize, 4usize);
        let kv_row = |t: usize| -> Vec<f32> {
            (0..h * dh).map(|i| (t * 100 + i) as f32).collect()
        };
        // 5-token prompt, rows [S, H, dh]
        let all: Vec<f32> = (0..5).flat_map(kv_row).collect();
        let head: Vec<f32> = (0..3).flat_map(kv_row).collect();
        let tail: Vec<f32> = (3..5).flat_map(kv_row).collect();
        for li in 0..2 {
            whole.write_prefill(li, sw, 0, 5, &all, &all);
            chunked.write_prefill(li, sc, 0, 3, &head, &head);
            chunked.write_prefill(li, sc, 3, 2, &tail, &tail);
        }
        assert_eq!(whole.pos[sw], 5);
        assert_eq!(chunked.pos[sc], 5);
        for li in 0..2 {
            assert_eq!(whole.k[li].data, chunked.k[li].data, "layer {li} K diverged");
            assert_eq!(whole.v[li].data, chunked.v[li].data, "layer {li} V diverged");
        }
    }

    #[test]
    fn reset_clears_active_and_positions() {
        let mut c = cache();
        c.alloc();
        c.alloc();
        c.pos[1] = 5;
        c.reset();
        assert_eq!(c.n_active, 0);
        assert!(c.pos.iter().all(|&p| p == 0));
        assert!(c.has_free());
        assert_eq!(c.alloc(), 0);
    }

    #[test]
    fn free_moves_pos_too() {
        let mut c = cache();
        c.alloc();
        c.alloc();
        c.pos[1] = 5;
        c.free(0);
        assert_eq!(c.pos[0], 5);
    }
}
