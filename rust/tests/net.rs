//! Network front-end integration tests, loopback-only: a real
//! `NetServer` on an ephemeral 127.0.0.1 port, driven by real TCP
//! clients. Pins the ISSUE-9 contract: token streaming is incremental
//! and byte-identical to the in-process scheduler, a mid-decode client
//! disconnect resolves as `Cancelled` with zero leaked pages, malformed
//! and oversized frames are refused without poisoning the connection,
//! and the per-connection queue bound backpressures as an `error`
//! frame.
//!
//! Hermetic: CpuRef backend + synthetic SplitMix64 weights.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use dualsparse::engine::policy::Fcfs;
use dualsparse::engine::scheduler::{serve, Completion, SchedOptions, ServeOutcome};
use dualsparse::server::net::{
    run_client, send_shutdown, ClientRequest, NetOptions, NetServer, NetStats,
};
use dualsparse::server::workload;
use dualsparse::util::json::{num, obj, s, write_ndjson, FrameDecoder, FrameEvent};
use dualsparse::{DropPolicy, Engine, EngineOptions};

fn artifacts() -> PathBuf {
    std::env::var("DUALSPARSE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn engine() -> Engine {
    Engine::new(&artifacts(), "mixtral_ish", DropPolicy::NoDrop, EngineOptions::default())
        .expect("hermetic engine (CpuRef + synthetic weights)")
}

/// The closed-loop in-process run the wire texts must reproduce
/// byte-for-byte (per-row attention makes texts independent of batch
/// composition, so arrival interleaving cannot perturb them).
fn reference_completions(reqs: &[dualsparse::engine::scheduler::Request]) -> Vec<Completion> {
    let mut e = engine();
    let (done, _) = serve(&mut e, reqs).expect("in-process reference run");
    done
}

struct ServerRun {
    outcome: ServeOutcome,
    net: NetStats,
    leaked: usize,
}

/// Bind an ephemeral loopback port and run the scheduler on a
/// background thread until a `shutdown` frame drains it. The engine
/// lives (and dies) on that thread; the run's outcome, wire counters
/// and page-pool deficit come back through the join handle.
fn spawn_server(
    opts: NetOptions,
    sched: SchedOptions,
) -> (SocketAddr, thread::JoinHandle<ServerRun>) {
    let (tx, rx) = mpsc::channel();
    let handle = thread::spawn(move || {
        let mut e = engine();
        let srv = NetServer::bind("127.0.0.1:0", opts).expect("bind ephemeral loopback port");
        tx.send(srv.local_addr()).expect("report bound address");
        let (outcome, net) = srv.serve(&mut e, &Fcfs, sched).expect("network serve run");
        let leaked = e.kv.n_pages - e.kv.free_page_count();
        ServerRun { outcome, net, leaked }
    });
    (rx.recv().expect("server thread bound"), handle)
}

fn assert_exactly_once(run: &ServerRun) {
    let st = &run.outcome.stats;
    assert_eq!(
        st.requests + st.rejected + st.failed + st.timed_out + st.cancelled,
        run.net.accepted_requests,
        "five-way terminal partition must cover every request accepted off the wire"
    );
    assert_eq!(run.leaked, 0, "page pool must drain back to full after the run");
}

#[test]
fn streamed_tokens_are_byte_identical_to_in_process_serve() {
    let reqs = workload(10, 5, 7);
    let reference = reference_completions(&reqs);
    assert_eq!(reference.len(), reqs.len(), "reference run must complete everything");

    let (addr, server) = spawn_server(NetOptions::default(), SchedOptions::default());
    // Two concurrent client connections, half the workload each — the
    // wire tag carries the original request id for correlation.
    let halves: Vec<Vec<ClientRequest>> = reqs
        .chunks(reqs.len() / 2)
        .map(|chunk| {
            chunk
                .iter()
                .map(|r| ClientRequest {
                    tag: r.id.to_string(),
                    prompt: r.prompt.clone(),
                    max_new: r.max_new,
                })
                .collect()
        })
        .collect();
    let clients: Vec<_> = halves
        .into_iter()
        .map(|half| thread::spawn(move || run_client(&addr, &half, false).expect("client run")))
        .collect();
    let reports: Vec<_> = clients.into_iter().map(|c| c.join().expect("client thread")).collect();
    send_shutdown(&addr).expect("graceful shutdown");
    let run = server.join().expect("server thread");

    for c in &reference {
        let tag = c.id.to_string();
        let out = reports
            .iter()
            .find_map(|r| r.outcome(&tag))
            .unwrap_or_else(|| panic!("no client outcome for request {tag}"));
        assert_eq!(out.terminal, "done", "request {tag} must complete");
        assert_eq!(
            out.done_text.as_deref(),
            Some(c.text.as_str()),
            "request {tag}: done text must match the in-process run byte-for-byte"
        );
        assert_eq!(
            out.streamed, c.text,
            "request {tag}: token frames must concatenate to the done text"
        );
        assert_eq!(
            out.token_frames,
            c.text.len(),
            "request {tag}: one token frame per generated byte"
        );
        if !c.text.is_empty() {
            assert!(
                out.token_before_done,
                "request {tag}: the first token frame must strictly precede the done frame"
            );
        }
    }
    assert_eq!(run.net.accepted_requests, reqs.len());
    assert_eq!(run.outcome.stats.requests, reqs.len(), "every wire request completes");
    assert_eq!(run.net.connections, 3, "two clients + the shutdown connection");
    assert_eq!(run.net.disconnects, 0, "clean closes are not disconnects");
    let streamed_total: usize = reference.iter().map(|c| c.text.len()).sum();
    assert_eq!(run.net.token_frames as usize, streamed_total);
    assert_exactly_once(&run);
}

/// Read frames off a raw victim socket until the first `token` frame —
/// proof the request is past prefill and generating.
fn read_until_token(stream: &mut TcpStream) {
    let mut dec = FrameDecoder::new(1 << 20);
    let mut buf = [0u8; 4096];
    loop {
        let n = stream.read(&mut buf).expect("victim read");
        assert!(n > 0, "server closed the victim connection before its first token");
        for ev in dec.feed(&buf[..n]) {
            if let FrameEvent::Frame(v) = ev {
                let kind = v.get("frame").expect("frame key").as_str().expect("frame kind");
                assert!(kind == "token", "expected a token frame first, got {kind:?}");
                return;
            }
        }
    }
}

#[test]
fn mid_decode_disconnect_cancels_and_frees_pages() {
    let reqs = workload(8, 6, 7);
    let reference = reference_completions(&reqs);
    // The victim replays the longest-output request with a raised cap,
    // so after its first token there are guaranteed further decode
    // iterations (each a full model forward) in which the EOF-driven
    // hangup → CancelSet → sweep path can land.
    let longest = reference.iter().max_by_key(|c| c.new_tokens).expect("non-empty reference");
    assert!(
        longest.new_tokens >= 2,
        "workload must contain a multi-token output for a mid-decode disconnect"
    );
    let victim_prompt =
        &reqs.iter().find(|r| r.id == longest.id).expect("reference id in workload").prompt;

    let (addr, server) = spawn_server(NetOptions::default(), SchedOptions::default());
    let mut victim = TcpStream::connect(addr).expect("victim connect");
    victim
        .set_read_timeout(Some(Duration::from_secs(60)))
        .expect("victim read timeout");
    let frame = obj(vec![
        ("op", s("generate")),
        ("prompt", s(victim_prompt)),
        ("max_new", num(64.0)),
        ("tag", s("victim")),
    ]);
    write_ndjson(&mut victim, &frame).expect("send victim request");
    read_until_token(&mut victim);
    drop(victim); // mid-decode hangup

    // A healthy client on another connection is unaffected.
    let healthy: Vec<ClientRequest> = reqs
        .iter()
        .take(2)
        .map(|r| ClientRequest {
            tag: r.id.to_string(),
            prompt: r.prompt.clone(),
            max_new: r.max_new,
        })
        .collect();
    let healthy_report = run_client(&addr, &healthy, false).expect("healthy client");
    send_shutdown(&addr).expect("graceful shutdown");
    let run = server.join().expect("server thread");

    assert_eq!(healthy_report.completions(), 2, "the disconnect must not poison other clients");
    assert!(
        run.outcome.stats.cancelled >= 1,
        "the victim's request must resolve Cancelled (stats: {:?})",
        run.outcome.stats.cancelled
    );
    assert!(run.net.disconnects >= 1, "the dropped connection must be counted");
    assert_exactly_once(&run);
}

#[test]
fn malformed_and_oversized_frames_are_refused_without_poisoning() {
    let opts = NetOptions { max_frame_bytes: 256, ..NetOptions::default() };
    let (addr, server) = spawn_server(opts, SchedOptions::default());
    let mut c = TcpStream::connect(addr).expect("connect");
    c.set_read_timeout(Some(Duration::from_secs(60))).expect("read timeout");
    // One garbage line, one frame past the 256-byte bound, then a valid
    // request — the connection must survive all three.
    c.write_all(b"this is not a frame\n").expect("garbage line");
    let oversized = obj(vec![("op", s("generate")), ("prompt", s(&"x".repeat(1000)))]);
    write_ndjson(&mut c, &oversized).expect("oversized frame");
    let valid = obj(vec![
        ("op", s("generate")),
        ("prompt", s("hi there")),
        ("max_new", num(4.0)),
        ("tag", s("ok")),
    ]);
    write_ndjson(&mut c, &valid).expect("valid frame");

    let mut dec = FrameDecoder::new(1 << 20);
    let mut buf = [0u8; 4096];
    let mut errors = 0usize;
    let mut done = false;
    while !(done && errors == 2) {
        let n = c.read(&mut buf).expect("read response frames");
        assert!(n > 0, "server closed before answering the valid request");
        for ev in dec.feed(&buf[..n]) {
            let v = match ev {
                FrameEvent::Frame(v) => v,
                other => panic!("undecodable server frame: {other:?}"),
            };
            match v.get("frame").expect("frame key").as_str().expect("frame kind") {
                "error" => errors += 1,
                "done" => {
                    assert_eq!(v.get("tag").expect("tag").as_str().expect("tag str"), "ok");
                    done = true;
                }
                "token" => {}
                other => panic!("unexpected frame kind {other:?}"),
            }
        }
    }
    drop(c);
    send_shutdown(&addr).expect("graceful shutdown");
    let run = server.join().expect("server thread");

    assert_eq!(run.net.inbound_rejections, 2, "exactly the two bad frames are refused");
    assert_eq!(run.net.accepted_requests, 1, "only the valid request reaches the scheduler");
    assert_eq!(run.outcome.stats.requests, 1);
    assert_exactly_once(&run);
}

#[test]
fn connection_queue_bound_backpressures_as_error_frame() {
    let opts = NetOptions { conn_queue: 1, ..NetOptions::default() };
    let (addr, server) = spawn_server(opts, SchedOptions::default());
    // Both frames land back-to-back in one connection's reader: the
    // first is admitted (pending = 1), the second trips the bound long
    // before the first can turn terminal.
    let reqs: Vec<ClientRequest> = workload(2, 4, 7)
        .into_iter()
        .map(|r| ClientRequest { tag: r.id.to_string(), prompt: r.prompt, max_new: r.max_new })
        .collect();
    let first_tag = reqs[0].tag.clone();
    let second_tag = reqs[1].tag.clone();
    let rep = run_client(&addr, &reqs, true).expect("client run");
    let run = server.join().expect("server thread");

    assert_eq!(rep.completions(), 1, "the admitted request completes");
    assert_eq!(rep.errors, 1, "the overflow request is answered with an error frame");
    assert_eq!(rep.outcome(&first_tag).expect("first outcome").terminal, "done");
    assert_eq!(
        rep.outcome(&second_tag).expect("second outcome").terminal,
        "",
        "the refused request never gets a lifecycle frame, only the error"
    );
    assert!(rep.shutdown_acked, "shutdown frame must be acked");
    assert_eq!(run.net.inbound_rejections, 1);
    assert_eq!(run.net.accepted_requests, 1);
    assert_exactly_once(&run);
}
