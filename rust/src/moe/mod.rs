//! MoE coordination: gating, drop policies, partition/reconstruction.
//!
//! This is the paper's system contribution at Layer 3: the router owns
//! Top-K selection, score normalization, the 1T/2T drop decisions, and
//! the sub-expert dispatch plan; the FFN compute itself runs through the
//! AOT Pallas artifacts (Layer 1).

pub mod drop;
pub mod gating;
pub mod partition;

pub use drop::{Decision, DropPolicy, DropStats};
pub use gating::{cmp_desc_nan_last, route_token, top_k, TokenRouting};
pub use partition::{
    build_layer, complete_transform_expert, complete_transform_gate,
    importance_order, remap_indices, PartitionedExpert, SubExpert,
};

/// A packed dispatch plan for one MoE layer invocation: which tokens run
/// on which (sub-)expert, with which combination weight.
#[derive(Debug, Default)]
pub struct DispatchPlan {
    /// Per original expert: (token row, weight) pairs that run FULL.
    pub full: Vec<Vec<(usize, f32)>>,
    /// Per original expert: (token row, weight) pairs that run MAJOR only.
    pub major_only: Vec<Vec<(usize, f32)>>,
    /// Drop accounting for this invocation.
    pub stats: DropStats,
}

impl DispatchPlan {
    pub fn new(n_experts: usize) -> Self {
        DispatchPlan {
            full: vec![Vec::new(); n_experts],
            major_only: vec![Vec::new(); n_experts],
            stats: DropStats::default(),
        }
    }

    /// Total kept token-expert pair count (full + major-only).
    pub fn kept_pairs(&self) -> usize {
        self.full.iter().map(Vec::len).sum::<usize>()
            + self.major_only.iter().map(Vec::len).sum::<usize>()
    }
}

/// Build the dispatch plan for a batch of routed tokens under `policy`.
///
/// `per_token_policy` optionally overrides the policy per token (the
/// load-aware EP path assigns each token its owning device's scaled
/// policy); otherwise `policy` applies uniformly.
pub fn plan_dispatch(
    routings: &[TokenRouting],
    n_experts: usize,
    policy: DropPolicy,
    per_pair_policy: Option<&dyn Fn(usize, usize) -> DropPolicy>,
) -> DispatchPlan {
    let mut plan = DispatchPlan::new(n_experts);
    for (row, r) in routings.iter().enumerate() {
        for &(e, score, norm) in &r.experts {
            let pol = match per_pair_policy {
                Some(f) => f(row, e),
                None => policy,
            };
            let d = pol.decide(norm);
            plan.stats.record(d);
            match d {
                Decision::Full => plan.full[e].push((row, score)),
                Decision::MajorOnly => plan.major_only[e].push((row, score)),
                Decision::Drop => {}
            }
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    fn routing(pairs: &[(usize, f32, f32)]) -> TokenRouting {
        TokenRouting { experts: pairs.to_vec() }
    }

    #[test]
    fn plan_no_drop_routes_everything() {
        let r = vec![
            routing(&[(0, 0.6, 0.75), (1, 0.2, 0.25)]),
            routing(&[(1, 0.5, 0.5), (2, 0.5, 0.5)]),
        ];
        let plan = plan_dispatch(&r, 4, DropPolicy::NoDrop, None);
        assert_eq!(plan.kept_pairs(), 4);
        assert_eq!(plan.full[1], vec![(0, 0.2), (1, 0.5)]);
        assert_eq!(plan.stats.drop_rate(), 0.0);
    }

    #[test]
    fn plan_two_t_splits_bands() {
        let r = vec![routing(&[(0, 0.9, 0.9), (1, 0.1, 0.10)])];
        let plan = plan_dispatch(&r, 2, DropPolicy::two_t(0.10), None);
        assert_eq!(plan.full[0].len(), 1);
        assert_eq!(plan.major_only[1].len(), 1);
        assert!((plan.stats.drop_rate() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn per_pair_policy_overrides() {
        let r = vec![routing(&[(0, 0.5, 0.5), (1, 0.5, 0.5)])];
        // expert 0 on a loaded device (drop), expert 1 on idle (keep)
        let f = |_row: usize, e: usize| {
            if e == 0 {
                DropPolicy::OneT(0.9)
            } else {
                DropPolicy::OneT(0.0)
            }
        };
        let plan = plan_dispatch(&r, 2, DropPolicy::NoDrop, Some(&f));
        assert!(plan.full[0].is_empty());
        assert_eq!(plan.full[1].len(), 1);
    }
}
