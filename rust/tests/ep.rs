//! Expert-parallel simulation, end-to-end through the engine.
//!
//! The EP layer's contract has two halves: (1) it is *pure accounting*
//! unless load-aware thresholding is on with ≥ 2 workers — static EP
//! at any worker count and load-aware EP with one worker must leave
//! generated text byte-identical to a no-EP run; (2) when load-aware
//! thresholding does change decisions, the in-run counterfactual
//! static shadow bounds it exactly: straggler ratio and drop rate
//! never exceed what the unscaled base policy would have produced on
//! the identical routings. Hermetic (CpuRef + synthetic weights), like
//! `integration.rs`.

#![allow(clippy::needless_range_loop, clippy::manual_memcpy, clippy::type_complexity)]

use std::path::PathBuf;

use dualsparse::engine::{Engine, EngineOptions, EpOptions};
use dualsparse::moe::DropPolicy;

fn artifacts() -> PathBuf {
    std::env::var("DUALSPARSE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn engine(policy: DropPolicy, ep: Option<EpOptions>) -> Engine {
    let opts = EngineOptions { ep, ..Default::default() };
    Engine::new(&artifacts(), "mixtral_ish", policy, opts)
        .expect("hermetic engine (CpuRef + synthetic weights)")
}

const PROMPTS: [&str; 5] = ["cpy:abcd|", "add:3+4|", "srt:dcba|", "maj:aabab|", "rev:fgh|"];

#[test]
fn static_ep_and_single_aware_worker_are_output_invisible() {
    // ISSUE-7 acceptance: completion texts byte-identical between
    // `--ep-workers 1` (even load-aware: every ratio is exactly 1.0,
    // and t × 1.0 == t in f32) or static EP at any N, and no EP at all.
    let policy = DropPolicy::two_t(0.45);
    let want = engine(policy, None).generate_batch(&PROMPTS, 8).unwrap();
    let mut ep4 = engine(policy, Some(EpOptions::new(4, false)));
    let got4 = ep4.generate_batch(&PROMPTS, 8).unwrap();
    assert_eq!(got4, want, "static EP must be pure accounting");
    let mut ep1 = engine(policy, Some(EpOptions::new(1, true)));
    let got1 = ep1.generate_batch(&PROMPTS, 8).unwrap();
    assert_eq!(got1, want, "one load-aware worker scales every threshold by 1.0");
}

#[test]
fn load_aware_run_is_bounded_by_its_static_counterfactual() {
    let mut e = engine(DropPolicy::two_t(0.45), Some(EpOptions::new(4, true)));
    e.generate_batch(&PROMPTS, 8).unwrap();
    let rep = e.ep_report().expect("EP is on");
    assert_eq!(rep.workers, 4);
    assert!(rep.load_aware);
    assert!(rep.invocations > 0, "the serve loop drove the simulation");
    // Exact per-run bounds from the shadow accounting (not statistical:
    // the hottest worker's policy is unchanged under hot-keyed scaling).
    assert!(
        rep.straggler_ratio <= rep.straggler_ratio_static + 1e-12,
        "aware ratio {} exceeds static counterfactual {}",
        rep.straggler_ratio,
        rep.straggler_ratio_static
    );
    assert!(
        rep.drop_rate <= rep.drop_rate_static + 1e-12,
        "scaling only lowers thresholds ⇒ can only keep more"
    );
    assert_eq!(rep.busy_secs.len(), 4);
    assert!(rep.busy_secs.iter().sum::<f64>() > 0.0, "measured time was attributed");
    assert!(rep.comm_secs > 0.0, "multi-worker EP pays AlltoAll every invocation");
    assert!(rep.sim_secs >= rep.comm_secs);
    assert_eq!(rep.replications, 0, "replication is off by default");
}

#[test]
fn static_ep_report_is_its_own_counterfactual() {
    let mut e = engine(DropPolicy::two_t(0.45), Some(EpOptions::new(4, false)));
    e.generate_batch(&PROMPTS, 8).unwrap();
    let rep = e.ep_report().unwrap();
    assert!(
        (rep.straggler_ratio - rep.straggler_ratio_static).abs() < 1e-12,
        "with load-aware off the shadow runs the same policy"
    );
    assert!((rep.drop_rate - rep.drop_rate_static).abs() < 1e-12);
    assert!(rep.straggler_ratio > 1.0, "round-robin placement on real routing straggles");
}

#[test]
fn replication_is_count_based_and_output_invisible() {
    let mk = || {
        let ep = EpOptions {
            n_devices: 4,
            load_aware: false,
            replicate_after: Some(1),
        };
        engine(DropPolicy::NoDrop, Some(ep))
    };
    let mut a = mk();
    let ga = a.generate_batch(&PROMPTS, 8).unwrap();
    let ra = a.ep_report().unwrap();
    let mut b = mk();
    let gb = b.generate_batch(&PROMPTS, 8).unwrap();
    let rb = b.ep_report().unwrap();
    assert_eq!(ga, gb, "identical runs take the identical placement trajectory");
    assert_eq!(ra.replications, rb.replications, "trigger counts invocations, not wall time");
    assert!(ra.replications > 0, "K=1 on skewed top-2 routing must fire");
    // Replication redistributes accounting only — never generations.
    let want = engine(DropPolicy::NoDrop, None).generate_batch(&PROMPTS, 8).unwrap();
    assert_eq!(ga, want);
}

#[test]
fn injected_ep_worker_failure_rehosts_experts_and_conserves_requests() {
    // ISSUE-8: a FaultPlan `ep-fail=W@STEP` trips at the configured
    // decode step; the failed worker's experts re-host onto the
    // least-loaded survivors (PR-7 replication machinery) and serving
    // carries on — every request still completes, and the failover
    // count surfaces in the serve stats.
    use dualsparse::engine::faults::FaultPlan;
    use dualsparse::engine::policy::Fcfs;
    use dualsparse::engine::scheduler::{serve_opts, ArrivalMode, SchedOptions};
    use dualsparse::server::workload;

    let mut e = engine(DropPolicy::two_t(0.45), Some(EpOptions::new(4, false)));
    let reqs = workload(8, 6, 7);
    let plan = FaultPlan::parse("ep-fail=1@2", 3).unwrap();
    let out = serve_opts(
        &mut e,
        &reqs,
        ArrivalMode::Closed,
        &Fcfs,
        SchedOptions { faults: Some(plan), ..Default::default() },
    )
    .unwrap();
    assert_eq!(out.completions.len(), 8, "an EP worker failure must not cost completions");
    assert!(out.casualties.is_empty(), "EP failure is infrastructure, not a request fault");
    assert!(out.stats.ep_failovers >= 1, "the failed worker hosted experts to re-host");
    assert_eq!(out.stats.ep_workers, 4);
    assert_eq!(out.stats.faults_injected, 1, "the armed ep-fail fires exactly once");

    // Static EP remains pure accounting even across a failover: texts
    // match a chaos-free, EP-free run byte-for-byte.
    let mut plain = engine(DropPolicy::two_t(0.45), None);
    let want = serve_opts(
        &mut plain,
        &reqs,
        ArrivalMode::Closed,
        &Fcfs,
        SchedOptions::default(),
    )
    .unwrap();
    for (a, b) in out.completions.iter().zip(&want.completions) {
        assert_eq!((a.id, &a.text), (b.id, &b.text), "failover leaked into generation");
    }
}
