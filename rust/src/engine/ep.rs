//! Expert-parallel serving simulation (paper §4.3).
//!
//! `EpSim` maps experts onto N virtual EP workers (round-robin by
//! default) and is driven by the **actual per-batch routing** the
//! engine computes inside the serve loop — not a synthetic load model.
//! Per MoE-layer invocation it:
//!
//! 1. assigns every routed (token, expert) pair to a hosting worker
//!    (`observe`) — deterministic greedy least-loaded choice among the
//!    expert's hosts, so replication actually sheds load;
//! 2. optionally derives per-worker drop policies
//!    ([`DropPolicy::scaled`]) keyed on each worker's routed load
//!    **relative to the hottest worker** (`--ep-load-aware`): the
//!    hottest worker keeps the base policy unchanged, colder workers
//!    get proportionally lower thresholds and keep more compute —
//!    the paper's load-imbalance-aware thresholding;
//! 3. charges the iteration the straggler's time (`charge`): the
//!    hottest worker's kept cost × measured per-pair seconds, plus a
//!    [`crate::commsim`] AlltoAll dispatch + return for the step's
//!    actual kept payload.
//!
//! Alongside the actual run, `observe`/`charge` keep a **counterfactual
//! static shadow**: what every worker would have kept under the
//! unscaled base policy on the *identical* routings. Because the
//! hottest worker's policy is unchanged under hot-keyed scaling, its
//! kept cost is identical in both worlds while colder workers keep
//! weakly more — so `straggler_ratio ≤ straggler_ratio_static` holds
//! *exactly*, per run, at any thread count (no cross-run trajectory
//! noise). The same duality is kept for the drop rate.
//!
//! Replication (`--ep-replicate-after K`): after the same worker has
//! been the routed-hottest (and above ideal load) for K consecutive
//! invocations, its hottest expert is replicated onto the coldest
//! worker. Purely count-based — never timing-based — so the placement
//! trajectory is identical at every thread count.
//!
//! Everything here is bookkeeping over counts and already-measured
//! backend seconds; it never changes what executes *except* through
//! the per-worker policies (the deliberate accuracy/latency trade of
//! load-aware thresholding). With `load_aware = false`, or with a
//! single worker, generated text is byte-identical to a no-EP run.
//!
//! Failure injection ([`crate::engine::faults`]) can fail a worker
//! mid-run — its experts re-host onto the least-loaded survivors via
//! the same append-only placement list replication uses
//! ([`EpSim::fail_worker`], counted in `EpReport::failovers`) — or
//! slow one down ([`EpSim::slow_worker`]), which inflates that
//! worker's attributed busy seconds and lets it overtake the
//! routed-hottest worker as the charged straggler. Both are pure
//! accounting like everything else here: generated text never changes.

use std::collections::HashMap;

use crate::commsim::{alltoall_time, Topology};
use crate::moe::{DispatchPlan, DropPolicy, DropStats, TokenRouting};

/// Expert-parallel simulation attached to the engine.
#[derive(Debug, Clone)]
pub struct EpOptions {
    /// Number of virtual EP workers (0 and 1 both mean one worker).
    pub n_devices: usize,
    /// Load-aware thresholding (§4.3) on/off.
    pub load_aware: bool,
    /// Replicate a sustained-hot worker's hottest expert onto the
    /// coldest worker after this many consecutive hot invocations.
    pub replicate_after: Option<u64>,
}

impl EpOptions {
    /// The pre-replication option set (the legacy constructor shape).
    pub fn new(n_devices: usize, load_aware: bool) -> EpOptions {
        EpOptions { n_devices, load_aware, replicate_after: None }
    }
}

/// One MoE-layer invocation's worker assignment, produced by
/// [`EpSim::observe`] and consumed by [`EpSim::policies`] /
/// [`EpSim::charge`].
#[derive(Debug)]
pub struct EpInvocation {
    /// Routed token-expert pairs per worker (before any dropping).
    pub routed: Vec<u64>,
    /// Flat `(row, expert, worker)` assignment, in routing order.
    pub pairs: Vec<(usize, usize, usize)>,
    worker_of: HashMap<(usize, usize), usize>,
    /// Counterfactual kept cost per worker under the unscaled base
    /// policy (Full = 1, MajorOnly = ½).
    static_kept: Vec<f64>,
    static_stats: DropStats,
}

impl EpInvocation {
    /// Worker hosting the given routed pair.
    pub fn worker(&self, row: usize, expert: usize) -> usize {
        self.worker_of[&(row, expert)]
    }
}

/// Aggregated EP observables for one run (see docs/REPORTS.md).
#[derive(Debug, Clone)]
pub struct EpReport {
    pub workers: usize,
    pub load_aware: bool,
    /// Per-worker attributed FFN busy seconds (measured backend time,
    /// split across an expert's hosts ∝ kept cost).
    pub busy_secs: Vec<f64>,
    /// Hottest worker's kept cost ÷ mean kept cost per worker,
    /// accumulated over the run. 1.0 = perfectly balanced.
    pub straggler_ratio: f64,
    /// The same ratio under the counterfactual static (unscaled)
    /// policy on the identical routings. With load-aware thresholding
    /// on, `straggler_ratio ≤ straggler_ratio_static` exactly; with it
    /// off the two are equal.
    pub straggler_ratio_static: f64,
    /// Hot-worker compute seconds avoided by dropping (routed − kept
    /// on the hottest worker, at the measured per-pair cost).
    pub imbalance_saved_secs: f64,
    /// Simulated AlltoAll dispatch + return seconds.
    pub comm_secs: f64,
    /// Simulated EP iteration time: straggler compute + comm.
    pub sim_secs: f64,
    /// Measured drop rate over EP-routed pairs (excludes shared experts).
    pub drop_rate: f64,
    /// Counterfactual drop rate under the static base policy.
    pub drop_rate_static: f64,
    pub replications: u64,
    pub invocations: u64,
    /// Experts re-hosted onto survivors by injected worker failures
    /// ([`EpSim::fail_worker`]).
    pub failovers: u64,
}

/// The virtual expert-parallel deployment (see module docs).
#[derive(Debug, Clone)]
pub struct EpSim {
    opts: EpOptions,
    topo: Topology,
    /// expert → hosting workers. Seeded round-robin (`e % n`);
    /// replication appends, never removes.
    hosts: Vec<Vec<usize>>,
    busy_secs: Vec<f64>,
    hot_kept: f64,
    total_kept: f64,
    static_hot_kept: f64,
    static_total_kept: f64,
    drop_actual: DropStats,
    drop_static: DropStats,
    saved_secs: f64,
    comm_secs: f64,
    sim_secs: f64,
    invocations: u64,
    replications: u64,
    /// Consecutive invocations the same worker has been routed-hottest
    /// while above ideal load.
    streak: u64,
    streak_worker: usize,
    /// Injected worker failures ([`EpSim::fail_worker`]); failed
    /// workers host nothing and are never replication targets.
    failed: Vec<bool>,
    /// Injected per-worker slow-down factors (1.0 = nominal speed).
    slow_factor: Vec<f64>,
    failovers: u64,
}

impl EpSim {
    pub fn new(opts: EpOptions, n_experts: usize) -> EpSim {
        let n = opts.n_devices.max(1);
        EpSim {
            topo: Topology::h20_node(),
            hosts: (0..n_experts).map(|e| vec![e % n]).collect(),
            busy_secs: vec![0.0; n],
            hot_kept: 0.0,
            total_kept: 0.0,
            static_hot_kept: 0.0,
            static_total_kept: 0.0,
            drop_actual: DropStats::default(),
            drop_static: DropStats::default(),
            saved_secs: 0.0,
            comm_secs: 0.0,
            sim_secs: 0.0,
            invocations: 0,
            replications: 0,
            streak: 0,
            streak_worker: 0,
            failed: vec![false; n],
            slow_factor: vec![1.0; n],
            failovers: 0,
            opts,
        }
    }

    /// Injected worker failure (`engine::faults`): remove `w` from
    /// every expert's host list and re-host experts left homeless onto
    /// the least-loaded survivor (fewest hosted experts, tie → lowest
    /// id) — the same append-only placement machinery replication
    /// uses, so straggler accounting keeps working across the
    /// failover. Returns the number of experts re-hosted (0 when `w`
    /// is unknown, already failed, or the last survivor — the
    /// simulation refuses to lose its final worker).
    pub fn fail_worker(&mut self, w: usize) -> u64 {
        let n = self.n_workers();
        if w >= n || self.failed[w] || self.failed.iter().filter(|&&f| !f).count() <= 1 {
            return 0;
        }
        self.failed[w] = true;
        let mut hosted = vec![0usize; n];
        for hs in &self.hosts {
            for &h in hs {
                hosted[h] += 1;
            }
        }
        let mut moved = 0u64;
        for hs in &mut self.hosts {
            hs.retain(|&h| h != w);
            if hs.is_empty() {
                let target = (0..n)
                    .filter(|&x| !self.failed[x])
                    .min_by_key(|&x| (hosted[x], x))
                    .expect("at least one survivor");
                hosted[target] += 1;
                hs.push(target);
                moved += 1;
            }
        }
        self.failovers += moved;
        moved
    }

    /// Injected worker slow-down (`engine::faults`): every second of
    /// work attributed to `w` costs `factor` simulated seconds from
    /// now on. Factors below 1.0 (or non-finite) are ignored.
    pub fn slow_worker(&mut self, w: usize, factor: f64) {
        if w < self.slow_factor.len() && factor.is_finite() && factor >= 1.0 {
            self.slow_factor[w] = factor;
        }
    }

    /// Workers currently failed (tests / diagnostics).
    pub fn failed_workers(&self) -> Vec<usize> {
        (0..self.n_workers()).filter(|&w| self.failed[w]).collect()
    }

    pub fn n_workers(&self) -> usize {
        self.busy_secs.len()
    }

    /// Current expert → hosts placement (tests / diagnostics).
    pub fn hosts(&self) -> &[Vec<usize>] {
        &self.hosts
    }

    /// Assign this invocation's routed pairs to workers and tally the
    /// static-policy counterfactual. Pure bookkeeping (`&self`) — the
    /// placement only changes in [`EpSim::charge`] via replication.
    pub fn observe(&self, routings: &[TokenRouting], base: DropPolicy) -> EpInvocation {
        let n = self.n_workers();
        let mut routed = vec![0u64; n];
        let mut static_kept = vec![0.0f64; n];
        let mut static_stats = DropStats::default();
        let mut worker_of = HashMap::new();
        let mut pairs = Vec::with_capacity(routings.iter().map(|r| r.experts.len()).sum());
        for (row, r) in routings.iter().enumerate() {
            for &(e, _, norm) in &r.experts {
                // Least-routed host wins, tie → lowest worker id: with a
                // single host this is the fixed round-robin placement;
                // with replicas it deterministically sheds the overflow.
                let w = self.hosts[e]
                    .iter()
                    .copied()
                    .min_by_key(|&w| (routed[w], w))
                    .expect("every expert has at least one host");
                routed[w] += 1;
                worker_of.insert((row, e), w);
                pairs.push((row, e, w));
                let d = base.decide(norm);
                static_stats.record(d);
                static_kept[w] += DropPolicy::cost_fraction(d) as f64;
            }
        }
        EpInvocation { routed, pairs, worker_of, static_kept, static_stats }
    }

    /// Per-worker load-aware policies for this invocation, or `None`
    /// when the base policy applies uniformly (load-aware off, or no
    /// routed load). Each worker's policy is the base scaled by
    /// `routed / hottest_routed ∈ (0, 1]` — the hottest worker's
    /// thresholds are exactly the base's, so scaling can only *lower*
    /// a colder worker's thresholds, never raise anyone's above the
    /// configured maximum.
    pub fn policies(&self, inv: &EpInvocation, base: DropPolicy) -> Option<Vec<DropPolicy>> {
        if !self.opts.load_aware {
            return None;
        }
        let hot = inv.routed.iter().copied().max().unwrap_or(0);
        if hot == 0 {
            return None;
        }
        Some(inv.routed.iter().map(|&l| base.scaled(l as f32 / hot as f32)).collect())
    }

    /// Routed-hottest worker of an invocation (tie → lowest id). The
    /// straggler anchor: routed load — not kept cost — so the ratio's
    /// static-vs-aware comparison shares one anchor in both worlds.
    fn hottest(&self, inv: &EpInvocation) -> usize {
        (0..self.n_workers())
            .max_by_key(|&w| (inv.routed[w], std::cmp::Reverse(w)))
            .unwrap_or(0)
    }

    /// Account one executed invocation: attribute the measured
    /// per-expert seconds (`expert_secs`) to workers, accumulate the
    /// straggler/drop observables, charge the simulated iteration time,
    /// and run the replication streak logic. Returns per-worker busy
    /// seconds for this invocation (the engine mirrors them into
    /// `EngineMetrics::device_time`).
    pub fn charge(
        &mut self,
        inv: &EpInvocation,
        plan: &DispatchPlan,
        expert_secs: &[(usize, f64)],
        d_model: usize,
    ) -> Vec<f64> {
        let n = self.n_workers();
        let n_experts = self.hosts.len();
        // Kept cost per (expert, worker): Full = 1, MajorOnly = ½ —
        // the same weights as DropStats' drop-rate definition.
        let mut ew = vec![vec![0.0f64; n]; n_experts];
        for e in 0..n_experts {
            for &(row, _) in &plan.full[e] {
                ew[e][inv.worker(row, e)] += 1.0;
            }
            for &(row, _) in &plan.major_only[e] {
                ew[e][inv.worker(row, e)] += 0.5;
            }
        }
        let mut kept = vec![0.0f64; n];
        for e in 0..n_experts {
            for w in 0..n {
                kept[w] += ew[e][w];
            }
        }
        // Attribute each expert's measured exec seconds to its hosting
        // workers ∝ kept cost (an expert executes as one packed call;
        // the split only matters once replication spreads its rows).
        let mut busy = vec![0.0f64; n];
        let mut total_secs = 0.0f64;
        for &(e, dt) in expert_secs {
            total_secs += dt;
            let ec: f64 = ew[e].iter().sum();
            if ec > 0.0 {
                for w in 0..n {
                    busy[w] += dt * ew[e][w] / ec * self.slow_factor[w];
                }
            } else {
                // Executed with no kept pairs cannot happen; degrade to
                // the first host rather than dropping time on the floor.
                let w0 = self.hosts[e][0];
                busy[w0] += dt * self.slow_factor[w0];
            }
        }
        let total_kept: f64 = kept.iter().sum();
        let w_star = self.hottest(inv);
        let per_pair = if total_kept > 0.0 { total_secs / total_kept } else { 0.0 };
        // Dispatch + return AlltoAll for the step's actual kept payload
        // (f32 activations, (n−1)/n of each row leaves its worker).
        let comm = if n > 1 {
            let bytes =
                plan.kept_pairs() as f64 * d_model as f64 * 4.0 * (n as f64 - 1.0) / n as f64;
            2.0 * alltoall_time(&self.topo, n, bytes)
        } else {
            0.0
        };
        // Straggler compute: the routed-hottest anchor at its effective
        // speed — or any injected-slow worker whose effective time now
        // exceeds it. With every slow factor at 1.0 this is exactly the
        // historical `kept[w_star] × per_pair` charge.
        let mut straggle = kept[w_star] * per_pair * self.slow_factor[w_star];
        for w in 0..n {
            if self.slow_factor[w] > 1.0 {
                straggle = straggle.max(kept[w] * per_pair * self.slow_factor[w]);
            }
        }
        self.sim_secs += straggle + comm;
        self.comm_secs += comm;
        self.saved_secs += (inv.routed[w_star] as f64 - kept[w_star]).max(0.0) * per_pair;
        self.hot_kept += kept[w_star];
        self.total_kept += total_kept;
        self.static_hot_kept += inv.static_kept[w_star];
        self.static_total_kept += inv.static_kept.iter().sum::<f64>();
        self.drop_actual.merge(&plan.stats);
        self.drop_static.merge(&inv.static_stats);
        for w in 0..n {
            self.busy_secs[w] += busy[w];
        }
        self.invocations += 1;
        self.maybe_replicate(inv, w_star);
        busy
    }

    /// Sustained-skew replication: K consecutive invocations with the
    /// same routed-hottest worker above ideal load replicate that
    /// worker's hottest expert onto the coldest non-hosting worker.
    fn maybe_replicate(&mut self, inv: &EpInvocation, w_star: usize) {
        let Some(k) = self.opts.replicate_after else {
            return;
        };
        let n = self.n_workers();
        let total: u64 = inv.routed.iter().sum();
        if n < 2 || total == 0 || k == 0 {
            return;
        }
        let ideal = total as f64 / n as f64;
        if (inv.routed[w_star] as f64) <= ideal {
            self.streak = 0;
            return;
        }
        if self.streak > 0 && self.streak_worker == w_star {
            self.streak += 1;
        } else {
            self.streak = 1;
            self.streak_worker = w_star;
        }
        if self.streak < k {
            return;
        }
        self.streak = 0;
        // Hottest expert on the hot worker this invocation (tie → lowest).
        let mut per_expert = vec![0u64; self.hosts.len()];
        for &(_, e, w) in &inv.pairs {
            if w == w_star {
                per_expert[e] += 1;
            }
        }
        let Some(e_hot) = (0..per_expert.len())
            .filter(|&e| per_expert[e] > 0)
            .max_by_key(|&e| (per_expert[e], std::cmp::Reverse(e)))
        else {
            return;
        };
        // Coldest live worker (tie → lowest id) not already hosting it.
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&w| (inv.routed[w], w));
        for w in order {
            if w != w_star && !self.failed[w] && !self.hosts[e_hot].contains(&w) {
                self.hosts[e_hot].push(w);
                self.replications += 1;
                return;
            }
        }
    }

    pub fn report(&self) -> EpReport {
        let n = self.n_workers();
        let ratio = |hot: f64, total: f64| {
            if total > 0.0 {
                hot / (total / n as f64)
            } else {
                1.0
            }
        };
        EpReport {
            workers: n,
            load_aware: self.opts.load_aware,
            busy_secs: self.busy_secs.clone(),
            straggler_ratio: ratio(self.hot_kept, self.total_kept),
            straggler_ratio_static: ratio(self.static_hot_kept, self.static_total_kept),
            imbalance_saved_secs: self.saved_secs,
            comm_secs: self.comm_secs,
            sim_secs: self.sim_secs,
            drop_rate: self.drop_actual.drop_rate(),
            drop_rate_static: self.drop_static.drop_rate(),
            replications: self.replications,
            invocations: self.invocations,
            failovers: self.failovers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::plan_dispatch;

    fn routings(rows: &[&[(usize, f32)]]) -> Vec<TokenRouting> {
        rows.iter()
            .map(|r| TokenRouting {
                experts: r.iter().map(|&(e, norm)| (e, norm, norm)).collect(),
            })
            .collect()
    }

    #[test]
    fn observe_conserves_routed_pairs_and_matches_round_robin() {
        let sim = EpSim::new(EpOptions::new(4, false), 8);
        // experts 0 and 4 both land on worker 0 (e % 4).
        let r = routings(&[&[(0, 0.6), (4, 0.4)], &[(1, 0.7), (2, 0.3)]]);
        let inv = sim.observe(&r, DropPolicy::NoDrop);
        assert_eq!(inv.routed.iter().sum::<u64>(), 4);
        assert_eq!(inv.routed, vec![2, 1, 1, 0]);
        assert_eq!(inv.worker(0, 0), 0);
        assert_eq!(inv.worker(0, 4), 0);
        assert_eq!(inv.worker(1, 1), 1);
    }

    #[test]
    fn hot_worker_keeps_base_policy_cold_workers_scale_down() {
        let sim = EpSim::new(EpOptions::new(2, true), 4);
        let base = DropPolicy::OneT(0.4);
        // worker 0 (experts 0, 2) gets 4 pairs; worker 1 (expert 1) gets 1.
        let r = routings(&[
            &[(0, 0.5), (2, 0.5)],
            &[(0, 0.5), (2, 0.5)],
            &[(1, 0.5)],
        ]);
        let inv = sim.observe(&r, base);
        let pols = sim.policies(&inv, base).expect("load-aware policies");
        assert_eq!(pols[0], base, "hottest worker keeps the base policy");
        assert_eq!(pols[1], DropPolicy::OneT(0.4 * 0.25));
        // Static sim returns None (uniform base policy).
        let stat = EpSim::new(EpOptions::new(2, false), 4);
        assert!(stat.policies(&stat.observe(&r, base), base).is_none());
    }

    #[test]
    fn aware_straggler_ratio_never_exceeds_static_counterfactual() {
        let base = DropPolicy::OneT(0.4);
        let mut sim = EpSim::new(EpOptions::new(2, true), 4);
        // Skewed: worker 0 hot with scores straddling the threshold.
        let r = routings(&[
            &[(0, 0.45), (2, 0.3)],
            &[(0, 0.35), (2, 0.6)],
            &[(1, 0.3)],
        ]);
        let inv = sim.observe(&r, base);
        let pols = sim.policies(&inv, base).unwrap();
        let f = |row: usize, e: usize| pols[inv.worker(row, e)];
        let plan = plan_dispatch(&r, 4, base, Some(&f));
        sim.charge(&inv, &plan, &[], 16);
        let rep = sim.report();
        assert!(rep.straggler_ratio <= rep.straggler_ratio_static + 1e-12);
        assert!(rep.drop_rate <= rep.drop_rate_static + 1e-12);
        // Cold worker 1's 0.3 is dropped statically but kept when its
        // threshold scales by 1/4 — the ratios actually differ here.
        assert!(rep.straggler_ratio < rep.straggler_ratio_static);
    }

    #[test]
    fn single_worker_ratio_is_exactly_one() {
        let mut sim = EpSim::new(EpOptions::new(1, true), 4);
        let base = DropPolicy::two_t(0.45);
        let r = routings(&[&[(0, 0.5), (1, 0.5)]]);
        let inv = sim.observe(&r, base);
        assert!(sim.policies(&inv, base).unwrap().iter().all(|p| *p == base));
        let plan = plan_dispatch(&r, 4, base, None);
        sim.charge(&inv, &plan, &[(0, 1e-3), (1, 2e-3)], 16);
        let rep = sim.report();
        assert_eq!(rep.straggler_ratio, 1.0);
        assert_eq!(rep.comm_secs, 0.0, "no AlltoAll within one worker");
        assert!((rep.busy_secs[0] - 3e-3).abs() < 1e-12);
    }

    #[test]
    fn failed_worker_rehosts_experts_onto_survivors() {
        let mut sim = EpSim::new(EpOptions::new(2, false), 4);
        // round-robin: experts 0, 2 → worker 0; experts 1, 3 → worker 1
        let moved = sim.fail_worker(0);
        assert_eq!(moved, 2, "both of worker 0's experts re-host");
        assert!(sim.hosts().iter().all(|hs| hs == &vec![1]));
        assert_eq!(sim.failed_workers(), vec![0]);
        assert_eq!(sim.report().failovers, 2);
        // the last survivor cannot fail, and double failure is a no-op
        assert_eq!(sim.fail_worker(1), 0);
        assert_eq!(sim.fail_worker(0), 0);
        // routing avoids the failed worker entirely
        let r = routings(&[&[(0, 0.5)], &[(1, 0.5)]]);
        let inv = sim.observe(&r, DropPolicy::NoDrop);
        assert_eq!(inv.routed, vec![0, 2]);
    }

    #[test]
    fn replication_never_targets_a_failed_worker() {
        let mut sim = EpSim::new(
            EpOptions { n_devices: 3, load_aware: false, replicate_after: Some(1) },
            3,
        );
        assert_eq!(sim.fail_worker(1), 1, "worker 1's expert re-hosts");
        let r = routings(&[&[(0, 0.9)], &[(0, 0.9)]]);
        let inv = sim.observe(&r, DropPolicy::NoDrop);
        let plan = plan_dispatch(&r, 3, DropPolicy::NoDrop, None);
        sim.charge(&inv, &plan, &[], 16);
        assert_eq!(sim.report().replications, 1);
        assert!(!sim.hosts()[0].contains(&1), "replica landed on a live worker");
    }

    #[test]
    fn slow_worker_inflates_attributed_time_and_straggler_charge() {
        let base = DropPolicy::NoDrop;
        let r = routings(&[&[(0, 0.6)], &[(1, 0.4)]]);
        let plan = plan_dispatch(&r, 2, base, None);
        let mut a = EpSim::new(EpOptions::new(2, false), 2);
        let inv = a.observe(&r, base);
        a.charge(&inv, &plan, &[(0, 1e-3), (1, 1e-3)], 16);
        let fast = a.report();
        let mut b = EpSim::new(EpOptions::new(2, false), 2);
        b.slow_worker(1, 3.0);
        let inv = b.observe(&r, base);
        b.charge(&inv, &plan, &[(0, 1e-3), (1, 1e-3)], 16);
        let slow = b.report();
        assert!((slow.busy_secs[1] - 3.0 * fast.busy_secs[1]).abs() < 1e-12);
        assert_eq!(slow.busy_secs[0], fast.busy_secs[0], "nominal workers are untouched");
        assert!(slow.sim_secs > fast.sim_secs, "the slow worker becomes the straggler");
        assert_eq!(slow.failovers, 0);
        // sub-nominal or garbage factors are ignored
        let mut c = EpSim::new(EpOptions::new(2, false), 2);
        c.slow_worker(0, 0.5);
        c.slow_worker(0, f64::NAN);
        let inv = c.observe(&r, base);
        c.charge(&inv, &plan, &[(0, 1e-3), (1, 1e-3)], 16);
        assert_eq!(c.report().sim_secs, fast.sim_secs);
    }

    #[test]
    fn sustained_skew_replicates_hot_expert_onto_coldest_worker() {
        let mut sim = EpSim::new(
            EpOptions { n_devices: 2, load_aware: false, replicate_after: Some(2) },
            4,
        );
        // Expert 0 (worker 0) takes everything: worker 0 is hot.
        let r = routings(&[&[(0, 0.9)], &[(0, 0.9)], &[(0, 0.9)]]);
        for step in 0..2 {
            let inv = sim.observe(&r, DropPolicy::NoDrop);
            let plan = plan_dispatch(&r, 4, DropPolicy::NoDrop, None);
            sim.charge(&inv, &plan, &[], 16);
            assert_eq!(sim.report().replications, u64::from(step >= 1));
        }
        assert_eq!(sim.hosts()[0], vec![0, 1], "expert 0 replicated onto worker 1");
        // Post-replication, greedy assignment splits expert 0's rows.
        let inv = sim.observe(&r, DropPolicy::NoDrop);
        assert_eq!(inv.routed, vec![2, 1]);
    }
}
