//! `CpuRef` — pure-Rust reference backend.
//!
//! Implements every serving artifact family (FFN, gating, probe,
//! prefill/step attention, LM head) directly over host tensors with the
//! shared kernels in `util::linalg`, numerically mirroring the jnp
//! oracles in `python/compile/kernels/ref.py` and the serving
//! decomposition in `python/compile/model.py`. Shapes come from the
//! argument tensors, so one implementation serves every capacity /
//! batch / width bucket; the artifact *name* is used for dispatch and
//! perf accounting only.
//!
//! This is the hermetic path: no AOT artifacts, no Python, no PJRT —
//! the seam the integration tests, golden-fixture tests and CI run on.
//!
//! Concurrency: all interior state is lock- or atomic-guarded, so the
//! engine's threaded expert dispatch can issue `exec` calls from many
//! workers at once (the `Backend: Sync` contract). The step-attention
//! and chunked-prefill (`attn_prefill_chunk_s{S}`) artifacts
//! additionally accept their KV cache as [`Arg::F32Slices`] (borrowed
//! per-slot slices) or [`Arg::F32Pages`] (borrowed per-page slices from
//! the paged cache) — so neither the decode hot path nor a prefill
//! continuation ever copies or gathers the cache. Both views preserve
//! the exact ascending-position FP operation order of the contiguous
//! layout, so all three are bit-identical.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{bail, Context, Result};

use crate::model::{ModelConfig, Tensor};
use crate::util::linalg::{
    dot, gather_ffn_kept, gemv_acc, matmul, matmul_bt, rmsnorm_rows, softmax_rows,
    swiglu_ffn, swiglu_ffn_q8, swish,
};

use super::{Arg, Backend, BufId, ExecCounters};

/// Below this `S²·d` volume prefill attention runs its heads serially —
/// the scoped-thread spawn would dominate the arithmetic.
const ATTN_PAR_MIN: usize = 1 << 19;

/// Pure-Rust reference executor (see module docs).
pub struct CpuRef {
    /// Uploaded weight buffers, indexed by [`BufId`]. RwLock: concurrent
    /// `exec` calls share read access; `upload` (load time) writes.
    bufs: RwLock<Vec<Tensor>>,
    /// Head geometry — required by `attn_prefill_*`, which cannot infer
    /// it from its arguments.
    n_heads: AtomicUsize,
    d_head: AtomicUsize,
    counters: ExecCounters,
    /// Distinct artifact names ever executed. Kept separate from the
    /// perf counters so `compiled_count` survives `reset_counters`,
    /// matching the PJRT backend's compiled-executable cache semantics.
    seen: Mutex<std::collections::HashSet<String>>,
    /// Memoized kept-neuron gathers for the `ffn_mask_*` /
    /// `ffn_q8_mask_*` artifacts, keyed by the three uploaded weight
    /// buffer ids + the mask. A serving run pays the O(d·K) gather once
    /// per (sub-expert, mask) and every later exec runs the dense fused
    /// kernel on the cached width-K triple at full per-madd efficiency.
    packs: Mutex<HashMap<(usize, usize, usize, Vec<i32>), Arc<(Tensor, Tensor, Tensor)>>>,
}

impl CpuRef {
    pub fn new() -> CpuRef {
        CpuRef {
            bufs: RwLock::new(Vec::new()),
            n_heads: AtomicUsize::new(0),
            d_head: AtomicUsize::new(0),
            counters: ExecCounters::default(),
            seen: Mutex::new(std::collections::HashSet::new()),
            packs: Mutex::new(HashMap::new()),
        }
    }

    /// Resolve (and memoize — see the `packs` field) the kept-column /
    /// kept-row gather of an FFN weight triple. Host-tensor args (as
    /// tests pass) have no stable identity and skip the cache.
    fn pack_kept(
        &self,
        args: &[Arg],
        w1: &Tensor,
        w3: &Tensor,
        w2: &Tensor,
        kept_raw: &[i32],
        kept: &[usize],
    ) -> Arc<(Tensor, Tensor, Tensor)> {
        let key = match (args.get(1), args.get(2), args.get(3)) {
            (Some(Arg::Buf(a)), Some(Arg::Buf(b)), Some(Arg::Buf(c))) => {
                Some((a.0, b.0, c.0, kept_raw.to_vec()))
            }
            _ => None,
        };
        if let Some(k) = &key {
            if let Some(hit) = self.packs.lock().unwrap().get(k) {
                return Arc::clone(hit);
            }
        }
        let packed = Arc::new(gather_ffn_kept(w1, w3, w2, kept));
        if let Some(k) = key {
            self.packs.lock().unwrap().insert(k, Arc::clone(&packed));
        }
        packed
    }
}

impl Default for CpuRef {
    fn default() -> Self {
        CpuRef::new()
    }
}

impl Backend for CpuRef {
    fn platform(&self) -> String {
        "cpu-ref".to_string()
    }

    fn set_model(&self, cfg: &ModelConfig) {
        self.n_heads.store(cfg.n_heads, Ordering::Relaxed);
        self.d_head.store(cfg.d_head, Ordering::Relaxed);
    }

    /// Pure Rust over lock-guarded state — concurrent exec is safe.
    fn supports_concurrent_exec(&self) -> bool {
        true
    }

    fn upload(&self, t: &Tensor) -> Result<BufId> {
        let mut bufs = self.bufs.write().unwrap();
        bufs.push(t.clone());
        Ok(BufId(bufs.len() - 1))
    }

    fn exec(&self, name: &str, args: &[Arg]) -> Result<Vec<Tensor>> {
        let t0 = std::time::Instant::now();
        let store = self.bufs.read().unwrap();
        // Resolve args up front: host tensors, uploaded buffers,
        // zero-copy slice views, i32 rows.
        let rs: Vec<RArg> = args
            .iter()
            .map(|a| -> Result<RArg> {
                Ok(match a {
                    Arg::F32(x) => RArg::T(*x),
                    Arg::Buf(id) => RArg::T(
                        store
                            .get(id.0)
                            .with_context(|| format!("{name}: dangling buffer id {}", id.0))?,
                    ),
                    Arg::F32Slices(slices, shape) => RArg::S(*slices, *shape),
                    Arg::F32Pages { pages, row_starts, n_heads, page, d_head, t_max } => {
                        RArg::P {
                            pages,
                            row_starts,
                            n_heads: *n_heads,
                            page: *page,
                            d_head: *d_head,
                            t_max: *t_max,
                        }
                    }
                    Arg::I32(v) => RArg::I(*v),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let out = if name.starts_with("ffn_h") {
            vec![swiglu_ffn(
                targ(name, &rs, 0)?,
                targ(name, &rs, 1)?,
                targ(name, &rs, 2)?,
                targ(name, &rs, 3)?,
            )]
        } else if name.starts_with("ffn_mask_h") {
            let (w1, w3, w2) =
                (targ(name, &rs, 1)?, targ(name, &rs, 2)?, targ(name, &rs, 3)?);
            let kept_raw = iarg(name, &rs, 4)?;
            let kept = kept_usize(name, kept_raw, w1.shape[1])?;
            let p = self.pack_kept(args, w1, w3, w2, kept_raw, &kept);
            vec![swiglu_ffn(targ(name, &rs, 0)?, &p.0, &p.1, &p.2)]
        } else if name.starts_with("ffn_q8_mask_h") {
            let (q1, q3, q2) =
                (targ(name, &rs, 1)?, targ(name, &rs, 2)?, targ(name, &rs, 3)?);
            let scales = scales_arg(name, &rs, 4)?;
            let kept_raw = iarg(name, &rs, 5)?;
            let kept = kept_usize(name, kept_raw, q1.shape[1])?;
            let p = self.pack_kept(args, q1, q3, q2, kept_raw, &kept);
            vec![swiglu_ffn_q8(targ(name, &rs, 0)?, &p.0, &p.1, &p.2, &scales)]
        } else if name.starts_with("ffn_q8_h") {
            let scales = scales_arg(name, &rs, 4)?;
            vec![swiglu_ffn_q8(
                targ(name, &rs, 0)?,
                targ(name, &rs, 1)?,
                targ(name, &rs, 2)?,
                targ(name, &rs, 3)?,
                &scales,
            )]
        } else if name.starts_with("gate_b") {
            vec![softmax_rows(&matmul(targ(name, &rs, 0)?, targ(name, &rs, 1)?))]
        } else if name.starts_with("probe_h") {
            vec![op_probe(
                targ(name, &rs, 0)?,
                targ(name, &rs, 1)?,
                targ(name, &rs, 2)?,
            )]
        } else if name.starts_with("attn_prefill_chunk_s") {
            let kv = kv_arg(name, &rs, 7)?;
            let vv = kv_arg(name, &rs, 8)?;
            op_attn_prefill_chunk(
                targ(name, &rs, 0)?,
                targ(name, &rs, 1)?,
                targ(name, &rs, 2)?,
                targ(name, &rs, 3)?,
                targ(name, &rs, 4)?,
                targ(name, &rs, 5)?,
                targ(name, &rs, 6)?,
                &kv,
                &vv,
                iarg(name, &rs, 9)?,
            )?
        } else if name.starts_with("attn_prefill_s") {
            let h = self.n_heads.load(Ordering::Relaxed);
            let dh = self.d_head.load(Ordering::Relaxed);
            if h == 0 {
                bail!("{name}: CpuRef needs set_model() before attention artifacts");
            }
            op_attn_prefill(
                targ(name, &rs, 0)?,
                targ(name, &rs, 1)?,
                targ(name, &rs, 2)?,
                targ(name, &rs, 3)?,
                targ(name, &rs, 4)?,
                targ(name, &rs, 5)?,
                targ(name, &rs, 6)?,
                h,
                dh,
            )?
        } else if name.starts_with("attn_step_b") {
            let kv = kv_arg(name, &rs, 7)?;
            let vv = kv_arg(name, &rs, 8)?;
            op_attn_step(
                targ(name, &rs, 0)?,
                targ(name, &rs, 1)?,
                targ(name, &rs, 2)?,
                targ(name, &rs, 3)?,
                targ(name, &rs, 4)?,
                targ(name, &rs, 5)?,
                targ(name, &rs, 6)?,
                &kv,
                &vv,
                iarg(name, &rs, 9)?,
            )?
        } else if name.starts_with("lm_head_b") {
            vec![matmul_bt(
                &rmsnorm_rows(targ(name, &rs, 0)?, &targ(name, &rs, 1)?.data),
                targ(name, &rs, 2)?,
            )]
        } else {
            bail!("CpuRef: unknown artifact {name:?}");
        };
        self.counters.record(name, t0.elapsed().as_secs_f64());
        {
            // membership check first: skip the String allocation on the
            // steady-state hot path once an artifact name is known.
            let mut seen = self.seen.lock().unwrap();
            if !seen.contains(name) {
                seen.insert(name.to_string());
            }
        }
        Ok(out)
    }

    fn compiled_count(&self) -> usize {
        self.seen.lock().unwrap().len()
    }

    fn reset_counters(&self) {
        self.counters.reset();
    }

    fn time_with_prefix(&self, prefix: &str) -> f64 {
        self.counters.time_with_prefix(prefix)
    }

    fn exec_counts(&self) -> HashMap<String, (u64, f64)> {
        self.counters.snapshot()
    }
}

/// A resolved executable argument.
#[derive(Clone, Copy)]
enum RArg<'a> {
    T(&'a Tensor),
    S(&'a [&'a [f32]], &'a [usize]),
    P {
        pages: &'a [&'a [f32]],
        row_starts: &'a [usize],
        n_heads: usize,
        page: usize,
        d_head: usize,
        t_max: usize,
    },
    I(&'a [i32]),
}

/// Resolved f32 tensor argument `i` (host or uploaded buffer).
fn targ<'a>(name: &str, rs: &[RArg<'a>], i: usize) -> Result<&'a Tensor> {
    match rs.get(i).copied() {
        Some(RArg::T(t)) => Ok(t),
        _ => bail!("{name}: missing f32 arg {i}"),
    }
}

/// Resolved i32 argument `i`.
fn iarg<'a>(name: &str, rs: &[RArg<'a>], i: usize) -> Result<&'a [i32]> {
    match rs.get(i).copied() {
        Some(RArg::I(v)) => Ok(v),
        _ => bail!("{name}: missing i32 arg {i}"),
    }
}

/// Validate a kept-neuron index list against the intermediate width.
fn kept_usize(name: &str, kept: &[i32], h: usize) -> Result<Vec<usize>> {
    kept.iter()
        .map(|&j| {
            if j < 0 || j as usize >= h {
                bail!("{name}: kept index {j} out of range (width {h})");
            }
            Ok(j as usize)
        })
        .collect()
}

/// Resolved `[s1, s3, s2]` quantization scale triple at argument `i`.
fn scales_arg(name: &str, rs: &[RArg<'_>], i: usize) -> Result<[f32; 3]> {
    let t = targ(name, rs, i)?;
    if t.data.len() != 3 {
        bail!("{name}: scale triple must have 3 elements, got {}", t.data.len());
    }
    Ok([t.data[0], t.data[1], t.data[2]])
}

/// One batch row of a KV-cache view: either a contiguous `H·T·dh`
/// block (tensor row or zero-copy per-slot slice) or an ordered list
/// of `[H, page, dh]` page slices from the paged cache.
enum KvRow<'a> {
    Contig(&'a [f32]),
    Paged { pages: &'a [&'a [f32]], page: usize },
}

/// Borrowed view of a `[B, H, T, dh]` KV cache. Positions past a
/// paged row's mapped pages read as zero (attention never looks there:
/// `pos` is clamped to the row's capacity).
struct KvView<'a> {
    rows: Vec<KvRow<'a>>,
    n_heads: usize,
    t_max: usize,
    d_head: usize,
}

impl<'a> KvView<'a> {
    /// Positions row `bi` can actually serve.
    fn capacity(&self, bi: usize) -> usize {
        match &self.rows[bi] {
            KvRow::Contig(_) => self.t_max,
            KvRow::Paged { pages, page } => (pages.len() * page).min(self.t_max),
        }
    }

    /// Walk head `hi` of row `bi` over positions `0..upto` as
    /// contiguous runs: `f(t0, lane)` where `lane` holds positions
    /// `t0..t0 + lane.len()/d_head` of that head, in ascending order.
    /// A contiguous row is one run; a paged row is one run per page —
    /// exactly the same scalars in exactly the same order, which keeps
    /// paged attention bit-identical to the contiguous layout.
    fn head_runs(&self, bi: usize, hi: usize, upto: usize, f: &mut dyn FnMut(usize, &'a [f32])) {
        let dh = self.d_head;
        match &self.rows[bi] {
            KvRow::Contig(data) => {
                let hbase = hi * self.t_max * dh;
                f(0, &data[hbase..hbase + upto * dh]);
            }
            KvRow::Paged { pages, page } => {
                for (pi, pg) in pages.iter().enumerate() {
                    let t0 = pi * page;
                    if t0 >= upto {
                        break;
                    }
                    let run = page.min(upto - t0);
                    let hbase = hi * page * dh;
                    f(t0, &pg[hbase..hbase + run * dh]);
                }
            }
        }
    }
}

/// Resolve argument `i` as a KV-cache view.
fn kv_arg<'a>(name: &str, rs: &[RArg<'a>], i: usize) -> Result<KvView<'a>> {
    match rs.get(i).copied() {
        Some(RArg::T(t)) => {
            if t.shape.len() != 4 {
                bail!("{name}: kv arg {i} must be rank 4, got {:?}", t.shape);
            }
            let (b, h, tm, dh) = (t.shape[0], t.shape[1], t.shape[2], t.shape[3]);
            let stride = h * tm * dh;
            Ok(KvView {
                rows: (0..b)
                    .map(|bi| KvRow::Contig(&t.data[bi * stride..(bi + 1) * stride]))
                    .collect(),
                n_heads: h,
                t_max: tm,
                d_head: dh,
            })
        }
        Some(RArg::S(slices, shape)) => {
            if shape.len() != 4 || shape[0] != slices.len() {
                bail!(
                    "{name}: kv arg {i} slice view shape {:?} vs {} slices",
                    shape,
                    slices.len()
                );
            }
            let stride = shape[1] * shape[2] * shape[3];
            for (bi, s) in slices.iter().enumerate() {
                if s.len() != stride {
                    bail!("{name}: kv arg {i} slice {bi} has {} elems, want {stride}", s.len());
                }
            }
            Ok(KvView {
                rows: slices.iter().map(|&s| KvRow::Contig(s)).collect(),
                n_heads: shape[1],
                t_max: shape[2],
                d_head: shape[3],
            })
        }
        Some(RArg::P { pages, row_starts, n_heads, page, d_head, t_max }) => {
            if row_starts.is_empty() || row_starts[0] != 0 {
                bail!("{name}: kv arg {i} row_starts must begin at 0");
            }
            if *row_starts.last().unwrap() != pages.len()
                || row_starts.windows(2).any(|w| w[0] > w[1])
            {
                bail!(
                    "{name}: kv arg {i} row_starts {row_starts:?} inconsistent with {} pages",
                    pages.len()
                );
            }
            let stride = n_heads * page * d_head;
            for (pi, p) in pages.iter().enumerate() {
                if p.len() != stride {
                    bail!("{name}: kv arg {i} page {pi} has {} elems, want {stride}", p.len());
                }
            }
            Ok(KvView {
                rows: row_starts
                    .windows(2)
                    .map(|w| KvRow::Paged { pages: &pages[w[0]..w[1]], page })
                    .collect(),
                n_heads,
                t_max,
                d_head,
            })
        }
        _ => bail!("{name}: missing kv-cache arg {i}"),
    }
}

/// Neuron-importance accumulators (`probe_ref`, paper Eqs. 14-17):
/// rows = [Σ swish(xW1), Σ |swish(xW1)|, Σ g·u, Σ |g·u|], shape [4, H].
/// Fused per row like `swiglu_ffn` — the `[n, H]` gate/up intermediates
/// are never materialized.
fn op_probe(x: &Tensor, w1: &Tensor, w3: &Tensor) -> Tensor {
    let (n, d) = (x.shape[0], x.shape[1]);
    let h = w1.shape[1];
    // release-mode guard (gemv_acc only debug_asserts): a truncated
    // weight read here would silently corrupt calibration tables.
    assert_eq!(w1.shape[0], d, "probe w1 shape mismatch");
    assert_eq!(w3.shape, w1.shape, "probe w3 shape mismatch");
    let mut out = vec![0.0f32; 4 * h];
    let mut g = vec![0.0f32; h];
    let mut u = vec![0.0f32; h];
    for i in 0..n {
        let xrow = &x.data[i * d..(i + 1) * d];
        g.fill(0.0);
        u.fill(0.0);
        gemv_acc(xrow, &w1.data, h, &mut g);
        gemv_acc(xrow, &w3.data, h, &mut u);
        for j in 0..h {
            let sw = swish(g[j]);
            let gu = sw * u[j];
            out[j] += sw;
            out[h + j] += sw.abs();
            out[2 * h + j] += gu;
            out[3 * h + j] += gu.abs();
        }
    }
    Tensor::new(vec![4, h], out)
}

/// Full-sequence causal prefill (`serve_attn_prefill`): returns
/// (y [S,d], ln2x [S,d], K [S,H,dh], V [S,H,dh]). Heads are
/// independent and run on the worker pool for long sequences; the
/// per-head math is identical either way, so outputs do not depend on
/// the thread count.
#[allow(clippy::too_many_arguments)]
fn op_attn_prefill(
    x: &Tensor,
    ln1: &Tensor,
    wq: &Tensor,
    wk: &Tensor,
    wv: &Tensor,
    wo: &Tensor,
    ln2: &Tensor,
    n_heads: usize,
    d_head: usize,
) -> Result<Vec<Tensor>> {
    let (s, d) = (x.shape[0], x.shape[1]);
    if n_heads * d_head != d {
        bail!("attn_prefill: {n_heads}x{d_head} heads != d_model {d}");
    }
    let xn = rmsnorm_rows(x, &ln1.data);
    let q = matmul(&xn, wq);
    let k = matmul(&xn, wk);
    let v = matmul(&xn, wv);
    let scale = 1.0 / (d_head as f32).sqrt();
    let per_head = |hi: usize| -> Vec<f32> {
        let off = hi * d_head;
        let mut hctx = vec![0.0f32; s * d_head];
        let mut scores = vec![0.0f32; s];
        for qi in 0..s {
            let qrow = &q.data[qi * d + off..qi * d + off + d_head];
            // causal: keys 0..=qi only (identical to -1e9 masking — the
            // masked terms exp to exactly 0 after max subtraction).
            for (ki, sc) in scores.iter_mut().enumerate().take(qi + 1) {
                *sc = dot(qrow, &k.data[ki * d + off..ki * d + off + d_head]) * scale;
            }
            softmax_inplace(&mut scores[..qi + 1]);
            let crow = &mut hctx[qi * d_head..(qi + 1) * d_head];
            for ki in 0..=qi {
                let w = scores[ki];
                let vrow = &v.data[ki * d + off..ki * d + off + d_head];
                for (o, &vv) in crow.iter_mut().zip(vrow) {
                    *o += w * vv;
                }
            }
        }
        hctx
    };
    let head_ctx: Vec<Vec<f32>> = if s * s * d >= ATTN_PAR_MIN {
        crate::util::threads::parallel_map(n_heads, per_head)
    } else {
        (0..n_heads).map(per_head).collect()
    };
    let mut ctx = vec![0.0f32; s * d];
    for (hi, hctx) in head_ctx.iter().enumerate() {
        let off = hi * d_head;
        for qi in 0..s {
            ctx[qi * d + off..qi * d + off + d_head]
                .copy_from_slice(&hctx[qi * d_head..(qi + 1) * d_head]);
        }
    }
    let proj = matmul(&Tensor::new(vec![s, d], ctx), wo);
    let y = Tensor::new(
        vec![s, d],
        x.data.iter().zip(&proj.data).map(|(a, b)| a + b).collect(),
    );
    let ln2x = rmsnorm_rows(&y, &ln2.data);
    Ok(vec![
        y,
        ln2x,
        Tensor::new(vec![s, n_heads, d_head], k.data),
        Tensor::new(vec![s, n_heads, d_head], v.data),
    ])
}

/// Chunked-prefill continuation (`attn_prefill_chunk_s{S}`): like
/// [`op_attn_prefill`] but query `qi` (global position `base + qi`)
/// first attends over the sequence's cached K/V — positions `0..base`,
/// borrowed zero-copy from the engine's KV cache as a single-row
/// contiguous or paged view — and then over the in-chunk causal window
/// `0..=qi`. Scores are computed and context accumulated in ascending
/// global-position order (cached first, then in-chunk), which is the
/// exact operation order of a single-pass prefill over the whole
/// prompt: chunked outputs are **bit-identical** to an unchunked pass
/// with a large-enough bucket, whatever the page size.
/// Returns (y [S,d], ln2x [S,d], K [S,H,dh], V [S,H,dh]) — chunk-local
/// K/V only; the engine writes them behind `base`. Head geometry comes
/// from the cache view.
fn op_attn_prefill_chunk(
    x: &Tensor,
    ln1: &Tensor,
    wq: &Tensor,
    wk: &Tensor,
    wv: &Tensor,
    wo: &Tensor,
    ln2: &Tensor,
    kcache: &KvView,
    vcache: &KvView,
    base_arg: &[i32],
) -> Result<Vec<Tensor>> {
    let (s, d) = (x.shape[0], x.shape[1]);
    let (n_heads, t_max, d_head) = (kcache.n_heads, kcache.t_max, kcache.d_head);
    if kcache.rows.len() != 1 || vcache.rows.len() != 1 {
        bail!(
            "attn_prefill_chunk: expected a single-slot cache view, got {}/{} rows",
            kcache.rows.len(),
            vcache.rows.len()
        );
    }
    if (vcache.n_heads, vcache.t_max, vcache.d_head) != (n_heads, t_max, d_head) {
        bail!("attn_prefill_chunk: K/V cache geometry mismatch");
    }
    if n_heads * d_head != d {
        bail!("attn_prefill_chunk: {n_heads}x{d_head} heads != d_model {d}");
    }
    let base = base_arg.first().copied().unwrap_or(0).max(0) as usize;
    if base > t_max {
        bail!("attn_prefill_chunk: base {base} > cache window {t_max}");
    }
    if base > kcache.capacity(0) || base > vcache.capacity(0) {
        bail!(
            "attn_prefill_chunk: base {base} exceeds the view's mapped capacity {}",
            kcache.capacity(0).min(vcache.capacity(0))
        );
    }
    let xn = rmsnorm_rows(x, &ln1.data);
    let q = matmul(&xn, wq);
    let k = matmul(&xn, wk);
    let v = matmul(&xn, wv);
    let scale = 1.0 / (d_head as f32).sqrt();
    let per_head = |hi: usize| -> Vec<f32> {
        let off = hi * d_head;
        let mut hctx = vec![0.0f32; s * d_head];
        let mut scores = vec![0.0f32; base + s];
        for qi in 0..s {
            let qrow = &q.data[qi * d + off..qi * d + off + d_head];
            // cached positions 0..base first…
            kcache.head_runs(0, hi, base, &mut |t0, lane| {
                for (j, kc) in lane.chunks_exact(d_head).enumerate() {
                    scores[t0 + j] = dot(qrow, kc) * scale;
                }
            });
            // …then the in-chunk causal window (global base..=base+qi).
            for ki in 0..=qi {
                scores[base + ki] =
                    dot(qrow, &k.data[ki * d + off..ki * d + off + d_head]) * scale;
            }
            softmax_inplace(&mut scores[..base + qi + 1]);
            let crow = &mut hctx[qi * d_head..(qi + 1) * d_head];
            vcache.head_runs(0, hi, base, &mut |t0, lane| {
                for (j, vrow) in lane.chunks_exact(d_head).enumerate() {
                    let w = scores[t0 + j];
                    for (o, &vv) in crow.iter_mut().zip(vrow) {
                        *o += w * vv;
                    }
                }
            });
            for ki in 0..=qi {
                let w = scores[base + ki];
                let vrow = &v.data[ki * d + off..ki * d + off + d_head];
                for (o, &vv) in crow.iter_mut().zip(vrow) {
                    *o += w * vv;
                }
            }
        }
        hctx
    };
    let head_ctx: Vec<Vec<f32>> = if (base + s) * s * d >= ATTN_PAR_MIN {
        crate::util::threads::parallel_map(n_heads, per_head)
    } else {
        (0..n_heads).map(per_head).collect()
    };
    let mut ctx = vec![0.0f32; s * d];
    for (hi, hctx) in head_ctx.iter().enumerate() {
        let off = hi * d_head;
        for qi in 0..s {
            ctx[qi * d + off..qi * d + off + d_head]
                .copy_from_slice(&hctx[qi * d_head..(qi + 1) * d_head]);
        }
    }
    let proj = matmul(&Tensor::new(vec![s, d], ctx), wo);
    let y = Tensor::new(
        vec![s, d],
        x.data.iter().zip(&proj.data).map(|(a, b)| a + b).collect(),
    );
    let ln2x = rmsnorm_rows(&y, &ln2.data);
    Ok(vec![
        y,
        ln2x,
        Tensor::new(vec![s, n_heads, d_head], k.data),
        Tensor::new(vec![s, n_heads, d_head], v.data),
    ])
}

/// Single-token decode step with KV cache (`serve_attn_step`): returns
/// (y [B,d], ln2x [B,d], new_k [B,H,dh], new_v [B,H,dh]). Head geometry
/// is inferred from the cache view.
#[allow(clippy::too_many_arguments)]
fn op_attn_step(
    x: &Tensor,
    ln1: &Tensor,
    wq: &Tensor,
    wk: &Tensor,
    wv: &Tensor,
    wo: &Tensor,
    ln2: &Tensor,
    kcache: &KvView,
    vcache: &KvView,
    pos: &[i32],
) -> Result<Vec<Tensor>> {
    let (b, d) = (x.shape[0], x.shape[1]);
    let (n_heads, t_max, d_head) = (kcache.n_heads, kcache.t_max, kcache.d_head);
    if kcache.rows.len() != b || vcache.rows.len() != b {
        bail!(
            "attn_step: cache batch {}/{} vs x batch {b}",
            kcache.rows.len(),
            vcache.rows.len()
        );
    }
    if (vcache.n_heads, vcache.t_max, vcache.d_head) != (n_heads, t_max, d_head) {
        bail!("attn_step: K/V cache geometry mismatch");
    }
    if n_heads * d_head != d || pos.len() < b {
        bail!("attn_step: {n_heads}x{d_head} heads vs d_model {d}, pos len {}", pos.len());
    }
    let xn = rmsnorm_rows(x, &ln1.data);
    let q = matmul(&xn, wq);
    let new_k = matmul(&xn, wk);
    let new_v = matmul(&xn, wv);
    let scale = 1.0 / (d_head as f32).sqrt();
    let mut ctx = vec![0.0f32; b * d];
    for bi in 0..b {
        // clamp to the row's mapped capacity: a padding row (no pages)
        // attends only to itself, exactly like the old zero-slot rows.
        let p = (pos[bi].max(0) as usize).min(kcache.capacity(bi).min(vcache.capacity(bi)));
        let mut scores = vec![0.0f32; p + 1];
        for hi in 0..n_heads {
            let off = hi * d_head;
            let qrow = &q.data[bi * d + off..bi * d + off + d_head];
            kcache.head_runs(bi, hi, p, &mut |t0, lane| {
                for (j, kc) in lane.chunks_exact(d_head).enumerate() {
                    scores[t0 + j] = dot(qrow, kc) * scale;
                }
            });
            // the token attends to itself via the freshly-projected K.
            scores[p] =
                dot(qrow, &new_k.data[bi * d + off..bi * d + off + d_head]) * scale;
            softmax_inplace(&mut scores);
            let crow = &mut ctx[bi * d + off..bi * d + off + d_head];
            vcache.head_runs(bi, hi, p, &mut |t0, lane| {
                for (j, vrow) in lane.chunks_exact(d_head).enumerate() {
                    let w = scores[t0 + j];
                    for (o, &vv) in crow.iter_mut().zip(vrow) {
                        *o += w * vv;
                    }
                }
            });
            let w = scores[p];
            for (o, &vv) in crow
                .iter_mut()
                .zip(&new_v.data[bi * d + off..bi * d + off + d_head])
            {
                *o += w * vv;
            }
        }
    }
    let proj = matmul(&Tensor::new(vec![b, d], ctx), wo);
    let y = Tensor::new(
        vec![b, d],
        x.data.iter().zip(&proj.data).map(|(a, b)| a + b).collect(),
    );
    let ln2x = rmsnorm_rows(&y, &ln2.data);
    Ok(vec![
        y,
        ln2x,
        Tensor::new(vec![b, n_heads, d_head], new_k.data),
        Tensor::new(vec![b, n_heads, d_head], new_v.data),
    ])
}

/// Numerically-stable in-place softmax over a score row.
fn softmax_inplace(xs: &mut [f32]) {
    let mx = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - mx).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn randn(rng: &mut SplitMix64, shape: Vec<usize>, scale: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.gauss() as f32 * scale).collect())
    }

    #[test]
    fn ffn_matches_shared_kernel() {
        let mut rng = SplitMix64::new(1);
        let x = randn(&mut rng, vec![4, 8], 0.5);
        let w1 = randn(&mut rng, vec![8, 6], 0.3);
        let w3 = randn(&mut rng, vec![8, 6], 0.3);
        let w2 = randn(&mut rng, vec![6, 8], 0.3);
        let be = CpuRef::new();
        let got = be
            .exec("ffn_h6_c4", &[Arg::F32(&x), Arg::F32(&w1), Arg::F32(&w3), Arg::F32(&w2)])
            .unwrap();
        let want = swiglu_ffn(&x, &w1, &w3, &w2);
        assert_eq!(got[0].data, want.data);
    }

    #[test]
    fn masked_ffn_dispatch_matches_kernel_and_memoizes_buf_args() {
        use crate::util::linalg::swiglu_ffn_masked;
        let mut rng = SplitMix64::new(7);
        let x = randn(&mut rng, vec![4, 8], 0.5);
        let w1 = randn(&mut rng, vec![8, 6], 0.3);
        let w3 = randn(&mut rng, vec![8, 6], 0.3);
        let w2 = randn(&mut rng, vec![6, 8], 0.3);
        let kept = [4i32, 0, 2];
        let be = CpuRef::new();
        // host-tensor args (no cache) vs the shared kernel
        let got = be
            .exec(
                "ffn_mask_h6k3_c4",
                &[Arg::F32(&x), Arg::F32(&w1), Arg::F32(&w3), Arg::F32(&w2), Arg::I32(&kept)],
            )
            .unwrap();
        let want = swiglu_ffn_masked(&x, &w1, &w3, &w2, &[4, 0, 2]);
        assert_eq!(got[0].data, want.data);
        assert_eq!(be.packs.lock().unwrap().len(), 0, "host args must not be cached");
        // uploaded-buffer args memoize the gather and stay byte-identical
        let (b1, b3, b2) =
            (be.upload(&w1).unwrap(), be.upload(&w3).unwrap(), be.upload(&w2).unwrap());
        let args =
            [Arg::F32(&x), Arg::Buf(b1), Arg::Buf(b3), Arg::Buf(b2), Arg::I32(&kept)];
        let first = be.exec("ffn_mask_h6k3_c4", &args).unwrap();
        let second = be.exec("ffn_mask_h6k3_c4", &args).unwrap();
        assert_eq!(first[0].data, want.data);
        assert_eq!(second[0].data, want.data);
        assert_eq!(be.packs.lock().unwrap().len(), 1, "one mask → one cached pack");
        // out-of-range kept index is a hard error, not a silent skip
        let bad = [6i32];
        assert!(be
            .exec(
                "ffn_mask_h6k1_c4",
                &[Arg::F32(&x), Arg::F32(&w1), Arg::F32(&w3), Arg::F32(&w2), Arg::I32(&bad)],
            )
            .is_err());
    }

    #[test]
    fn q8_ffn_dispatch_matches_kernel() {
        use crate::util::linalg::{quantize_symmetric, swiglu_ffn_masked_q8};
        let mut rng = SplitMix64::new(8);
        let x = randn(&mut rng, vec![3, 8], 0.5);
        let (q1, s1) = quantize_symmetric(&randn(&mut rng, vec![8, 6], 0.3));
        let (q3, s3) = quantize_symmetric(&randn(&mut rng, vec![8, 6], 0.3));
        let (q2, s2) = quantize_symmetric(&randn(&mut rng, vec![6, 8], 0.3));
        let scales = Tensor::new(vec![3], vec![s1, s3, s2]);
        let be = CpuRef::new();
        let got = be
            .exec(
                "ffn_q8_h6_c3",
                &[Arg::F32(&x), Arg::F32(&q1), Arg::F32(&q3), Arg::F32(&q2), Arg::F32(&scales)],
            )
            .unwrap();
        let want = swiglu_ffn_q8(&x, &q1, &q3, &q2, &[s1, s3, s2]);
        assert_eq!(got[0].data, want.data);
        // masked + quantized composition
        let kept = [1i32, 5];
        let got = be
            .exec(
                "ffn_q8_mask_h6k2_c3",
                &[
                    Arg::F32(&x),
                    Arg::F32(&q1),
                    Arg::F32(&q3),
                    Arg::F32(&q2),
                    Arg::F32(&scales),
                    Arg::I32(&kept),
                ],
            )
            .unwrap();
        let want = swiglu_ffn_masked_q8(&x, &q1, &q3, &q2, &[s1, s3, s2], &[1, 5]);
        assert_eq!(got[0].data, want.data);
    }

    #[test]
    fn uploaded_buffers_resolve() {
        let be = CpuRef::new();
        let mut rng = SplitMix64::new(2);
        let x = randn(&mut rng, vec![2, 4], 0.5);
        let wg = randn(&mut rng, vec![4, 3], 0.5);
        let id = be.upload(&wg).unwrap();
        let via_buf = be.exec("gate_b2_e3", &[Arg::F32(&x), Arg::Buf(id)]).unwrap();
        let via_host = be.exec("gate_b2_e3", &[Arg::F32(&x), Arg::F32(&wg)]).unwrap();
        assert_eq!(via_buf[0].data, via_host[0].data);
        for r in 0..2 {
            let s: f32 = via_buf[0].row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn probe_abs_rows_dominate() {
        let mut rng = SplitMix64::new(3);
        let x = randn(&mut rng, vec![6, 8], 0.5);
        let w1 = randn(&mut rng, vec![8, 5], 0.4);
        let w3 = randn(&mut rng, vec![8, 5], 0.4);
        let imp = op_probe(&x, &w1, &w3);
        assert_eq!(imp.shape, vec![4, 5]);
        for j in 0..5 {
            assert!(imp.data[5 + j] >= imp.data[j].abs() - 1e-5);
            assert!(imp.data[15 + j] >= imp.data[10 + j].abs() - 1e-5);
        }
    }

    #[test]
    fn prefill_last_row_matches_step_on_same_cache() {
        // Decode consistency: running S tokens through prefill equals
        // prefilling S-1 and stepping the last token over that cache.
        let mut rng = SplitMix64::new(4);
        let (s, d, h, dh, t_max) = (5usize, 8usize, 2usize, 4usize, 9usize);
        let x = randn(&mut rng, vec![s, d], 0.5);
        let ln1 = Tensor::new(vec![d], vec![1.0; d]);
        let ln2 = Tensor::new(vec![d], vec![1.0; d]);
        let wq = randn(&mut rng, vec![d, d], 0.3);
        let wk = randn(&mut rng, vec![d, d], 0.3);
        let wv = randn(&mut rng, vec![d, d], 0.3);
        let wo = randn(&mut rng, vec![d, d], 0.3);
        let full = op_attn_prefill(&x, &ln1, &wq, &wk, &wv, &wo, &ln2, h, dh).unwrap();
        let part = op_attn_prefill(
            &x.row_slice(0, s - 1),
            &ln1,
            &wq,
            &wk,
            &wv,
            &wo,
            &ln2,
            h,
            dh,
        )
        .unwrap();
        // pack part's K/V ([S-1, H, dh]) into a [1, H, T, dh] cache
        let mut kc = vec![0.0f32; h * t_max * dh];
        let mut vc = vec![0.0f32; h * t_max * dh];
        for ti in 0..s - 1 {
            for hi in 0..h {
                for e in 0..dh {
                    kc[(hi * t_max + ti) * dh + e] = part[2].data[(ti * h + hi) * dh + e];
                    vc[(hi * t_max + ti) * dh + e] = part[3].data[(ti * h + hi) * dh + e];
                }
            }
        }
        let last = x.row_slice(s - 1, s);
        let kt = Tensor::new(vec![1, h, t_max, dh], kc);
        let vt = Tensor::new(vec![1, h, t_max, dh], vc);
        // head geometry comes from the cache view — no set_model needed
        let be = CpuRef::new();
        let step = be
            .exec(
                "attn_step_b1",
                &[
                    Arg::F32(&last),
                    Arg::F32(&ln1),
                    Arg::F32(&wq),
                    Arg::F32(&wk),
                    Arg::F32(&wv),
                    Arg::F32(&wo),
                    Arg::F32(&ln2),
                    Arg::F32(&kt),
                    Arg::F32(&vt),
                    Arg::I32(&[(s - 1) as i32]),
                ],
            )
            .unwrap();
        for e in 0..d {
            let want = full[0].data[(s - 1) * d + e];
            let got = step[0].data[e];
            assert!((want - got).abs() < 1e-5, "y[{e}]: {want} vs {got}");
        }
    }

    #[test]
    fn prefill_chunk_matches_full_prefill_bitwise() {
        // Rows s0..s of a full prefill must equal a chunk pass whose
        // cache holds the first s0 positions — the kernel-level
        // invariant behind chunked prefill.
        let mut rng = SplitMix64::new(6);
        let (s, s0, d, h, dh, t_max) = (7usize, 4usize, 8usize, 2usize, 4usize, 10usize);
        let x = randn(&mut rng, vec![s, d], 0.5);
        let ln1 = Tensor::new(vec![d], vec![1.0; d]);
        let ln2 = Tensor::new(vec![d], vec![1.0; d]);
        let wq = randn(&mut rng, vec![d, d], 0.3);
        let wk = randn(&mut rng, vec![d, d], 0.3);
        let wv = randn(&mut rng, vec![d, d], 0.3);
        let wo = randn(&mut rng, vec![d, d], 0.3);
        let full = op_attn_prefill(&x, &ln1, &wq, &wk, &wv, &wo, &ln2, h, dh).unwrap();
        let head = op_attn_prefill(
            &x.row_slice(0, s0), &ln1, &wq, &wk, &wv, &wo, &ln2, h, dh,
        )
        .unwrap();
        // pack the head chunk's K/V ([s0, H, dh]) into a [1, H, T, dh]
        // slot exactly like KvCache::write_prefill does.
        let mut kc = vec![0.0f32; h * t_max * dh];
        let mut vc = vec![0.0f32; h * t_max * dh];
        for ti in 0..s0 {
            for hi in 0..h {
                for e in 0..dh {
                    kc[(hi * t_max + ti) * dh + e] = head[2].data[(ti * h + hi) * dh + e];
                    vc[(hi * t_max + ti) * dh + e] = head[3].data[(ti * h + hi) * dh + e];
                }
            }
        }
        let kt = Tensor::new(vec![1, h, t_max, dh], kc);
        let vt = Tensor::new(vec![1, h, t_max, dh], vc);
        let tail_x = Tensor::new(
            vec![s - s0, d],
            x.data[s0 * d..s * d].to_vec(),
        );
        let be = CpuRef::new();
        let tail = be
            .exec(
                &format!("attn_prefill_chunk_s{}", s - s0),
                &[
                    Arg::F32(&tail_x),
                    Arg::F32(&ln1),
                    Arg::F32(&wq),
                    Arg::F32(&wk),
                    Arg::F32(&wv),
                    Arg::F32(&wo),
                    Arg::F32(&ln2),
                    Arg::F32(&kt),
                    Arg::F32(&vt),
                    Arg::I32(&[s0 as i32]),
                ],
            )
            .unwrap();
        // y and ln2x rows must be bit-identical to the full pass.
        for out_i in 0..2 {
            for r in 0..s - s0 {
                let want = &full[out_i].data[(s0 + r) * d..(s0 + r + 1) * d];
                let got = &tail[out_i].data[r * d..(r + 1) * d];
                assert_eq!(want, got, "output {out_i} row {r} diverged");
            }
        }
        // chunk-local K/V equal the full pass's tail rows bitwise.
        let hd = h * dh;
        assert_eq!(tail[2].data, full[2].data[s0 * hd..s * hd]);
        assert_eq!(tail[3].data, full[3].data[s0 * hd..s * hd]);
    }

    #[test]
    fn attn_step_slice_view_is_bit_identical_to_contiguous() {
        // Arg::F32Slices (zero-copy per-slot KV) must be byte-identical
        // to feeding the same cache as one contiguous tensor.
        let mut rng = SplitMix64::new(5);
        let (b, d, h, dh, t_max) = (3usize, 8usize, 2usize, 4usize, 6usize);
        let x = randn(&mut rng, vec![b, d], 0.5);
        let ln1 = Tensor::new(vec![d], vec![1.0; d]);
        let ln2 = Tensor::new(vec![d], vec![1.0; d]);
        let wq = randn(&mut rng, vec![d, d], 0.3);
        let wk = randn(&mut rng, vec![d, d], 0.3);
        let wv = randn(&mut rng, vec![d, d], 0.3);
        let wo = randn(&mut rng, vec![d, d], 0.3);
        let kc = randn(&mut rng, vec![b, h, t_max, dh], 0.4);
        let vc = randn(&mut rng, vec![b, h, t_max, dh], 0.4);
        let pos = [2i32, 0, 4];
        let be = CpuRef::new();
        let args_t = [
            Arg::F32(&x),
            Arg::F32(&ln1),
            Arg::F32(&wq),
            Arg::F32(&wk),
            Arg::F32(&wv),
            Arg::F32(&wo),
            Arg::F32(&ln2),
            Arg::F32(&kc),
            Arg::F32(&vc),
            Arg::I32(&pos),
        ];
        let via_tensor = be.exec("attn_step_b3", &args_t).unwrap();
        let stride = h * t_max * dh;
        let kslices: Vec<&[f32]> =
            (0..b).map(|bi| &kc.data[bi * stride..(bi + 1) * stride]).collect();
        let vslices: Vec<&[f32]> =
            (0..b).map(|bi| &vc.data[bi * stride..(bi + 1) * stride]).collect();
        let shape = [b, h, t_max, dh];
        let args_s = vec![
            Arg::F32(&x),
            Arg::F32(&ln1),
            Arg::F32(&wq),
            Arg::F32(&wk),
            Arg::F32(&wv),
            Arg::F32(&wo),
            Arg::F32(&ln2),
            Arg::F32Slices(&kslices, &shape),
            Arg::F32Slices(&vslices, &shape),
            Arg::I32(&pos),
        ];
        let via_slices = be.exec("attn_step_b3", &args_s).unwrap();
        for (a, bt) in via_tensor.iter().zip(&via_slices) {
            assert_eq!(a.data, bt.data);
            assert_eq!(a.shape, bt.shape);
        }
    }

    /// Split a contiguous `[H, t_max, dh]` row into `[H, page, dh]`
    /// pages (zero-padded tail), the layout `PagedKvCache` stores.
    fn paginate(row: &[f32], h: usize, t_max: usize, dh: usize, page: usize) -> Vec<Vec<f32>> {
        let n_pages = t_max.div_ceil(page);
        let mut out = vec![vec![0.0f32; h * page * dh]; n_pages];
        for (pi, pg) in out.iter_mut().enumerate() {
            for hi in 0..h {
                for r in 0..page {
                    let t = pi * page + r;
                    if t >= t_max {
                        break;
                    }
                    pg[(hi * page + r) * dh..(hi * page + r + 1) * dh]
                        .copy_from_slice(&row[(hi * t_max + t) * dh..(hi * t_max + t + 1) * dh]);
                }
            }
        }
        out
    }

    #[test]
    fn attn_step_paged_view_is_bit_identical_to_contiguous() {
        // Arg::F32Pages (paged KV, any page size) must be byte-identical
        // to the same cache fed as one contiguous tensor — including a
        // pageless padding row, which must behave like a zeroed slot.
        let mut rng = SplitMix64::new(7);
        let (b, d, h, dh, t_max) = (3usize, 8usize, 2usize, 4usize, 6usize);
        let x = randn(&mut rng, vec![b, d], 0.5);
        let ln1 = Tensor::new(vec![d], vec![1.0; d]);
        let ln2 = Tensor::new(vec![d], vec![1.0; d]);
        let wq = randn(&mut rng, vec![d, d], 0.3);
        let wk = randn(&mut rng, vec![d, d], 0.3);
        let wv = randn(&mut rng, vec![d, d], 0.3);
        let wo = randn(&mut rng, vec![d, d], 0.3);
        let mut kc = randn(&mut rng, vec![b, h, t_max, dh], 0.4);
        let mut vc = randn(&mut rng, vec![b, h, t_max, dh], 0.4);
        // row 1 is the "padding" row: pos 0, zero cache contiguously,
        // zero pages in the paged view.
        let stride = h * t_max * dh;
        kc.data[stride..2 * stride].fill(0.0);
        vc.data[stride..2 * stride].fill(0.0);
        let pos = [2i32, 0, 5];
        let be = CpuRef::new();
        let via_tensor = be
            .exec(
                "attn_step_b3",
                &[
                    Arg::F32(&x),
                    Arg::F32(&ln1),
                    Arg::F32(&wq),
                    Arg::F32(&wk),
                    Arg::F32(&wv),
                    Arg::F32(&wo),
                    Arg::F32(&ln2),
                    Arg::F32(&kc),
                    Arg::F32(&vc),
                    Arg::I32(&pos),
                ],
            )
            .unwrap();
        for page in [1usize, 2, 4, 16] {
            let mut kpages_own: Vec<Vec<f32>> = Vec::new();
            let mut vpages_own: Vec<Vec<f32>> = Vec::new();
            let mut row_starts = vec![0usize];
            for bi in 0..b {
                if bi != 1 {
                    kpages_own
                        .extend(paginate(&kc.data[bi * stride..], h, t_max, dh, page));
                    vpages_own
                        .extend(paginate(&vc.data[bi * stride..], h, t_max, dh, page));
                }
                row_starts.push(kpages_own.len());
            }
            let kpages: Vec<&[f32]> = kpages_own.iter().map(|p| p.as_slice()).collect();
            let vpages: Vec<&[f32]> = vpages_own.iter().map(|p| p.as_slice()).collect();
            let via_pages = be
                .exec(
                    "attn_step_b3",
                    &[
                        Arg::F32(&x),
                        Arg::F32(&ln1),
                        Arg::F32(&wq),
                        Arg::F32(&wk),
                        Arg::F32(&wv),
                        Arg::F32(&wo),
                        Arg::F32(&ln2),
                        Arg::F32Pages {
                            pages: &kpages,
                            row_starts: &row_starts,
                            n_heads: h,
                            page,
                            d_head: dh,
                            t_max,
                        },
                        Arg::F32Pages {
                            pages: &vpages,
                            row_starts: &row_starts,
                            n_heads: h,
                            page,
                            d_head: dh,
                            t_max,
                        },
                        Arg::I32(&pos),
                    ],
                )
                .unwrap();
            for (a, bt) in via_tensor.iter().zip(&via_pages) {
                assert_eq!(a.data, bt.data, "page size {page} diverged");
            }
        }
    }

    #[test]
    fn prefill_chunk_paged_view_is_bit_identical_to_contiguous() {
        let mut rng = SplitMix64::new(8);
        let (s, base, d, h, dh, t_max) = (3usize, 5usize, 8usize, 2usize, 4usize, 10usize);
        let x = randn(&mut rng, vec![s, d], 0.5);
        let ln1 = Tensor::new(vec![d], vec![1.0; d]);
        let ln2 = Tensor::new(vec![d], vec![1.0; d]);
        let wq = randn(&mut rng, vec![d, d], 0.3);
        let wk = randn(&mut rng, vec![d, d], 0.3);
        let wv = randn(&mut rng, vec![d, d], 0.3);
        let wo = randn(&mut rng, vec![d, d], 0.3);
        let kc = randn(&mut rng, vec![1, h, t_max, dh], 0.4);
        let vc = randn(&mut rng, vec![1, h, t_max, dh], 0.4);
        let base_arg = [base as i32];
        let be = CpuRef::new();
        let name = format!("attn_prefill_chunk_s{s}");
        let via_tensor = be
            .exec(
                &name,
                &[
                    Arg::F32(&x),
                    Arg::F32(&ln1),
                    Arg::F32(&wq),
                    Arg::F32(&wk),
                    Arg::F32(&wv),
                    Arg::F32(&wo),
                    Arg::F32(&ln2),
                    Arg::F32(&kc),
                    Arg::F32(&vc),
                    Arg::I32(&base_arg),
                ],
            )
            .unwrap();
        for page in [2usize, 3, 16] {
            let kpages_own = paginate(&kc.data, h, t_max, dh, page);
            let vpages_own = paginate(&vc.data, h, t_max, dh, page);
            let kpages: Vec<&[f32]> = kpages_own.iter().map(|p| p.as_slice()).collect();
            let vpages: Vec<&[f32]> = vpages_own.iter().map(|p| p.as_slice()).collect();
            let row_starts = [0, kpages.len()];
            let via_pages = be
                .exec(
                    &name,
                    &[
                        Arg::F32(&x),
                        Arg::F32(&ln1),
                        Arg::F32(&wq),
                        Arg::F32(&wk),
                        Arg::F32(&wv),
                        Arg::F32(&wo),
                        Arg::F32(&ln2),
                        Arg::F32Pages {
                            pages: &kpages,
                            row_starts: &row_starts,
                            n_heads: h,
                            page,
                            d_head: dh,
                            t_max,
                        },
                        Arg::F32Pages {
                            pages: &vpages,
                            row_starts: &row_starts,
                            n_heads: h,
                            page,
                            d_head: dh,
                            t_max,
                        },
                        Arg::I32(&base_arg),
                    ],
                )
                .unwrap();
            for (a, bt) in via_tensor.iter().zip(&via_pages) {
                assert_eq!(a.data, bt.data, "page size {page} diverged");
            }
        }
    }

    #[test]
    fn unknown_artifact_is_an_error() {
        let be = CpuRef::new();
        assert!(be.exec("mystery_kernel", &[]).is_err());
    }

    #[test]
    fn counters_track_named_execs() {
        let be = CpuRef::new();
        let x = Tensor::zeros(vec![1, 2]);
        let wg = Tensor::zeros(vec![2, 2]);
        be.exec("gate_b1_e2", &[Arg::F32(&x), Arg::F32(&wg)]).unwrap();
        be.exec("gate_b1_e2", &[Arg::F32(&x), Arg::F32(&wg)]).unwrap();
        assert_eq!(be.exec_counts()["gate_b1_e2"].0, 2);
        assert_eq!(be.compiled_count(), 1);
        be.reset_counters();
        assert!(be.exec_counts().is_empty());
        // compiled_count mirrors PJRT's executable cache: it survives
        // counter resets.
        assert_eq!(be.compiled_count(), 1);
    }
}
