"""SplitMix64 — deterministic RNG mirrored bit-for-bit in Rust.

The synthetic benchmark suite must produce *identical* prompts in the
build-time Python corpus generator and the run-time Rust evaluation
harness (`rust/src/util/rng.rs`), so both sides implement this exact
generator and the cross-language tests compare golden streams.
"""

MASK = (1 << 64) - 1


class SplitMix64:
    def __init__(self, seed: int):
        self.state = seed & MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return (z ^ (z >> 31)) & MASK

    def below(self, n: int) -> int:
        """Uniform integer in [0, n) via 64-bit modulo (bias negligible)."""
        return self.next_u64() % n

    def choice(self, seq):
        return seq[self.below(len(seq))]

    def f64(self) -> float:
        """Uniform in [0, 1) with 53 bits."""
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))
