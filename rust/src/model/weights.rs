//! Serialized-weight loading: flat little-endian f32 `.bin` + manifest.
//!
//! Layout is defined by `python/compile/aot.py::flatten_params`; tensor
//! names are `emb`, `pos`, `lnf`, and `layers.{i}.{ln1,wq,wk,wv,wo,ln2,
//! wg,w1,w3,w2[,sw1,sw3,sw2]}`.

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::ModelConfig;
use crate::util::json::Json;
use crate::util::rng::SplitMix64;

/// A host-resident f32 tensor (row-major).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let cols = self.shape[1];
        &self.data[i * cols..(i + 1) * cols]
    }

    /// Sub-tensor `t[i]` of the leading dimension (any rank ≥ 1).
    pub fn index0(&self, i: usize) -> Tensor {
        let inner: usize = self.shape[1..].iter().product();
        Tensor {
            shape: self.shape[1..].to_vec(),
            data: self.data[i * inner..(i + 1) * inner].to_vec(),
        }
    }

    /// Column-slice of a 2-D tensor: keep columns [c0, c1).
    pub fn col_slice(&self, c0: usize, c1: usize) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        let (rows, cols) = (self.shape[0], self.shape[1]);
        let mut data = Vec::with_capacity(rows * (c1 - c0));
        for r in 0..rows {
            data.extend_from_slice(&self.data[r * cols + c0..r * cols + c1]);
        }
        Tensor::new(vec![rows, c1 - c0], data)
    }

    /// Row-slice of a 2-D tensor: keep rows [r0, r1).
    pub fn row_slice(&self, r0: usize, r1: usize) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        let cols = self.shape[1];
        Tensor::new(
            vec![r1 - r0, cols],
            self.data[r0 * cols..r1 * cols].to_vec(),
        )
    }

    /// Gather columns of a 2-D tensor by index (reconstruction permute).
    pub fn gather_cols(&self, idx: &[usize]) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        let (rows, cols) = (self.shape[0], self.shape[1]);
        let mut data = Vec::with_capacity(rows * idx.len());
        for r in 0..rows {
            let row = &self.data[r * cols..(r + 1) * cols];
            data.extend(idx.iter().map(|&j| row[j]));
        }
        Tensor::new(vec![rows, idx.len()], data)
    }

    /// Gather rows of a 2-D tensor by index.
    pub fn gather_rows(&self, idx: &[usize]) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        let cols = self.shape[1];
        let mut data = Vec::with_capacity(idx.len() * cols);
        for &i in idx {
            data.extend_from_slice(&self.data[i * cols..(i + 1) * cols]);
        }
        Tensor::new(vec![idx.len(), cols], data)
    }

    pub fn scale(&self, k: f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|x| x * k).collect(),
        }
    }
}

/// A loaded model: config + named tensors.
pub struct Weights {
    pub config: ModelConfig,
    pub tensors: BTreeMap<String, Tensor>,
}

impl Weights {
    pub fn load(models_dir: &Path, name: &str) -> Result<Self> {
        let manifest_path = models_dir.join(format!("{name}.json"));
        let bin_path = models_dir.join(format!("{name}.bin"));
        let manifest = Json::parse(
            &fs::read_to_string(&manifest_path)
                .with_context(|| format!("reading {manifest_path:?}"))?,
        )?;
        let config = ModelConfig::from_json(manifest.get("config")?)?;
        config.validate()?;
        let raw = fs::read(&bin_path).with_context(|| format!("reading {bin_path:?}"))?;
        if raw.len() % 4 != 0 {
            bail!("{bin_path:?} is not a whole number of f32s");
        }
        let floats: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut tensors = BTreeMap::new();
        for (tname, meta) in manifest.get("tensors")?.as_obj()? {
            let offset = meta.get("offset")?.as_usize()?;
            let shape = meta.get("shape")?.as_usize_vec()?;
            let numel: usize = shape.iter().product();
            if offset + numel > floats.len() {
                bail!("tensor {tname} out of range");
            }
            tensors.insert(
                tname.clone(),
                Tensor::new(shape, floats[offset..offset + numel].to_vec()),
            );
        }
        Ok(Weights { config, tensors })
    }

    /// Load the serialized model if it exists, otherwise materialize
    /// deterministic synthetic weights for a built-in preset — the
    /// hermetic path that lets the whole serving stack (and CI) run
    /// with no `make artifacts` step.
    pub fn load_or_synthetic(models_dir: &Path, name: &str) -> Result<Self> {
        if models_dir.join(format!("{name}.json")).exists() {
            return Self::load(models_dir, name);
        }
        let cfg = ModelConfig::preset(name).with_context(|| {
            format!(
                "no serialized model {name:?} under {models_dir:?} and no \
                 built-in preset of that name — run `make artifacts` or use \
                 one of {:?}",
                ModelConfig::PRESET_NAMES
            )
        })?;
        Ok(Self::synthetic(&cfg))
    }

    /// Deterministic untrained weights (SplitMix64-seeded, N(0, 0.02²)
    /// like `python/compile/model.py::init_params`; norm gains = 1).
    /// Same name ⇒ bit-identical weights on every machine.
    pub fn synthetic(cfg: &ModelConfig) -> Self {
        let mut rng = SplitMix64::new(synth_seed(&cfg.name));
        let mut tensors = BTreeMap::new();
        let scale = 0.02f32;
        let mut randn = |shape: Vec<usize>| {
            let n: usize = shape.iter().product();
            Tensor::new(shape, (0..n).map(|_| rng.gauss() as f32 * scale).collect())
        };
        let d = cfg.d_model;
        tensors.insert("emb".to_string(), randn(vec![cfg.vocab, d]));
        tensors.insert("pos".to_string(), randn(vec![cfg.max_seq, d]));
        for li in 0..cfg.n_layers {
            let mut put = |key: &str, t: Tensor| {
                tensors.insert(format!("layers.{li}.{key}"), t);
            };
            put("ln1", Tensor::new(vec![d], vec![1.0; d]));
            put("wq", randn(vec![d, d]));
            put("wk", randn(vec![d, d]));
            put("wv", randn(vec![d, d]));
            put("wo", randn(vec![d, d]));
            put("ln2", Tensor::new(vec![d], vec![1.0; d]));
            put("wg", randn(vec![d, cfg.n_experts]));
            put("w1", randn(vec![cfg.n_experts, d, cfg.d_ffn]));
            put("w3", randn(vec![cfg.n_experts, d, cfg.d_ffn]));
            put("w2", randn(vec![cfg.n_experts, cfg.d_ffn, d]));
            if cfg.n_shared > 0 {
                put("sw1", randn(vec![d, cfg.d_ffn_shared]));
                put("sw3", randn(vec![d, cfg.d_ffn_shared]));
                put("sw2", randn(vec![cfg.d_ffn_shared, d]));
            }
        }
        tensors.insert("lnf".to_string(), Tensor::new(vec![d], vec![1.0; d]));
        Weights { config: cfg.clone(), tensors }
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("missing tensor {name}"))
    }

    pub fn layer(&self, li: usize, key: &str) -> Result<&Tensor> {
        self.get(&format!("layers.{li}.{key}"))
    }

    /// Expert sub-tensor: `layers.{li}.{key}[e]` for key in {w1, w3, w2}.
    pub fn expert(&self, li: usize, key: &str, e: usize) -> Result<Tensor> {
        Ok(self.layer(li, key)?.index0(e))
    }
}

/// Stable per-model seed for synthetic weights (FNV-1a over the name).
fn synth_seed(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_slicing() {
        // 2x4 matrix 0..8
        let t = Tensor::new(vec![2, 4], (0..8).map(|x| x as f32).collect());
        assert_eq!(t.row(1), &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(t.col_slice(1, 3).data, vec![1.0, 2.0, 5.0, 6.0]);
        assert_eq!(t.row_slice(1, 2).data, vec![4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn tensor_gather() {
        let t = Tensor::new(vec![2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.gather_cols(&[2, 0]).data, vec![2., 0., 5., 3.]);
        assert_eq!(t.gather_rows(&[1, 0]).data, vec![3., 4., 5., 0., 1., 2.]);
    }

    #[test]
    fn index0_splits_leading_dim() {
        let t = Tensor::new(vec![2, 2, 2], (0..8).map(|x| x as f32).collect());
        assert_eq!(t.index0(1).data, vec![4., 5., 6., 7.]);
        assert_eq!(t.index0(1).shape, vec![2, 2]);
    }

    #[test]
    fn scale_scales() {
        let t = Tensor::new(vec![2], vec![1.0, -2.0]);
        assert_eq!(t.scale(2.0).data, vec![2.0, -4.0]);
    }

    #[test]
    fn synthetic_weights_complete_and_deterministic() {
        let cfg = ModelConfig::preset("deepseek_ish").unwrap();
        let a = Weights::synthetic(&cfg);
        let b = Weights::synthetic(&cfg);
        assert_eq!(a.get("emb").unwrap().shape, vec![256, 64]);
        assert_eq!(a.layer(0, "w1").unwrap().shape, vec![14, 64, 64]);
        assert_eq!(a.layer(3, "sw2").unwrap().shape, vec![128, 64]);
        assert_eq!(a.get("lnf").unwrap().data, vec![1.0; 64]);
        assert_eq!(
            a.layer(2, "wq").unwrap().data,
            b.layer(2, "wq").unwrap().data,
            "same name must give bit-identical weights"
        );
        // distinct models diverge
        let o = Weights::synthetic(&ModelConfig::preset("olmoe_ish").unwrap());
        assert_ne!(a.get("emb").unwrap().data, o.get("emb").unwrap().data);
    }

    #[test]
    fn load_or_synthetic_falls_back_to_preset() {
        let w = Weights::load_or_synthetic(Path::new("/nonexistent/models"), "mixtral_ish")
            .unwrap();
        assert_eq!(w.config.n_experts, 8);
        assert!(Weights::load_or_synthetic(Path::new("/nonexistent/models"), "nope").is_err());
    }
}
