//! Calibration: neuron-importance profiling via the L1 probe artifact
//! (paper §4.2b, Eqs. 14-17), plus the Fig. 1 / Fig. 13 data products.
//!
//! Streams a deterministic calibration corpus through the engine; at
//! every MoE layer the tokens routed to each expert are packed through
//! `probe_h{width}` which returns the four accumulated importance rows
//! per neuron. Tables are saved to `artifacts/results/` and consumed by
//! expert *reconstruction* at engine load — and, since ISSUE-10, by the
//! neuron-level keep masks (`moe::partition::keep_mask`) that the
//! masked FFN kernels run under `--neuron-keep`.

use std::path::Path;

use anyhow::{Context, Result};

use crate::engine::Engine;
use crate::tasks::calibration_tokens;
use crate::util::json::{Json, self};

pub const METRICS: [&str; 4] = ["gate", "abs_gate", "gate_up", "abs_gate_up"];

/// `[layer][expert][metric 0..4][neuron]` accumulated importance.
#[derive(Debug, Clone)]
pub struct ProbeTables {
    pub t: Vec<Vec<[Vec<f32>; 4]>>,
    pub width: usize,
}

impl ProbeTables {
    pub fn new(n_layers: usize, n_experts: usize, width: usize) -> Self {
        ProbeTables {
            t: (0..n_layers)
                .map(|_| {
                    (0..n_experts)
                        .map(|_| std::array::from_fn(|_| vec![0.0; width]))
                        .collect()
                })
                .collect(),
            width,
        }
    }

    /// Importance tables for one metric: `[layer][expert][neuron]`.
    pub fn importance(&self, metric: &str) -> Vec<Vec<Vec<f32>>> {
        let mi = METRICS
            .iter()
            .position(|&m| m == metric)
            .unwrap_or(1 /* abs_gate */);
        self.t
            .iter()
            .map(|layer| layer.iter().map(|e| e[mi].clone()).collect())
            .collect()
    }

    pub fn to_json(&self) -> Json {
        let layers: Vec<Json> = self
            .t
            .iter()
            .map(|layer| {
                Json::Arr(
                    layer
                        .iter()
                        .map(|e| {
                            Json::Arr(
                                e.iter()
                                    .map(|m| {
                                        Json::Arr(
                                            m.iter().map(|&x| Json::Num(x as f64)).collect(),
                                        )
                                    })
                                    .collect(),
                            )
                        })
                        .collect(),
                )
            })
            .collect();
        json::obj(vec![
            ("width", Json::Num(self.width as f64)),
            ("tables", Json::Arr(layers)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let width = j.get("width")?.as_usize()?;
        let mut t = Vec::new();
        for layer in j.get("tables")?.as_arr()? {
            let mut experts = Vec::new();
            for e in layer.as_arr()? {
                let ms = e.as_arr()?;
                let arr: [Vec<f32>; 4] = std::array::from_fn(|i| {
                    ms[i].as_f32_vec().unwrap_or_default()
                });
                experts.push(arr);
            }
            t.push(experts);
        }
        Ok(ProbeTables { t, width })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::create_dir_all(path.parent().context("no parent")?)?;
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?} — run `dualsparse calibrate` first"))?;
        Self::from_json(&Json::parse(&text)?)
    }
}

/// Stream `n_tokens` of the calibration corpus through the engine with
/// probing enabled; returns the accumulated tables.
pub fn run_calibration(engine: &mut Engine, n_tokens: usize) -> Result<ProbeTables> {
    let window = 32usize; // prefill bucket used for calibration windows
    let stream = calibration_tokens(n_tokens);
    engine.probe = Some(ProbeTables::new(
        engine.cfg.n_layers,
        engine.cfg.n_experts,
        engine.cfg.d_ffn,
    ));
    for chunk in stream.chunks(window) {
        if chunk.len() < 2 {
            break;
        }
        engine.kv.reset();
        let slot = engine.kv.alloc();
        engine.prefill(slot, chunk)?;
    }
    Ok(engine.probe.take().expect("probe tables"))
}

/// Number of probe-ranked neurons a width-`width` sub-expert keeps
/// under `--neuron-keep keep`: `⌈keep·width⌉`, with `keep` clamped to
/// `0.0..=1.0`. Ceiling (not round) so any keep > 0 keeps at least one
/// neuron of a non-empty sub-expert, and keep = 1.0 keeps all of them.
/// Pure integer/IEEE arithmetic — identical on every platform.
pub fn keep_count(width: usize, keep: f32) -> usize {
    ((keep.clamp(0.0, 1.0) as f64 * width as f64).ceil() as usize).min(width)
}

/// Default path for a model's calibration tables.
pub fn tables_path(artifacts_dir: &Path, model: &str) -> std::path::PathBuf {
    artifacts_dir
        .join("results")
        .join(format!("importance_{model}.json"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_json_roundtrip() {
        let mut t = ProbeTables::new(2, 3, 4);
        t.t[1][2][0][3] = 1.5;
        t.t[0][0][3][0] = -2.0;
        let j = t.to_json();
        let r = ProbeTables::from_json(&j).unwrap();
        assert_eq!(r.width, 4);
        assert_eq!(r.t[1][2][0][3], 1.5);
        assert_eq!(r.t[0][0][3][0], -2.0);
    }

    #[test]
    fn importance_defaults_to_abs_gate() {
        let mut t = ProbeTables::new(1, 1, 2);
        t.t[0][0][1] = vec![3.0, 1.0];
        let imp = t.importance("nonsense-metric");
        assert_eq!(imp[0][0], vec![3.0, 1.0]);
    }

    #[test]
    fn metric_selection() {
        let mut t = ProbeTables::new(1, 1, 2);
        t.t[0][0][2] = vec![7.0, 8.0];
        assert_eq!(t.importance("gate_up")[0][0], vec![7.0, 8.0]);
    }

    #[test]
    fn keep_count_boundaries() {
        assert_eq!(keep_count(128, 1.0), 128);
        assert_eq!(keep_count(128, 0.75), 96);
        assert_eq!(keep_count(128, 0.5), 64);
        assert_eq!(keep_count(128, 0.0), 0);
        assert_eq!(keep_count(3, 0.01), 1, "any keep > 0 keeps a neuron");
        assert_eq!(keep_count(0, 0.5), 0);
        // out-of-range inputs clamp instead of exploding
        assert_eq!(keep_count(8, 2.0), 8);
        assert_eq!(keep_count(8, -1.0), 0);
        // monotone in keep
        let mut last = usize::MAX;
        for p in [1.0f32, 0.9, 0.75, 0.5, 0.25, 0.1, 0.0] {
            let k = keep_count(100, p);
            assert!(k <= last);
            last = k;
        }
    }
}
