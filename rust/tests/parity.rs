//! Cross-language parity: the Rust task/RNG mirrors must match the
//! Python generators bit-for-bit. The same golden values are asserted
//! in python/tests/test_parity.py.

#![allow(clippy::needless_range_loop, clippy::manual_memcpy, clippy::type_complexity)]

use dualsparse::tasks::{self, eval_set};
use dualsparse::util::rng::SplitMix64;

#[test]
fn rng_stream_matches_python() {
    let mut r = SplitMix64::new(0);
    let got: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
    assert_eq!(
        got,
        vec![
            0xE220_A839_7B1D_CDAF,
            0x6E78_9E6A_A1B9_65F4,
            0x06C4_5D18_8009_454F,
            0xF88B_B8A8_724C_81EC,
        ]
    );
}

#[test]
fn eval_sets_match_python_golden() {
    let cases: &[(&str, &[(&str, &str)])] = &[
        ("cpy", &[("cpy:afdg|", "afdg"), ("cpy:edaf|", "edaf"), ("cpy:aabc|", "aabc")]),
        ("add", &[("add:6+8|", "4"), ("add:0+0|", "0"), ("add:4+7|", "1")]),
        ("ind", &[("ind:a6 d6 b7 a|", "6"), ("ind:b0 c9 d1 c|", "9"),
                  ("ind:b7 d4 c2 d|", "4")]),
        ("lm", &[("lm:the mo|", "on is"), ("lm:a dog |", "ran t"),
                 ("lm:birds fly over t|", "he se")]),
        ("bal", &[("bal:()()|", "Y"), ("bal:))((|", "N"), ("bal:(())|", "Y")]),
        ("srt", &[("srt:aecb|", "abce"), ("srt:fdbc|", "bcdf"), ("srt:ecdf|", "cdef")]),
    ];
    for (task, expected) in cases {
        let got = eval_set(task, 3, false);
        let want: Vec<(String, String)> = expected
            .iter()
            .map(|(p, a)| (p.to_string(), a.to_string()))
            .collect();
        assert_eq!(got, want, "task {task} diverged from the Python generator");
    }
}

#[test]
fn corpus_prefix_is_stable() {
    // Calibration stream must be stable across releases (importance
    // tables and EES/EEP calibrations depend on it).
    let c = tasks::calibration_tokens(64);
    let text = String::from_utf8(c).unwrap();
    let first = text.lines().next().unwrap();
    assert!(first.len() >= 7 && first.contains('|'), "got {first:?}");
}

#[test]
fn every_task_generates_nonempty_answers() {
    for task in tasks::TASKS {
        for (p, a) in eval_set(task, 20, false) {
            assert!(p.ends_with('|'), "{task}: prompt {p:?}");
            assert!(!a.is_empty(), "{task}: empty answer for {p:?}");
            assert!(a.len() <= 8, "{task}: answer too long {a:?}");
        }
    }
}

#[test]
fn shifted_sets_differ() {
    for task in ["cpy", "add", "bal", "lm"] {
        assert_ne!(eval_set(task, 8, false), eval_set(task, 8, true), "{task}");
    }
}
