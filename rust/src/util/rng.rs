//! SplitMix64 — bit-for-bit mirror of `python/compile/rng.py`.
//!
//! The benchmark-task generators on both sides share this stream, so the
//! Rust evaluation harness reproduces the exact prompts the Python corpus
//! generator trained on. Golden-stream tests pin the two implementations
//! together (see `tests/test_parity.py` and `rust/tests/parity.rs`).

/// Deterministic 64-bit RNG (Steele et al., SplitMix64).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform integer in `[0, n)` (modulo; bias negligible for our n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    pub fn choice_byte(&mut self, s: &str) -> char {
        let bytes = s.as_bytes();
        bytes[self.below(bytes.len())] as char
    }

    /// Uniform in [0, 1) with 53 bits (matches Python `f64`).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Exponential variate with the given `rate` (mean 1/rate), via the
    /// inverse CDF over the `f64` stream. Drives the deterministic
    /// open-loop Poisson arrival process in `engine::scheduler`.
    pub fn exp(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0 && rate.is_finite(), "arrival rate must be positive");
        -(1.0 - self.f64()).ln() / rate
    }

    /// Standard normal via Box-Muller (deterministic; used for the
    /// synthetic test-weight materialization and fuzz fixtures — the
    /// Python fixture generator mirrors this exact formula).
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_stream() {
        // First outputs for seed 0 (cross-checked against the Python side
        // in tests/test_parity.py::test_rng_stream).
        let mut r = SplitMix64::new(0);
        let first: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        assert_eq!(first[0], 0xE220_A839_7B1D_CDAF);
        assert_eq!(first[1], 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn below_is_bounded() {
        let mut r = SplitMix64::new(123);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn exp_is_positive_with_mean_one_over_rate() {
        let mut r = SplitMix64::new(77);
        let n = 4000;
        let rate = 4.0;
        let xs: Vec<f64> = (0..n).map(|_| r.exp(rate)).collect();
        assert!(xs.iter().all(|&x| x > 0.0 && x.is_finite()));
        let mean: f64 = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.03, "mean {mean}");
    }

    #[test]
    fn gauss_is_roughly_standard_normal() {
        let mut r = SplitMix64::new(42);
        let n = 4000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean: f64 = xs.iter().sum::<f64>() / n as f64;
        let var: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.1, "mean {mean}");
        assert!((var - 1.0).abs() < 0.15, "var {var}");
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
