"""Model-family configuration shared by training, AOT export, and tests.

Three TinyMoE variants stand in for the paper's three evaluation models
(DESIGN.md §2): `mixtral_ish` (coarse experts, top-2), `olmoe_ish`
(fine-grained, top-4), `deepseek_ish` (shared + routed experts).

All variants share d_model / heads / layers / vocab so that the
attention, LM-head and FFN artifacts are reusable across the family;
only the MoE shape differs.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int = 64
    n_layers: int = 4
    n_heads: int = 4
    d_head: int = 16
    vocab: int = 256
    max_seq: int = 160
    # MoE
    n_experts: int = 8
    d_ffn: int = 128
    top_k: int = 2
    # DeepSeek-style shared expert (0 or 1), with its own width.
    n_shared: int = 0
    d_ffn_shared: int = 0
    # Gating-score normalization already applied by the model itself
    # (DeepSeek-V3/Qwen3-style); all our variants use plain softmax+TopK
    # so the DualSparse normalization step is required (paper §4.1).
    normalized_gating: bool = False

    @property
    def d_attn(self):
        return self.n_heads * self.d_head

    def as_dict(self):
        return asdict(self)


MIXTRAL_ISH = ModelConfig(
    name="mixtral_ish", n_experts=8, d_ffn=128, top_k=2
)
OLMOE_ISH = ModelConfig(
    name="olmoe_ish", n_experts=16, d_ffn=64, top_k=4
)
DEEPSEEK_ISH = ModelConfig(
    name="deepseek_ish", n_experts=14, d_ffn=64, top_k=2,
    n_shared=1, d_ffn_shared=128,
)

MODELS = {m.name: m for m in (MIXTRAL_ISH, OLMOE_ISH, DEEPSEEK_ISH)}

# Serving artifact shape buckets (DESIGN.md §6). The Rust dispatcher
# rounds live batch / kept-token counts up to the nearest bucket.
BATCH_BUCKETS = (1, 2, 4, 8, 16)
PREFILL_BUCKETS = (16, 32, 64, 128)
# ~1.4× spacing so a 25% drop in kept pairs usually lands in a smaller
# bucket (coarser spacing would hide the paper's drop→speedup effect).
CAPACITY_BUCKETS = (2, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128)
# Every distinct (sub-)expert FFN width across the family:
#   mixtral full/half = 128/64, olmoe + deepseek routed full/half = 64/32,
#   deepseek shared = 128, mixtral P=4 fine-tune full/half = 32/16.
FFN_WIDTHS = (128, 64, 32, 16)
PROBE_CAPACITY = 32

# Training hyper-parameters (build-time only).
PRETRAIN_STEPS = 2000
FINETUNE_STEPS = 400
BATCH = 16
SEQ = 48
LR = 3e-3
AUX_LOSS_COEF = 0.01
