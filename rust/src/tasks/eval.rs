//! Accuracy evaluation harness over the synthetic benchmark suite —
//! the stand-in for LM-Eval-Harness in Tables 1-3 and Figs. 7/11.

use anyhow::Result;

use super::{eval_set, TASKS};
use crate::engine::{Engine, MAX_SLOTS};

#[derive(Debug, Clone)]
pub struct TaskResult {
    pub task: String,
    pub n: usize,
    pub correct: usize,
    pub accuracy: f64,
}

/// Evaluate every task with `n_per_task` prompts; exact-match accuracy.
pub fn evaluate(engine: &mut Engine, n_per_task: usize, shift: bool) -> Result<Vec<TaskResult>> {
    let mut out = Vec::with_capacity(TASKS.len());
    for task in TASKS {
        let set = eval_set(task, n_per_task, shift);
        let mut correct = 0usize;
        for chunk in set.chunks(MAX_SLOTS) {
            let prompts: Vec<&str> = chunk.iter().map(|(p, _)| p.as_str()).collect();
            let max_new = chunk.iter().map(|(_, a)| a.len()).max().unwrap_or(4) + 2;
            let gens = engine.generate_batch(&prompts, max_new)?;
            for (g, (_, ans)) in gens.iter().zip(chunk) {
                if g == ans {
                    correct += 1;
                }
            }
        }
        out.push(TaskResult {
            task: task.to_string(),
            n: n_per_task,
            correct,
            accuracy: 100.0 * correct as f64 / n_per_task.max(1) as f64,
        });
    }
    Ok(out)
}

/// Unweighted average accuracy (the paper's AVG column).
pub fn avg_accuracy(results: &[TaskResult]) -> f64 {
    if results.is_empty() {
        return 0.0;
    }
    results.iter().map(|r| r.accuracy).sum::<f64>() / results.len() as f64
}

/// Paper-style one-line accuracy row.
pub fn format_row(label: &str, results: &[TaskResult]) -> String {
    let cells: Vec<String> = results
        .iter()
        .map(|r| format!("{:>5.1}", r.accuracy))
        .collect();
    format!(
        "{label:<28} {}  avg={:.2}",
        cells.join(" "),
        avg_accuracy(results)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn avg_of_empty_is_zero() {
        assert_eq!(avg_accuracy(&[]), 0.0);
    }

    #[test]
    fn avg_is_unweighted() {
        let r = vec![
            TaskResult { task: "a".into(), n: 10, correct: 10, accuracy: 100.0 },
            TaskResult { task: "b".into(), n: 10, correct: 0, accuracy: 0.0 },
        ];
        assert_eq!(avg_accuracy(&r), 50.0);
    }
}
