//! `CpuRef` — pure-Rust reference backend.
//!
//! Implements every serving artifact family (FFN, gating, probe,
//! prefill/step attention, LM head) directly over host tensors with the
//! shared kernels in `util::linalg`, numerically mirroring the jnp
//! oracles in `python/compile/kernels/ref.py` and the serving
//! decomposition in `python/compile/model.py`. Shapes come from the
//! argument tensors, so one implementation serves every capacity /
//! batch / width bucket; the artifact *name* is used for dispatch and
//! perf accounting only.
//!
//! This is the hermetic path: no AOT artifacts, no Python, no PJRT —
//! the seam the integration tests, golden-fixture tests and CI run on.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::model::{ModelConfig, Tensor};
use crate::util::linalg::{matmul, matmul_bt, rmsnorm_rows, softmax_rows, swiglu_ffn, swish};

use super::{Arg, Backend, BufId, ExecCounters};

/// Pure-Rust reference executor (see module docs).
pub struct CpuRef {
    /// Uploaded weight buffers, indexed by [`BufId`].
    bufs: RefCell<Vec<Tensor>>,
    /// (n_heads, d_head) — required by `attn_prefill_*`, which cannot
    /// infer head geometry from its arguments.
    heads: Cell<(usize, usize)>,
    counters: ExecCounters,
    /// Distinct artifact names ever executed. Kept separate from the
    /// perf counters so `compiled_count` survives `reset_counters`,
    /// matching the PJRT backend's compiled-executable cache semantics.
    seen: RefCell<std::collections::HashSet<String>>,
}

impl CpuRef {
    pub fn new() -> CpuRef {
        CpuRef {
            bufs: RefCell::new(Vec::new()),
            heads: Cell::new((0, 0)),
            counters: ExecCounters::default(),
            seen: RefCell::new(std::collections::HashSet::new()),
        }
    }
}

impl Default for CpuRef {
    fn default() -> Self {
        CpuRef::new()
    }
}

impl Backend for CpuRef {
    fn platform(&self) -> String {
        "cpu-ref".to_string()
    }

    fn set_model(&self, cfg: &ModelConfig) {
        self.heads.set((cfg.n_heads, cfg.d_head));
    }

    fn upload(&self, t: &Tensor) -> Result<BufId> {
        let mut bufs = self.bufs.borrow_mut();
        bufs.push(t.clone());
        Ok(BufId(bufs.len() - 1))
    }

    fn exec(&self, name: &str, args: &[Arg]) -> Result<Vec<Tensor>> {
        let t0 = std::time::Instant::now();
        let store = self.bufs.borrow();
        // Resolve args up front: tensors (host or uploaded) and i32 rows.
        let mut ts: Vec<Option<&Tensor>> = Vec::with_capacity(args.len());
        let mut is: Vec<Option<&[i32]>> = Vec::with_capacity(args.len());
        for a in args {
            match a {
                Arg::F32(x) => {
                    ts.push(Some(*x));
                    is.push(None);
                }
                Arg::Buf(id) => {
                    let t = store
                        .get(id.0)
                        .with_context(|| format!("{name}: dangling buffer id {}", id.0))?;
                    ts.push(Some(t));
                    is.push(None);
                }
                Arg::I32(v) => {
                    ts.push(None);
                    is.push(Some(*v));
                }
            }
        }
        let out = if name.starts_with("ffn_h") {
            vec![swiglu_ffn(
                targ(name, &ts, 0)?,
                targ(name, &ts, 1)?,
                targ(name, &ts, 2)?,
                targ(name, &ts, 3)?,
            )]
        } else if name.starts_with("gate_b") {
            vec![softmax_rows(&matmul(targ(name, &ts, 0)?, targ(name, &ts, 1)?))]
        } else if name.starts_with("probe_h") {
            vec![op_probe(
                targ(name, &ts, 0)?,
                targ(name, &ts, 1)?,
                targ(name, &ts, 2)?,
            )]
        } else if name.starts_with("attn_prefill_s") {
            let (h, dh) = self.heads.get();
            if h == 0 {
                bail!("{name}: CpuRef needs set_model() before attention artifacts");
            }
            op_attn_prefill(
                targ(name, &ts, 0)?,
                targ(name, &ts, 1)?,
                targ(name, &ts, 2)?,
                targ(name, &ts, 3)?,
                targ(name, &ts, 4)?,
                targ(name, &ts, 5)?,
                targ(name, &ts, 6)?,
                h,
                dh,
            )?
        } else if name.starts_with("attn_step_b") {
            op_attn_step(
                targ(name, &ts, 0)?,
                targ(name, &ts, 1)?,
                targ(name, &ts, 2)?,
                targ(name, &ts, 3)?,
                targ(name, &ts, 4)?,
                targ(name, &ts, 5)?,
                targ(name, &ts, 6)?,
                targ(name, &ts, 7)?,
                targ(name, &ts, 8)?,
                iarg(name, &is, 9)?,
            )?
        } else if name.starts_with("lm_head_b") {
            vec![matmul_bt(
                &rmsnorm_rows(targ(name, &ts, 0)?, &targ(name, &ts, 1)?.data),
                targ(name, &ts, 2)?,
            )]
        } else {
            bail!("CpuRef: unknown artifact {name:?}");
        };
        self.counters.record(name, t0.elapsed().as_secs_f64());
        if !self.seen.borrow().contains(name) {
            self.seen.borrow_mut().insert(name.to_string());
        }
        Ok(out)
    }

    fn compiled_count(&self) -> usize {
        self.seen.borrow().len()
    }

    fn reset_counters(&self) {
        self.counters.reset();
    }

    fn time_with_prefix(&self, prefix: &str) -> f64 {
        self.counters.time_with_prefix(prefix)
    }

    fn exec_counts(&self) -> HashMap<String, (u64, f64)> {
        self.counters.snapshot()
    }
}

/// Resolved f32 tensor argument `i` (host or uploaded buffer).
fn targ<'a>(name: &str, ts: &[Option<&'a Tensor>], i: usize) -> Result<&'a Tensor> {
    ts.get(i)
        .copied()
        .flatten()
        .with_context(|| format!("{name}: missing f32 arg {i}"))
}

/// Resolved i32 argument `i`.
fn iarg<'a>(name: &str, is: &[Option<&'a [i32]>], i: usize) -> Result<&'a [i32]> {
    is.get(i)
        .copied()
        .flatten()
        .with_context(|| format!("{name}: missing i32 arg {i}"))
}

/// Neuron-importance accumulators (`probe_ref`, paper Eqs. 14-17):
/// rows = [Σ swish(xW1), Σ |swish(xW1)|, Σ g·u, Σ |g·u|], shape [4, H].
fn op_probe(x: &Tensor, w1: &Tensor, w3: &Tensor) -> Tensor {
    let g = matmul(x, w1);
    let u = matmul(x, w3);
    let (n, h) = (g.shape[0], g.shape[1]);
    let mut out = vec![0.0f32; 4 * h];
    for i in 0..n {
        for j in 0..h {
            let sw = swish(g.data[i * h + j]);
            let gu = sw * u.data[i * h + j];
            out[j] += sw;
            out[h + j] += sw.abs();
            out[2 * h + j] += gu;
            out[3 * h + j] += gu.abs();
        }
    }
    Tensor::new(vec![4, h], out)
}

/// Full-sequence causal prefill (`serve_attn_prefill`): returns
/// (y [S,d], ln2x [S,d], K [S,H,dh], V [S,H,dh]).
#[allow(clippy::too_many_arguments)]
fn op_attn_prefill(
    x: &Tensor,
    ln1: &Tensor,
    wq: &Tensor,
    wk: &Tensor,
    wv: &Tensor,
    wo: &Tensor,
    ln2: &Tensor,
    n_heads: usize,
    d_head: usize,
) -> Result<Vec<Tensor>> {
    let (s, d) = (x.shape[0], x.shape[1]);
    if n_heads * d_head != d {
        bail!("attn_prefill: {n_heads}x{d_head} heads != d_model {d}");
    }
    let xn = rmsnorm_rows(x, &ln1.data);
    let q = matmul(&xn, wq);
    let k = matmul(&xn, wk);
    let v = matmul(&xn, wv);
    let scale = 1.0 / (d_head as f32).sqrt();
    let mut ctx = vec![0.0f32; s * d];
    let mut scores = vec![0.0f32; s];
    for hi in 0..n_heads {
        let off = hi * d_head;
        for qi in 0..s {
            // causal: keys 0..=qi only (identical to -1e9 masking — the
            // masked terms exp to exactly 0 after max subtraction).
            for (ki, sc) in scores.iter_mut().enumerate().take(qi + 1) {
                let mut dot = 0.0f32;
                for e in 0..d_head {
                    dot += q.data[qi * d + off + e] * k.data[ki * d + off + e];
                }
                *sc = dot * scale;
            }
            softmax_inplace(&mut scores[..qi + 1]);
            for ki in 0..=qi {
                let w = scores[ki];
                for e in 0..d_head {
                    ctx[qi * d + off + e] += w * v.data[ki * d + off + e];
                }
            }
        }
    }
    let proj = matmul(&Tensor::new(vec![s, d], ctx), wo);
    let y = Tensor::new(
        vec![s, d],
        x.data.iter().zip(&proj.data).map(|(a, b)| a + b).collect(),
    );
    let ln2x = rmsnorm_rows(&y, &ln2.data);
    Ok(vec![
        y,
        ln2x,
        Tensor::new(vec![s, n_heads, d_head], k.data),
        Tensor::new(vec![s, n_heads, d_head], v.data),
    ])
}

/// Single-token decode step with KV cache (`serve_attn_step`): returns
/// (y [B,d], ln2x [B,d], new_k [B,H,dh], new_v [B,H,dh]). Head geometry
/// is inferred from the cache shape [B,H,T,dh].
#[allow(clippy::too_many_arguments)]
fn op_attn_step(
    x: &Tensor,
    ln1: &Tensor,
    wq: &Tensor,
    wk: &Tensor,
    wv: &Tensor,
    wo: &Tensor,
    ln2: &Tensor,
    kcache: &Tensor,
    vcache: &Tensor,
    pos: &[i32],
) -> Result<Vec<Tensor>> {
    let (b, d) = (x.shape[0], x.shape[1]);
    if kcache.shape.len() != 4 || kcache.shape[0] != b {
        bail!("attn_step: bad kcache shape {:?}", kcache.shape);
    }
    let (n_heads, t_max, d_head) = (kcache.shape[1], kcache.shape[2], kcache.shape[3]);
    if n_heads * d_head != d || pos.len() < b {
        bail!("attn_step: {n_heads}x{d_head} heads vs d_model {d}, pos len {}", pos.len());
    }
    let xn = rmsnorm_rows(x, &ln1.data);
    let q = matmul(&xn, wq);
    let new_k = matmul(&xn, wk);
    let new_v = matmul(&xn, wv);
    let scale = 1.0 / (d_head as f32).sqrt();
    let mut ctx = vec![0.0f32; b * d];
    for bi in 0..b {
        let p = (pos[bi].max(0) as usize).min(t_max);
        let mut scores = vec![0.0f32; p + 1];
        for hi in 0..n_heads {
            let off = hi * d_head;
            let cbase = (bi * n_heads + hi) * t_max * d_head;
            for (ti, sc) in scores.iter_mut().enumerate().take(p) {
                let mut dot = 0.0f32;
                for e in 0..d_head {
                    dot += q.data[bi * d + off + e] * kcache.data[cbase + ti * d_head + e];
                }
                *sc = dot * scale;
            }
            // the token attends to itself via the freshly-projected K.
            let mut dot = 0.0f32;
            for e in 0..d_head {
                dot += q.data[bi * d + off + e] * new_k.data[bi * d + off + e];
            }
            scores[p] = dot * scale;
            softmax_inplace(&mut scores);
            for ti in 0..p {
                let w = scores[ti];
                for e in 0..d_head {
                    ctx[bi * d + off + e] += w * vcache.data[cbase + ti * d_head + e];
                }
            }
            let w = scores[p];
            for e in 0..d_head {
                ctx[bi * d + off + e] += w * new_v.data[bi * d + off + e];
            }
        }
    }
    let proj = matmul(&Tensor::new(vec![b, d], ctx), wo);
    let y = Tensor::new(
        vec![b, d],
        x.data.iter().zip(&proj.data).map(|(a, b)| a + b).collect(),
    );
    let ln2x = rmsnorm_rows(&y, &ln2.data);
    Ok(vec![
        y,
        ln2x,
        Tensor::new(vec![b, n_heads, d_head], new_k.data),
        Tensor::new(vec![b, n_heads, d_head], new_v.data),
    ])
}

/// Numerically-stable in-place softmax over a score row.
fn softmax_inplace(xs: &mut [f32]) {
    let mx = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - mx).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn randn(rng: &mut SplitMix64, shape: Vec<usize>, scale: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor::new(shape, (0..n).map(|_| rng.gauss() as f32 * scale).collect())
    }

    #[test]
    fn ffn_matches_shared_kernel() {
        let mut rng = SplitMix64::new(1);
        let x = randn(&mut rng, vec![4, 8], 0.5);
        let w1 = randn(&mut rng, vec![8, 6], 0.3);
        let w3 = randn(&mut rng, vec![8, 6], 0.3);
        let w2 = randn(&mut rng, vec![6, 8], 0.3);
        let be = CpuRef::new();
        let got = be
            .exec("ffn_h6_c4", &[Arg::F32(&x), Arg::F32(&w1), Arg::F32(&w3), Arg::F32(&w2)])
            .unwrap();
        let want = swiglu_ffn(&x, &w1, &w3, &w2);
        assert_eq!(got[0].data, want.data);
    }

    #[test]
    fn uploaded_buffers_resolve() {
        let be = CpuRef::new();
        let mut rng = SplitMix64::new(2);
        let x = randn(&mut rng, vec![2, 4], 0.5);
        let wg = randn(&mut rng, vec![4, 3], 0.5);
        let id = be.upload(&wg).unwrap();
        let via_buf = be.exec("gate_b2_e3", &[Arg::F32(&x), Arg::Buf(id)]).unwrap();
        let via_host = be.exec("gate_b2_e3", &[Arg::F32(&x), Arg::F32(&wg)]).unwrap();
        assert_eq!(via_buf[0].data, via_host[0].data);
        for r in 0..2 {
            let s: f32 = via_buf[0].row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn probe_abs_rows_dominate() {
        let mut rng = SplitMix64::new(3);
        let x = randn(&mut rng, vec![6, 8], 0.5);
        let w1 = randn(&mut rng, vec![8, 5], 0.4);
        let w3 = randn(&mut rng, vec![8, 5], 0.4);
        let imp = op_probe(&x, &w1, &w3);
        assert_eq!(imp.shape, vec![4, 5]);
        for j in 0..5 {
            assert!(imp.data[5 + j] >= imp.data[j].abs() - 1e-5);
            assert!(imp.data[15 + j] >= imp.data[10 + j].abs() - 1e-5);
        }
    }

    #[test]
    fn prefill_last_row_matches_step_on_same_cache() {
        // Decode consistency: running S tokens through prefill equals
        // prefilling S-1 and stepping the last token over that cache.
        let mut rng = SplitMix64::new(4);
        let (s, d, h, dh, t_max) = (5usize, 8usize, 2usize, 4usize, 9usize);
        let x = randn(&mut rng, vec![s, d], 0.5);
        let ln1 = Tensor::new(vec![d], vec![1.0; d]);
        let ln2 = Tensor::new(vec![d], vec![1.0; d]);
        let wq = randn(&mut rng, vec![d, d], 0.3);
        let wk = randn(&mut rng, vec![d, d], 0.3);
        let wv = randn(&mut rng, vec![d, d], 0.3);
        let wo = randn(&mut rng, vec![d, d], 0.3);
        let full = op_attn_prefill(&x, &ln1, &wq, &wk, &wv, &wo, &ln2, h, dh).unwrap();
        let part = op_attn_prefill(
            &x.row_slice(0, s - 1),
            &ln1,
            &wq,
            &wk,
            &wv,
            &wo,
            &ln2,
            h,
            dh,
        )
        .unwrap();
        // pack part's K/V ([S-1, H, dh]) into a [1, H, T, dh] cache
        let mut kc = vec![0.0f32; h * t_max * dh];
        let mut vc = vec![0.0f32; h * t_max * dh];
        for ti in 0..s - 1 {
            for hi in 0..h {
                for e in 0..dh {
                    kc[(hi * t_max + ti) * dh + e] = part[2].data[(ti * h + hi) * dh + e];
                    vc[(hi * t_max + ti) * dh + e] = part[3].data[(ti * h + hi) * dh + e];
                }
            }
        }
        let last = x.row_slice(s - 1, s);
        let step = op_attn_step(
            &last,
            &ln1,
            &wq,
            &wk,
            &wv,
            &wo,
            &ln2,
            &Tensor::new(vec![1, h, t_max, dh], kc),
            &Tensor::new(vec![1, h, t_max, dh], vc),
            &[(s - 1) as i32],
        )
        .unwrap();
        for e in 0..d {
            let want = full[0].data[(s - 1) * d + e];
            let got = step[0].data[e];
            assert!((want - got).abs() < 1e-5, "y[{e}]: {want} vs {got}");
        }
    }

    #[test]
    fn unknown_artifact_is_an_error() {
        let be = CpuRef::new();
        assert!(be.exec("mystery_kernel", &[]).is_err());
    }

    #[test]
    fn counters_track_named_execs() {
        let be = CpuRef::new();
        let x = Tensor::zeros(vec![1, 2]);
        let wg = Tensor::zeros(vec![2, 2]);
        be.exec("gate_b1_e2", &[Arg::F32(&x), Arg::F32(&wg)]).unwrap();
        be.exec("gate_b1_e2", &[Arg::F32(&x), Arg::F32(&wg)]).unwrap();
        assert_eq!(be.exec_counts()["gate_b1_e2"].0, 2);
        assert_eq!(be.compiled_count(), 1);
        be.reset_counters();
        assert!(be.exec_counts().is_empty());
        // compiled_count mirrors PJRT's executable cache: it survives
        // counter resets.
        assert_eq!(be.compiled_count(), 1);
    }
}
