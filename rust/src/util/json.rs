//! Minimal JSON parser/emitter (serde is not in the offline vendor set).
//!
//! Supports the full JSON grammar we produce: objects, arrays, strings
//! with standard escapes, f64 numbers, bools, null. Used for model
//! manifests, golden vectors, and experiment result files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    /// Flatten a numeric array (arbitrary nesting is not needed — one level).
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        Ok(self
            .as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect::<Result<Vec<_>>>()?)
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        Ok(self
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<Vec<_>>>()?)
    }

    // An inherent `to_string` (not Display) is deliberate: this is the
    // only serialization entry point and a Display impl would invite
    // formatting-machinery overhead on large tensors.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for result emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Reassemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for t in ["null", "true", "false", "3.5", "-2", "\"hi\""] {
            let v = Json::parse(t).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_float_arrays() {
        let v = Json::parse("[1.5e-3, -2.25, 0]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![0.0015, -2.25, 0.0]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn emit_escapes() {
        let v = Json::Str("a\"b\\c\n".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse("\"caf\\u00e9 — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café — ok");
    }
}
