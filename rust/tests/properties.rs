//! Property-based tests (seeded SplitMix64 fuzzing — proptest is not in
//! the offline vendor set) over the coordinator invariants: routing,
//! drop policies, dispatch planning, load-aware thresholding, capacity
//! bucketing, paged KV-cache allocation, and the comm model.

#![allow(clippy::needless_range_loop, clippy::manual_memcpy, clippy::type_complexity)]

use dualsparse::commsim::{etp_time, setp_time, Topology};
use dualsparse::engine::kv::KvCache;
use dualsparse::engine::{EpOptions, EpSim};
use dualsparse::moe::{
    plan_dispatch, remap_indices, route_token, DropPolicy, TokenRouting,
};
use dualsparse::util::rng::SplitMix64;
use dualsparse::util::round_up_bucket;

fn random_scores(rng: &mut SplitMix64, n: usize) -> Vec<f32> {
    // random logits → softmax
    let logits: Vec<f64> = (0..n).map(|_| rng.f64() * 6.0 - 3.0).collect();
    let m = logits.iter().cloned().fold(f64::MIN, f64::max);
    let exps: Vec<f64> = logits.iter().map(|l| (l - m).exp()).collect();
    let s: f64 = exps.iter().sum();
    exps.iter().map(|e| (e / s) as f32).collect()
}

#[test]
fn routing_invariants_fuzz() {
    let mut rng = SplitMix64::new(0xA11CE);
    for _ in 0..500 {
        let e = 2 + rng.below(30);
        let k = 1 + rng.below(e.min(8));
        let scores = random_scores(&mut rng, e);
        let r = route_token(&scores, k, false);
        assert_eq!(r.experts.len(), k);
        // descending original scores, normalized sums to 1
        for w in r.experts.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
        let norm_sum: f32 = r.experts.iter().map(|(_, _, n)| n).sum();
        assert!((norm_sum - 1.0).abs() < 1e-4);
        // normalized >= original (sum of selected <= 1)
        for &(_, s, n) in &r.experts {
            assert!(n >= s - 1e-6);
        }
        // distinct expert indices
        let mut idx: Vec<usize> = r.experts.iter().map(|(e, _, _)| *e).collect();
        idx.sort();
        idx.dedup();
        assert_eq!(idx.len(), k);
    }
}

#[test]
fn drop_rate_monotone_in_threshold_fuzz() {
    let mut rng = SplitMix64::new(0xB0B);
    for _ in 0..100 {
        let routings: Vec<TokenRouting> = (0..20)
            .map(|_| route_token(&random_scores(&mut rng, 8), 2, false))
            .collect();
        let mut last_rate = -1.0;
        for t in [0.0f32, 0.1, 0.2, 0.3, 0.5, 0.8] {
            let plan = plan_dispatch(&routings, 8, DropPolicy::OneT(t), None);
            let rate = plan.stats.drop_rate();
            assert!(
                rate >= last_rate - 1e-12,
                "drop rate must be monotone in T (t={t}, {rate} < {last_rate})"
            );
            last_rate = rate;
        }
    }
}

#[test]
fn two_t_never_drops_more_than_matched_one_t_fuzz() {
    // 2T with (T-δ, T+δ) keeps at least the major half wherever 1T@T
    // would have dropped in [T-δ, T): compute fraction dropped must be
    // within ±(half the band) of 1T.
    let mut rng = SplitMix64::new(0xC0FFEE);
    for _ in 0..100 {
        let routings: Vec<TokenRouting> = (0..40)
            .map(|_| route_token(&random_scores(&mut rng, 16), 4, false))
            .collect();
        let t = 0.05 + (rng.f64() as f32) * 0.3;
        let one = plan_dispatch(&routings, 16, DropPolicy::OneT(t), None);
        let two = plan_dispatch(&routings, 16, DropPolicy::two_t(t), None);
        // every pair fully dropped by 2T would also be dropped by 1T
        assert!(two.stats.dropped <= one.stats.dropped);
        // and 2T's extra kept compute is only ever half-experts
        assert_eq!(
            two.stats.total(),
            one.stats.total(),
            "same pair universe"
        );
    }
}

#[test]
fn load_aware_scaling_invariants_fuzz() {
    // §4.3: lighter devices get proportionally lower thresholds; a
    // device at or above ideal load keeps the maximum threshold.
    let mut rng = SplitMix64::new(0xD00D);
    for _ in 0..200 {
        let t = 0.05 + (rng.f64() as f32) * 0.4;
        let max_pol = DropPolicy::OneT(t);
        let heavy = max_pol.scaled(1.0 + rng.f64() as f32);
        assert_eq!(heavy, max_pol);
        let r1 = rng.f64() as f32;
        let r2 = (rng.f64() as f32).min(r1);
        let (DropPolicy::OneT(t1), DropPolicy::OneT(t2)) =
            (max_pol.scaled(r1), max_pol.scaled(r2))
        else {
            panic!()
        };
        assert!(t2 <= t1 + 1e-7, "lighter load ⇒ lower threshold");
    }
}

#[test]
fn load_aware_reduces_makespan_bound_fuzz() {
    // The step-down rule never drops *less* on the heaviest device than
    // the uniform policy, so the post-drop max load cannot exceed the
    // uniform policy's max load.
    let mut rng = SplitMix64::new(0xFEED);
    for _ in 0..50 {
        let n_dev = 4;
        let routings: Vec<TokenRouting> = (0..64)
            .map(|_| route_token(&random_scores(&mut rng, 8), 2, false))
            .collect();
        let placement: Vec<usize> = (0..8).map(|e| e % n_dev).collect();
        let mut load = vec![0u64; n_dev];
        for r in &routings {
            for &(e, _, _) in &r.experts {
                load[placement[e]] += 1;
            }
        }
        let total: u64 = load.iter().sum();
        let ideal = total as f32 / n_dev as f32;
        let t = 0.3f32;
        let pol = DropPolicy::OneT(t);
        let policies: Vec<DropPolicy> =
            load.iter().map(|&l| pol.scaled(l as f32 / ideal)).collect();
        let f = |_row: usize, e: usize| policies[placement[e]];
        let aware = plan_dispatch(&routings, 8, pol, Some(&f));
        let uniform = plan_dispatch(&routings, 8, pol, None);
        // total kept compute: aware keeps at least as much (higher acc)
        assert!(aware.kept_pairs() >= uniform.kept_pairs());
        // heaviest-device kept load under aware <= uniform's on that device
        let kept_per_dev = |plan: &dualsparse::moe::DispatchPlan| {
            let mut kept = vec![0u64; n_dev];
            for e in 0..8 {
                kept[placement[e]] +=
                    (plan.full[e].len() + plan.major_only[e].len()) as u64;
            }
            kept
        };
        let ka = kept_per_dev(&aware);
        let ku = kept_per_dev(&uniform);
        let heaviest = (0..n_dev).max_by_key(|&d| load[d]).unwrap();
        assert!(ka[heaviest] <= ku[heaviest] + 0);
    }
}

#[test]
fn ep_assignment_conserves_routed_pairs_fuzz() {
    // Every routed (token, expert) pair lands on exactly one worker:
    // Σ per-worker routed load == total routed pairs, and the flat
    // `(row, expert, worker)` assignment agrees with the per-worker
    // tallies — at any worker count, load-aware on or off.
    let mut rng = SplitMix64::new(0xE9001);
    for _ in 0..200 {
        let n_experts = 2 + rng.below(15);
        let k = 1 + rng.below(n_experts.min(4));
        let workers = 1 + rng.below(8);
        let aware = rng.below(2) == 1;
        let routings: Vec<TokenRouting> = (0..(1 + rng.below(30)))
            .map(|_| route_token(&random_scores(&mut rng, n_experts), k, false))
            .collect();
        let total: u64 = routings.iter().map(|r| r.experts.len() as u64).sum();
        let sim = EpSim::new(EpOptions::new(workers, aware), n_experts);
        let inv = sim.observe(&routings, DropPolicy::OneT(0.2));
        assert_eq!(inv.routed.len(), workers);
        assert_eq!(inv.routed.iter().sum::<u64>(), total, "pair conservation");
        let mut per_worker = vec![0u64; workers];
        for &(_, _, w) in &inv.pairs {
            per_worker[w] += 1;
        }
        assert_eq!(per_worker, inv.routed, "flat assignment matches the tallies");
    }
}

#[test]
fn ep_load_aware_never_raises_thresholds_fuzz() {
    // §4.3 cap: every worker's scaled policy keeps its thresholds at or
    // below the configured maximum, the routed-hottest worker keeps
    // exactly the base policy, and 2T bands stay ordered after scaling.
    let mut rng = SplitMix64::new(0xE9002);
    for _ in 0..200 {
        let n_experts = 4 + rng.below(12);
        let workers = 2 + rng.below(7);
        let routings: Vec<TokenRouting> = (0..(4 + rng.below(30)))
            .map(|_| route_token(&random_scores(&mut rng, n_experts), 2, false))
            .collect();
        let t = 0.05 + (rng.f64() as f32) * 0.5;
        let base = if rng.below(2) == 0 {
            DropPolicy::OneT(t)
        } else {
            DropPolicy::two_t(t)
        };
        let sim = EpSim::new(EpOptions::new(workers, true), n_experts);
        let inv = sim.observe(&routings, base);
        let pols = sim.policies(&inv, base).expect("routed load is nonzero");
        let bands = |p: DropPolicy| -> (f32, f32) {
            match p {
                DropPolicy::NoDrop => (0.0, 0.0),
                DropPolicy::OneT(t) => (t, t),
                DropPolicy::TwoT { major, minor } => (major, minor),
            }
        };
        let (b_lo, b_hi) = bands(base);
        let hot = (0..workers)
            .max_by_key(|&w| (inv.routed[w], std::cmp::Reverse(w)))
            .unwrap();
        assert_eq!(pols[hot], base, "hottest worker keeps the configured maximum");
        for (w, &p) in pols.iter().enumerate() {
            let (lo, hi) = bands(p);
            assert!(
                lo <= b_lo + 1e-7 && hi <= b_hi + 1e-7,
                "worker {w} raised a threshold above the configured maximum"
            );
            assert!(lo <= hi + 1e-7, "scaling must keep 2T bands ordered");
        }
    }
}

#[test]
fn remap_indices_partition_properties_fuzz() {
    let mut rng = SplitMix64::new(0x1234);
    for _ in 0..200 {
        let e = 4 + rng.below(28);
        let k = 1 + rng.below(4);
        let p = [2, 4][rng.below(2)];
        let mut idx: Vec<usize> = (0..e).collect();
        for i in (1..idx.len()).rev() {
            let j = rng.below(i + 1);
            idx.swap(i, j);
        }
        idx.truncate(k);
        let remapped = remap_indices(&idx, p);
        assert_eq!(remapped.len(), k * p);
        // all sub-experts of each original expert present exactly once
        for &i in &idx {
            for pi in 0..p {
                assert_eq!(
                    remapped.iter().filter(|&&x| x == i * p + pi).count(),
                    1
                );
            }
        }
        // all within range
        assert!(remapped.iter().all(|&x| x < e * p));
    }
}

#[test]
fn bucket_rounding_fuzz() {
    let buckets = [4usize, 8, 16, 32, 64, 128];
    let mut rng = SplitMix64::new(0x9999);
    for _ in 0..1000 {
        let n = 1 + rng.below(128);
        let b = round_up_bucket(n, &buckets);
        assert!(b >= n);
        assert!(buckets.contains(&b));
        // tight: the next smaller bucket (if any) is < n
        if let Some(&smaller) = buckets.iter().rev().find(|&&x| x < b) {
            assert!(smaller < n);
        }
    }
}

#[test]
fn kv_paged_alloc_free_fuzz() {
    // Free-list conservation under a fuzzed alloc / grow / evict
    // schedule (the preemption path is one `free(seq)`): every page is
    // either on the free list or mapped by exactly one live sequence,
    // a refused all-or-nothing grant changes nothing, and nothing ever
    // double-frees or leaks a page.
    let mut rng = SplitMix64::new(0x5EED);
    for round in 0..50 {
        let page_size = 1 + rng.below(5);
        let n_pages = 4 + rng.below(13);
        let max_seq = page_size * n_pages.min(8);
        let mut kv = KvCache::new(2, 2, max_seq, 4, 6, page_size, n_pages);
        let mut live: Vec<usize> = Vec::new();
        for _ in 0..300 {
            match rng.below(3) {
                0 if kv.has_free() => {
                    let s = kv.alloc();
                    assert!(!live.contains(&s), "sequence id {s} handed out twice");
                    assert_eq!(kv.seq_pages(s).len(), 0, "fresh sequences own no pages");
                    live.push(s);
                }
                1 if !live.is_empty() => {
                    // Grow a random live sequence by a random amount.
                    let s = live[rng.below(live.len())];
                    if kv.pos[s] >= max_seq {
                        continue; // window exhausted; only free can help
                    }
                    let upto = (kv.pos[s] + 1 + rng.below(2 * page_size)).min(max_seq);
                    let before = (kv.free_page_count(), kv.seq_pages(s).len());
                    if kv.ensure(s, upto) {
                        assert!(kv.seq_capacity(s) >= upto);
                        // write one token so pos advances into the grant
                        let k = vec![1.0f32; 8];
                        kv.append(0, s, &k, &k);
                        kv.append(1, s, &k, &k);
                    } else {
                        assert_eq!(
                            (kv.free_page_count(), kv.seq_pages(s).len()),
                            before,
                            "a refused grant must not partially allocate"
                        );
                    }
                }
                _ if !live.is_empty() => {
                    // Evict a random victim: pages return immediately.
                    let s = live.swap_remove(rng.below(live.len()));
                    let mapped = kv.seq_pages(s).len();
                    let free_before = kv.free_page_count();
                    kv.free(s);
                    assert_eq!(kv.free_page_count(), free_before + mapped);
                    assert_eq!(kv.seq_pages(s).len(), 0);
                }
                _ => {}
            }
            // Conservation: free + mapped == pool, no page mapped twice.
            let mut seen = vec![false; n_pages];
            let mut mapped = 0usize;
            for &s in &live {
                for &p in kv.seq_pages(s) {
                    assert!(!seen[p], "page {p} mapped twice (round {round})");
                    seen[p] = true;
                    mapped += 1;
                }
            }
            assert_eq!(kv.free_page_count() + mapped, n_pages, "page leak (round {round})");
            assert_eq!(kv.n_active, live.len());
        }
        for &s in &live {
            kv.free(s);
        }
        assert_eq!(kv.free_page_count(), n_pages, "drain must restore the full pool");
        assert_eq!(kv.n_active, 0);
    }
}

#[test]
fn commsim_monotonicity_fuzz() {
    let mut rng = SplitMix64::new(0x7070);
    let topos = [Topology::h20_node(), Topology::nvl72(), Topology::cm384()];
    for _ in 0..200 {
        let t = &topos[rng.below(3)];
        let tp = [2usize, 4, 8][rng.below(3)];
        let max_ep = t.world / tp;
        if max_ep < 2 {
            continue;
        }
        let ep = 2 + rng.below(max_ep - 1); // 2 ..= max_ep
        let s1 = 1024.0 * (1.0 + rng.f64() * 1e4);
        let s2 = s1 * (1.0 + rng.f64() * 4.0);
        // time monotone in bytes
        assert!(etp_time(t, ep, tp, s2) >= etp_time(t, ep, tp, s1));
        assert!(setp_time(t, ep, tp, s2) >= setp_time(t, ep, tp, s1));
        // both strictly positive
        assert!(setp_time(t, ep, tp, s1) > 0.0);
    }
}

#[test]
fn fault_plan_is_deterministic_and_conserves_requests() {
    // ISSUE-8 satellite: across 50 random fault plans, the same seed
    // replays the identical run (texts and counters), the five-way
    // terminal partition (Done ∪ Rejected ∪ Failed ∪ TimedOut ∪
    // Cancelled) covers every request exactly once, and the KV page
    // pool drains back to its full size after every chaos run.
    use dualsparse::engine::faults::{FaultPlan, FaultSpec};
    use dualsparse::engine::policy::Fcfs;
    use dualsparse::engine::scheduler::{serve_opts, ArrivalMode, SchedOptions};
    use dualsparse::engine::{Engine, EngineOptions};
    use dualsparse::server::workload;
    use std::path::PathBuf;

    let artifacts = std::env::var("DUALSPARSE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    let mut e =
        Engine::new(&artifacts, "mixtral_ish", DropPolicy::NoDrop, EngineOptions::default())
            .expect("hermetic engine (CpuRef + synthetic weights)");
    let reqs = workload(6, 3, 7);
    let mut rng = SplitMix64::new(0xFA017);
    for round in 0..50 {
        let spec = FaultSpec {
            exec_p: if rng.below(2) == 0 { rng.f64() * 0.6 } else { 0.0 },
            spike_p: if rng.below(2) == 0 { rng.f64() * 0.3 } else { 0.0 },
            spike_ms: 1.0,
            pressure_p: if rng.below(2) == 0 { rng.f64() * 0.4 } else { 0.0 },
            pressure_pages: 1 + rng.below(6),
            pressure_hold: 1 + rng.below(4) as u64,
            ep_fail: None,
            ep_slow: None,
            cancel_p: if rng.below(3) == 0 { rng.f64() * 0.5 } else { 0.0 },
        };
        let seed = rng.next_u64();
        let run = |e: &mut Engine| {
            serve_opts(
                e,
                &reqs,
                ArrivalMode::Closed,
                &Fcfs,
                SchedOptions {
                    faults: Some(FaultPlan::new(spec, seed)),
                    max_retries: 2,
                    ..Default::default()
                },
            )
            .expect("injected faults must never abort the run")
        };
        let a = run(&mut e);
        let b = run(&mut e);
        // Same seed ⇒ identical resolution (closed mode: wall-clock
        // never reaches a scheduling or injection decision).
        assert_eq!(
            (a.stats.requests, a.stats.rejected, a.stats.failed, a.stats.cancelled),
            (b.stats.requests, b.stats.rejected, b.stats.failed, b.stats.cancelled),
            "round {round}: same seed must replay the same resolution"
        );
        assert_eq!(a.stats.retries, b.stats.retries, "round {round}: retry counts");
        assert_eq!(
            a.stats.faults_injected, b.stats.faults_injected,
            "round {round}: injection counts"
        );
        assert_eq!(a.completions.len(), b.completions.len());
        for (x, y) in a.completions.iter().zip(&b.completions) {
            assert_eq!((x.id, &x.text), (y.id, &y.text), "round {round}: texts diverged");
        }
        // Five-way exactly-once + page-pool conservation.
        let mut seen = vec![0usize; reqs.len()];
        for c in &a.completions {
            seen[c.id] += 1;
        }
        for r in &a.rejections {
            seen[r.id] += 1;
        }
        for c in &a.casualties {
            seen[c.id] += 1;
        }
        assert!(seen.iter().all(|&k| k == 1), "round {round}: exactly-once broken: {seen:?}");
        assert_eq!(a.stats.timed_out, 0, "no deadline configured in this fuzz");
        assert_eq!(e.kv.free_page_count(), e.kv.n_pages, "round {round}: leaked pages");
        assert_eq!(e.kv.n_active, 0, "round {round}: leaked sequences");
    }
    // Exec-only plans under an unbounded retry budget: every injected
    // transient error is answered by exactly one retry, so the counters
    // must agree and nothing ever fails.
    for round in 0..20 {
        let spec = FaultSpec { exec_p: rng.f64() * 0.8, ..Default::default() };
        let out = serve_opts(
            &mut e,
            &reqs,
            ArrivalMode::Closed,
            &Fcfs,
            SchedOptions {
                faults: Some(FaultPlan::new(spec, rng.next_u64())),
                max_retries: u32::MAX,
                ..Default::default()
            },
        )
        .expect("retried faults must never abort the run");
        assert_eq!(
            out.stats.retries, out.stats.faults_injected,
            "round {round}: retry count == injected transient errors"
        );
        assert_eq!(out.stats.failed, 0, "an unbounded budget never exhausts");
        assert_eq!(out.completions.len(), reqs.len(), "round {round}: everything completes");
        assert_eq!(e.kv.free_page_count(), e.kv.n_pages, "round {round}: leaked pages");
    }
}

#[test]
fn neuron_keep_mask_is_monotone_nested_and_deterministic_fuzz() {
    // ISSUE-10 satellite: for any variant and importance profile,
    // keep masks are *prefixes of one fixed permutation* — so for
    // 1.0 ≥ p1 > p2, kept(p2) is literally a prefix of kept(p1)
    // (nesting is structural, not statistical), the mask size is
    // exactly `keep_count`, and repeated evaluation is bit-identical
    // (keep_mask is a pure function of (cols, importance, keep); no
    // thread count, hash order or clock can reach it).
    use dualsparse::calib::keep_count;
    use dualsparse::moe::partition::keep_mask;

    let mut rng = SplitMix64::new(0x2ee9);
    for case in 0..300 {
        let full_width = 4 + rng.below(60);
        let width = 1 + rng.below(full_width);
        // variant cols: a random distinct subset of the full width,
        // in random order (sub-experts after partition are gathers).
        let mut pool: Vec<usize> = (0..full_width).collect();
        for i in (1..pool.len()).rev() {
            pool.swap(i, rng.below(i + 1));
        }
        let cols = pool[..width].to_vec();
        // importance with deliberate collisions (quantized to few
        // levels) and an occasional NaN — ties and NaN must order
        // deterministically, not panic.
        let mut importance: Vec<f32> =
            (0..full_width).map(|_| (rng.below(5) as f32) * 0.25).collect();
        if case % 7 == 0 {
            importance[rng.below(full_width)] = f32::NAN;
        }
        let ladder = [1.0f32, 0.9, 0.75, 0.5, 0.25, 0.1, 0.0];
        let mut prev: Option<Vec<i32>> = None;
        for &keep in &ladder {
            let m = keep_mask(&cols, &importance, keep);
            assert_eq!(m.len(), keep_count(width, keep), "case {case}: mask size");
            for &j in &m {
                assert!((j as usize) < width, "case {case}: variant-local index");
            }
            let again = keep_mask(&cols, &importance, keep);
            assert_eq!(m, again, "case {case}: keep_mask must be deterministic");
            if let Some(p) = &prev {
                assert_eq!(
                    &p[..m.len()],
                    &m[..],
                    "case {case}: kept({keep}) must be a prefix of the larger mask"
                );
            }
            prev = Some(m);
        }
    }
}

#[test]
fn neuron_keep_strictly_reduces_measured_ffn_madds() {
    // ISSUE-10 satellite, engine level: walking keep down the ladder
    // must strictly shrink the *measured* FFN multiply-add count,
    // derived from the executed artifact names (`ffn_h{H}_c{C}` ⇒
    // 3·d·H·C per exec, `ffn_mask_h{H}k{K}_c{C}` ⇒ 3·d·K·C — the
    // masked kernel gathers K columns and runs dense at width K).
    // Bucket slack can shift C a little when masking perturbs later
    // layers' routing, but the K reduction dominates by construction.
    use dualsparse::calib::run_calibration;
    use dualsparse::engine::{Engine, EngineOptions};
    use std::collections::HashMap;
    use std::path::PathBuf;

    fn ffn_madds(stats: &HashMap<String, (u64, f64)>, d: usize) -> u128 {
        let mut total = 0u128;
        for (name, &(count, _)) in stats {
            let Some(rest) = name.strip_prefix("ffn_") else { continue };
            let rest = rest.strip_prefix("q8_").unwrap_or(rest);
            let (k, c) = if let Some(r) = rest.strip_prefix("mask_h") {
                let (hk, c) = r.split_once("_c").expect("mask artifact name");
                let (_h, k) = hk.split_once('k').expect("mask artifact name");
                (k.parse::<u128>().unwrap(), c.parse::<u128>().unwrap())
            } else if let Some(r) = rest.strip_prefix('h') {
                let (h, c) = r.split_once("_c").expect("ffn artifact name");
                (h.parse::<u128>().unwrap(), c.parse::<u128>().unwrap())
            } else {
                panic!("unrecognized ffn artifact {name:?}");
            };
            total += 3 * d as u128 * k * c * count as u128;
        }
        total
    }

    let artifacts = std::env::var("DUALSPARSE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    let mut cal =
        Engine::new(&artifacts, "mixtral_ish", DropPolicy::NoDrop, EngineOptions::default())
            .expect("hermetic engine");
    let imp = run_calibration(&mut cal, 256).expect("calibration").importance("abs_gate");

    let mut last = u128::MAX;
    for keep in [1.0f32, 0.5, 0.25] {
        let mut e = Engine::new(
            &artifacts,
            "mixtral_ish",
            DropPolicy::NoDrop,
            EngineOptions {
                neuron_keep: Some(keep),
                importance: Some(imp.clone()),
                ..Default::default()
            },
        )
        .expect("hermetic engine");
        let slot = e.kv.alloc();
        e.prefill_logits(slot, b"cpy:abcdefgh|").expect("prefill");
        let madds = ffn_madds(&e.exec_stats(), e.cfg.d_model);
        assert!(madds > 0, "keep {keep}: prefill must execute FFN artifacts");
        assert!(
            madds < last,
            "keep {keep}: measured madds must strictly decrease ({madds} vs {last})"
        );
        last = madds;
    }
}
