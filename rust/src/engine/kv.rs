//! Paged KV-cache manager: page-granularity storage for continuous
//! batching with preemption (vLLM-style paging).
//!
//! Layout: one page pool per layer, `[N_PAGES, H, P, dh]` — a page
//! holds `P` consecutive logical positions for every head of one
//! sequence, head-major within the page so each head's positions form
//! one contiguous run. A sequence owns an ordered *page table*
//! (`tables[seq]`) shared across layers: logical position `t` of layer
//! `li` lives in physical page `tables[seq][t / P]` of layer `li`'s
//! pool, at row `t % P`. The engine lends per-page slices to the
//! attention kernels (`Arg::F32Pages`), so the decode hot path still
//! never clones the cache; a gather happens only when a backend needs
//! contiguous memory (PJRT upload).
//!
//! Allocation is a free-list of page indices: `alloc` claims a sequence
//! id (lowest free, deterministic), `ensure` grants pages all-or-nothing
//! as the sequence grows, and `free` returns every page immediately —
//! which is what makes preemption cheap: evicting a victim is one
//! `free(seq)`, and re-admission recomputes from the prompt. There is
//! no slot compaction anymore; sequence ids are stable for a request's
//! whole residency.
//!
//! Writers all append behind `pos[seq]`'s invariant (tokens cached ==
//! next write position):
//!
//! * [`PagedKvCache::write_prefill`] — bulk chunk write at an explicit
//!   `base`; chunked prefill calls it once per chunk so a long prompt's
//!   positions land exactly where a single-pass prefill would put them.
//! * [`PagedKvCache::append`] — one decode-step (k, v) head-vector set.
//! * [`PagedKvCache::reset`] / [`PagedKvCache::alloc`] — recycling
//!   between runs; `ensure` re-zeroes pages on grant so a stale
//!   sequence can never widen a later request's attention window.
//!
//! With `page_size >= max_seq` every sequence occupies exactly one page
//! whose interior layout `[H, max_seq, dh]` is byte-identical to the
//! old slot-granularity cache — the basis of the paged-vs-slot pin in
//! `rust/tests/scheduler.rs`.

use crate::model::Tensor;

/// Default positions per page. Small enough that a retiring request
/// frees capacity in fine grains, large enough that per-page slice
/// bookkeeping stays cheap.
pub const DEFAULT_PAGE_SIZE: usize = 16;

/// Backwards-compatible name: the paged cache replaced the slot cache
/// in place.
pub type KvCache = PagedKvCache;

pub struct PagedKvCache {
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_seq: usize,
    pub d_head: usize,
    /// Maximum concurrently live sequences (decode batch width bound).
    pub max_seqs: usize,
    /// Positions per page.
    pub page_size: usize,
    /// Total physical pages per layer pool.
    pub n_pages: usize,
    /// Per-layer K / V page pools, shape [N_PAGES, H, P, dh].
    pub k: Vec<Tensor>,
    pub v: Vec<Tensor>,
    /// Tokens cached per sequence (== next write position).
    pub pos: Vec<usize>,
    /// Live sequences (ids are stable — no compaction).
    pub n_active: usize,
    /// Free physical page indices (stack; popped in ascending order
    /// from a fresh reset, so allocation is deterministic).
    free_list: Vec<usize>,
    /// Per-sequence page tables, shared across layers: logical position
    /// `t` lives in physical page `tables[seq][t / page_size]`.
    tables: Vec<Vec<usize>>,
    live: Vec<bool>,
    /// Pages withheld from the free list by injected page-pool pressure
    /// (`engine::faults`): physically absent from `free_list` until
    /// [`Self::release_sequestered`] returns them.
    sequestered: Vec<usize>,
}

impl PagedKvCache {
    pub fn new(n_layers: usize, n_heads: usize, max_seq: usize, d_head: usize,
               max_seqs: usize, page_size: usize, n_pages: usize) -> Self {
        assert!(page_size > 0, "page_size must be positive");
        assert!(n_pages > 0, "page budget must be positive");
        let shape = vec![n_pages, n_heads, page_size, d_head];
        PagedKvCache {
            n_layers,
            n_heads,
            max_seq,
            d_head,
            max_seqs,
            page_size,
            n_pages,
            k: (0..n_layers).map(|_| Tensor::zeros(shape.clone())).collect(),
            v: (0..n_layers).map(|_| Tensor::zeros(shape.clone())).collect(),
            pos: vec![0; max_seqs],
            n_active: 0,
            free_list: (0..n_pages).rev().collect(),
            tables: vec![Vec::new(); max_seqs],
            live: vec![false; max_seqs],
            sequestered: Vec::new(),
        }
    }

    /// Floats per page per layer (`H · P · dh`) — the stride of the
    /// zero-copy per-page views the engine feeds to attention kernels.
    pub fn page_stride(&self) -> usize {
        self.n_heads * self.page_size * self.d_head
    }

    /// Pages needed to hold `positions` logical positions.
    pub fn pages_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.page_size)
    }

    /// Physical pages currently on the free list.
    pub fn free_page_count(&self) -> usize {
        self.free_list.len()
    }

    /// Physical pages currently mapped by live sequences (sequestered
    /// pages are neither free nor mapped).
    pub fn pages_in_use(&self) -> usize {
        self.n_pages - self.free_list.len() - self.sequestered.len()
    }

    /// Fraction of the page pool currently mapped.
    pub fn utilization(&self) -> f64 {
        self.pages_in_use() as f64 / self.n_pages as f64
    }

    /// Whether a sequence id is free (page availability is checked
    /// separately — admission is page-budget-aware).
    pub fn has_free(&self) -> bool {
        self.n_active < self.max_seqs
    }

    /// Claim the lowest free sequence id. The sequence starts with an
    /// empty page table; call [`Self::ensure`] (or let the engine's
    /// prefill/decode paths do it) before writing. Panics if all ids
    /// are taken (the scheduler checks `has_free` first).
    pub fn alloc(&mut self) -> usize {
        let seq = (0..self.max_seqs)
            .find(|&s| !self.live[s])
            .expect("KV cache full");
        self.live[seq] = true;
        self.pos[seq] = 0;
        debug_assert!(self.tables[seq].is_empty());
        self.n_active += 1;
        seq
    }

    /// Grow `seq`'s page table to cover positions `0..upto`. Grants are
    /// all-or-nothing: returns `false` (state unchanged) when the free
    /// list cannot supply every needed page. Newly granted pages are
    /// zeroed in every layer so recycled pages never leak stale K/V.
    pub fn ensure(&mut self, seq: usize, upto: usize) -> bool {
        debug_assert!(self.live[seq], "ensure on a dead sequence {seq}");
        debug_assert!(upto <= self.max_seq, "sequence overflow: {upto} > {}", self.max_seq);
        let need = self.pages_for(upto);
        let have = self.tables[seq].len();
        if need <= have {
            return true;
        }
        if need - have > self.free_list.len() {
            return false;
        }
        let stride = self.page_stride();
        for _ in have..need {
            let page = self.free_list.pop().expect("free list underflow");
            for li in 0..self.n_layers {
                self.k[li].data[page * stride..(page + 1) * stride].fill(0.0);
                self.v[li].data[page * stride..(page + 1) * stride].fill(0.0);
            }
            self.tables[seq].push(page);
        }
        true
    }

    /// Retire `seq`: every page returns to the free list immediately
    /// (pushed in reverse mapping order, so a fresh allocation after a
    /// lone free reuses the same pages in the same order). Sequence ids
    /// are stable — nothing moves.
    pub fn free(&mut self, seq: usize) {
        assert!(self.live[seq], "double free of sequence {seq}");
        while let Some(page) = self.tables[seq].pop() {
            self.free_list.push(page);
        }
        self.pos[seq] = 0;
        self.live[seq] = false;
        self.n_active -= 1;
    }

    /// Drop every live sequence and rebuild the free list (start of a
    /// fresh serving run). Deterministic: allocation order after a
    /// reset is identical run-to-run. Sequestered pages come back too.
    pub fn reset(&mut self) {
        for t in &mut self.tables {
            t.clear();
        }
        self.free_list = (0..self.n_pages).rev().collect();
        self.sequestered.clear();
        self.pos.fill(0);
        self.live.fill(false);
        self.n_active = 0;
    }

    /// Withhold up to `n` free pages from the pool — injected page-pool
    /// pressure (`engine::faults`). Pages are popped off the free list,
    /// so `ensure` and `free_page_count` genuinely see a smaller pool.
    /// Returns how many pages were actually taken; the caller must
    /// leave enough for outstanding conservative reservations.
    pub fn sequester_pages(&mut self, n: usize) -> usize {
        let take = n.min(self.free_list.len());
        for _ in 0..take {
            let page = self.free_list.pop().expect("free list underflow");
            self.sequestered.push(page);
        }
        take
    }

    /// Return every sequestered page to the free list (pressure over).
    /// Returns how many pages came back.
    pub fn release_sequestered(&mut self) -> usize {
        let n = self.sequestered.len();
        while let Some(page) = self.sequestered.pop() {
            self.free_list.push(page);
        }
        n
    }

    /// Pages currently withheld by [`Self::sequester_pages`].
    pub fn sequestered_count(&self) -> usize {
        self.sequestered.len()
    }

    /// `seq`'s page table: physical page ids in logical order. The
    /// engine maps these to per-page pool slices for the zero-copy
    /// attention views.
    pub fn seq_pages(&self, seq: usize) -> &[usize] {
        &self.tables[seq]
    }

    /// Positions `seq`'s page table can hold without another `ensure`.
    pub fn seq_capacity(&self, seq: usize) -> usize {
        self.tables[seq].len() * self.page_size
    }

    /// Write one new (k, v) head-vector set for `seq` at its current
    /// position and advance it. `new_k`/`new_v`: `[H, dh]` row-major.
    /// The caller must have `ensure`d the page (the engine does this
    /// once per decode step, before any layer writes).
    pub fn append(&mut self, layer: usize, seq: usize, new_k: &[f32], new_v: &[f32]) {
        let t = self.pos[seq];
        assert!(t < self.max_seq, "sequence overflow in seq {seq}");
        let (h, dh, p) = (self.n_heads, self.d_head, self.page_size);
        let page = self.tables[seq][t / p];
        let within = t % p;
        for hi in 0..h {
            let dst = ((page * h + hi) * p + within) * dh;
            let src = hi * dh;
            self.k[layer].data[dst..dst + dh].copy_from_slice(&new_k[src..src + dh]);
            self.v[layer].data[dst..dst + dh].copy_from_slice(&new_v[src..src + dh]);
        }
        if layer == self.n_layers - 1 {
            self.pos[seq] = t + 1;
        }
    }

    /// Bulk-write prefill K/V for `seq` at positions
    /// `base..base + s_len`: `ks`/`vs` are `[S, H, dh]` chunk-local.
    /// `base = 0` is a whole-prompt (or first-chunk) prefill; `base > 0`
    /// is a chunked-prefill continuation appending behind the positions
    /// already cached. Advances `pos[seq]` to `base + s_len` on the
    /// last layer, so after the final chunk the sequence's decode
    /// position is exactly the prompt length. The caller must have
    /// `ensure`d pages through `base + s_len`.
    pub fn write_prefill(&mut self, layer: usize, seq: usize, base: usize,
                         s_len: usize, ks: &[f32], vs: &[f32]) {
        debug_assert!(base + s_len <= self.max_seq, "prefill overflows the KV window");
        debug_assert!(base + s_len <= self.seq_capacity(seq), "prefill without ensure");
        let (h, dh, p) = (self.n_heads, self.d_head, self.page_size);
        for t in 0..s_len {
            let page = self.tables[seq][(base + t) / p];
            let within = (base + t) % p;
            for hi in 0..h {
                let dst = ((page * h + hi) * p + within) * dh;
                let src = (t * h + hi) * dh;
                self.k[layer].data[dst..dst + dh].copy_from_slice(&ks[src..src + dh]);
                self.v[layer].data[dst..dst + dh].copy_from_slice(&vs[src..src + dh]);
            }
        }
        if layer == self.n_layers - 1 {
            self.pos[seq] = base + s_len;
        }
    }

    /// Materialize `seq`'s layer-`layer` K and V in the old contiguous
    /// slot layout `[H, max_seq, dh]` (zeros past the mapped pages).
    /// Test/diagnostic helper — the hot path never gathers on CpuRef.
    pub fn gather_seq(&self, layer: usize, seq: usize) -> (Vec<f32>, Vec<f32>) {
        let (h, dh, p, tt) = (self.n_heads, self.d_head, self.page_size, self.max_seq);
        let mut gk = vec![0.0f32; h * tt * dh];
        let mut gv = vec![0.0f32; h * tt * dh];
        for (pi, &page) in self.tables[seq].iter().enumerate() {
            let t0 = pi * p;
            let run = p.min(tt.saturating_sub(t0));
            for hi in 0..h {
                for r in 0..run {
                    let src = ((page * h + hi) * p + r) * dh;
                    let dst = (hi * tt + t0 + r) * dh;
                    gk[dst..dst + dh].copy_from_slice(&self.k[layer].data[src..src + dh]);
                    gv[dst..dst + dh].copy_from_slice(&self.v[layer].data[src..src + dh]);
                }
            }
        }
        (gk, gv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 2 layers, 2 heads, window 8, dh 4, up to 3 seqs, page 4, 6 pages.
    fn cache() -> PagedKvCache {
        PagedKvCache::new(2, 2, 8, 4, 3, 4, 6)
    }

    fn conserved(c: &PagedKvCache) -> bool {
        let mapped: usize = (0..c.max_seqs).map(|s| c.seq_pages(s).len()).sum();
        c.free_page_count() + mapped == c.n_pages
    }

    #[test]
    fn alloc_returns_lowest_free_id_and_free_is_stable() {
        let mut c = cache();
        assert_eq!((c.alloc(), c.alloc(), c.alloc()), (0, 1, 2));
        assert!(!c.has_free());
        c.free(1);
        assert_eq!(c.n_active, 2);
        // ids are stable: seq 2 stays 2, the freed id is reused
        assert_eq!(c.alloc(), 1);
        assert!(conserved(&c));
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut c = cache();
        let s = c.alloc();
        c.free(s);
        c.free(s);
    }

    #[test]
    fn ensure_grants_all_or_nothing_and_frees_return_pages() {
        let mut c = cache();
        let a = c.alloc();
        let b = c.alloc();
        assert!(c.ensure(a, 8)); // 2 pages
        assert!(c.ensure(b, 8)); // 2 pages
        assert_eq!(c.free_page_count(), 2);
        let d = c.alloc();
        assert!(c.ensure(d, 8));
        assert_eq!(c.free_page_count(), 0);
        assert!(conserved(&c));
        c.free(b);
        assert_eq!(c.free_page_count(), 2);
        assert!(conserved(&c));
    }

    #[test]
    fn ensure_failure_leaves_state_unchanged() {
        let mut c = PagedKvCache::new(1, 2, 8, 4, 2, 4, 2);
        let a = c.alloc();
        let b = c.alloc();
        assert!(c.ensure(a, 8)); // both pages
        assert!(!c.ensure(b, 4), "no pages left");
        assert_eq!(c.seq_pages(b).len(), 0);
        assert_eq!(c.free_page_count(), 0);
        assert!(c.ensure(b, 0), "zero-page ensure is trivially satisfied");
        c.free(a);
        assert!(c.ensure(b, 4), "freed pages become grantable");
    }

    #[test]
    fn append_advances_on_last_layer_only() {
        let mut c = cache();
        let s = c.alloc();
        assert!(c.ensure(s, 1));
        let k = vec![1.0; 8];
        let v = vec![2.0; 8];
        c.append(0, s, &k, &v);
        assert_eq!(c.pos[s], 0); // not the last layer yet
        c.append(1, s, &k, &v);
        assert_eq!(c.pos[s], 1);
    }

    #[test]
    fn append_lands_in_page_layout() {
        let mut c = cache();
        let s = c.alloc();
        assert!(c.ensure(s, 1));
        let page = c.seq_pages(s)[0];
        let k: Vec<f32> = (0..8).map(|x| x as f32).collect();
        c.append(0, s, &k, &k);
        c.append(1, s, &k, &k);
        // head 1, position 0 of the page → ((page*2+1)*4+0)*4
        let off = ((page * 2 + 1) * 4) * 4;
        assert_eq!(c.k[0].data[off..off + 4], [4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn decode_appends_cross_page_boundaries() {
        let mut c = cache();
        let s = c.alloc();
        assert!(c.ensure(s, 8)); // window of 8 = two pages of 4
        for t in 0..8 {
            let k: Vec<f32> = (0..8).map(|i| (t * 10 + i) as f32).collect();
            c.append(0, s, &k, &k);
            c.append(1, s, &k, &k);
        }
        assert_eq!(c.pos[s], 8);
        let (gk, _) = c.gather_seq(0, s);
        // head 0, position 5 (page 1, row 1) must hold row 5's head-0 lane
        assert_eq!(gk[5 * 4..6 * 4], [50.0, 51.0, 52.0, 53.0]);
        // head 1, position 5
        assert_eq!(gk[(8 + 5) * 4..(8 + 6) * 4], [54.0, 55.0, 56.0, 57.0]);
    }

    #[test]
    fn chunked_prefill_continuation_appends_behind_base() {
        // Two chunks into one sequence must equal one whole-prompt
        // write: positions line up across a page boundary and pos ends
        // at the prompt length.
        let mut whole = cache();
        let mut chunked = cache();
        let sw = whole.alloc();
        let sc = chunked.alloc();
        assert!(whole.ensure(sw, 5));
        let (h, dh) = (2usize, 4usize);
        let kv_row = |t: usize| -> Vec<f32> {
            (0..h * dh).map(|i| (t * 100 + i) as f32).collect()
        };
        // 5-token prompt (crosses the page-4 boundary), rows [S, H, dh]
        let all: Vec<f32> = (0..5).flat_map(kv_row).collect();
        let head: Vec<f32> = (0..3).flat_map(kv_row).collect();
        let tail: Vec<f32> = (3..5).flat_map(kv_row).collect();
        assert!(chunked.ensure(sc, 3));
        for li in 0..2 {
            whole.write_prefill(li, sw, 0, 5, &all, &all);
            chunked.write_prefill(li, sc, 0, 3, &head, &head);
        }
        assert!(chunked.ensure(sc, 5));
        for li in 0..2 {
            chunked.write_prefill(li, sc, 3, 2, &tail, &tail);
        }
        assert_eq!(whole.pos[sw], 5);
        assert_eq!(chunked.pos[sc], 5);
        for li in 0..2 {
            assert_eq!(whole.gather_seq(li, sw), chunked.gather_seq(li, sc),
                       "layer {li} K/V diverged");
        }
    }

    #[test]
    fn recycled_pages_are_zeroed_on_grant() {
        let mut c = cache();
        let s = c.alloc();
        assert!(c.ensure(s, 4));
        let k = vec![9.0; 8];
        c.append(0, s, &k, &k);
        c.append(1, s, &k, &k);
        c.free(s);
        let s2 = c.alloc();
        assert!(c.ensure(s2, 4));
        let (gk, gv) = c.gather_seq(0, s2);
        assert!(gk.iter().chain(&gv).all(|&x| x == 0.0), "stale K/V leaked");
    }

    #[test]
    fn reset_restores_full_free_list() {
        let mut c = cache();
        let a = c.alloc();
        c.alloc();
        assert!(c.ensure(a, 5));
        c.reset();
        assert_eq!(c.n_active, 0);
        assert_eq!(c.free_page_count(), c.n_pages);
        assert!(c.pos.iter().all(|&p| p == 0));
        assert!(c.has_free());
        assert_eq!(c.alloc(), 0);
        assert!(conserved(&c));
    }

    #[test]
    fn sequester_shrinks_the_pool_and_release_restores_it() {
        let mut c = cache();
        assert_eq!(c.sequester_pages(2), 2);
        assert_eq!(c.free_page_count(), 4);
        assert_eq!(c.sequestered_count(), 2);
        assert_eq!(c.pages_in_use(), 0, "sequestered pages are not mapped");
        let s = c.alloc();
        assert!(c.ensure(s, 8), "a 2-page grant fits beside 2 sequestered pages");
        let t = c.alloc();
        // pressure beyond the free list is clamped, never underflows
        assert_eq!(c.sequester_pages(100), 2);
        assert_eq!(c.free_page_count(), 0);
        assert!(!c.ensure(t, 8), "the sequestered pages are genuinely gone");
        assert_eq!(c.release_sequestered(), 4);
        assert!(c.ensure(t, 8), "released pages are grantable again");
        c.free(t);
        assert_eq!(c.free_page_count(), 4);
        assert_eq!(c.sequestered_count(), 0);
        let mapped: usize = (0..c.max_seqs).map(|q| c.seq_pages(q).len()).sum();
        assert_eq!(c.free_page_count() + mapped, c.n_pages, "pool conserved after release");
        // reset drops sequestered state entirely
        assert_eq!(c.sequester_pages(1), 1);
        c.reset();
        assert_eq!(c.sequestered_count(), 0);
        assert_eq!(c.free_page_count(), c.n_pages);
    }

    #[test]
    fn single_page_covers_whole_window() {
        // page_size >= max_seq: one page per sequence, interior layout
        // [H, max_seq, dh] — the slot-compatible configuration.
        let mut c = PagedKvCache::new(1, 2, 8, 4, 2, 8, 2);
        let s = c.alloc();
        assert!(c.ensure(s, 8));
        assert_eq!(c.seq_pages(s).len(), 1);
        assert_eq!(c.pages_for(8), 1);
        let k: Vec<f32> = (0..8).map(|x| x as f32).collect();
        c.append(0, s, &k, &k);
        // head 1, t=0 inside one [H=2, P=8, dh=4] page → ((p*2+1)*8)*4
        let page = c.seq_pages(s)[0];
        let off = (page * 2 + 1) * 8 * 4;
        assert_eq!(c.k[0].data[off..off + 4], [4.0, 5.0, 6.0, 7.0]);
    }
}
