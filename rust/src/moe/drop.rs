//! Token-expert computation dropping (paper §4.1-§4.2).
//!
//! * `OneT` (1T-Drop): drop the pair when the normalized gating score is
//!   below T¹.
//! * `TwoT` (2T-Drop): dual thresholds over the reconstructed
//!   major/minor sub-experts — score ≥ T²_minor runs both halves,
//!   T²_major ≤ score < T²_minor runs only the major half, and
//!   score < T²_major drops the pair entirely. The paper's default pair
//!   is (T¹ − 0.01, T¹ + 0.01), constructed by [`DropPolicy::two_t`].
//!
//! Dropping is the *intra-request* sparsity lever: it shrinks the
//! capacity buckets real GEMMs run at, converting drop rate into
//! MoE-module speedup (Fig. 10). It composes orthogonally with the
//! *inter-request* levers in [`crate::engine::policy`] (admission
//! ordering + queue bounds): the serving sweep (`dualsparse serve
//! --sweep`) measures the drop ladder and the scheduling-policy
//! dimension side by side into SERVE_cpu.json (see docs/REPORTS.md).
//! Under expert parallelism, [`DropPolicy::scaled`] applies the §4.3
//! load-aware per-device threshold scaling.

/// Per-(token, expert) drop decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Compute the full expert (both sub-experts).
    Full,
    /// Compute only the major (high-importance) half of the neurons.
    MajorOnly,
    /// Skip this token-expert computation entirely.
    Drop,
}

/// The drop policy applied by the router.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DropPolicy {
    NoDrop,
    /// 1T-Drop with threshold T¹ on the normalized gating score.
    OneT(f32),
    /// 2T-Drop with thresholds (T²_major, T²_minor), T²_major ≤ T²_minor.
    TwoT { major: f32, minor: f32 },
}

impl DropPolicy {
    /// The paper's default dual-threshold construction:
    /// T²_major = T¹ − δ, T²_minor = T¹ + δ with δ = 0.01 (§4.2c).
    pub fn two_t(t1: f32) -> DropPolicy {
        DropPolicy::two_t_bands(t1 - 0.01, t1 + 0.01)
    }

    /// Validated 2T constructor: clamps both thresholds to ≥ 0 and
    /// orders them, so the `major ≤ minor` invariant [`decide`] relies
    /// on always holds. The raw `TwoT { major, minor }` form stays
    /// constructible for serialization compatibility, but an inverted
    /// band silently collapses the MajorOnly region — build through
    /// here (NaN thresholds clamp to 0, i.e. keep everything).
    ///
    /// [`decide`]: DropPolicy::decide
    pub fn two_t_bands(a: f32, b: f32) -> DropPolicy {
        // f32::max returns the non-NaN operand, so NaN inputs land at 0.
        let lo = a.max(0.0);
        let hi = b.max(0.0);
        DropPolicy::TwoT { major: lo.min(hi), minor: lo.max(hi) }
    }

    /// Decide for one token-expert pair given its normalized score.
    pub fn decide(&self, norm_score: f32) -> Decision {
        match *self {
            DropPolicy::NoDrop => Decision::Full,
            DropPolicy::OneT(t) => {
                if norm_score < t {
                    Decision::Drop
                } else {
                    Decision::Full
                }
            }
            DropPolicy::TwoT { major, minor } => {
                debug_assert!(
                    major <= minor,
                    "inverted 2T bands ({major} > {minor}): use DropPolicy::two_t_bands"
                );
                if norm_score >= minor {
                    Decision::Full
                } else if norm_score >= major {
                    Decision::MajorOnly
                } else {
                    Decision::Drop
                }
            }
        }
    }

    /// Scale the threshold(s) for load-aware thresholding (§4.3): a
    /// device whose load ratio is below 1 applies a proportionally lower
    /// threshold; ratios ≥ 1 keep the full (maximum) threshold.
    ///
    /// Multiplying both 2T bands by the same `k ∈ [0, 1]` preserves the
    /// `major ≤ minor` ordering, so scaling a valid policy never
    /// produces an inverted band.
    pub fn scaled(&self, ratio: f32) -> DropPolicy {
        let k = ratio.clamp(0.0, 1.0);
        match *self {
            DropPolicy::NoDrop => DropPolicy::NoDrop,
            DropPolicy::OneT(t) => DropPolicy::OneT(t * k),
            DropPolicy::TwoT { major, minor } => {
                DropPolicy::TwoT { major: major * k, minor: minor * k }
            }
        }
    }

    /// Fraction of FLOPs of a full expert that the decision costs
    /// (major/minor halves are equal width ⇒ MajorOnly = 0.5).
    pub fn cost_fraction(d: Decision) -> f32 {
        match d {
            Decision::Full => 1.0,
            Decision::MajorOnly => 0.5,
            Decision::Drop => 0.0,
        }
    }
}

/// Drop-rate accounting: kept/total token-expert *computation* fraction,
/// matching the paper's definition (MajorOnly counts as half a drop).
#[derive(Debug, Default, Clone)]
pub struct DropStats {
    pub full: u64,
    pub major_only: u64,
    pub dropped: u64,
}

impl DropStats {
    pub fn record(&mut self, d: Decision) {
        match d {
            Decision::Full => self.full += 1,
            Decision::MajorOnly => self.major_only += 1,
            Decision::Drop => self.dropped += 1,
        }
    }

    pub fn total(&self) -> u64 {
        self.full + self.major_only + self.dropped
    }

    /// Fraction of token-expert compute dropped (Table 2 "Drop Rate").
    pub fn drop_rate(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            return 0.0;
        }
        (self.dropped as f64 + 0.5 * self.major_only as f64) / t as f64
    }

    pub fn merge(&mut self, other: &DropStats) {
        self.full += other.full;
        self.major_only += other.major_only;
        self.dropped += other.dropped;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_drop_always_full() {
        assert_eq!(DropPolicy::NoDrop.decide(0.0), Decision::Full);
    }

    #[test]
    fn one_t_thresholds() {
        let p = DropPolicy::OneT(0.1);
        assert_eq!(p.decide(0.05), Decision::Drop);
        assert_eq!(p.decide(0.1), Decision::Full);
        assert_eq!(p.decide(0.5), Decision::Full);
    }

    #[test]
    fn two_t_bands() {
        let p = DropPolicy::two_t(0.10); // (0.09, 0.11)
        assert_eq!(p.decide(0.05), Decision::Drop);
        assert_eq!(p.decide(0.10), Decision::MajorOnly);
        assert_eq!(p.decide(0.12), Decision::Full);
    }

    #[test]
    fn two_t_equal_thresholds_degenerates_to_one_t() {
        let p = DropPolicy::TwoT { major: 0.1, minor: 0.1 };
        let q = DropPolicy::OneT(0.1);
        for s in [0.0, 0.05, 0.0999, 0.1, 0.3] {
            let pd = p.decide(s);
            let qd = q.decide(s);
            // TwoT with equal thresholds never yields MajorOnly.
            assert_ne!(pd, Decision::MajorOnly);
            assert_eq!(pd == Decision::Drop, qd == Decision::Drop);
        }
    }

    #[test]
    fn two_t_bands_normalizes_inverted_input() {
        // Swapped arguments come back ordered, not inverted.
        assert_eq!(
            DropPolicy::two_t_bands(0.5, 0.1),
            DropPolicy::TwoT { major: 0.1, minor: 0.5 }
        );
        // Negative thresholds clamp to 0 before ordering.
        assert_eq!(
            DropPolicy::two_t_bands(0.2, -0.3),
            DropPolicy::TwoT { major: 0.0, minor: 0.2 }
        );
        // NaN thresholds degrade to keep-everything, not to a poisoned band.
        assert_eq!(
            DropPolicy::two_t_bands(f32::NAN, 0.3),
            DropPolicy::TwoT { major: 0.0, minor: 0.3 }
        );
    }

    #[test]
    fn two_t_small_t1_keeps_bands_ordered() {
        // t1 ≤ 0.01 used to clamp major to 0 while minor could go
        // negative (t1 < −0.01), silently inverting the band. The
        // validated constructor keeps major ≤ minor in every case.
        for t1 in [-0.5, -0.011, 0.0, 0.005, 0.01, 0.3] {
            if let DropPolicy::TwoT { major, minor } = DropPolicy::two_t(t1) {
                assert!(major <= minor, "two_t({t1}) inverted: {major} > {minor}");
                assert!(major >= 0.0 && minor >= 0.0);
            } else {
                unreachable!();
            }
        }
        // Sanity: a degenerate negative t1 keeps everything rather than
        // computing MajorOnly for scores the band no longer covers.
        assert_eq!(DropPolicy::two_t(-0.5).decide(0.0), Decision::Full);
    }

    #[test]
    fn scaled_preserves_band_ordering() {
        for ratio in [0.0, 0.3, 0.7, 1.0, 2.5] {
            if let DropPolicy::TwoT { major, minor } =
                DropPolicy::two_t_bands(0.44, 0.46).scaled(ratio)
            {
                assert!(major <= minor);
            } else {
                unreachable!();
            }
        }
    }

    #[test]
    fn load_aware_scaling() {
        let p = DropPolicy::OneT(0.2);
        assert_eq!(p.scaled(1.5), DropPolicy::OneT(0.2)); // clamped at max
        assert_eq!(p.scaled(0.5), DropPolicy::OneT(0.1));
        assert_eq!(p.scaled(0.0), DropPolicy::OneT(0.0));
    }

    #[test]
    fn drop_rate_counts_major_as_half() {
        let mut s = DropStats::default();
        s.record(Decision::Full);
        s.record(Decision::MajorOnly);
        s.record(Decision::Drop);
        s.record(Decision::Drop);
        assert!((s.drop_rate() - (2.0 + 0.5) / 4.0).abs() < 1e-12);
    }
}
