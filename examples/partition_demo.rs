//! Expert partition & reconstruction demonstrated numerically on model
//! weights, without any Python in the loop (paper §3, §4.2b). Runs on
//! trained weights when `make artifacts` has produced them, otherwise
//! on the deterministic synthetic preset — hermetic either way.
//!
//!     cargo run --release --example partition_demo

#![allow(clippy::needless_range_loop, clippy::manual_memcpy, clippy::type_complexity)]

use anyhow::Result;
use dualsparse::engine::artifacts_dir;
use dualsparse::model::{Tensor, Weights};
use dualsparse::moe::{
    complete_transform_expert, complete_transform_gate, importance_order,
    remap_indices,
};
use dualsparse::util::linalg::{max_abs_diff, matmul, softmax_rows, swiglu_ffn};
use dualsparse::util::rng::SplitMix64;

fn main() -> Result<()> {
    let artifacts = artifacts_dir();
    let w = Weights::load_or_synthetic(&artifacts.join("models"), "mixtral_ish")?;
    let cfg = &w.config;
    println!("model {}: E={} h={} top-{}", cfg.name, cfg.n_experts, cfg.d_ffn, cfg.top_k);

    // a random activation batch
    let mut rng = SplitMix64::new(9);
    let x = Tensor::new(
        vec![4, cfg.d_model],
        (0..4 * cfg.d_model).map(|_| rng.f64() as f32 - 0.5).collect(),
    );

    // --- complete transformation (Fig. 3b): gate repeat + W2 scaling ---
    let wg = w.layer(0, "wg")?;
    let wg2 = complete_transform_gate(wg, 2);
    let probs = softmax_rows(&matmul(&x, wg));
    let probs2 = softmax_rows(&matmul(&x, &wg2));
    // Eq. 9: each repeated column carries exactly half the original score
    let mut worst = 0.0f32;
    for r in 0..4 {
        for e in 0..cfg.n_experts {
            for p in 0..2 {
                worst = worst.max(
                    (probs2.row(r)[e * 2 + p] - probs.row(r)[e] / 2.0).abs(),
                );
            }
        }
    }
    println!("Eq.9  (score split s/P):          max |Δ| = {worst:.2e}");

    // Eq. 11: sub-expert outputs (W2 × P) average back to the original
    let (w1, w3, w2) = (w.expert(0, "w1", 0)?, w.expert(0, "w3", 0)?, w.expert(0, "w2", 0)?);
    let y0 = swiglu_ffn(&x, &w1, &w3, &w2);
    let subs = complete_transform_expert(&w1, &w3, &w2, 2);
    let mut y_sum = Tensor::zeros(y0.shape.clone());
    for s in &subs {
        let ys = swiglu_ffn(&x, &s.w1, &s.w3, &s.w2);
        for (a, b) in y_sum.data.iter_mut().zip(&ys.data) {
            *a += b / 2.0; // gating score is halved (Eq. 9) ⇒ (1/P)·Σ f_p
        }
    }
    println!("Eq.11 (complete transform):       max |Δ| = {:.2e}", max_abs_diff(&y0, &y_sum));

    // --- partial transformation (Fig. 3c): no scaling, repeated scores ---
    let remap = remap_indices(&[3, 1], 2);
    println!("Eq.12 (index remap of [3,1], P=2): {remap:?}");
    let half = cfg.d_ffn / 2;
    let cols_a: Vec<usize> = (0..half).collect();
    let cols_b: Vec<usize> = (half..cfg.d_ffn).collect();
    let fa = swiglu_ffn(&x, &w1.gather_cols(&cols_a), &w3.gather_cols(&cols_a), &w2.gather_rows(&cols_a));
    let fb = swiglu_ffn(&x, &w1.gather_cols(&cols_b), &w3.gather_cols(&cols_b), &w2.gather_rows(&cols_b));
    let mut y_part = fa.clone();
    for (a, b) in y_part.data.iter_mut().zip(&fb.data) {
        *a += b;
    }
    println!("Eq.13 (partial transform):        max |Δ| = {:.2e}", max_abs_diff(&y0, &y_part));

    // --- reconstruction (§4.2b): importance permutation is a no-op ---
    let imp: Vec<f32> = (0..cfg.d_ffn).map(|_| rng.f64() as f32).collect();
    let order = importance_order(&imp);
    let (maj, min_) = order.split_at(half);
    let fm = swiglu_ffn(&x, &w1.gather_cols(maj), &w3.gather_cols(maj), &w2.gather_rows(maj));
    let fn_ = swiglu_ffn(&x, &w1.gather_cols(min_), &w3.gather_cols(min_), &w2.gather_rows(min_));
    let mut y_rec = fm.clone();
    for (a, b) in y_rec.data.iter_mut().zip(&fn_.data) {
        *a += b;
    }
    println!("§4.2b (reconstruct = permutation): max |Δ| = {:.2e}", max_abs_diff(&y0, &y_rec));
    println!("\nall transformations preserve the MoE output to f32 round-off —\n\
              the paper's 'mathematical consistency' property.");
    Ok(())
}
