"""Layer-1 Pallas kernel: neuron-importance probe.

Computes the four accumulated importance statistics of §4.2 (Eqs. 14-17)
for every FFN neuron over a calibration token block. The Rust calibration
driver (`rust/src/calib/`) streams calibration batches through the AOT
artifact of this kernel and sums the [4, d_ffn] partials; the resulting
tables drive expert *reconstruction* (major/minor sub-expert split) and
regenerate Figures 1 and 13.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PROBE_TILE = 128


def _probe_kernel(x_ref, w1_ref, w3_ref, o_ref):
    """One FFN tile: accumulate the 4 importance rows for these neurons.

    x_ref:  [C, d_model]
    w1_ref: [d_model, FT]
    w3_ref: [d_model, FT]
    o_ref:  [4, FT]
    """
    x = x_ref[...]
    h = x @ w1_ref[...]
    gate = h * (1.0 / (1.0 + jnp.exp(-h)))
    up = x @ w3_ref[...]
    gu = gate * up
    o_ref[0, :] = jnp.sum(gate, axis=0)
    o_ref[1, :] = jnp.sum(jnp.abs(gate), axis=0)
    o_ref[2, :] = jnp.sum(gu, axis=0)
    o_ref[3, :] = jnp.sum(jnp.abs(gu), axis=0)


@jax.jit
def probe(x, w1, w3):
    """Importance probe; shapes as in ref.probe_ref. Returns [4, d_ffn]."""
    c, d_model = x.shape
    d_ffn = w1.shape[1]
    ft = min(PROBE_TILE, d_ffn)
    assert d_ffn % ft == 0
    grid = (d_ffn // ft,)
    return pl.pallas_call(
        _probe_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, d_model), lambda j: (0, 0)),
            pl.BlockSpec((d_model, ft), lambda j: (0, j)),
            pl.BlockSpec((d_model, ft), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((4, ft), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((4, d_ffn), x.dtype),
        interpret=True,
    )(x, w1, w3)
