"""AOT exporter: lower every serving artifact to HLO *text*, train and
serialize the model family, emit golden test vectors.

Run once via `make artifacts`; the Rust binary is self-contained
afterwards (Python never runs on the request path).

Interchange is HLO text, NOT serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
behind the published `xla` 0.1.6 crate) rejects (`proto.id() <= INT_MAX`);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage:
    python -m compile.aot --out-dir ../artifacts [--skip-train] [--quick]
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, data
from .configs import (
    BATCH_BUCKETS, CAPACITY_BUCKETS, FFN_WIDTHS, MODELS, PREFILL_BUCKETS,
    PROBE_CAPACITY,
)
from .kernels import ref
from .model import (
    init_params, serve_attn_prefill, serve_attn_step, serve_ffn, serve_gate,
    serve_lm_head,
)

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _write(path, text):
    with open(path, "w") as f:
        f.write(text)


# --------------------------------------------------------------------------
# Artifact lowering
# --------------------------------------------------------------------------

def lower_artifacts(out_dir, cfg0):
    """Lower every shape-bucketed serving artifact. cfg0 supplies the
    family-shared dims (d_model, heads, max_seq, vocab)."""
    d, nh, dh = cfg0.d_model, cfg0.n_heads, cfg0.d_head
    t, v = cfg0.max_seq, cfg0.vocab
    da = nh * dh
    os.makedirs(out_dir, exist_ok=True)
    made = []

    def emit(name, fn, *specs):
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(jax.jit(fn).lower(*specs))
        _write(path, text)
        made.append(name)

    attn = functools.partial(serve_attn_step, n_heads=nh, d_head=dh)
    for b in BATCH_BUCKETS:
        emit(
            f"attn_step_b{b}", attn,
            _spec((b, d)), _spec((d,)), _spec((d, da)), _spec((d, da)),
            _spec((d, da)), _spec((da, d)), _spec((d,)),
            _spec((b, nh, t, dh)), _spec((b, nh, t, dh)), _spec((b,), I32),
        )
    prefill = functools.partial(serve_attn_prefill, n_heads=nh, d_head=dh)
    for s in PREFILL_BUCKETS:
        emit(
            f"attn_prefill_s{s}", prefill,
            _spec((s, d)), _spec((d,)), _spec((d, da)), _spec((d, da)),
            _spec((d, da)), _spec((da, d)), _spec((d,)),
        )
    # Gate shapes for the base family plus the complete-transformation
    # fine-tunes (E·P for P = 2, 4 of the mixtral_ish base).
    expert_counts = sorted(
        {m.n_experts for m in MODELS.values()} | {16, 32}
    )
    for b in sorted(set(BATCH_BUCKETS) | set(PREFILL_BUCKETS)):
        for e in expert_counts:
            emit(f"gate_b{b}_e{e}", serve_gate, _spec((b, d)), _spec((d, e)))
    for b in BATCH_BUCKETS:
        emit(
            f"lm_head_b{b}", serve_lm_head,
            _spec((b, d)), _spec((d,)), _spec((v, d)),
        )
    from .kernels.probe import probe
    for h in FFN_WIDTHS:
        for c in CAPACITY_BUCKETS:
            emit(
                f"ffn_h{h}_c{c}", serve_ffn,
                _spec((c, d)), _spec((d, h)), _spec((d, h)), _spec((h, d)),
            )
        emit(
            f"probe_h{h}", probe,
            _spec((PROBE_CAPACITY, d)), _spec((d, h)), _spec((d, h)),
        )
    return made


# --------------------------------------------------------------------------
# Weight serialization
# --------------------------------------------------------------------------

def flatten_params(params, cfg):
    """Stable (name, array) list; order defines the .bin layout."""
    out = [("emb", params["emb"]), ("pos", params["pos"])]
    for li, layer in enumerate(params["layers"]):
        keys = ["ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "w1", "w3", "w2"]
        if cfg.n_shared:
            keys += ["sw1", "sw3", "sw2"]
        for k in keys:
            out.append((f"layers.{li}.{k}", layer[k]))
    out.append(("lnf", params["lnf"]))
    return out


def save_model(out_dir, name, params, cfg):
    os.makedirs(out_dir, exist_ok=True)
    tensors = flatten_params(params, cfg)
    manifest = {"config": cfg.as_dict(), "tensors": {}, "format": "f32le"}
    offset = 0
    with open(os.path.join(out_dir, f"{name}.bin"), "wb") as f:
        for tname, arr in tensors:
            a = np.asarray(arr, dtype=np.float32)
            manifest["tensors"][tname] = {
                "offset": offset, "shape": list(a.shape),
            }
            f.write(a.tobytes())
            offset += a.size
    manifest["total_elems"] = offset
    with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
        json.dump(manifest, f, indent=1)


# --------------------------------------------------------------------------
# Golden vectors (Rust integration tests)
# --------------------------------------------------------------------------

def emit_golden(out_dir, cfg0):
    """Small input/output pairs from the pure-jnp oracle for the Rust
    runtime tests (artifact load + execute must match these)."""
    os.makedirs(out_dir, exist_ok=True)
    d, nh, dh = cfg0.d_model, cfg0.n_heads, cfg0.d_head
    k = jax.random.PRNGKey(42)
    ks = jax.random.split(k, 12)

    def dump(name, obj):
        flat = {kk: np.asarray(vv, np.float32).ravel().tolist() for kk, vv in obj.items()}
        with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
            json.dump(flat, f)

    # ffn_h64_c4
    x = jax.random.normal(ks[0], (4, d)) * 0.5
    w1 = jax.random.normal(ks[1], (d, 64)) * 0.1
    w3 = jax.random.normal(ks[2], (d, 64)) * 0.1
    w2 = jax.random.normal(ks[3], (64, d)) * 0.1
    dump("ffn_h64_c4", {
        "x": x, "w1": w1, "w3": w3, "w2": w2,
        "y": ref.swiglu_ffn_ref(x, w1, w3, w2),
    })
    # gate_b2_e8
    xg = jax.random.normal(ks[4], (2, d)) * 0.5
    wg = jax.random.normal(ks[5], (d, 8)) * 0.2
    dump("gate_b2_e8", {"x": xg, "wg": wg, "probs": ref.gate_ref(xg, wg)})
    # probe_h64
    xp = jax.random.normal(ks[6], (PROBE_CAPACITY, d)) * 0.5
    dump("probe_h64", {
        "x": xp, "w1": w1, "w3": w3, "imp": ref.probe_ref(xp, w1, w3),
    })
    # attn_step_b1 with a 3-token cache
    da = nh * dh
    t = cfg0.max_seq
    xa = jax.random.normal(ks[7], (1, d)) * 0.5
    ws = {
        "ln1": jnp.ones((d,)),
        "wq": jax.random.normal(ks[8], (d, da)) * 0.1,
        "wk": jax.random.normal(ks[9], (d, da)) * 0.1,
        "wv": jax.random.normal(ks[10], (d, da)) * 0.1,
        "wo": jax.random.normal(ks[11], (da, d)) * 0.1,
        "ln2": jnp.ones((d,)),
    }
    kc = np.zeros((1, nh, t, dh), np.float32)
    vc = np.zeros((1, nh, t, dh), np.float32)
    kc[:, :, :3] = np.asarray(jax.random.normal(ks[0], (1, nh, 3, dh))) * 0.3
    vc[:, :, :3] = np.asarray(jax.random.normal(ks[1], (1, nh, 3, dh))) * 0.3
    pos = jnp.asarray([3], I32)
    y, ln2x, nk, nv = serve_attn_step(
        xa, ws["ln1"], ws["wq"], ws["wk"], ws["wv"], ws["wo"], ws["ln2"],
        jnp.asarray(kc), jnp.asarray(vc), pos, n_heads=nh, d_head=dh,
    )
    dump("attn_step_b1", {
        "x": xa, **ws, "kcache": kc, "vcache": vc,
        "pos_f": np.asarray(pos, np.float32),  # stored as f32 list; rust casts
        "y": y, "ln2x": ln2x, "new_k": nk, "new_v": nv,
    })


# --------------------------------------------------------------------------
# Main
# --------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--skip-train", action="store_true",
                    help="only lower artifacts + golden (random init weights "
                         "are still written if none exist)")
    ap.add_argument("--quick", action="store_true",
                    help="tiny step counts (CI smoke)")
    args = ap.parse_args()
    out = args.out_dir
    models_dir = os.path.join(out, "models")
    results_dir = os.path.join(out, "results")
    golden_dir = os.path.join(out, "golden")
    os.makedirs(results_dir, exist_ok=True)

    cfg0 = MODELS["mixtral_ish"]
    t0 = time.time()
    made = lower_artifacts(out, cfg0)
    print(f"[aot] lowered {len(made)} artifacts in {time.time() - t0:.0f}s",
          flush=True)
    emit_golden(golden_dir, cfg0)
    print("[aot] golden vectors written", flush=True)

    from . import train as trainer  # heavy import kept out of --help path

    steps_pre = 30 if args.quick else configs.PRETRAIN_STEPS
    for name, cfg in MODELS.items():
        mpath = os.path.join(models_dir, f"{name}.json")
        if os.path.exists(mpath):
            print(f"[aot] {name}: cached", flush=True)
            continue
        if args.skip_train:
            params = init_params(jax.random.PRNGKey(0), cfg)
            save_model(models_dir, name, params, cfg)
            print(f"[aot] {name}: random init (--skip-train)", flush=True)
            continue
        params, log = trainer.pretrain(cfg, steps=steps_pre)
        save_model(models_dir, name, params, cfg)
        with open(os.path.join(results_dir, f"pretrain_{name}.json"), "w") as f:
            json.dump(log, f)
        print(f"[aot] {name}: trained + saved", flush=True)

    # Figure 4 / Table 1: fine-tune original vs complete-transformed.
    fig4_path = os.path.join(results_dir, "fig4_curves.json")
    if not args.skip_train and not os.path.exists(fig4_path):
        import pickle  # noqa: F401 (params reload below uses manifest)
        base_cfg = MODELS["mixtral_ish"]
        base_params = load_model(models_dir, "mixtral_ish")
        for P, cfg, tuned in trainer.fig4_experiment(
            base_cfg, base_params, fig4_path
        ):
            save_model(models_dir, f"mixtral_ish_p{P}_ft", tuned, cfg)
            print(f"[aot] fig4 P={P} fine-tuned + saved", flush=True)

    print(f"[aot] done in {time.time() - t0:.0f}s", flush=True)


def load_model(models_dir, name):
    """Reload a serialized model into the params pytree."""
    with open(os.path.join(models_dir, f"{name}.json")) as f:
        manifest = json.load(f)
    raw = np.fromfile(os.path.join(models_dir, f"{name}.bin"), dtype=np.float32)
    cfgd = manifest["config"]
    n_layers = cfgd["n_layers"]

    def get(tname):
        meta = manifest["tensors"][tname]
        shape = meta["shape"]
        size = int(np.prod(shape))
        return jnp.asarray(raw[meta["offset"] : meta["offset"] + size].reshape(shape))

    params = {"emb": get("emb"), "pos": get("pos"), "lnf": get("lnf"), "layers": []}
    for li in range(n_layers):
        keys = ["ln1", "wq", "wk", "wv", "wo", "ln2", "wg", "w1", "w3", "w2"]
        if cfgd["n_shared"]:
            keys += ["sw1", "sw3", "sw2"]
        params["layers"].append({k: get(f"layers.{li}.{k}") for k in keys})
    return params


if __name__ == "__main__":
    main()
