//! Serving front-end: workload generation + benchmark runs over the
//! arrival-driven scheduler (the paper's §5.3.2 efficiency
//! methodology: "2,000 random prompts, input 500 / output 100", scaled
//! to this testbed per DESIGN.md §2), plus the TCP network front end
//! ([`net`]) that feeds the same scheduler off live sockets.

pub mod net;

use anyhow::Result;

use crate::engine::policy::SchedConfig;
use crate::engine::scheduler::{serve, serve_opts, ArrivalMode, Request, ServeStats};
use crate::engine::Engine;
use crate::moe::DropPolicy;
use crate::util::rng::SplitMix64;
use crate::util::stats::speedup_ratio;

/// Build a serving workload from the benchmark tasks (round-robin over
/// tasks), standing in for the paper's "2000 random prompts".
pub fn task_workload(n: usize, max_new: usize) -> Vec<Request> {
    let tasks = crate::tasks::TASKS;
    let mut out = Vec::with_capacity(n);
    let mut per_task: Vec<Vec<(String, String)>> = tasks
        .iter()
        .map(|t| crate::tasks::eval_set(t, n / tasks.len() + 1, false))
        .collect();
    for i in 0..n {
        let t = i % tasks.len();
        let (prompt, _) = per_task[t].pop().expect("enough prompts");
        out.push(Request { id: i, prompt, max_new, priority: 0, deadline_secs: None });
    }
    out
}

/// A serving workload: prompts drawn from the benchmark task mixture
/// with a deterministic shuffle (stand-in for "2000 random prompts").
///
/// Each request also carries a deterministic scheduling lane
/// (`priority` ∈ {0, 1, 2}, higher = more urgent, drawn from the same
/// seeded stream after the shuffle) so the `priority` policy has lanes
/// to work with; FCFS/SPF runs ignore the field entirely.
pub fn workload(n_requests: usize, max_new: usize, seed: u64) -> Vec<Request> {
    let mut reqs = task_workload(n_requests, max_new);
    let mut rng = SplitMix64::new(seed);
    // Fisher-Yates shuffle for arrival order.
    for i in (1..reqs.len()).rev() {
        let j = rng.below(i + 1);
        reqs.swap(i, j);
    }
    for (i, r) in reqs.iter_mut().enumerate() {
        r.id = i;
        r.priority = rng.below(3) as u8;
    }
    reqs
}

/// One measured serving run under a drop policy.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub label: String,
    pub stats: ServeStats,
    /// MoE-module speedup vs a baseline run (filled by `compare`).
    pub moe_speedup: f64,
    pub e2e_speedup: f64,
}

/// Compile + touch every artifact the workload will need so that timed
/// runs don't pay lazy-compilation costs (PJRT compiles on first use).
pub fn warmup(engine: &mut Engine) -> Result<()> {
    let reqs = task_workload_small();
    let saved = engine.policy;
    // 2T touches the half-width artifacts as well.
    engine.policy = DropPolicy::TwoT { major: 0.05, minor: 0.5 };
    serve(engine, &reqs)?;
    engine.policy = saved;
    Ok(())
}

fn task_workload_small() -> Vec<Request> {
    task_workload(18, 6)
}

/// Run the workload under `policy`; the engine's drop policy is
/// restored afterwards. Warms up lazily-compiled artifacts first.
pub fn run_once(engine: &mut Engine, reqs: &[Request], policy: DropPolicy,
                label: &str) -> Result<RunReport> {
    run_once_mode(engine, reqs, policy, label, ArrivalMode::Closed, SchedConfig::default())
}

/// [`run_once`] under an explicit arrival mode (closed batch loop or
/// open-loop Poisson arrivals) and scheduling configuration (admission
/// ordering policy, queue bound, preemption / aging / interleaving
/// knobs). `SchedConfig::default()` — FCFS, unbounded, no preemption —
/// reproduces the pre-policy completion texts byte-for-byte.
pub fn run_once_mode(engine: &mut Engine, reqs: &[Request], policy: DropPolicy,
                     label: &str, mode: ArrivalMode, sched: SchedConfig) -> Result<RunReport> {
    warmup(engine)?;
    let saved = engine.policy;
    engine.policy = policy;
    let measured = serve_opts(engine, reqs, mode, sched.policy.policy(), sched.options());
    engine.policy = saved;
    let out = measured?;
    Ok(RunReport {
        label: label.to_string(),
        stats: out.stats,
        moe_speedup: 1.0,
        e2e_speedup: 1.0,
    })
}

/// Fill speedups of `runs` relative to `baseline` (Fig. 10/11 columns).
/// Ratios are guarded: when either side's phase time is too small to
/// measure (instant `CpuRef` runs), the column reports a neutral 1.0
/// instead of a division-by-near-zero artifact.
pub fn compare(baseline: &RunReport, runs: &mut [RunReport]) {
    for r in runs.iter_mut() {
        r.moe_speedup = speedup_ratio(baseline.stats.moe_secs, r.stats.moe_secs);
        r.e2e_speedup = speedup_ratio(baseline.stats.artifact_secs, r.stats.artifact_secs);
    }
}

/// Paper-style row: label, drop rate, MoE speedup, e2e speedup, tput,
/// goodput, queue-inclusive p50, TTFT, queue depth and rejection count.
pub fn format_report(r: &RunReport) -> String {
    format!(
        "{:<22} drop={:>5.1}%  moe×{:<5.2} e2e×{:<5.2} {:>7.1} tok/s gp={:.2}r/s  \
         p50={:.0}ms ttft50={:.0}ms qd={:.1} rej={}",
        r.label,
        100.0 * r.stats.drop_rate,
        r.moe_speedup,
        r.e2e_speedup,
        r.stats.tokens_per_sec,
        r.stats.goodput_rps,
        r.stats.p50_latency * 1e3,
        r.stats.p50_ttft * 1e3,
        r.stats.mean_queue_depth,
        r.stats.rejected,
    )
}

/// One-line EP simulation summary for a measured serve run, empty when
/// EP was off (`ep_workers == 0`). The `straggler_ratio=`/`static=`
/// spellings are load-bearing: CI's `ep-smoke` job extracts both and
/// asserts the load-aware ratio never exceeds its in-run static
/// counterfactual.
pub fn format_ep_report(st: &ServeStats) -> String {
    if st.ep_workers == 0 {
        return String::new();
    }
    let busy: Vec<String> =
        st.ep_worker_busy_secs.iter().map(|b| format!("{:.3}", b)).collect();
    format!(
        "ep: workers={} load_aware={} straggler_ratio={:.4} static={:.4} \
         drop={:.4} drop_static={:.4} saved_s={:.4} comm_s={:.4} repl={} \
         busy_s=[{}]",
        st.ep_workers,
        st.ep_load_aware,
        st.ep_straggler_ratio,
        st.ep_straggler_ratio_static,
        st.ep_drop_rate,
        st.ep_drop_rate_static,
        st.ep_imbalance_saved_secs,
        st.ep_comm_secs,
        st.ep_replications,
        busy.join(" "),
    )
}

/// One-line failure-domain summary for a measured serve run, empty when
/// nothing was injected and nothing died. The `faults_injected=` /
/// `timed_out=` / `leaked_pages=` spellings are load-bearing: CI's
/// `chaos-smoke` job greps them to pin that injected faults stay
/// contained. `leaked_pages` is the page-pool deficit after the run
/// (`n_pages - free_page_count`), which must be 0.
pub fn format_chaos_report(st: &ServeStats, leaked_pages: usize) -> String {
    if st.faults_injected == 0 && st.failed + st.timed_out + st.cancelled == 0 && st.retries == 0
    {
        return String::new();
    }
    format!(
        "chaos: faults_injected={} retries={} backoff_ms={:.1} failed={} \
         timed_out={} cancelled={} degrade_max={} ep_failovers={} leaked_pages={}",
        st.faults_injected,
        st.retries,
        st.backoff_secs * 1e3,
        st.failed,
        st.timed_out,
        st.cancelled,
        st.degrade_level_max,
        st.ep_failovers,
        leaked_pages,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compare_guards_instant_runs() {
        let mk = |moe: f64, art: f64| RunReport {
            label: "x".into(),
            stats: ServeStats { moe_secs: moe, artifact_secs: art, ..Default::default() },
            moe_speedup: 1.0,
            e2e_speedup: 1.0,
        };
        // measurable times → real ratio
        let base = mk(2.0, 4.0);
        let mut runs = vec![mk(1.0, 2.0)];
        compare(&base, &mut runs);
        assert_eq!(runs[0].moe_speedup, 2.0);
        assert_eq!(runs[0].e2e_speedup, 2.0);
        // instant CpuRef-style run → neutral 1.0, not an inflated column
        let base = mk(0.0, 0.0);
        let mut runs = vec![mk(1e-12, 1e-12)];
        compare(&base, &mut runs);
        assert_eq!(runs[0].moe_speedup, 1.0);
        assert_eq!(runs[0].e2e_speedup, 1.0);
    }

    #[test]
    fn report_row_has_ttft_queue_and_rejection_columns() {
        let r = RunReport {
            label: "x".into(),
            stats: ServeStats {
                p50_ttft: 0.25,
                mean_queue_depth: 3.5,
                rejected: 2,
                ..Default::default()
            },
            moe_speedup: 1.0,
            e2e_speedup: 1.0,
        };
        let row = format_report(&r);
        assert!(row.contains("ttft50=250ms"), "{row}");
        assert!(row.contains("qd=3.5"), "{row}");
        assert!(row.contains("rej=2"), "{row}");
    }

    #[test]
    fn ep_report_line_carries_ci_greppable_ratios() {
        let off = ServeStats::default();
        assert!(format_ep_report(&off).is_empty(), "no EP line when EP is off");
        let on = ServeStats {
            ep_workers: 4,
            ep_load_aware: true,
            ep_worker_busy_secs: vec![0.25, 0.125, 0.125, 0.0625],
            ep_straggler_ratio: 1.25,
            ep_straggler_ratio_static: 1.5,
            ..Default::default()
        };
        let line = format_ep_report(&on);
        assert!(line.contains("straggler_ratio=1.2500"), "{line}");
        assert!(line.contains("static=1.5000"), "{line}");
        assert!(line.contains("workers=4"), "{line}");
        assert!(line.contains("busy_s=[0.250 0.125 0.125 0.062]"), "{line}");
    }

    #[test]
    fn chaos_report_line_carries_ci_greppable_counts() {
        let quiet = ServeStats::default();
        assert!(format_chaos_report(&quiet, 0).is_empty(), "no chaos line when nothing happened");
        let loud = ServeStats {
            faults_injected: 7,
            retries: 3,
            backoff_secs: 0.007,
            failed: 1,
            timed_out: 2,
            cancelled: 1,
            degrade_level_max: 3,
            ep_failovers: 2,
            ..Default::default()
        };
        let line = format_chaos_report(&loud, 0);
        assert!(line.contains("faults_injected=7"), "{line}");
        assert!(line.contains("retries=3"), "{line}");
        assert!(line.contains("timed_out=2"), "{line}");
        assert!(line.contains("cancelled=1"), "{line}");
        assert!(line.contains("leaked_pages=0"), "{line}");
        assert!(line.contains("degrade_max=3"), "{line}");
        assert!(line.contains("ep_failovers=2"), "{line}");
        // deadline-only runs still report (timed_out > 0, no injection).
        let dl = ServeStats { timed_out: 4, ..Default::default() };
        assert!(format_chaos_report(&dl, 0).contains("timed_out=4"));
    }

    #[test]
    fn workload_is_deterministic_and_shuffled() {
        let a = workload(20, 8, 1);
        let b = workload(20, 8, 1);
        assert_eq!(
            a.iter().map(|r| r.prompt.clone()).collect::<Vec<_>>(),
            b.iter().map(|r| r.prompt.clone()).collect::<Vec<_>>()
        );
        let c = workload(20, 8, 2);
        assert_ne!(
            a.iter().map(|r| r.prompt.clone()).collect::<Vec<_>>(),
            c.iter().map(|r| r.prompt.clone()).collect::<Vec<_>>()
        );
        // ids are re-sequenced after shuffling
        assert!(a.iter().enumerate().all(|(i, r)| r.id == i));
        // priority lanes are deterministic per seed, in-range, and the
        // workload actually spreads across more than one lane.
        assert_eq!(
            a.iter().map(|r| r.priority).collect::<Vec<_>>(),
            b.iter().map(|r| r.priority).collect::<Vec<_>>()
        );
        assert!(a.iter().all(|r| r.priority <= 2));
        let lanes: std::collections::HashSet<u8> = a.iter().map(|r| r.priority).collect();
        assert!(lanes.len() > 1, "20 draws over 3 lanes must hit ≥ 2 lanes");
    }
}
