"""Cross-language parity pins: the Python generators and the Rust
mirrors (rust/src/util/rng.rs, rust/src/tasks/mod.rs) must produce
identical streams. The same golden values are asserted in
rust/tests/parity.rs — change one side and these tell you."""

from compile import data
from compile.rng import SplitMix64

GOLDEN_RNG_SEED0 = [
    0xE220A8397B1DCDAF,
    0x6E789E6AA1B965F4,
    0x06C45D188009454F,
    0xF88BB8A8724C81EC,
]

GOLDEN_EVAL = {
    "cpy": [("cpy:afdg|", "afdg"), ("cpy:edaf|", "edaf"), ("cpy:aabc|", "aabc")],
    "add": [("add:6+8|", "4"), ("add:0+0|", "0"), ("add:4+7|", "1")],
    "ind": [("ind:a6 d6 b7 a|", "6"), ("ind:b0 c9 d1 c|", "9"),
            ("ind:b7 d4 c2 d|", "4")],
    "lm": [("lm:the mo|", "on is"), ("lm:a dog |", "ran t"),
           ("lm:birds fly over t|", "he se")],
    "bal": [("bal:()()|", "Y"), ("bal:))((|", "N"), ("bal:(())|", "Y")],
    "srt": [("srt:aecb|", "abce"), ("srt:fdbc|", "bcdf"), ("srt:ecdf|", "cdef")],
}


def test_rng_stream():
    r = SplitMix64(0)
    assert [r.next_u64() for _ in range(4)] == GOLDEN_RNG_SEED0


def test_rng_below_bounded():
    r = SplitMix64(123)
    assert all(r.below(7) < 7 for _ in range(1000))


def test_eval_sets_match_golden():
    for task, expected in GOLDEN_EVAL.items():
        assert data.eval_set(task, 3) == expected, task


def test_eval_set_deterministic():
    assert data.eval_set("rev", 5) == data.eval_set("rev", 5)


def test_corpus_structure():
    c = data.corpus_tokens(2000, data.TRAIN_SEED)
    text = c.decode()
    line = text.splitlines()[0]
    assert ":" in line and "|" in line


def test_corpus_deterministic():
    a = data.corpus_tokens(500, data.TRAIN_SEED)
    b = data.corpus_tokens(500, data.TRAIN_SEED)
    assert a == b


def test_answers_correct_add():
    for p, ans in data.eval_set("add", 50):
        body = p[len("add:"):-1]
        a, b = body.split("+")
        assert ans == str((int(a) + int(b)) % 10)


def test_answers_correct_rev():
    for p, ans in data.eval_set("rev", 50):
        body = p[len("rev:"):-1]
        assert ans == body[::-1]


def test_answers_correct_maj():
    for p, ans in data.eval_set("maj", 50):
        body = p[len("maj:"):-1]
        assert ans == ("a" if body.count("a") >= 3 else "b")


def test_shifted_distribution_differs():
    assert data.eval_set("cpy", 5, shift=True) != data.eval_set("cpy", 5)
