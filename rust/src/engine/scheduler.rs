//! Arrival-driven serving scheduler: the request lifecycle behind every
//! measured serving number in this repo.
//!
//! Every request walks an explicit state machine
//!
//! ```text
//! Queued → Prefill → Decode → Done
//!        ↘ Rejected            (queue full: bounded admission control)
//!                  ↘ Done      (immediate EOS / max_new ≤ 1)
//!                  ↘ Rejected  (admission validation: prompt + max_new
//!                               exceed the KV window / page budget)
//!            Prefill ↘
//!             Decode → Preempted → Queued   (page fault or a more
//!                                  urgent arrival: pages freed now,
//!                                  recompute-from-prompt on
//!                                  re-admission)
//!   Queued / Prefill / Decode → Failed     (injected backend error:
//!                                  bounded retries exhausted)
//!   Queued / Prefill / Decode → TimedOut   (deadline exceeded)
//!   Queued / Prefill / Decode → Cancelled  (external CancelSet)
//! ```
//!
//! driven by an **iteration-level** continuous-batching loop: each
//! iteration admits what fits, runs at most one prefill chunk of the
//! oldest staged prompt *alongside* the current decode batch
//! (`interleave`, the default — long prompts no longer monopolize the
//! engine between decode steps), then decodes the whole active set.
//! `interleave = false` restores the legacy run-whole-prefill-at-
//! admission timing, which is the baseline the SERVE_cpu.json sweep
//! compares p99 TTFT against.
//!
//! Two arrival modes:
//!
//! * [`ArrivalMode::Closed`] — the classic closed batch loop: every
//!   request is available at t = 0 and admission is limited only by KV
//!   sequence ids + pages. Completion texts reproduce the legacy
//!   `serve()` loop byte-for-byte (pinned by `rust/tests/scheduler.rs`).
//! * [`ArrivalMode::Open`] — open-loop serving: deterministic Poisson
//!   arrivals (SplitMix64 exponential inter-arrival gaps); a request
//!   becomes admissible only once the wall clock reaches its arrival
//!   time.
//!
//! KV capacity is **page-granular** ([`crate::engine::kv`]): admission
//! is page-budget-aware, and two regimes exist:
//!
//! * `preempt = false` (default) — conservative reservation: admission
//!   reserves every page the request could ever need
//!   (`pages_for(prompt + max_new)`), so a decode step can never fault.
//!   With the default page budget this is exactly the legacy
//!   slot-bound admission.
//! * `preempt = true` — optimistic admission (pages for the prompt
//!   only). A decode-time page fault evicts a victim chosen by the
//!   [`SchedulingPolicy::victim`] order (Decode → Preempted → Queued,
//!   pages freed immediately); the victim re-admits later and
//!   *recomputes from its prompt* (prefill over prompt ++ generated so
//!   far — [`ServeStats::recompute_tokens`] counts the cost). Priority
//!   lanes additionally preempt at admission when a strictly more
//!   urgent request finds no free pages
//!   ([`SchedulingPolicy::preempts`]).
//!
//! Ordering and admission stay pluggable via [`crate::engine::policy`]
//! ([`serve_policy`] / [`serve_opts`]); starvation control
//! ([`crate::engine::policy::AgingConfig`]) boosts long-waiting queued
//! requests for the SPF / priority pickers.
//!
//! Latency accounting is **arrival-anchored**: `latency` includes queue
//! wait, `ttft` is arrival → first token (a preempted request keeps its
//! original first-token time), and the admission-anchored number
//! survives as `service_secs`.
//!
//! **Failure domains** ([`crate::engine::faults`]): every fault is
//! contained to the request it hits. *Injected* transient backend
//! errors (chaos testing) get bounded retries with virtual-backoff
//! accounting, then fail exactly the offending request (`Failed`,
//! pages freed immediately — the run never aborts). Deadlines
//! (per-request or run-default) and external cancellation
//! ([`CancelSet`]) retire requests as `TimedOut` / `Cancelled` at the
//! per-iteration sweep. A *real* (non-injected) backend error past
//! validation still aborts the run: it signals an engine invariant
//! violation, not traffic weather. A [`DegradeController`] closes the
//! loop from observed TTFT / queue depth onto the engine's drop policy
//! via `DropPolicy::scaled`. The exactly-once invariant is completions
//! ∪ rejections ∪ casualties: every request ends in exactly one of
//! Done / Rejected / Failed / TimedOut / Cancelled, with every KV page
//! back on the free list.

use std::collections::VecDeque;

use anyhow::Result;

use super::faults::{CancelSet, DegradeController, FaultPlan};
use super::policy::{
    ActiveSeq, AdmissionControl, AgingConfig, Fcfs, QueuedRequest, SchedulingPolicy,
};
use super::{Engine, EOS};
use crate::util::rng::SplitMix64;
use crate::util::stats::{mean, percentile};
use crate::util::Timer;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    pub prompt: String,
    pub max_new: usize,
    /// Scheduling lane for
    /// [`PriorityLanes`](crate::engine::policy::PriorityLanes); higher =
    /// more urgent. 0 (the conventional default lane) everywhere a
    /// workload does not say otherwise; FCFS and SPF ignore it.
    pub priority: u8,
    /// Optional per-request deadline, seconds from arrival. Past it the
    /// scheduler retires the request as [`Phase::TimedOut`] at the next
    /// iteration sweep. `None` defers to [`SchedOptions::deadline_secs`].
    pub deadline_secs: Option<f64>,
}

/// When requests become admissible.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalMode {
    /// Closed batch loop: every request has arrival time 0.
    Closed,
    /// Open loop: Poisson arrivals at `rate` requests/second,
    /// deterministic given `seed` (SplitMix64 exponential gaps).
    Open { rate: f64, seed: u64 },
}

/// Lifecycle states of one request inside the scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Queued,
    Prefill,
    Decode,
    /// Evicted mid-flight (page fault or admission preemption): pages
    /// already freed; transitions straight back to Queued for
    /// recompute-from-prompt re-admission.
    Preempted,
    Done,
    Rejected,
    /// Injected-fault casualty: a transient backend error exhausted the
    /// request's retry budget. Pages freed immediately; the run keeps
    /// going (real, non-injected errors still abort).
    Failed,
    /// Deadline exceeded ([`Request::deadline_secs`] or
    /// [`SchedOptions::deadline_secs`]).
    TimedOut,
    /// Externally cancelled via [`CancelSet`].
    Cancelled,
}

/// Scheduler knobs beyond the ordering policy — the
/// [`crate::engine::policy::SchedConfig::options`] slice.
#[derive(Debug, Clone)]
pub struct SchedOptions {
    pub admission: AdmissionControl,
    /// Resolve page faults by eviction instead of reserving worst-case
    /// pages at admission.
    pub preempt: bool,
    /// Starvation control for the SPF / priority pickers.
    pub aging: Option<AgingConfig>,
    /// One prefill chunk per iteration alongside the decode batch
    /// (default); `false` = legacy whole-prompt prefill at admission.
    pub interleave: bool,
    /// Deterministic fault injection ([`crate::engine::faults`]).
    /// `None` — and a zero-probability plan — leave the loop
    /// byte-identical to the fault-free scheduler.
    pub faults: Option<FaultPlan>,
    /// Bounded retries per request for *injected* transient backend
    /// errors before the request fails (`Failed`). Retries charge
    /// exponential virtual backoff to [`ServeStats::backoff_secs`].
    pub max_retries: u32,
    /// Run-default deadline (seconds from arrival) for every request
    /// without its own [`Request::deadline_secs`].
    pub deadline_secs: Option<f64>,
    /// External-cancellation hook, swept every iteration; the network
    /// front end drives this from client disconnects.
    pub cancel: Option<CancelSet>,
    /// SLO-driven drop-policy degradation: observed p99 TTFT / queue
    /// depth feed `DropPolicy::scaled` at runtime.
    pub degrade: Option<DegradeController>,
}

impl Default for SchedOptions {
    fn default() -> Self {
        SchedOptions {
            admission: AdmissionControl::default(),
            preempt: false,
            aging: None,
            interleave: true,
            faults: None,
            max_retries: 2,
            deadline_secs: None,
            cancel: None,
            degrade: None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Completion {
    pub id: usize,
    /// The request's scheduling lane (copied from
    /// [`Request::priority`]).
    pub priority: u8,
    pub text: String,
    /// Generated tokens excluding the EOS terminator (== `text.len()`).
    pub new_tokens: usize,
    /// Arrival time (seconds from run start; 0 in closed-loop mode).
    pub arrival: f64,
    /// Arrival → (first) admission (time spent waiting for KV space).
    pub queue_secs: f64,
    /// Arrival → first token (queue wait + prefill). A preempted
    /// request keeps its original first-token time.
    pub ttft: f64,
    /// First admission → completion — the legacy, admission-anchored
    /// metric.
    pub service_secs: f64,
    /// Arrival → completion (queue-inclusive — the honest number).
    pub latency: f64,
    /// First token → completion (decode-phase wall time).
    pub decode_secs: f64,
    /// Times this request was evicted and re-admitted.
    pub preemptions: u32,
}

/// A request rejected without consuming KV space and without affecting
/// any other request — either at admission validation (prompt cannot
/// fit the KV window / page budget) or on arrival at a full bounded
/// queue.
#[derive(Debug, Clone)]
pub struct Rejection {
    pub id: usize,
    pub reason: String,
    pub arrival: f64,
    pub rejected_at: f64,
}

/// A request that died mid-lifecycle — [`Phase::Failed`],
/// [`Phase::TimedOut`] or [`Phase::Cancelled`]. Its KV pages were freed
/// on the spot and the run kept going; no other request was affected.
#[derive(Debug, Clone)]
pub struct Casualty {
    pub id: usize,
    /// Terminal state (`Failed` / `TimedOut` / `Cancelled`).
    pub phase: Phase,
    pub reason: String,
    pub arrival: f64,
    pub ended_at: f64,
    /// Injected-error retries this request burned before dying.
    pub retries: u32,
    /// Tokens generated before the cut — work thrown away.
    pub generated: usize,
}

#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub wall_secs: f64,
    /// Completed requests.
    pub requests: usize,
    /// Rejected requests (per-request failures; the run kept going).
    /// Includes both capacity-validation and queue-full rejections.
    pub rejected: usize,
    /// The subset of `rejected` turned away by the
    /// [`AdmissionControl`] queue bound (`reason` = "queue full…").
    pub rejected_queue_full: usize,
    /// Completed requests per wall-clock second — the goodput to plot
    /// against offered load (open-loop arrival rate). Diverges from the
    /// offered rate past the knee, where the queue bound rejects.
    pub goodput_rps: f64,
    pub generated_tokens: u64,
    pub prefill_tokens: u64,
    pub tokens_per_sec: f64,
    /// Arrival-anchored (queue-inclusive) latency.
    pub mean_latency: f64,
    pub p50_latency: f64,
    pub p99_latency: f64,
    /// Admission-anchored service time (the pre-scheduler "latency").
    pub p50_service: f64,
    pub p99_service: f64,
    /// Time to first token, measured from arrival.
    pub mean_ttft: f64,
    pub p50_ttft: f64,
    pub p99_ttft: f64,
    /// Mean arrival → admission wait across completions.
    pub mean_queue_secs: f64,
    /// Mean decode-phase seconds per generated token.
    pub mean_decode_secs_per_token: f64,
    /// Time-weighted average queue depth over the whole run.
    pub mean_queue_depth: f64,
    pub max_queue_depth: usize,
    /// Evictions (Decode/Prefill → Preempted → Queued) over the run.
    pub preemptions: usize,
    /// KV positions thrown away by evictions and rebuilt by
    /// recompute-from-prompt re-admissions.
    pub recompute_tokens: u64,
    /// Time-weighted mean fraction of the physical page pool mapped.
    pub page_utilization: f64,
    /// Prefill chunks run inside the iteration loop (0 when
    /// `interleave` is off).
    pub interleaved_prefill_steps: u64,
    /// Per-priority-lane p50 TTFT `(lane, seconds)`, ascending lane —
    /// the starvation-control report column.
    pub lane_ttft50: Vec<(u8, f64)>,
    /// Seconds inside MoE artifacts (gate + FFN).
    pub moe_secs: f64,
    /// Seconds inside all artifacts.
    pub artifact_secs: f64,
    pub drop_rate: f64,
    /// Virtual EP workers the run simulated (0 = EP off; the remaining
    /// `ep_*` fields are zeros/empty then).
    pub ep_workers: usize,
    /// Whether §4.3 load-aware thresholding modulated per-worker drop
    /// policies during the run.
    pub ep_load_aware: bool,
    /// Per-worker attributed FFN busy seconds.
    pub ep_worker_busy_secs: Vec<f64>,
    /// Hottest worker's kept cost ÷ mean per-worker kept cost (1.0 =
    /// perfectly balanced).
    pub ep_straggler_ratio: f64,
    /// The same ratio under the unscaled base policy on identical
    /// routings (counterfactual; equals `ep_straggler_ratio` when
    /// load-aware is off, and bounds it from above when on).
    pub ep_straggler_ratio_static: f64,
    /// Hot-worker compute seconds avoided by dropping.
    pub ep_imbalance_saved_secs: f64,
    /// Simulated AlltoAll dispatch + return seconds.
    pub ep_comm_secs: f64,
    /// Drop rate over EP-routed pairs (excludes shared experts).
    pub ep_drop_rate: f64,
    /// Counterfactual drop rate under the unscaled base policy.
    pub ep_drop_rate_static: f64,
    /// Hot-expert replications (`--ep-replicate-after`).
    pub ep_replications: u64,
    /// Injected-fault casualties (retry budget exhausted).
    pub failed: usize,
    /// Deadline casualties.
    pub timed_out: usize,
    /// External cancellations honored.
    pub cancelled: usize,
    /// Bounded retries of injected transient backend errors.
    pub retries: u64,
    /// Virtual backoff seconds charged by those retries (accounting
    /// only — the loop never actually sleeps on a retry).
    pub backoff_secs: f64,
    /// Total fault events the plan injected over the run.
    pub faults_injected: u64,
    /// Highest degrade-ladder level reached (0 = controller off or
    /// never escalated).
    pub degrade_level_max: u32,
    /// `(iteration, level)` at every degrade-level change.
    pub degrade_timeline: Vec<(u64, u32)>,
    /// Experts re-hosted off injected EP worker failures.
    pub ep_failovers: u64,
}

/// Everything one serving run produced.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    /// Sorted by request id.
    pub completions: Vec<Completion>,
    pub rejections: Vec<Rejection>,
    /// Failed / timed-out / cancelled requests, sorted by id. Empty
    /// without chaos, deadlines or cancellation.
    pub casualties: Vec<Casualty>,
    pub stats: ServeStats,
}

/// Deterministic Poisson arrival offsets (seconds from run start):
/// exponential inter-arrival gaps at `rate` requests/second drawn from
/// a SplitMix64 stream. Strictly increasing.
pub fn poisson_arrivals(n: usize, rate: f64, seed: u64) -> Vec<f64> {
    let mut rng = SplitMix64::new(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            t += rng.exp(rate);
            t
        })
        .collect()
}

/// The receiving end of a per-request [`TokenSink`] went away (client
/// disconnected, writer thread dead). The scheduler reacts by marking
/// the request in the run's [`CancelSet`] so the next sweep retires it
/// as [`Phase::Cancelled`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SinkClosed;

/// Per-request streaming output: the scheduler pushes every retired
/// token the moment it exists, then exactly one terminal notification.
///
/// The token stream is **append-only and lossless**: the concatenation
/// of all `token` calls equals the final [`Completion::text`] byte for
/// byte (the EOS terminator is never emitted, and a preempted request's
/// recompute re-derives — never re-emits — what was already streamed).
pub trait TokenSink {
    /// One retired, non-EOS token. `Err(SinkClosed)` tells the
    /// scheduler the client is unreachable; the request is cancelled at
    /// the next sweep.
    fn token(&mut self, tok: u8) -> std::result::Result<(), SinkClosed>;
    /// Terminal: the request completed.
    fn done(&mut self, c: &Completion);
    /// Terminal: rejected at admission (queue full / validation).
    fn rejected(&mut self, r: &Rejection);
    /// Terminal: failed / timed out / cancelled mid-lifecycle.
    fn casualty(&mut self, c: &Casualty);
}

/// One request delivered by an [`ArrivalSource`]: the request itself,
/// its arrival timestamp (seconds from run start — a workload source
/// reports its scheduled offset, a live source the delivery time), and
/// an optional streaming sink for its output.
pub struct Arrival {
    pub request: Request,
    pub at: f64,
    pub sink: Option<Box<dyn TokenSink>>,
}

/// Where requests come from. The scheduler polls the source once per
/// iteration instead of walking a pre-materialized `Vec<Request>`, so
/// the same loop serves both synthetic workloads ([`WorkloadSource`])
/// and live sockets ([`crate::server::net`]).
pub trait ArrivalSource {
    /// Every request that has arrived by `now`, in arrival order.
    fn poll(&mut self, now: f64) -> Vec<Arrival>;
    /// Earliest known future arrival, if the source has a schedule
    /// (workloads do; a socket source returns `None` and is polled at a
    /// steady cadence instead).
    fn next_arrival(&self) -> Option<f64>;
    /// True once no further arrival can ever be delivered; the loop
    /// exits when the source is exhausted and nothing is in flight.
    fn exhausted(&self) -> bool;
}

/// The pre-materialized workload as an [`ArrivalSource`]: a request
/// list plus [`ArrivalMode`] offsets (closed loop = everything at
/// t = 0). Delivery replays the legacy scheduler's arrival scan
/// exactly, which is what keeps `serve_opts` byte-pinned.
pub struct WorkloadSource {
    requests: Vec<Request>,
    arrivals: Vec<f64>,
    next: usize,
}

impl WorkloadSource {
    pub fn new(requests: &[Request], mode: ArrivalMode) -> Self {
        let arrivals = match mode {
            ArrivalMode::Closed => vec![0.0; requests.len()],
            ArrivalMode::Open { rate, seed } => poisson_arrivals(requests.len(), rate, seed),
        };
        WorkloadSource { requests: requests.to_vec(), arrivals, next: 0 }
    }
}

impl ArrivalSource for WorkloadSource {
    fn poll(&mut self, now: f64) -> Vec<Arrival> {
        let mut out = Vec::new();
        while self.next < self.requests.len() && self.arrivals[self.next] <= now {
            out.push(Arrival {
                request: self.requests[self.next].clone(),
                at: self.arrivals[self.next],
                sink: None,
            });
            self.next += 1;
        }
        out
    }

    fn next_arrival(&self) -> Option<f64> {
        self.arrivals.get(self.next).copied()
    }

    fn exhausted(&self) -> bool {
        self.next == self.requests.len()
    }
}

/// One admitted request (staged for prefill or decoding). Its KV
/// sequence id is stable for the whole residency — eviction frees it,
/// re-admission claims a fresh one.
struct InFlight {
    id: usize,
    priority: u8,
    /// Index into the `requests` slice (drives the phase table).
    ridx: usize,
    arrival: f64,
    /// First admission (queue_secs anchors here even across evictions).
    admitted_at: f64,
    first_token_at: f64,
    has_first: bool,
    /// KV sequence id for this residency.
    seq: usize,
    /// What prefill recomputes: the prompt, plus — after an eviction —
    /// every token generated before it (recompute-from-prompt).
    input: Vec<u8>,
    /// Prefill progress: positions already cached (chunk base).
    base: usize,
    out: Vec<u8>,
    next: u8,
    max_new: usize,
    /// Decode steps this request participated in.
    steps: u64,
    /// Pages reserved at admission (conservative mode; 0 under
    /// `preempt`). Released when the request retires or is evicted.
    reserved: usize,
    /// Evictions suffered so far.
    preempted: u32,
}

/// Everything an eviction must park so re-admission can continue the
/// request exactly where it left off (minus the KV pages, which are
/// recomputed from the prompt).
struct ResumeState {
    admitted_at: f64,
    first_token_at: f64,
    has_first: bool,
    out: Vec<u8>,
    next: u8,
    steps: u64,
    preempted: u32,
}

/// Virtual backoff base for injected-error retries: attempt `k`
/// charges `base × 2^(k−1)` seconds to [`ServeStats::backoff_secs`]
/// (accounting only; the loop never sleeps on a retry).
const RETRY_BACKOFF_BASE_SECS: f64 = 1e-3;

fn set_phase(phases: &mut [Phase], ri: usize, to: Phase) {
    let from = phases[ri];
    debug_assert!(
        matches!(
            (from, to),
            (Phase::Queued, Phase::Prefill)
                | (Phase::Queued, Phase::Rejected) // queue full at arrival
                | (Phase::Prefill, Phase::Decode)
                | (Phase::Prefill, Phase::Done)
                | (Phase::Prefill, Phase::Rejected)
                | (Phase::Prefill, Phase::Preempted) // page fault mid-prefill
                | (Phase::Decode, Phase::Done)
                | (Phase::Decode, Phase::Preempted) // page fault / urgent arrival
                | (Phase::Preempted, Phase::Queued) // recompute-from-prompt
                // failure domains: any live stage can be cut down,
                // always straight to a terminal state.
                | (Phase::Queued, Phase::Failed)
                | (Phase::Queued, Phase::TimedOut)
                | (Phase::Queued, Phase::Cancelled)
                | (Phase::Prefill, Phase::Failed)
                | (Phase::Prefill, Phase::TimedOut)
                | (Phase::Prefill, Phase::Cancelled)
                | (Phase::Decode, Phase::Failed)
                | (Phase::Decode, Phase::TimedOut)
                | (Phase::Decode, Phase::Cancelled)
        ),
        "illegal lifecycle transition {from:?} → {to:?}"
    );
    phases[ri] = to;
}

fn finish(a: InFlight, now: f64) -> Completion {
    let end = a.out.iter().position(|&c| c == EOS).unwrap_or(a.out.len());
    Completion {
        id: a.id,
        priority: a.priority,
        text: a.out[..end].iter().map(|&b| b as char).collect(),
        new_tokens: end,
        arrival: a.arrival,
        queue_secs: a.admitted_at - a.arrival,
        ttft: a.first_token_at - a.arrival,
        service_secs: now - a.admitted_at,
        latency: now - a.arrival,
        decode_secs: if a.steps > 0 { now - a.first_token_at } else { 0.0 },
        preemptions: a.preempted,
    }
}

fn snapshot(a: &InFlight) -> ActiveSeq {
    ActiveSeq {
        id: a.id,
        priority: a.priority,
        prompt_len: a.input.len(),
        arrival: a.arrival,
        admitted_at: a.admitted_at,
        generated: a.out.len(),
    }
}

/// Mutable scheduler state an eviction touches, bundled so the helpers
/// below stay callable while `active` / `prefilling` are borrowed.
struct EvictCtx<'a> {
    phases: &'a mut [Phase],
    queue: &'a mut VecDeque<usize>,
    resume: &'a mut [Option<ResumeState>],
    enqueued_at: &'a mut [f64],
    committed: &'a mut usize,
    preemptions: &'a mut usize,
    recompute_tokens: &'a mut u64,
}

/// Evict one in-flight request: free its pages now, park its progress,
/// and push it to the queue **front** (it re-admits with recompute-
/// from-prompt as soon as space allows).
fn evict(engine: &mut Engine, a: InFlight, ctx: &mut EvictCtx<'_>, now: f64) {
    *ctx.recompute_tokens += engine.kv.pos[a.seq] as u64;
    engine.kv.free(a.seq);
    *ctx.committed -= a.reserved;
    set_phase(ctx.phases, a.ridx, Phase::Preempted);
    set_phase(ctx.phases, a.ridx, Phase::Queued);
    ctx.resume[a.ridx] = Some(ResumeState {
        admitted_at: a.admitted_at,
        first_token_at: a.first_token_at,
        has_first: a.has_first,
        out: a.out,
        next: a.next,
        steps: a.steps,
        preempted: a.preempted + 1,
    });
    ctx.enqueued_at[a.ridx] = now;
    *ctx.preemptions += 1;
    ctx.queue.push_front(a.ridx);
}

/// Run all `requests` to completion with continuous batching in
/// closed-loop mode (every request available at t = 0), keeping the
/// historical `(completions, stats)` shape.
///
/// An oversized prompt does not abort the run: the offending request
/// is rejected at admission validation (no KV slot consumed) and the
/// count shows up in [`ServeStats::rejected`].
pub fn serve(engine: &mut Engine, requests: &[Request]) -> Result<(Vec<Completion>, ServeStats)> {
    let out = serve_with(engine, requests, ArrivalMode::Closed)?;
    Ok((out.completions, out.stats))
}

/// Run `requests` to completion (or rejection) under `mode` with the
/// legacy scheduling configuration: FCFS admission order, unbounded
/// queue, no preemption. Completion texts are byte-for-byte the
/// pre-policy scheduler's (pinned by `rust/tests/scheduler.rs`).
pub fn serve_with(
    engine: &mut Engine,
    requests: &[Request],
    mode: ArrivalMode,
) -> Result<ServeOutcome> {
    serve_policy(engine, requests, mode, &Fcfs, AdmissionControl::unbounded())
}

/// [`serve_opts`] with the default scheduler knobs (no preemption, no
/// aging, interleaving on) — the policy-plus-admission entry point the
/// pre-paging callers used.
pub fn serve_policy(
    engine: &mut Engine,
    requests: &[Request],
    mode: ArrivalMode,
    policy: &dyn SchedulingPolicy,
    admission: AdmissionControl,
) -> Result<ServeOutcome> {
    serve_opts(engine, requests, mode, policy, SchedOptions { admission, ..Default::default() })
}

/// Run `requests` to completion (or rejection) under `mode`, admitting
/// in the order `policy` chooses, with the full paged-KV knob set
/// ([`SchedOptions`]): bounded admission, preemption, aging,
/// prefill/decode interleaving. Thin wrapper over [`serve_source`]
/// with a [`WorkloadSource`]; completion texts stay byte-pinned by
/// `rust/tests/scheduler.rs`.
pub fn serve_opts(
    engine: &mut Engine,
    requests: &[Request],
    mode: ArrivalMode,
    policy: &dyn SchedulingPolicy,
    opts: SchedOptions,
) -> Result<ServeOutcome> {
    // Fail fast on backends that cannot run the chunked-prefill
    // continuation artifacts a long prompt will need mid-run. A live
    // source cannot know its prompts up front, so the check lives here
    // on the workload path only.
    let longest = requests.iter().map(|r| r.prompt.len()).max().unwrap_or(0);
    engine.check_chunked_prefill_support(longest)?;
    let mut source = WorkloadSource::new(requests, mode);
    serve_source(engine, &mut source, policy, opts)
}

/// The iteration-level serving loop over an arbitrary
/// [`ArrivalSource`]: requests enter whenever the source delivers them
/// (synthetic workload offsets or live socket frames), tokens leave
/// through each request's [`TokenSink`] the moment a decode step (or
/// the final prefill chunk) retires them, and a sink write failure
/// flips the request into the run's [`CancelSet`] so the next sweep
/// retires it as [`Phase::Cancelled`] and frees its KV pages.
pub fn serve_source(
    engine: &mut Engine,
    source: &mut dyn ArrivalSource,
    policy: &dyn SchedulingPolicy,
    opts: SchedOptions,
) -> Result<ServeOutcome> {
    engine.kv.reset();
    engine.reset_metrics();
    // Per-request state, indexed by delivery order (`ridx`). Grown as
    // the source delivers — a live source's request count is unknown
    // until shutdown.
    let mut reqs: Vec<Request> = Vec::new();
    let mut arrivals: Vec<f64> = Vec::new();
    let mut queue: VecDeque<usize> = VecDeque::new();
    let mut phases: Vec<Phase> = Vec::new();
    let mut enqueued_at: Vec<f64> = Vec::new();
    let mut resume: Vec<Option<ResumeState>> = Vec::new();
    let mut sinks: Vec<Option<Box<dyn TokenSink>>> = Vec::new();
    // Staged prefill jobs, oldest first; only the front job ever runs
    // a chunk (and therefore only the front job holds prefill pages —
    // the invariant that keeps optimistic admission deadlock-free).
    let mut prefilling: VecDeque<InFlight> = VecDeque::new();
    let mut active: Vec<InFlight> = Vec::new();
    let mut done: Vec<Completion> = Vec::new();
    let mut rejections: Vec<Rejection> = Vec::new();
    let mut queue_full = 0usize;
    // Conservative-mode page reservations currently outstanding.
    let mut committed = 0usize;
    let mut preemptions = 0usize;
    let mut recompute_tokens = 0u64;
    let mut interleaved_chunks = 0u64;
    // Scratch for the policy's queue snapshot, reused across admissions
    // so picking never allocates on the serving hot path.
    let mut view: Vec<QueuedRequest> = Vec::new();
    // Time-weighted queue-depth / page-utilization integrals: the value
    // observed at one sample point weights the interval until the next.
    let mut qd_integral = 0.0f64;
    let mut qd_prev = 0usize;
    let mut util_integral = 0.0f64;
    let mut util_prev = 0.0f64;
    let mut sample_last_t = 0.0f64;
    let mut qd_max = 0usize;
    let mut decode_busy = 0.0f64;
    let mut decode_toks = 0u64;
    // Chaos state. Everything lives on locals cloned out of `opts` so
    // the fault-free path stays identical to the pre-chaos loop: a
    // `None` plan (or a zero-probability one) draws nothing, sweeps
    // nothing, and changes no policy.
    let mut plan = opts.faults.clone();
    let cancel = match (&opts.cancel, &plan) {
        (Some(c), _) => Some(c.clone()),
        (None, Some(p)) if p.spec.cancel_p > 0.0 => Some(CancelSet::new()),
        _ => None,
    };
    let mut degrade = opts.degrade.clone();
    let base_policy = engine.policy;
    // Re-evaluated as arrivals come in: a deadline only needs sweeping
    // once a request carrying one exists.
    let mut deadlines_on = opts.deadline_secs.is_some();
    let mut req_retries: Vec<u32> = Vec::new();
    let mut retries_total = 0u64;
    let mut backoff_secs = 0.0f64;
    let mut casualties: Vec<Casualty> = Vec::new();
    let mut iter = 0u64;
    let mut total_decode_steps = 0u64;
    // Page-pool pressure episode: sequestered pages return at
    // `pressure_until`, and an equal-length cool-down window follows so
    // admission always makes forward progress between episodes.
    let mut pressure_until: Option<u64> = None;
    let mut pressure_cooldown = 0u64;
    if let Some(p) = plan.as_mut() {
        if let Some((w, f)) = p.spec.ep_slow {
            engine.slow_ep_worker(w, f);
            p.note_injected();
        }
    }
    if let Some(d) = degrade.as_ref() {
        engine.policy = base_policy.scaled(d.scale() as f32);
    }
    let timer = Timer::start();

    macro_rules! evict_ctx {
        () => {
            EvictCtx {
                phases: &mut phases,
                queue: &mut queue,
                resume: &mut resume,
                enqueued_at: &mut enqueued_at,
                committed: &mut committed,
                preemptions: &mut preemptions,
                recompute_tokens: &mut recompute_tokens,
            }
        };
    }

    // Cut one live request down to a terminal failure-domain state and
    // record the casualty. Pages (if any) are freed by the caller —
    // each holding collection knows what it holds. The request's sink
    // (if streaming) gets its terminal notification here.
    macro_rules! reap {
        ($ri:expr, $to:expr, $reason:expr, $generated:expr, $now:expr) => {{
            let ri = $ri;
            set_phase(&mut phases, ri, $to);
            casualties.push(Casualty {
                id: reqs[ri].id,
                phase: $to,
                reason: $reason,
                arrival: arrivals[ri],
                ended_at: $now,
                retries: req_retries[ri],
                generated: $generated,
            });
            if let Some(mut sk) = sinks[ri].take() {
                sk.casualty(casualties.last().expect("just pushed"));
            }
        }};
    }

    loop {
        iter += 1;
        // 0. chaos clock: expire a page-pool pressure episode (pages
        // return to the free list, a cool-down window opens), then
        // maybe start a new one. Sequestration never touches pages
        // backing conservative reservations — a granted reservation
        // must always be able to map.
        if let Some(p) = plan.as_mut() {
            if pressure_until.is_some_and(|t| iter >= t) {
                engine.kv.release_sequestered();
                pressure_until = None;
                pressure_cooldown = iter + p.spec.pressure_hold.max(1);
            }
            if pressure_until.is_none() && iter >= pressure_cooldown {
                if let Some((pages, hold)) = p.pressure() {
                    let reserved_unmapped = committed.saturating_sub(engine.kv.pages_in_use());
                    let cap = engine.kv.free_page_count().saturating_sub(reserved_unmapped);
                    if engine.kv.sequester_pages(pages.min(cap)) > 0 {
                        pressure_until = Some(iter + hold);
                    }
                }
            }
        }

        // 1. arrivals: poll the source for everything whose time has
        // come and move it into the queue — unless the admission-
        // control bound refuses it, in which case the request is
        // rejected on the spot (Queued → Rejected, no KV space ever
        // involved) and the rejection is answered on its sink.
        let now = timer.secs();
        for arrival in source.poll(now) {
            let Arrival { request, at, sink } = arrival;
            let i = reqs.len();
            deadlines_on |= request.deadline_secs.is_some();
            reqs.push(request);
            arrivals.push(at);
            phases.push(Phase::Queued);
            enqueued_at.push(0.0);
            resume.push(None);
            req_retries.push(0);
            sinks.push(sink);
            // Injected client disconnect: mark the id cancelled so the
            // sweep below reaps it wherever it lands.
            if plan.as_mut().is_some_and(|p| p.cancel_on_arrival()) {
                if let Some(cs) = cancel.as_ref() {
                    cs.cancel(reqs[i].id);
                }
            }
            if !opts.admission.admits(queue.len()) {
                set_phase(&mut phases, i, Phase::Rejected);
                queue_full += 1;
                rejections.push(Rejection {
                    id: reqs[i].id,
                    reason: format!(
                        "queue full: {} waiting at max_queue_depth {}",
                        queue.len(),
                        opts.admission.max_queue_depth.unwrap_or(0)
                    ),
                    arrival: arrivals[i],
                    rejected_at: timer.secs(),
                });
                if let Some(mut sk) = sinks[i].take() {
                    sk.rejected(rejections.last().expect("just pushed"));
                }
                continue;
            }
            enqueued_at[i] = arrivals[i];
            queue.push_back(i);
        }

        // 1b. failure-domain sweep: deadlines and external
        // cancellation. Terminal transitions free held pages
        // immediately; queued victims simply never admit. Cancellation
        // wins over a simultaneous deadline expiry.
        let cancel_live = cancel.as_ref().is_some_and(|c| !c.is_empty());
        if deadlines_on || cancel_live {
            let now = timer.secs();
            let axed = |ri: usize| -> Option<(Phase, String)> {
                if cancel_live && cancel.as_ref().is_some_and(|c| c.is_cancelled(reqs[ri].id)) {
                    return Some((Phase::Cancelled, "cancelled by client".to_string()));
                }
                match reqs[ri].deadline_secs.or(opts.deadline_secs) {
                    Some(d) if now - arrivals[ri] > d => Some((
                        Phase::TimedOut,
                        format!("deadline {:.0} ms exceeded", d * 1e3),
                    )),
                    _ => None,
                }
            };
            let mut qi = 0;
            while qi < queue.len() {
                let ri = queue[qi];
                match axed(ri) {
                    Some((to, reason)) => {
                        queue.remove(qi).expect("index in range");
                        let generated = resume[ri].take().map(|r| r.out.len()).unwrap_or(0);
                        reap!(ri, to, reason, generated, now);
                    }
                    None => qi += 1,
                }
            }
            let mut pi = 0;
            while pi < prefilling.len() {
                match axed(prefilling[pi].ridx) {
                    Some((to, reason)) => {
                        let job = prefilling.remove(pi).expect("index in range");
                        engine.kv.free(job.seq);
                        committed -= job.reserved;
                        reap!(job.ridx, to, reason, job.out.len(), now);
                    }
                    None => pi += 1,
                }
            }
            let mut ai = 0;
            while ai < active.len() {
                match axed(active[ai].ridx) {
                    Some((to, reason)) => {
                        let a = active.swap_remove(ai);
                        engine.kv.free(a.seq);
                        committed -= a.reserved;
                        reap!(a.ridx, to, reason, a.out.len(), now);
                    }
                    None => ai += 1,
                }
            }
        }

        // 2. admission: the policy picks which queued request claims
        // the next KV sequence; validation, the page gate and prefill
        // staging follow. Validation failures (prompt cannot fit the
        // KV window / page budget together with max_new) reject exactly
        // that request before any KV space is claimed.
        while engine.kv.has_free() && !queue.is_empty() {
            let now = timer.secs();
            // A singleton queue has only one possible pick (out-of-range
            // picks clamp to the last element anyway), so skip the
            // snapshot entirely — the common case at low load.
            let pos = if queue.len() == 1 {
                0
            } else {
                view.clear();
                view.extend(queue.iter().map(|&i| QueuedRequest {
                    id: reqs[i].id,
                    prompt_len: reqs[i].prompt.len()
                        + resume[i].as_ref().map(|r| r.out.len()).unwrap_or(0),
                    priority: reqs[i].priority,
                    arrival: arrivals[i],
                    age_boost: opts
                        .aging
                        .map(|a| a.boost(now - enqueued_at[i]))
                        .unwrap_or(0),
                }));
                policy.pick(&view).min(queue.len() - 1)
            };
            let ri = queue.remove(pos).expect("pos clamped into range");
            let req = &reqs[ri];
            let parked = resume[ri].take();
            // Fresh requests get validated once; a resumed request
            // already passed (its prompt + max_new fit, and generated
            // tokens only move budget from max_new to input).
            if parked.is_none() {
                let capacity = engine.prompt_capacity(req.max_new);
                if req.prompt.len() > capacity {
                    set_phase(&mut phases, ri, Phase::Prefill);
                    set_phase(&mut phases, ri, Phase::Rejected);
                    rejections.push(Rejection {
                        id: req.id,
                        reason: format!(
                            "prompt too long: {} tokens + max_new {} exceed the \
                             KV window (max_seq {}, page budget {})",
                            req.prompt.len(),
                            req.max_new,
                            engine.cfg.max_seq,
                            engine.kv.n_pages * engine.kv.page_size,
                        ),
                        arrival: arrivals[ri],
                        rejected_at: timer.secs(),
                    });
                    if let Some(mut sk) = sinks[ri].take() {
                        sk.rejected(rejections.last().expect("just pushed"));
                    }
                    continue;
                }
            }
            let mut input = req.prompt.as_bytes().to_vec();
            if let Some(r) = &parked {
                input.extend_from_slice(&r.out);
            }
            // Page gate. Conservative mode reserves worst-case pages up
            // front so later ensures can never fail; optimistic mode
            // needs free pages for the prompt, evicting a victim when a
            // more urgent arrival is entitled to one (priority lanes).
            let reserved = if opts.preempt {
                let need = engine.kv.pages_for(input.len());
                while engine.kv.free_page_count() < need && !active.is_empty() {
                    let snap: Vec<ActiveSeq> = active.iter().map(snapshot).collect();
                    let v = policy.victim(&snap).min(snap.len() - 1);
                    let cand = QueuedRequest {
                        id: req.id,
                        prompt_len: input.len(),
                        priority: req.priority,
                        arrival: arrivals[ri],
                        age_boost: opts
                            .aging
                            .map(|a| a.boost(now - enqueued_at[ri]))
                            .unwrap_or(0),
                    };
                    if !policy.preempts(&cand, &snap[v]) {
                        break;
                    }
                    let victim = active.swap_remove(v);
                    evict(engine, victim, &mut evict_ctx!(), now);
                }
                if engine.kv.free_page_count() < need {
                    // Blocked on pages: put the candidate back (evicted
                    // victims sit at the front; relative order among
                    // them is the policy's to re-decide next round) and
                    // stop admitting this iteration.
                    resume[ri] = parked;
                    queue.insert(pos.min(queue.len()), ri);
                    break;
                }
                0
            } else {
                let remaining = req.max_new - parked.as_ref().map(|r| r.out.len()).unwrap_or(0);
                let need = engine.kv.pages_for(input.len() + remaining);
                if committed + need > engine.kv.n_pages - engine.kv.sequestered_count() {
                    resume[ri] = parked;
                    queue.insert(pos.min(queue.len()), ri);
                    break;
                }
                committed += need;
                need
            };
            let seq = engine.kv.alloc();
            set_phase(&mut phases, ri, Phase::Prefill);
            let admitted_at = timer.secs();
            let job = match parked {
                Some(r) => InFlight {
                    id: req.id,
                    priority: req.priority,
                    ridx: ri,
                    arrival: arrivals[ri],
                    admitted_at: r.admitted_at,
                    first_token_at: r.first_token_at,
                    has_first: r.has_first,
                    seq,
                    input,
                    base: 0,
                    out: r.out,
                    next: r.next,
                    max_new: req.max_new,
                    steps: r.steps,
                    reserved,
                    preempted: r.preempted,
                },
                None => InFlight {
                    id: req.id,
                    priority: req.priority,
                    ridx: ri,
                    arrival: arrivals[ri],
                    admitted_at,
                    first_token_at: 0.0,
                    has_first: false,
                    seq,
                    input,
                    base: 0,
                    out: Vec::new(),
                    next: 0,
                    max_new: req.max_new,
                    steps: 0,
                    reserved,
                    preempted: 0,
                },
            };
            prefilling.push_back(job);
        }

        // 3. time-weighted samples (queue depth, page utilization).
        let sample_now = timer.secs();
        qd_integral += qd_prev as f64 * (sample_now - sample_last_t);
        util_integral += util_prev * (sample_now - sample_last_t);
        sample_last_t = sample_now;
        qd_prev = queue.len();
        util_prev = engine.kv.utilization();
        qd_max = qd_max.max(queue.len());

        // 3b. degrade-controller evaluation: observed TTFT / queue
        // depth move the live drop policy along the scaled ladder.
        if let Some(d) = degrade.as_mut() {
            if let Some(scale) = d.tick(iter, queue.len()) {
                engine.policy = base_policy.scaled(scale as f32);
            }
        }

        // 4. prefill: one chunk of the oldest staged prompt per
        // iteration (interleaved with decode), or — with interleaving
        // off — every chunk of every staged prompt right here (the
        // legacy whole-prompt-at-admission timing).
        while let Some(mut job) = prefilling.pop_front() {
            // Pre-flight the chunk's pages so an engine-level grant
            // failure (which aborts the run) cannot happen: under
            // preemption, evict decode victims until the chunk fits.
            let upto = (job.base + engine.max_prefill_chunk()).min(job.input.len());
            let need = engine
                .kv
                .pages_for(upto)
                .saturating_sub(engine.kv.seq_pages(job.seq).len());
            if opts.preempt && engine.kv.free_page_count() < need {
                let now = timer.secs();
                while engine.kv.free_page_count() < need && !active.is_empty() {
                    let snap: Vec<ActiveSeq> = active.iter().map(snapshot).collect();
                    let v = policy.victim(&snap).min(snap.len() - 1);
                    let victim = active.swap_remove(v);
                    evict(engine, victim, &mut evict_ctx!(), now);
                }
                if engine.kv.free_page_count() < need {
                    // No decode victims left and still short: only this
                    // job holds pages, so re-queue it (front) and let
                    // re-admission restart it with the full pool.
                    evict(engine, job, &mut evict_ctx!(), now);
                    break;
                }
            }
            // Injected transient backend error — intercepted *before*
            // the engine runs, so there is never partial state to
            // unwind. Burn a retry (virtual backoff) and try again next
            // iteration, or fail exactly this request when the budget
            // is gone.
            if plan.as_mut().is_some_and(|p| p.inject_exec_error()) {
                let now = timer.secs();
                if req_retries[job.ridx] < opts.max_retries {
                    req_retries[job.ridx] += 1;
                    retries_total += 1;
                    backoff_secs += RETRY_BACKOFF_BASE_SECS
                        * f64::from(1u32 << (req_retries[job.ridx] - 1).min(16));
                    prefilling.push_front(job);
                } else {
                    engine.kv.free(job.seq);
                    committed -= job.reserved;
                    let generated = job.out.len();
                    reap!(
                        job.ridx,
                        Phase::Failed,
                        format!(
                            "injected backend error at prefill: {} retries exhausted",
                            opts.max_retries
                        ),
                        generated,
                        now
                    );
                }
                break;
            }
            let chunk = engine.prefill_chunk(job.seq, &job.input, job.base);
            match chunk {
                Ok((next_base, None)) => {
                    job.base = next_base;
                    if opts.interleave {
                        interleaved_chunks += 1;
                        prefilling.push_front(job);
                        break; // one chunk per iteration
                    }
                    prefilling.push_front(job); // keep draining this job
                }
                Ok((_, Some(tok))) => {
                    if opts.interleave {
                        interleaved_chunks += 1;
                    }
                    let now = timer.secs();
                    if !job.has_first {
                        job.first_token_at = now;
                        job.has_first = true;
                        if let Some(d) = degrade.as_mut() {
                            d.observe_ttft(now - job.arrival);
                        }
                    }
                    if job.out.len() < job.max_new {
                        job.out.push(tok);
                        // Stream the token the moment it retires. A closed
                        // sink (client hung up) flips the id into the
                        // CancelSet so the next sweep reaps the request.
                        if tok != EOS {
                            if let Some(sk) = sinks[job.ridx].as_mut() {
                                if sk.token(tok).is_err() {
                                    if let Some(cs) = cancel.as_ref() {
                                        cs.cancel(reqs[job.ridx].id);
                                    }
                                }
                            }
                        }
                    }
                    job.next = tok;
                    if tok == EOS || job.out.len() >= job.max_new {
                        // Finished at prefill: retire immediately
                        // instead of burning a decode step on a dead
                        // row.
                        engine.kv.free(job.seq);
                        committed -= job.reserved;
                        let ridx = job.ridx;
                        set_phase(&mut phases, ridx, Phase::Done);
                        done.push(finish(job, now));
                        if let Some(mut sk) = sinks[ridx].take() {
                            sk.done(done.last().expect("just pushed"));
                        }
                    } else {
                        set_phase(&mut phases, job.ridx, Phase::Decode);
                        active.push(job);
                    }
                    if opts.interleave {
                        break; // one chunk per iteration
                    }
                }
                Err(err) => {
                    // A real backend failure — injected ones never
                    // reach the engine (intercepted above). Free what
                    // this job holds, then abort: past validation an
                    // execution error signals an engine invariant
                    // violation, and masking it as a request fault
                    // would corrupt every number downstream.
                    engine.kv.free(job.seq);
                    committed -= job.reserved;
                    return Err(err);
                }
            }
        }

        if active.is_empty() {
            if queue.is_empty() && prefilling.is_empty() && source.exhausted() {
                break;
            }
            if queue.is_empty() && prefilling.is_empty() {
                // Idle until the next arrival (capped so the loop re-checks
                // the clock — and live sources like a socket queue — at a
                // sane cadence). A source with no known next arrival (e.g.
                // the network front end) is polled every millisecond.
                match source.next_arrival() {
                    Some(next_at) => {
                        let wait = next_at - timer.secs();
                        if wait > 0.0 {
                            std::thread::sleep(std::time::Duration::from_secs_f64(wait.min(0.05)));
                        }
                    }
                    None => std::thread::sleep(std::time::Duration::from_millis(1)),
                }
            }
            continue;
        }

        // 5. page-fault resolution: every decode row needs one more
        // position this step. Under preemption a fault evicts a victim
        // (someone else's pages — self only as the last resort);
        // conservative reservations make faults impossible otherwise.
        if opts.preempt {
            let mut i = 0;
            while i < active.len() {
                let seq = active[i].seq;
                let upto = engine.kv.pos[seq] + 1;
                if engine.kv.ensure(seq, upto) {
                    i += 1;
                    continue;
                }
                let now = timer.secs();
                if active.len() == 1 {
                    // Alone and faulting: the remaining pages belong to
                    // a staged prefill — yield them and recompute.
                    let victim = active.swap_remove(0);
                    evict(engine, victim, &mut evict_ctx!(), now);
                    continue;
                }
                let snap: Vec<ActiveSeq> = active
                    .iter()
                    .enumerate()
                    .filter(|&(j, _)| j != i)
                    .map(|(_, a)| snapshot(a))
                    .collect();
                let p = policy.victim(&snap).min(snap.len() - 1);
                let j = if p < i { p } else { p + 1 };
                let victim = active.swap_remove(j);
                evict(engine, victim, &mut evict_ctx!(), now);
                if j < i {
                    i -= 1; // swap_remove shifted our row down
                }
                // retry the same row with the freed pages
            }
            if active.is_empty() {
                continue;
            }
        }

        // 6. one decode step for the whole active set — after chaos has
        // its say. A latency spike stalls the step; an injected exec
        // error costs one victim row its turn (bounded retry) or its
        // life (budget exhausted). Per-row attention means a skipped
        // row's eventual text is byte-identical — the fault only delays
        // it.
        if let Some(p) = plan.as_mut() {
            if let Some(ms) = p.spike_ms() {
                std::thread::sleep(std::time::Duration::from_secs_f64(ms / 1e3));
            }
        }
        let mut skip_row = usize::MAX;
        if let Some(p) = plan.as_mut() {
            if p.inject_exec_error() {
                let v = p.pick(active.len());
                let ridx = active[v].ridx;
                if req_retries[ridx] < opts.max_retries {
                    req_retries[ridx] += 1;
                    retries_total += 1;
                    backoff_secs += RETRY_BACKOFF_BASE_SECS
                        * f64::from(1u32 << (req_retries[ridx] - 1).min(16));
                    skip_row = v;
                } else {
                    let now = timer.secs();
                    let a = active.swap_remove(v);
                    engine.kv.free(a.seq);
                    committed -= a.reserved;
                    reap!(
                        a.ridx,
                        Phase::Failed,
                        format!(
                            "injected backend error at decode: {} retries exhausted",
                            opts.max_retries
                        ),
                        a.out.len(),
                        now
                    );
                    if active.is_empty() {
                        continue;
                    }
                }
            }
        }
        let step_t0 = timer.secs();
        let rows: Vec<usize> = (0..active.len()).filter(|&r| r != skip_row).collect();
        if rows.is_empty() {
            continue; // the lone decode row is sitting out an injected error
        }
        let seqs: Vec<usize> = rows.iter().map(|&r| active[r].seq).collect();
        let tokens: Vec<u8> = rows.iter().map(|&r| active[r].next).collect();
        let next = engine.decode_step_seqs(&seqs, &tokens)?;
        let step_secs = timer.secs() - step_t0;
        decode_busy += step_secs * rows.len() as f64;
        decode_toks += rows.len() as u64;
        for (k, &r) in rows.iter().enumerate() {
            let a = &mut active[r];
            a.out.push(next[k]);
            a.next = next[k];
            a.steps += 1;
            let (ridx, id) = (a.ridx, reqs[a.ridx].id);
            // Stream the freshly retired token; EOS terminates the text and
            // is never emitted. A closed sink (client hung up mid-decode)
            // cancels the request so the next sweep frees its pages.
            if next[k] != EOS {
                if let Some(sk) = sinks[ridx].as_mut() {
                    if sk.token(next[k]).is_err() {
                        if let Some(cs) = cancel.as_ref() {
                            cs.cancel(id);
                        }
                    }
                }
            }
        }
        total_decode_steps += 1;
        // Injected EP worker failure: trip at the configured decode
        // step; surviving workers re-host its experts.
        if let Some(p) = plan.as_mut() {
            if let Some(w) = p.take_ep_fail(total_decode_steps) {
                engine.fail_ep_worker(w);
            }
        }

        // 7. retire finished rows (reverse order keeps swap_remove
        // index math trivial; sequence ids are stable so nothing else
        // moves).
        let mut row = active.len();
        while row > 0 {
            row -= 1;
            let fin = active[row].next == EOS || active[row].out.len() >= active[row].max_new;
            if !fin {
                continue;
            }
            let a = active.swap_remove(row);
            engine.kv.free(a.seq);
            committed -= a.reserved;
            let ridx = a.ridx;
            set_phase(&mut phases, ridx, Phase::Done);
            done.push(finish(a, timer.secs()));
            if let Some(mut sk) = sinks[ridx].take() {
                sk.done(done.last().expect("just pushed"));
            }
        }
    }

    // Chaos teardown: return any sequestered pages so the conservation
    // asserts below see the full pool, and restore the configured drop
    // policy the degrade controller may have scaled.
    if plan.is_some() {
        engine.kv.release_sequestered();
    }
    if degrade.is_some() {
        engine.policy = base_policy;
    }

    debug_assert!(
        phases.iter().all(|&p| matches!(
            p,
            Phase::Done | Phase::Rejected | Phase::Failed | Phase::TimedOut | Phase::Cancelled
        )),
        "every request must end in a terminal phase: {phases:?}"
    );
    debug_assert_eq!(engine.kv.n_active, 0, "all KV sequences must retire");
    debug_assert_eq!(
        engine.kv.free_page_count(),
        engine.kv.n_pages,
        "every page must return to the free list"
    );
    debug_assert_eq!(committed, 0, "all page reservations must be released");

    let wall = timer.secs();
    // close the last sample interval
    qd_integral += qd_prev as f64 * (wall - sample_last_t);
    util_integral += util_prev * (wall - sample_last_t);
    let lats: Vec<f64> = done.iter().map(|c| c.latency).collect();
    let servs: Vec<f64> = done.iter().map(|c| c.service_secs).collect();
    let ttfts: Vec<f64> = done.iter().map(|c| c.ttft).collect();
    let queues: Vec<f64> = done.iter().map(|c| c.queue_secs).collect();
    let mut lanes: Vec<u8> = done.iter().map(|c| c.priority).collect();
    lanes.sort_unstable();
    lanes.dedup();
    let lane_ttft50: Vec<(u8, f64)> = lanes
        .iter()
        .map(|&lane| {
            let ts: Vec<f64> =
                done.iter().filter(|c| c.priority == lane).map(|c| c.ttft).collect();
            (lane, percentile(&ts, 50.0))
        })
        .collect();
    let ep = engine.ep_report();
    let stats = ServeStats {
        wall_secs: wall,
        requests: done.len(),
        rejected: rejections.len(),
        rejected_queue_full: queue_full,
        goodput_rps: done.len() as f64 / wall.max(1e-9),
        generated_tokens: engine.metrics.generated_tokens,
        prefill_tokens: engine.metrics.prefill_tokens,
        tokens_per_sec: engine.metrics.generated_tokens as f64 / wall.max(1e-9),
        mean_latency: mean(&lats),
        p50_latency: percentile(&lats, 50.0),
        p99_latency: percentile(&lats, 99.0),
        p50_service: percentile(&servs, 50.0),
        p99_service: percentile(&servs, 99.0),
        mean_ttft: mean(&ttfts),
        p50_ttft: percentile(&ttfts, 50.0),
        p99_ttft: percentile(&ttfts, 99.0),
        mean_queue_secs: mean(&queues),
        mean_decode_secs_per_token: if decode_toks > 0 {
            decode_busy / decode_toks as f64
        } else {
            0.0
        },
        mean_queue_depth: if wall > 0.0 { qd_integral / wall } else { 0.0 },
        max_queue_depth: qd_max,
        preemptions,
        recompute_tokens,
        page_utilization: if wall > 0.0 { util_integral / wall } else { 0.0 },
        interleaved_prefill_steps: interleaved_chunks,
        lane_ttft50,
        moe_secs: engine.moe_time(),
        artifact_secs: engine.total_artifact_time(),
        drop_rate: engine.metrics.drop_rate(),
        ep_workers: ep.as_ref().map(|r| r.workers).unwrap_or(0),
        ep_load_aware: ep.as_ref().map(|r| r.load_aware).unwrap_or(false),
        ep_worker_busy_secs: ep.as_ref().map(|r| r.busy_secs.clone()).unwrap_or_default(),
        ep_straggler_ratio: ep.as_ref().map(|r| r.straggler_ratio).unwrap_or(0.0),
        ep_straggler_ratio_static: ep
            .as_ref()
            .map(|r| r.straggler_ratio_static)
            .unwrap_or(0.0),
        ep_imbalance_saved_secs: ep.as_ref().map(|r| r.imbalance_saved_secs).unwrap_or(0.0),
        ep_comm_secs: ep.as_ref().map(|r| r.comm_secs).unwrap_or(0.0),
        ep_drop_rate: ep.as_ref().map(|r| r.drop_rate).unwrap_or(0.0),
        ep_drop_rate_static: ep.as_ref().map(|r| r.drop_rate_static).unwrap_or(0.0),
        ep_replications: ep.as_ref().map(|r| r.replications).unwrap_or(0),
        failed: phases.iter().filter(|&&p| p == Phase::Failed).count(),
        timed_out: phases.iter().filter(|&&p| p == Phase::TimedOut).count(),
        cancelled: phases.iter().filter(|&&p| p == Phase::Cancelled).count(),
        retries: retries_total,
        backoff_secs,
        faults_injected: plan.as_ref().map(|p| p.injected()).unwrap_or(0),
        degrade_level_max: degrade.as_ref().map(|d| d.max_level()).unwrap_or(0),
        degrade_timeline: degrade.as_ref().map(|d| d.timeline().to_vec()).unwrap_or_default(),
        ep_failovers: ep.as_ref().map(|r| r.failovers).unwrap_or(0),
    };
    done.sort_by_key(|c| c.id);
    rejections.sort_by_key(|r| r.id);
    casualties.sort_by_key(|c| c.id);
    Ok(ServeOutcome { completions: done, rejections, casualties, stats })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_arrivals_are_deterministic_and_increasing() {
        let a = poisson_arrivals(64, 10.0, 7);
        let b = poisson_arrivals(64, 10.0, 7);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "strictly increasing");
        assert!(a[0] > 0.0);
        // mean gap ≈ 1/rate (loose bound; 64 samples)
        let mean_gap = a.last().unwrap() / 64.0;
        assert!(mean_gap > 0.02 && mean_gap < 0.5, "mean gap {mean_gap}");
        let c = poisson_arrivals(64, 10.0, 8);
        assert_ne!(a, c, "seed must matter");
    }

    #[test]
    fn phase_transitions_legal_paths_only() {
        let mut p = vec![Phase::Queued];
        set_phase(&mut p, 0, Phase::Prefill);
        set_phase(&mut p, 0, Phase::Decode);
        set_phase(&mut p, 0, Phase::Done);
        assert_eq!(p[0], Phase::Done);
        let mut p = vec![Phase::Queued];
        set_phase(&mut p, 0, Phase::Prefill);
        set_phase(&mut p, 0, Phase::Rejected);
        assert_eq!(p[0], Phase::Rejected);
        // queue-full admission control rejects straight from Queued.
        let mut p = vec![Phase::Queued];
        set_phase(&mut p, 0, Phase::Rejected);
        assert_eq!(p[0], Phase::Rejected);
        // eviction: Decode → Preempted → Queued → Prefill again.
        let mut p = vec![Phase::Queued];
        set_phase(&mut p, 0, Phase::Prefill);
        set_phase(&mut p, 0, Phase::Decode);
        set_phase(&mut p, 0, Phase::Preempted);
        set_phase(&mut p, 0, Phase::Queued);
        set_phase(&mut p, 0, Phase::Prefill);
        set_phase(&mut p, 0, Phase::Preempted); // mid-prefill fault
        set_phase(&mut p, 0, Phase::Queued);
        assert_eq!(p[0], Phase::Queued);
    }

    #[test]
    fn every_live_stage_can_reach_every_failure_terminal() {
        for terminal in [Phase::Failed, Phase::TimedOut, Phase::Cancelled] {
            // Queued → terminal (deadline/cancel while waiting).
            let mut p = vec![Phase::Queued];
            set_phase(&mut p, 0, terminal);
            assert_eq!(p[0], terminal);
            // Prefill → terminal.
            let mut p = vec![Phase::Queued];
            set_phase(&mut p, 0, Phase::Prefill);
            set_phase(&mut p, 0, terminal);
            assert_eq!(p[0], terminal);
            // Decode → terminal.
            let mut p = vec![Phase::Queued];
            set_phase(&mut p, 0, Phase::Prefill);
            set_phase(&mut p, 0, Phase::Decode);
            set_phase(&mut p, 0, terminal);
            assert_eq!(p[0], terminal);
        }
    }

    #[test]
    #[should_panic(expected = "illegal lifecycle transition")]
    #[cfg(debug_assertions)]
    fn failure_terminals_are_terminal() {
        let mut p = vec![Phase::Queued];
        set_phase(&mut p, 0, Phase::TimedOut);
        set_phase(&mut p, 0, Phase::Queued); // no resurrection
    }

    #[test]
    #[should_panic(expected = "illegal lifecycle transition")]
    #[cfg(debug_assertions)]
    fn phase_skipping_prefill_is_illegal() {
        let mut p = vec![Phase::Queued];
        set_phase(&mut p, 0, Phase::Done);
    }

    #[test]
    #[should_panic(expected = "illegal lifecycle transition")]
    #[cfg(debug_assertions)]
    fn preempted_cannot_finish_without_readmission() {
        let mut p = vec![Phase::Queued];
        set_phase(&mut p, 0, Phase::Prefill);
        set_phase(&mut p, 0, Phase::Decode);
        set_phase(&mut p, 0, Phase::Preempted);
        set_phase(&mut p, 0, Phase::Done);
    }

    #[test]
    fn sched_options_default_is_legacy_plus_interleave() {
        let o = SchedOptions::default();
        assert!(!o.preempt);
        assert!(o.aging.is_none());
        assert!(o.interleave);
        assert_eq!(o.admission, AdmissionControl::unbounded());
        // Chaos off by default: no plan, no deadline, no cancellation
        // hook, no degrade controller; retry budget bounded.
        assert!(o.faults.is_none());
        assert!(o.deadline_secs.is_none());
        assert!(o.cancel.is_none());
        assert!(o.degrade.is_none());
        assert_eq!(o.max_retries, 2);
    }
}
