//! End-to-end engine tests, hermetic by construction.
//!
//! With no artifacts tree these run on the pure-Rust `CpuRef` backend
//! over deterministic synthetic weights (`Weights::synthetic`), so the
//! whole coordination layer — routing, 1T/2T dropping, partition/
//! reconstruction dispatch, load-aware EP, KV cache, batching — is
//! exercised by `cargo test` alone. When `DUALSPARSE_ARTIFACTS` points
//! at a `make artifacts` tree (and the `pjrt` feature is on), the same
//! tests fall through to trained weights on the PJRT runtime.

#![allow(clippy::needless_range_loop, clippy::manual_memcpy, clippy::type_complexity)]

use std::path::PathBuf;

use dualsparse::engine::{Engine, EngineOptions, EpOptions};
use dualsparse::moe::DropPolicy;
use dualsparse::runtime::Backend as _;
use dualsparse::tasks::eval::evaluate;

fn artifacts() -> PathBuf {
    std::env::var("DUALSPARSE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn engine(model: &str, policy: DropPolicy) -> Engine {
    Engine::new(&artifacts(), model, policy, EngineOptions::default())
        .expect("engine builds hermetically (CpuRef + synthetic weights)")
}

#[test]
fn generation_is_deterministic() {
    let mut e = engine("mixtral_ish", DropPolicy::NoDrop);
    let prompts = ["cpy:abc|", "add:3+4|"];
    let a = e.generate_batch(&prompts, 8).unwrap();
    let b = e.generate_batch(&prompts, 8).unwrap();
    assert_eq!(a, b);
    assert!(a.iter().all(|s| s.len() <= 8));
}

#[test]
fn batched_equals_single_generation() {
    // Continuous batching must not change results: each prompt generated
    // alone equals the same prompt generated in a batch.
    let mut e = engine("mixtral_ish", DropPolicy::NoDrop);
    let prompts = ["cpy:abc|", "rev:fgh|", "maj:aabab|", "srt:dcba|"];
    let batched = e.generate_batch(&prompts, 8).unwrap();
    for (i, p) in prompts.iter().enumerate() {
        let single = e.generate_batch(&[p], 8).unwrap();
        assert_eq!(single[0], batched[i], "prompt {p}");
    }
}

#[test]
fn partial_transform_split_preserves_outputs() {
    // Eq. 13 at the engine level: serving every expert as two
    // sub-experts with the repeated score reproduces the generation.
    let prompts = ["cpy:abcd|", "add:5+2|", "bal:()()|", "ind:a3 b4 c5 b|"];
    let mut normal = engine("mixtral_ish", DropPolicy::NoDrop);
    let base = normal.generate_batch(&prompts, 8).unwrap();
    let mut split = engine("mixtral_ish", DropPolicy::NoDrop);
    split.force_split = true;
    let got = split.generate_batch(&prompts, 8).unwrap();
    assert_eq!(base, got, "partial transformation must be output-preserving");
}

#[test]
fn drop_rate_increases_with_threshold() {
    let mut e = engine("olmoe_ish", DropPolicy::NoDrop);
    let mut last = -1.0;
    for t in [0.0f32, 0.1, 0.25] {
        e.policy = if t == 0.0 { DropPolicy::NoDrop } else { DropPolicy::OneT(t) };
        e.reset_metrics();
        evaluate(&mut e, 4, false).unwrap();
        let rate = e.metrics.drop_rate();
        assert!(rate >= last, "rate {rate} < {last} at T={t}");
        last = rate;
    }
    assert!(last > 0.05, "top-4 routing at T=0.25 must drop something");
}

#[test]
fn two_t_bands_execute_major_only() {
    // Top-2 normalized scores live near 0.5, so a band straddling 0.45
    // reliably routes some pairs major-only on trained *and* synthetic
    // gates (a band at 0.30 only sees 5σ outliers on near-uniform
    // untrained gating).
    let mut e = engine("mixtral_ish", DropPolicy::two_t(0.45));
    e.reset_metrics();
    evaluate(&mut e, 3, false).unwrap();
    let total = e.metrics.total_drop();
    assert!(total.major_only > 0, "2T should route some pairs major-only");
    // MoE ran half-width (major) kernels
    let stats = e.exec_stats();
    assert!(
        stats.keys().any(|k| k.starts_with("ffn_h64_")),
        "half-width (major) FFN kernels must have executed: {:?}",
        stats.keys().collect::<Vec<_>>()
    );
}

#[test]
fn shared_expert_counted_in_drop_rate() {
    let mut e = engine("deepseek_ish", DropPolicy::OneT(0.9));
    e.reset_metrics();
    evaluate(&mut e, 2, false).unwrap();
    // Nearly all routed pairs dropped, but the shared expert keeps the
    // denominator > 0 ⇒ drop rate strictly below 1.
    let rate = e.metrics.drop_rate();
    assert!(rate > 0.3 && rate < 0.95, "deepseek drop rate {rate}");
    assert!(e.metrics.shared_pairs > 0);
}

#[test]
fn ep_device_accounting() {
    let opts = EngineOptions {
        ep: Some(EpOptions::new(4, false)),
        ..Default::default()
    };
    let mut e = Engine::new(&artifacts(), "olmoe_ish", DropPolicy::NoDrop, opts).unwrap();
    e.generate_batch(&["cpy:abc|", "rev:def|"], 6).unwrap();
    let m = &e.metrics;
    assert_eq!(m.device_time.len(), 4);
    assert!(m.device_time.iter().all(|&t| t > 0.0), "{:?}", m.device_time);
    assert!(m.device_load.iter().sum::<u64>() > 0);
    assert!(m.makespan() >= m.device_time.iter().sum::<f64>() / 4.0);
}

#[test]
fn load_aware_keeps_more_compute_at_same_max_threshold() {
    let reqs: Vec<&str> = vec!["cpy:abcd|", "add:3+3|", "srt:cbad|", "maj:abbba|"];
    let mk = |aware: bool| {
        let opts = EngineOptions {
            ep: Some(EpOptions::new(4, aware)),
            ..Default::default()
        };
        Engine::new(&artifacts(), "olmoe_ish", DropPolicy::OneT(0.2), opts).unwrap()
    };
    let mut uni = mk(false);
    uni.generate_batch(&reqs, 6).unwrap();
    let mut aware = mk(true);
    aware.generate_batch(&reqs, 6).unwrap();
    let kept = |e: &Engine| {
        let t = e.metrics.total_drop();
        t.full + t.major_only
    };
    assert!(
        kept(&aware) >= kept(&uni),
        "load-aware must keep at least as many pairs ({} vs {})",
        kept(&aware),
        kept(&uni)
    );
}

#[test]
fn calibration_produces_nonzero_tables() {
    let mut e = engine("mixtral_ish", DropPolicy::NoDrop);
    let tables = dualsparse::calib::run_calibration(&mut e, 256).unwrap();
    assert_eq!(tables.t.len(), e.cfg.n_layers);
    let total: f32 = tables.t[0].iter().flat_map(|e| e[1].iter()).sum();
    assert!(total > 0.0, "abs-gate accumulations must be positive");
    // abs rows dominate signed rows
    for layer in &tables.t {
        for exp in layer {
            for (s, a) in exp[0].iter().zip(&exp[1]) {
                assert!(*a >= s.abs() - 1e-3);
            }
        }
    }
}

#[test]
fn reconstruction_no_drop_is_output_preserving() {
    // Permuting neurons (reconstruction) + NoDrop must not change
    // generations: permutation invariance end-to-end through the
    // backend.
    let mut base = engine("mixtral_ish", DropPolicy::NoDrop);
    let prompts = ["cpy:hgf|", "add:1+9|", "lm:the mo|"];
    let want = base.generate_batch(&prompts, 8).unwrap();
    let tables = dualsparse::calib::run_calibration(&mut base, 128).unwrap();
    let opts = EngineOptions {
        reconstructed: true,
        importance: Some(tables.importance("abs_gate")),
        ..Default::default()
    };
    let mut recon = Engine::new(&artifacts(), "mixtral_ish", DropPolicy::NoDrop, opts).unwrap();
    recon.force_split = true; // run major+minor separately, still exact
    let got = recon.generate_batch(&prompts, 8).unwrap();
    assert_eq!(want, got);
}

#[test]
fn one_t_zero_threshold_equals_no_drop() {
    // DropPolicy::OneT(0.0) keeps every pair ⇒ generations match NoDrop
    // token for token (the NoDrop reference bound of backend_parity,
    // here at the full engine level).
    let prompts = ["cpy:abc|", "srt:badc|", "lm:a dog |"];
    let mut a = engine("mixtral_ish", DropPolicy::NoDrop);
    let mut b = engine("mixtral_ish", DropPolicy::OneT(0.0));
    assert_eq!(
        a.generate_batch(&prompts, 8).unwrap(),
        b.generate_batch(&prompts, 8).unwrap()
    );
}

#[test]
fn backend_reports_platform_and_counters() {
    let mut e = engine("mixtral_ish", DropPolicy::NoDrop);
    assert!(!e.rt.platform().is_empty());
    e.generate_batch(&["cpy:ab|"], 4).unwrap();
    let stats = e.exec_stats();
    assert!(stats.keys().any(|k| k.starts_with("attn_prefill_s")), "{stats:?}");
    assert!(stats.keys().any(|k| k.starts_with("gate_b")), "{stats:?}");
    assert!(stats.keys().any(|k| k.starts_with("lm_head_b")), "{stats:?}");
    assert!(e.moe_time() >= 0.0);
    assert!(e.total_artifact_time() >= e.moe_time());
}
