//! Shared utilities: deterministic RNG, JSON, stats, timing, threads.

pub mod json;
pub mod linalg;
pub mod rng;
pub mod stats;
pub mod threads;

use std::time::Instant;

/// Wall-clock stopwatch returning seconds.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Round `n` up to the nearest bucket; falls back to the largest bucket.
/// Central to the capacity-bucket dispatch (DESIGN.md §6).
pub fn round_up_bucket(n: usize, buckets: &[usize]) -> usize {
    for &b in buckets {
        if n <= b {
            return b;
        }
    }
    *buckets.last().expect("empty bucket list")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_rounding() {
        let b = [4, 8, 16];
        assert_eq!(round_up_bucket(1, &b), 4);
        assert_eq!(round_up_bucket(4, &b), 4);
        assert_eq!(round_up_bucket(5, &b), 8);
        assert_eq!(round_up_bucket(99, &b), 16);
    }
}
