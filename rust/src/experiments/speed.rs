//! Efficiency experiments: Fig. 10 (drop-rate → real speedup across
//! deployments) and Fig. 11 (load-aware thresholding under EP=8).

use std::path::Path;

use anyhow::Result;

use super::{
    ensure_importance, eval_with_rate, find_threshold, mk_engine,
    mk_engine_ep, save_result,
};
use crate::engine::scheduler::serve;
use crate::moe::DropPolicy;
use crate::server::{compare, format_report, run_once, workload};
use crate::tasks::eval::avg_accuracy;
use crate::util::json::{num, obj, s, Json};
use crate::util::stats::speedup_ratio;

fn n_requests() -> usize {
    std::env::var("DUALSPARSE_REQS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(120)
}

/// Fig. 10 — actual speedups of 1T/2T-Drop at the Table-2 drop rates,
/// across the three models / deployment styles.
pub fn fig10(artifacts: &Path) -> Result<()> {
    println!("Fig.10 — MoE-module / end-to-end speedup from computation dropping");
    let reqs = workload(n_requests(), 12, 7);
    let mut records = Vec::new();
    for (model, target) in [
        ("mixtral_ish", 0.24),
        ("olmoe_ish", 0.22),
        ("deepseek_ish", 0.27),
    ] {
        let t1 = find_threshold(artifacts, model, target)?;
        let mut engine = mk_engine(artifacts, model, DropPolicy::NoDrop)?;
        let baseline = run_once(&mut engine, &reqs, DropPolicy::NoDrop, "no-drop")?;
        let mut runs = vec![
            run_once(&mut engine, &reqs, DropPolicy::OneT(t1), "1T-Drop")?,
            run_once(&mut engine, &reqs, DropPolicy::two_t(t1), "2T-Drop")?,
        ];
        compare(&baseline, &mut runs);
        println!("--- {model} (T¹={t1:.3}) ---");
        println!("{}", format_report(&baseline));
        for r in &runs {
            println!("{}", format_report(r));
            records.push(obj(vec![
                ("model", s(model)),
                ("method", s(&r.label)),
                ("drop_rate", num(r.stats.drop_rate)),
                ("moe_speedup", num(r.moe_speedup)),
                ("e2e_speedup", num(r.e2e_speedup)),
                ("tokens_per_sec", num(r.stats.tokens_per_sec)),
            ]));
        }
    }
    save_result(artifacts, "fig10", Json::Arr(records))?;
    println!(
        "(paper: 22-27% drop → 1.17-1.23× MoE-module and 1.07-1.12× e2e;\n\
         tensor-level drops convert to real speedup because the saved work\n\
         is whole capacity-bucket GEMMs)"
    );
    Ok(())
}

/// Fig. 11 — speedup vs accuracy for 1T / 2T / 2T+load-aware under EP=8
/// on the DeepSeek stand-in. Speedup = MoE makespan ratio (max
/// per-device busy time), the quantity EP inference is blocked on.
pub fn fig11(artifacts: &Path) -> Result<()> {
    let model = "deepseek_ish";
    let n_dev = 8;
    println!("Fig.11 — EP={n_dev} load-aware thresholding ({model})");
    ensure_importance(artifacts, model)?;
    let reqs = workload(n_requests().min(80), 10, 11);
    // deepseek_ish routes top-2 (normalized scores cluster near 0.5), so
    // paper-scale drop rates need higher thresholds than the paper's
    // top-6 DeepSeek-V2-Lite.
    let thresholds = [0.20f32, 0.35, 0.50];

    // e2e model under EP: the non-MoE artifact work is replicated per
    // device, the MoE part is blocked on the slowest device (makespan).
    let e2e_time = |e: &crate::engine::Engine| {
        let ffn_total: f64 = e.metrics.device_time.iter().sum();
        (e.total_artifact_time() - ffn_total).max(0.0) + e.metrics.makespan()
    };

    // Baseline: no drop, EP makespan.
    let mut base = mk_engine_ep(artifacts, model, DropPolicy::NoDrop, n_dev, false, false)?;
    serve(&mut base, &reqs)?; // warm compile
    base.reset_metrics();
    serve(&mut base, &reqs)?;
    let base_makespan = base.metrics.makespan();
    let base_e2e = e2e_time(&base);
    let (bres, _) = eval_with_rate(&mut base)?;
    let base_acc = avg_accuracy(&bres);
    let base_math = bres.iter().find(|r| r.task == "add").unwrap().accuracy;
    println!(
        "baseline: makespan={:.3}s acc={:.2} math={:.1}",
        base_makespan, base_acc, base_math
    );

    let mut records = Vec::new();
    for &t in &thresholds {
        for (label, policy, load_aware, recon) in [
            ("1T", DropPolicy::OneT(t), false, false),
            ("2T", DropPolicy::two_t(t), false, true),
            ("2T+load-aware", DropPolicy::two_t(t), true, true),
        ] {
            let mut e = mk_engine_ep(artifacts, model, policy, n_dev, load_aware, recon)?;
            serve(&mut e, &reqs)?; // warm compile
            e.reset_metrics();
            serve(&mut e, &reqs)?;
            let makespan = e.metrics.makespan();
            let moe_speedup = speedup_ratio(base_makespan, makespan);
            let e2e_speedup = speedup_ratio(base_e2e, e2e_time(&e));
            let (res, rate) = eval_with_rate(&mut e)?;
            let acc = avg_accuracy(&res);
            let math = res.iter().find(|r| r.task == "add").unwrap().accuracy;
            println!(
                "T={t:.2} {label:<14} drop={:>5.1}% moe×{moe_speedup:<5.2} \
                 e2e×{e2e_speedup:<5.2} avg={acc:.2} ({:+.2}) math={math:.1}",
                100.0 * rate,
                acc - base_acc,
            );
            records.push(obj(vec![
                ("threshold", num(t as f64)),
                ("method", s(label)),
                ("drop_rate", num(rate)),
                ("moe_speedup", num(moe_speedup)),
                ("e2e_speedup", num(e2e_speedup)),
                ("avg_acc", num(acc)),
                ("math_acc", num(math)),
            ]));
        }
    }
    save_result(artifacts, "fig11", Json::Arr(records))?;
    println!(
        "(paper: 2T beats 1T on accuracy at equal speedup, and load-aware\n\
         thresholding recovers further accuracy — 1.41× MoE speedup at\n\
         −0.5% avg accuracy)"
    );
    Ok(())
}
