//! TCP network front end: socket-fed arrivals, per-token streaming,
//! and disconnect-driven cancellation.
//!
//! The serving loop itself lives in
//! [`crate::engine::scheduler::serve_source`]; this module supplies its
//! live endpoints:
//!
//! * an accept loop + per-connection reader/writer threads speaking a
//!   framed NDJSON protocol (one JSON object per line in both
//!   directions), parsed *incrementally* off the socket by
//!   [`FrameDecoder`] — a request body is never buffered beyond the
//!   frame-size bound, and an oversized frame is discarded as it
//!   streams in;
//! * [`SocketSource`], the [`ArrivalSource`] that drains the inbound
//!   queue into the scheduler; and
//! * [`NetSink`], the per-request [`TokenSink`] that writes a `token`
//!   frame the moment a decode step (or the final prefill chunk)
//!   retires a token, then a terminal `done` / `rejected` /
//!   `cancelled` / `timed_out` / `failed` frame — so the five-way
//!   exactly-once lifecycle is observable on the wire.
//!
//! Failure handling is one path, shared with injected faults: a read
//! or write error on a connection marks every request it still has in
//! flight in the run's [`CancelSet`], and the scheduler's next sweep
//! retires them as `Cancelled` and frees their KV pages immediately.
//!
//! ## Wire protocol
//!
//! Client → server frames (`op` discriminates):
//!
//! ```json
//! {"op":"generate","prompt":"...","max_new":16,"priority":0,
//!  "deadline_ms":500,"tag":"r0"}
//! {"op":"shutdown"}
//! ```
//!
//! Only `prompt` is required. `tag` is an opaque client string echoed
//! on every response frame for that request. `shutdown` stops the
//! accept loop, drains every in-flight request to a terminal state,
//! and ends the serve run (the graceful-shutdown path).
//!
//! Server → client frames (`frame` discriminates): `token`, `done`,
//! `rejected`, `cancelled`, `timed_out`, `failed`, `error` (a frame
//! the connection layer refused: malformed, oversized, unknown op,
//! connection queue full, shutting down), and `shutdown` (the ack).
//! Concatenating a request's `token` texts reproduces its `done` text
//! byte-for-byte.

use std::collections::{HashSet, VecDeque};
use std::io::{BufWriter, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::engine::faults::CancelSet;
use crate::engine::policy::SchedulingPolicy;
use crate::engine::scheduler::{
    serve_source, Arrival, ArrivalSource, Casualty, Completion, Phase, Rejection, Request,
    SchedOptions, ServeOutcome, ServeStats, SinkClosed, TokenSink,
};
use crate::engine::Engine;
use crate::util::json::{num, obj, s, write_ndjson, FrameDecoder, FrameEvent, Json};
use crate::util::stats::percentile;

/// Connection-layer knobs (the scheduler's own bounds — global
/// admission control, deadlines — live in [`SchedOptions`]).
#[derive(Debug, Clone)]
pub struct NetOptions {
    /// Per-connection bound on requests accepted but not yet terminal.
    /// Past it, `generate` frames are refused with an `error` frame —
    /// the connection-level backpressure in front of the scheduler's
    /// global admission control.
    pub conn_queue: usize,
    /// Largest request frame the decoder will buffer; bigger frames
    /// are discarded as they stream in and answered with `error`.
    pub max_frame_bytes: usize,
    /// `max_new` for `generate` frames that do not carry one.
    pub default_max_new: usize,
}

impl Default for NetOptions {
    fn default() -> Self {
        NetOptions { conn_queue: 32, max_frame_bytes: 64 * 1024, default_max_new: 16 }
    }
}

/// Wire-level counters for the run (the scheduler's own accounting is
/// in [`ServeStats`]).
#[derive(Debug, Clone, Default)]
pub struct NetStats {
    /// Requests delivered to the scheduler (== the submitted count the
    /// five-way exactly-once identity covers).
    pub accepted_requests: usize,
    /// Connections accepted over the run.
    pub connections: usize,
    /// Connections that dropped with requests still in flight (each
    /// drove its requests through the disconnect → Cancelled path).
    pub disconnects: usize,
    /// Frames refused at the connection layer: malformed, oversized,
    /// unknown op, bad fields, per-connection queue full, shutdown.
    pub inbound_rejections: usize,
    /// `token` frames written — streaming is real iff this exceeds the
    /// completion count.
    pub token_frames: u64,
    /// Median seconds from reading a `generate` frame to writing its
    /// first `token` frame — TTFT as a client on this host observes
    /// it (queue wait + prefill + frame plumbing).
    pub client_ttft50: f64,
}

/// State shared between the socket threads and the scheduler thread.
struct Shared {
    inbound: Mutex<VecDeque<NetArrival>>,
    /// Set by a `shutdown` frame (under the `inbound` lock, so a frame
    /// admitted concurrently is either refused or drained — never
    /// stranded). Stops the accept loop and, once the queue drains,
    /// ends the serve run.
    shutdown: AtomicBool,
    cancel: CancelSet,
    connections: AtomicUsize,
    disconnects: AtomicUsize,
    inbound_rejections: AtomicUsize,
    token_frames: AtomicU64,
    ttfts: Mutex<Vec<f64>>,
}

/// One accepted request, parked between the reader thread and
/// [`SocketSource::poll`].
struct NetArrival {
    conn: Arc<Conn>,
    prompt: String,
    max_new: usize,
    priority: u8,
    deadline_secs: Option<f64>,
    tag: Option<String>,
    received: Instant,
}

/// Per-connection shared state. The writer thread owns the stream's
/// write half; everyone else talks to it through the channel.
struct Conn {
    /// `None` once the connection is torn down (dropping the sender
    /// unblocks the writer thread).
    tx: Mutex<Option<Sender<Json>>>,
    /// Request ids this connection has in flight in the scheduler.
    live: Mutex<HashSet<usize>>,
    /// Requests accepted but not yet terminal (backpressure gauge;
    /// counts queued-inbound as well as live ids).
    pending: AtomicUsize,
    dead: AtomicBool,
}

impl Conn {
    fn new(tx: Sender<Json>) -> Self {
        Conn {
            tx: Mutex::new(Some(tx)),
            live: Mutex::new(HashSet::new()),
            pending: AtomicUsize::new(0),
            dead: AtomicBool::new(false),
        }
    }

    /// Queue one frame to the writer thread. Fails iff the connection
    /// is (or just became) dead — the caller treats that as a closed
    /// sink.
    fn send(&self, frame: Json) -> std::result::Result<(), SinkClosed> {
        if self.dead.load(Ordering::SeqCst) {
            return Err(SinkClosed);
        }
        match self.tx.lock().expect("conn.tx lock").as_ref() {
            Some(tx) => tx.send(frame).map_err(|_| SinkClosed),
            None => Err(SinkClosed),
        }
    }

    /// Tear the connection down exactly once: close the writer channel
    /// and flip every live request into the run's [`CancelSet`] so the
    /// scheduler's next sweep frees its pages.
    fn hangup(&self, shared: &Shared) {
        if self.dead.swap(true, Ordering::SeqCst) {
            return;
        }
        *self.tx.lock().expect("conn.tx lock") = None;
        let live: Vec<usize> = self.live.lock().expect("conn.live lock").drain().collect();
        if !live.is_empty() {
            shared.disconnects.fetch_add(1, Ordering::SeqCst);
            for id in live {
                shared.cancel.cancel(id);
            }
        }
    }

    /// A request reached a terminal state: drop it from the live set
    /// *before* its terminal frame is written, so a hangup racing the
    /// frame can no longer cancel an already-resolved id.
    fn finish(&self, id: usize) {
        self.live.lock().expect("conn.live lock").remove(&id);
        self.pending.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The [`TokenSink`] half of a connection: one per in-flight request,
/// owned by the scheduler.
struct NetSink {
    conn: Arc<Conn>,
    shared: Arc<Shared>,
    id: usize,
    tag: Option<String>,
    received: Instant,
    got_first: bool,
}

impl NetSink {
    fn frame(&self, kind: &str, extra: Vec<(&str, Json)>) -> Json {
        let mut pairs = vec![("frame", s(kind)), ("id", num(self.id as f64))];
        pairs.extend(extra);
        if let Some(t) = &self.tag {
            pairs.push(("tag", s(t)));
        }
        obj(pairs)
    }
}

impl TokenSink for NetSink {
    fn token(&mut self, tok: u8) -> std::result::Result<(), SinkClosed> {
        let text = (tok as char).to_string();
        self.conn.send(self.frame("token", vec![("text", s(&text))]))?;
        self.shared.token_frames.fetch_add(1, Ordering::SeqCst);
        if !self.got_first {
            self.got_first = true;
            let t = self.received.elapsed().as_secs_f64();
            self.shared.ttfts.lock().expect("ttfts lock").push(t);
        }
        Ok(())
    }

    fn done(&mut self, c: &Completion) {
        self.conn.finish(self.id);
        let _ = self.conn.send(self.frame(
            "done",
            vec![
                ("text", s(&c.text)),
                ("new_tokens", num(c.new_tokens as f64)),
                ("ttft_ms", num(c.ttft * 1e3)),
                ("latency_ms", num(c.latency * 1e3)),
            ],
        ));
    }

    fn rejected(&mut self, r: &Rejection) {
        self.conn.finish(self.id);
        let _ = self.conn.send(self.frame("rejected", vec![("reason", s(&r.reason))]));
    }

    fn casualty(&mut self, c: &Casualty) {
        self.conn.finish(self.id);
        let kind = match c.phase {
            Phase::TimedOut => "timed_out",
            Phase::Failed => "failed",
            _ => "cancelled",
        };
        let _ = self.conn.send(self.frame(
            kind,
            vec![("reason", s(&c.reason)), ("generated", num(c.generated as f64))],
        ));
    }
}

/// The [`ArrivalSource`] over the shared inbound queue: assigns the
/// run-global request ids, registers each with its connection, and
/// attaches the streaming sink.
struct SocketSource {
    shared: Arc<Shared>,
    delivered: usize,
}

impl ArrivalSource for SocketSource {
    fn poll(&mut self, now: f64) -> Vec<Arrival> {
        let drained: Vec<NetArrival> =
            self.shared.inbound.lock().expect("inbound lock").drain(..).collect();
        drained
            .into_iter()
            .map(|na| {
                let id = self.delivered;
                self.delivered += 1;
                na.conn.live.lock().expect("conn.live lock").insert(id);
                if na.conn.dead.load(Ordering::SeqCst) {
                    // The client vanished while this request sat in the
                    // inbound queue (after its hangup drained `live`).
                    // Deliver it cancelled so it is still accounted.
                    self.shared.cancel.cancel(id);
                }
                let sink = NetSink {
                    conn: na.conn.clone(),
                    shared: self.shared.clone(),
                    id,
                    tag: na.tag,
                    received: na.received,
                    got_first: false,
                };
                Arrival {
                    request: Request {
                        id,
                        prompt: na.prompt,
                        max_new: na.max_new,
                        priority: na.priority,
                        deadline_secs: na.deadline_secs,
                    },
                    at: now,
                    sink: Some(Box::new(sink)),
                }
            })
            .collect()
    }

    fn next_arrival(&self) -> Option<f64> {
        None // live source: the scheduler polls at its idle cadence
    }

    fn exhausted(&self) -> bool {
        // Checked under the inbound lock: a reader admits a frame only
        // while `shutdown` is unset under this same lock, so shutdown
        // + empty here means no request can appear later.
        let inbound = self.shared.inbound.lock().expect("inbound lock");
        self.shared.shutdown.load(Ordering::SeqCst) && inbound.is_empty()
    }
}

/// A bound TCP listener plus its accept thread. `serve` runs the
/// scheduler loop on the calling thread until a `shutdown` frame
/// drains the run.
pub struct NetServer {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and
    /// start accepting connections. Requests queue up until [`serve`]
    /// starts draining them.
    ///
    /// [`serve`]: NetServer::serve
    pub fn bind(addr: &str, opts: NetOptions) -> Result<NetServer> {
        let sock: SocketAddr =
            addr.parse().with_context(|| format!("--listen {addr:?} is not HOST:PORT"))?;
        let listener = TcpListener::bind(sock).with_context(|| format!("binding {sock}"))?;
        let local_addr = listener.local_addr().context("resolving bound address")?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let shared = Arc::new(Shared {
            inbound: Mutex::new(VecDeque::new()),
            shutdown: AtomicBool::new(false),
            cancel: CancelSet::new(),
            connections: AtomicUsize::new(0),
            disconnects: AtomicUsize::new(0),
            inbound_rejections: AtomicUsize::new(0),
            token_frames: AtomicU64::new(0),
            ttfts: Mutex::new(Vec::new()),
        });
        let accept_shared = shared.clone();
        let accept_thread = std::thread::spawn(move || {
            accept_loop(&listener, &accept_shared, &opts);
        });
        Ok(NetServer { shared, local_addr, accept_thread: Some(accept_thread) })
    }

    /// The address actually bound (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Run the scheduler over the socket queue until a `shutdown`
    /// frame arrives and every in-flight request reaches a terminal
    /// state. The run's [`CancelSet`] is installed over whatever the
    /// caller put in `sched.cancel` — disconnects must land in the set
    /// the loop sweeps.
    pub fn serve(
        mut self,
        engine: &mut Engine,
        policy: &dyn SchedulingPolicy,
        mut sched: SchedOptions,
    ) -> Result<(ServeOutcome, NetStats)> {
        sched.cancel = Some(self.shared.cancel.clone());
        let mut source = SocketSource { shared: self.shared.clone(), delivered: 0 };
        let outcome = serve_source(engine, &mut source, policy, sched)?;
        // The scheduler only returns after shutdown; reap the accept
        // thread (it exits within one poll interval).
        self.shared.shutdown.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        let ttfts = self.shared.ttfts.lock().expect("ttfts lock");
        let net = NetStats {
            accepted_requests: source.delivered,
            connections: self.shared.connections.load(Ordering::SeqCst),
            disconnects: self.shared.disconnects.load(Ordering::SeqCst),
            inbound_rejections: self.shared.inbound_rejections.load(Ordering::SeqCst),
            token_frames: self.shared.token_frames.load(Ordering::SeqCst),
            client_ttft50: percentile(&ttfts, 50.0),
        };
        Ok((outcome, net))
    }
}

impl Drop for NetServer {
    /// Stop accepting even if `serve` never ran (or errored out): the
    /// accept thread exits within one poll interval once `shutdown` is
    /// set.
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn accept_loop(listener: &TcpListener, shared: &Arc<Shared>, opts: &NetOptions) {
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                shared.connections.fetch_add(1, Ordering::SeqCst);
                spawn_connection(stream, shared.clone(), opts.clone());
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(_) => return, // listener died; the serve run ends via shutdown
        }
    }
}

fn spawn_connection(stream: TcpStream, shared: Arc<Shared>, opts: NetOptions) {
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let _ = stream.set_nodelay(true);
    let (tx, rx) = channel::<Json>();
    let conn = Arc::new(Conn::new(tx));
    let wconn = conn.clone();
    let wshared = shared.clone();
    std::thread::spawn(move || {
        let mut w = BufWriter::new(write_half);
        while let Ok(frame) = rx.recv() {
            if write_ndjson(&mut w, &frame).is_err() {
                wconn.hangup(&wshared);
                // Drain so senders never block on a dead peer (the
                // channel is unbounded, but the sender half is dropped
                // by hangup — this just empties what raced in).
                while rx.try_recv().is_ok() {}
                return;
            }
        }
    });
    std::thread::spawn(move || {
        reader_loop(stream, &conn, &shared, &opts);
        conn.hangup(&shared);
    });
}

fn reader_loop(mut stream: TcpStream, conn: &Arc<Conn>, shared: &Arc<Shared>, opts: &NetOptions) {
    let mut dec = FrameDecoder::new(opts.max_frame_bytes);
    let mut buf = [0u8; 4096];
    loop {
        let n = match stream.read(&mut buf) {
            Ok(0) | Err(_) => return, // EOF or error: caller hangs up
            Ok(n) => n,
        };
        for ev in dec.feed(&buf[..n]) {
            match ev {
                FrameEvent::Frame(v) => handle_frame(&v, conn, shared, opts),
                FrameEvent::Malformed(e) => {
                    refuse(conn, shared, None, &format!("malformed frame: {e}"));
                }
                FrameEvent::Oversized(size) => {
                    refuse(
                        conn,
                        shared,
                        None,
                        &format!("frame of {size} bytes exceeds {} limit", opts.max_frame_bytes),
                    );
                }
            }
        }
    }
}

/// Refuse one inbound frame with an `error` frame (counted — these are
/// the wire-level rejections the report surfaces).
fn refuse(conn: &Arc<Conn>, shared: &Arc<Shared>, tag: Option<&str>, reason: &str) {
    shared.inbound_rejections.fetch_add(1, Ordering::SeqCst);
    let mut pairs = vec![("frame", s("error")), ("reason", s(reason))];
    if let Some(t) = tag {
        pairs.push(("tag", s(t)));
    }
    let _ = conn.send(obj(pairs));
}

fn handle_frame(v: &Json, conn: &Arc<Conn>, shared: &Arc<Shared>, opts: &NetOptions) {
    let tag = v.opt("tag").and_then(|t| t.as_str().ok()).map(str::to_string);
    let op = match v.opt("op").and_then(|o| o.as_str().ok()) {
        Some(op) => op.to_string(),
        None => return refuse(conn, shared, tag.as_deref(), "missing \"op\""),
    };
    match op.as_str() {
        "generate" => {
            let prompt = match v.opt("prompt").and_then(|p| p.as_str().ok()) {
                Some(p) if !p.is_empty() => p.to_string(),
                Some(_) => {
                    return refuse(conn, shared, tag.as_deref(), "empty \"prompt\"");
                }
                None => {
                    return refuse(conn, shared, tag.as_deref(), "generate needs a \"prompt\"");
                }
            };
            let max_new = match v.opt("max_new") {
                Some(m) => match m.as_f64() {
                    Ok(x) if x >= 1.0 => x as usize,
                    _ => {
                        return refuse(
                            conn,
                            shared,
                            tag.as_deref(),
                            "\"max_new\" must be a number ≥ 1",
                        );
                    }
                },
                None => opts.default_max_new,
            };
            let priority = v
                .opt("priority")
                .and_then(|p| p.as_f64().ok())
                .map(|x| x.clamp(0.0, 2.0) as u8)
                .unwrap_or(0);
            let deadline_secs =
                v.opt("deadline_ms").and_then(|d| d.as_f64().ok()).map(|ms| ms / 1e3);
            if conn.pending.load(Ordering::SeqCst) >= opts.conn_queue {
                return refuse(
                    conn,
                    shared,
                    tag.as_deref(),
                    &format!("connection queue full ({} in flight)", opts.conn_queue),
                );
            }
            let arrival = NetArrival {
                conn: conn.clone(),
                prompt,
                max_new,
                priority,
                deadline_secs,
                tag,
                received: Instant::now(),
            };
            // Admit under the inbound lock so shutdown linearizes: a
            // frame either lands before the drain check or is refused.
            let mut inbound = shared.inbound.lock().expect("inbound lock");
            if shared.shutdown.load(Ordering::SeqCst) {
                drop(inbound);
                return refuse(conn, shared, arrival.tag.as_deref(), "server shutting down");
            }
            conn.pending.fetch_add(1, Ordering::SeqCst);
            inbound.push_back(arrival);
        }
        "shutdown" => {
            // Store under the inbound lock (see `SocketSource::exhausted`).
            let inbound = shared.inbound.lock().expect("inbound lock");
            shared.shutdown.store(true, Ordering::SeqCst);
            drop(inbound);
            let _ = conn.send(obj(vec![("frame", s("shutdown"))]));
        }
        other => refuse(conn, shared, tag.as_deref(), &format!("unknown op {other:?}")),
    }
}

/// One-line wire summary, printed next to the chaos line. The
/// `token_frames=` / `leaked_pages=` spellings are load-bearing: CI's
/// `net-smoke` job greps them to pin that streaming is real (more
/// token frames than completions) and nothing leaked.
pub fn format_net_report(net: &NetStats, leaked_pages: usize) -> String {
    format!(
        "net: connections={} disconnects={} accepted={} inbound_rejections={} \
         token_frames={} client_ttft50_ms={:.1} leaked_pages={}",
        net.connections,
        net.disconnects,
        net.accepted_requests,
        net.inbound_rejections,
        net.token_frames,
        net.client_ttft50 * 1e3,
        leaked_pages,
    )
}

/// Serialize a network serve run to the SERVE_cpu.json schema's net
/// variant: the usual stats columns that apply plus the wire columns
/// (see docs/REPORTS.md).
pub fn write_net_serve_json(
    model: &str,
    addr: &SocketAddr,
    st: &ServeStats,
    net: &NetStats,
    out: &std::path::Path,
) -> Result<()> {
    let j = obj(vec![
        ("model", s(model)),
        ("mode", s("network ndjson")),
        ("listen", s(&addr.to_string())),
        ("completed", num(st.requests as f64)),
        ("rejected", num(st.rejected as f64)),
        ("rejected_queue_full", num(st.rejected_queue_full as f64)),
        ("failed", num(st.failed as f64)),
        ("timed_out", num(st.timed_out as f64)),
        ("cancelled", num(st.cancelled as f64)),
        ("tokens_per_sec", num(st.tokens_per_sec)),
        ("goodput_rps", num(st.goodput_rps)),
        ("p50_latency", num(st.p50_latency)),
        ("p99_latency", num(st.p99_latency)),
        ("p50_ttft", num(st.p50_ttft)),
        ("p99_ttft", num(st.p99_ttft)),
        ("wall_secs", num(st.wall_secs)),
        ("drop_rate", num(st.drop_rate)),
        ("page_utilization", num(st.page_utilization)),
        ("connections", num(net.connections as f64)),
        ("disconnects", num(net.disconnects as f64)),
        ("accepted_requests", num(net.accepted_requests as f64)),
        ("inbound_rejections", num(net.inbound_rejections as f64)),
        ("token_frames", num(net.token_frames as f64)),
        ("client_ttft50", num(net.client_ttft50)),
    ]);
    let text = j.to_string() + "\n";
    std::fs::write(out, text).with_context(|| format!("writing {out:?}"))?;
    Ok(())
}

// ---------------------------------------------------------------------
// Loopback client driver (CI net-smoke + integration tests)
// ---------------------------------------------------------------------

/// One request the client driver submits.
#[derive(Debug, Clone)]
pub struct ClientRequest {
    /// Echoed on every response frame — the client's correlation key.
    pub tag: String,
    pub prompt: String,
    pub max_new: usize,
}

/// Client-observed outcome of one tagged request.
#[derive(Debug, Clone, Default)]
pub struct ClientOutcome {
    /// Concatenation of the `token` frame texts, in arrival order.
    pub streamed: String,
    /// The `done` frame's full text (None if the request ended
    /// rejected / cancelled / timed out / failed).
    pub done_text: Option<String>,
    /// Terminal frame kind (`done`, `rejected`, `cancelled`, …).
    pub terminal: String,
    /// Number of `token` frames that arrived before the terminal one.
    pub token_frames: usize,
    /// A `token` frame arrived strictly before the terminal frame.
    pub token_before_done: bool,
    /// Seconds from submit to the first `token` frame.
    pub ttft: Option<f64>,
}

/// What one driver connection observed.
#[derive(Debug, Clone, Default)]
pub struct ClientReport {
    /// Keyed by tag, submission order.
    pub outcomes: Vec<(String, ClientOutcome)>,
    /// `error` frames received (wire-level refusals).
    pub errors: usize,
    pub shutdown_acked: bool,
}

impl ClientReport {
    pub fn outcome(&self, tag: &str) -> Option<&ClientOutcome> {
        self.outcomes.iter().find(|(t, _)| t == tag).map(|(_, o)| o)
    }

    pub fn completions(&self) -> usize {
        self.outcomes.iter().filter(|(_, o)| o.terminal == "done").count()
    }

    pub fn token_frames(&self) -> usize {
        self.outcomes.iter().map(|(_, o)| o.token_frames).sum()
    }
}

/// Drive one connection: submit every request up front (tags must be
/// unique), stream responses until each reaches a terminal frame, then
/// optionally send `shutdown` and wait for the ack. Per-frame receive
/// gaps are bounded by a 60 s read timeout so a wedged server fails
/// loudly instead of hanging CI.
pub fn run_client(
    addr: &SocketAddr,
    reqs: &[ClientRequest],
    shutdown_after: bool,
) -> Result<ClientReport> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(60)))
        .context("setting read timeout")?;
    let _ = stream.set_nodelay(true);
    let submitted = Instant::now();
    for r in reqs {
        let frame = obj(vec![
            ("op", s("generate")),
            ("prompt", s(&r.prompt)),
            ("max_new", num(r.max_new as f64)),
            ("tag", s(&r.tag)),
        ]);
        write_ndjson(&mut stream, &frame)?;
    }
    if reqs.is_empty() && shutdown_after {
        write_ndjson(&mut stream, &obj(vec![("op", s("shutdown"))]))?;
    }
    let mut report = ClientReport::default();
    for r in reqs {
        report.outcomes.push((r.tag.clone(), ClientOutcome::default()));
    }
    let mut dec = FrameDecoder::new(1 << 20);
    let mut buf = [0u8; 4096];
    let mut terminal = 0usize;
    let mut shutdown_sent = reqs.is_empty() && shutdown_after;
    loop {
        if terminal == reqs.len() && !shutdown_after {
            return Ok(report);
        }
        if terminal == reqs.len() && shutdown_after && !shutdown_sent {
            write_ndjson(&mut stream, &obj(vec![("op", s("shutdown"))]))?;
            shutdown_sent = true;
        }
        let n = stream.read(&mut buf).context("reading response frames")?;
        if n == 0 {
            anyhow::bail!("server closed the connection with {terminal}/{} terminal", reqs.len());
        }
        for ev in dec.feed(&buf[..n]) {
            let v = match ev {
                FrameEvent::Frame(v) => v,
                other => anyhow::bail!("undecodable server frame: {other:?}"),
            };
            let kind = v.get("frame")?.as_str()?.to_string();
            if kind == "shutdown" {
                report.shutdown_acked = true;
                if terminal == reqs.len() {
                    return Ok(report);
                }
                continue;
            }
            if kind == "error" {
                report.errors += 1;
                terminal += 1; // an error frame is this request's only answer
                continue;
            }
            let tag = v.get("tag")?.as_str()?.to_string();
            let out = report
                .outcomes
                .iter_mut()
                .find(|(t, _)| *t == tag)
                .map(|(_, o)| o)
                .ok_or_else(|| anyhow::anyhow!("unknown tag {tag:?}"))?;
            if kind == "token" {
                out.streamed.push_str(v.get("text")?.as_str()?);
                out.token_frames += 1;
                if out.ttft.is_none() {
                    out.ttft = Some(submitted.elapsed().as_secs_f64());
                }
            } else {
                out.terminal = kind.clone();
                out.token_before_done = out.token_frames > 0;
                if kind == "done" {
                    out.done_text = Some(v.get("text")?.as_str()?.to_string());
                }
                terminal += 1;
            }
        }
    }
}

/// Connect, send a `shutdown` frame, and wait for the ack — the
/// graceful-shutdown trigger for tests and operators.
pub fn send_shutdown(addr: &SocketAddr) -> Result<()> {
    run_client(addr, &[], true).map(|_| ())
}
