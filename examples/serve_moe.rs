//! End-to-end serving driver (the DESIGN.md §"End-to-end validation"
//! example): load a trained model, serve a batched request workload
//! through the continuous-batching scheduler under several drop policies, and
//! report latency / throughput / MoE-module speedup.
//!
//!     cargo run --release --example serve_moe [model] [n_reqs]
//!
//! Hermetic on the `CpuRef` backend; `make artifacts` upgrades to
//! trained weights on PJRT.

#![allow(clippy::needless_range_loop, clippy::manual_memcpy, clippy::type_complexity)]

use anyhow::Result;
use dualsparse::engine::scheduler::{serve_with, ArrivalMode};
use dualsparse::engine::{artifacts_dir, EngineOptions};
use dualsparse::moe::DropPolicy;
use dualsparse::server::{compare, format_report, run_once, workload};
use dualsparse::Engine;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).map(|s| s.as_str()).unwrap_or("mixtral_ish");
    let n: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(100);
    let artifacts = artifacts_dir();

    let mut engine = Engine::new(
        &artifacts,
        model,
        DropPolicy::NoDrop,
        EngineOptions::default(),
    )?;
    println!(
        "serving {model} — {} requests, continuous batching over {} KV slots",
        n,
        dualsparse::engine::MAX_SLOTS
    );

    let reqs = workload(n, 12, 7);
    let baseline = run_once(&mut engine, &reqs, DropPolicy::NoDrop, "no-drop")?;
    let mut runs = vec![
        run_once(&mut engine, &reqs, DropPolicy::OneT(0.12), "1T-Drop T=0.12")?,
        run_once(&mut engine, &reqs, DropPolicy::two_t(0.12), "2T-Drop T=0.12")?,
        run_once(&mut engine, &reqs, DropPolicy::OneT(0.25), "1T-Drop T=0.25")?,
    ];
    compare(&baseline, &mut runs);

    println!("\n{}", format_report(&baseline));
    for r in &runs {
        println!("{}", format_report(r));
    }
    println!(
        "\nbaseline: wall={:.2}s gen={} tok ({:.1} tok/s), \
         mean latency {:.0} ms (queue-inclusive), p99 {:.0} ms, \
         ttft p50 {:.0} ms",
        baseline.stats.wall_secs,
        baseline.stats.generated_tokens,
        baseline.stats.tokens_per_sec,
        baseline.stats.mean_latency * 1e3,
        baseline.stats.p99_latency * 1e3,
        baseline.stats.p50_ttft * 1e3,
    );

    // Open loop: the same workload under deterministic Poisson arrivals
    // at ~1.5× the closed-loop service rate — queue wait becomes real
    // and the arrival-anchored latency columns show it.
    let rps = n as f64 / baseline.stats.wall_secs.max(1e-3);
    let open = serve_with(&mut engine, &reqs, ArrivalMode::Open { rate: 1.5 * rps, seed: 11 })?;
    println!(
        "\nopen-loop @ {:.1} req/s: p50={:.0}ms p99={:.0}ms (queue-incl.) \
         vs service p50={:.0}ms | ttft50={:.0}ms qdepth mean={:.1} max={} rejected={}",
        1.5 * rps,
        open.stats.p50_latency * 1e3,
        open.stats.p99_latency * 1e3,
        open.stats.p50_service * 1e3,
        open.stats.p50_ttft * 1e3,
        open.stats.mean_queue_depth,
        open.stats.max_queue_depth,
        open.stats.rejected,
    );
    // Scheduling policies under the same overload with a bounded queue:
    // admission order + backpressure are the serving levers the drop
    // policy can't reach (docs/ARCHITECTURE.md).
    use dualsparse::engine::policy::{AdmissionControl, PolicyKind};
    use dualsparse::engine::scheduler::serve_policy;
    println!("\nscheduling policies @ {:.1} req/s, max queue 32:", 1.5 * rps);
    for kind in PolicyKind::ALL {
        let out = serve_policy(
            &mut engine,
            &reqs,
            ArrivalMode::Open { rate: 1.5 * rps, seed: 11 },
            kind.policy(),
            AdmissionControl::bounded(32),
        )?;
        println!(
            "  {:>8}: ttft p50={:.0}ms p99={:.0}ms goodput={:.2} req/s \
             rejected={} (queue-full {})",
            kind.label(),
            out.stats.p50_ttft * 1e3,
            out.stats.p99_ttft * 1e3,
            out.stats.goodput_rps,
            out.stats.rejected,
            out.stats.rejected_queue_full,
        );
    }
    println!(
        "(the paper's Fig. 10 effect: drop rate converts into MoE-module\n\
         speedup because dropped pairs shrink whole capacity buckets)"
    );
    Ok(())
}
