//! Parallel-dispatch determinism + kernel-vs-naive property tests.
//!
//! The threaded CPU hot path promises that worker count is invisible in
//! the numerics: every parallel unit (expert task, attention head, GEMM
//! row block) computes exactly what the serial path computes and merges
//! in a fixed order. These tests pin that promise at the engine level
//! (byte-identical generations and metrics for `DUALSPARSE_THREADS=1`
//! vs `=8`) and pin the blocked linalg kernels against naive
//! triple-loop references on fuzzed shapes.

#![allow(clippy::needless_range_loop, clippy::manual_memcpy, clippy::type_complexity)]

use std::path::PathBuf;

use dualsparse::engine::{Engine, EngineOptions, EpOptions};
use dualsparse::model::Tensor;
use dualsparse::moe::DropPolicy;
use dualsparse::util::linalg::{matmul, matmul_bt, max_abs_diff, swiglu_ffn, swish};
use dualsparse::util::rng::SplitMix64;
use dualsparse::util::threads;

fn artifacts() -> PathBuf {
    std::env::var("DUALSPARSE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn randn(rng: &mut SplitMix64, shape: Vec<usize>, scale: f32) -> Tensor {
    let n = shape.iter().product();
    Tensor::new(shape, (0..n).map(|_| rng.gauss() as f32 * scale).collect())
}

/// Everything deterministic a generation run produces (timings
/// excluded — only those may differ across thread counts).
#[derive(Debug, PartialEq)]
struct RunFingerprint {
    generations: Vec<String>,
    full: u64,
    major_only: u64,
    dropped: u64,
    shared_pairs: u64,
    decode_steps: u64,
    prefill_tokens: u64,
    generated_tokens: u64,
    expert_counts: Vec<Vec<u64>>,
    raw_scores: Vec<f32>,
    device_load: Vec<u64>,
}

fn run_generation(threads: usize, ep: Option<EpOptions>) -> RunFingerprint {
    threads::set_thread_override(Some(threads));
    let opts = EngineOptions { collect_stats: true, ep, ..Default::default() };
    // two_t(0.45) exercises full, major-only and dropped bands on the
    // synthetic mixtral gates (top-2 norms cluster near 0.5).
    let mut e = Engine::new(&artifacts(), "mixtral_ish", DropPolicy::two_t(0.45), opts)
        .expect("hermetic engine");
    let prompts = ["cpy:abcd|", "add:3+4|", "srt:dcba|", "maj:aabab|", "rev:fgh|"];
    let generations = e.generate_batch(&prompts, 8).unwrap();
    threads::set_thread_override(None);
    let t = e.metrics.total_drop();
    RunFingerprint {
        generations,
        full: t.full,
        major_only: t.major_only,
        dropped: t.dropped,
        shared_pairs: e.metrics.shared_pairs,
        decode_steps: e.metrics.decode_steps,
        prefill_tokens: e.metrics.prefill_tokens,
        generated_tokens: e.metrics.generated_tokens,
        expert_counts: e.metrics.expert_counts.clone(),
        raw_scores: e.metrics.raw_scores.clone(),
        device_load: e.metrics.device_load.clone(),
    }
}

/// One test (not several) on purpose: the thread override is a
/// process-global, and cargo runs tests in one binary concurrently —
/// two tests flipping it could race and silently compare two runs at
/// the SAME thread count. Sequential in a single test, the 1-thread
/// and 8-thread fingerprints really come from different worker counts.
#[test]
fn one_thread_and_eight_threads_are_byte_identical() {
    let serial = run_generation(1, None);
    let threaded = run_generation(8, None);
    assert_eq!(serial, threaded, "thread count leaked into the numerics");
    assert!(serial.major_only > 0, "2T band must actually split work");

    let ep = || Some(EpOptions::new(4, true));
    let serial_ep = run_generation(1, ep());
    let threaded_ep = run_generation(8, ep());
    assert_eq!(serial_ep, threaded_ep);
    assert!(serial_ep.device_load.iter().sum::<u64>() > 0);
}

// ---------------------------------------------------------------------
// Kernel-vs-naive property tests (random shapes, ≤ 1e-5)
// ---------------------------------------------------------------------

fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let n = b.shape[1];
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            for j in 0..n {
                out[i * n + j] += a.data[i * k + p] * b.data[p * n + j];
            }
        }
    }
    Tensor::new(vec![m, n], out)
}

fn naive_matmul_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let n = b.shape[0];
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += a.data[i * k + p] * b.data[j * k + p];
            }
            out[i * n + j] = acc;
        }
    }
    Tensor::new(vec![m, n], out)
}

fn naive_swiglu(x: &Tensor, w1: &Tensor, w3: &Tensor, w2: &Tensor) -> Tensor {
    let g = naive_matmul(x, w1);
    let u = naive_matmul(x, w3);
    let h: Vec<f32> = g
        .data
        .iter()
        .zip(&u.data)
        .map(|(&gv, &uv)| swish(gv) * uv)
        .collect();
    naive_matmul(&Tensor::new(g.shape.clone(), h), w2)
}

#[test]
fn blocked_matmul_matches_naive_fuzz() {
    let mut rng = SplitMix64::new(0xB10C);
    for case in 0..40 {
        let m = 1 + rng.below(17);
        let k = 1 + rng.below(33);
        let n = 1 + rng.below(33);
        let a = randn(&mut rng, vec![m, k], 0.3);
        let b = randn(&mut rng, vec![k, n], 0.3);
        let err = max_abs_diff(&matmul(&a, &b), &naive_matmul(&a, &b));
        assert!(err <= 1e-5, "case {case}: matmul |Δ|={err} (m={m} k={k} n={n})");
    }
}

#[test]
fn blocked_matmul_bt_matches_naive_fuzz() {
    let mut rng = SplitMix64::new(0xB11C);
    for case in 0..40 {
        let m = 1 + rng.below(17);
        let k = 1 + rng.below(33);
        let n = 1 + rng.below(33);
        let a = randn(&mut rng, vec![m, k], 0.3);
        let b = randn(&mut rng, vec![n, k], 0.3);
        let err = max_abs_diff(&matmul_bt(&a, &b), &naive_matmul_bt(&a, &b));
        assert!(err <= 1e-5, "case {case}: matmul_bt |Δ|={err} (m={m} k={k} n={n})");
    }
}

#[test]
fn fused_swiglu_matches_naive_fuzz() {
    let mut rng = SplitMix64::new(0xB12C);
    for case in 0..30 {
        let c = 1 + rng.below(9);
        let d = 2 + rng.below(15);
        let h = 2 + rng.below(23);
        let x = randn(&mut rng, vec![c, d], 0.25);
        let w1 = randn(&mut rng, vec![d, h], 0.25);
        let w3 = randn(&mut rng, vec![d, h], 0.25);
        let w2 = randn(&mut rng, vec![h, d], 0.25);
        let err = max_abs_diff(
            &swiglu_ffn(&x, &w1, &w3, &w2),
            &naive_swiglu(&x, &w1, &w3, &w2),
        );
        assert!(err <= 1e-5, "case {case}: swiglu |Δ|={err} (c={c} d={d} h={h})");
    }
}
