"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references: pytest checks each Pallas kernel
(interpret=True) against these functions with `assert_allclose`, and the
Rust integration tests check the loaded HLO artifacts against golden
vectors generated from these same functions.
"""

import jax.numpy as jnp


def swish(x):
    """Swish / SiLU activation: x * sigmoid(x)."""
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def swiglu_ffn_ref(x, w1, w3, w2):
    """SwiGLU expert FFN (Eq. 4 of the paper).

    f(x) = (Swish(x @ W1) * (x @ W3)) @ W2

    Args:
      x:  [C, d_model] token block.
      w1: [d_model, d_ffn] gate projection.
      w3: [d_model, d_ffn] up projection.
      w2: [d_ffn, d_model] down projection.

    Returns:
      [C, d_model] expert output.
    """
    gate = swish(x @ w1)
    up = x @ w3
    return (gate * up) @ w2


def probe_ref(x, w1, w3):
    """Neuron-importance accumulators (Eqs. 14-17 of the paper).

    Returns [4, d_ffn]:
      row 0: sum_t Swish(x W1)            (accumulated gate)
      row 1: sum_t |Swish(x W1)|          (accumulated absolute gate)
      row 2: sum_t Swish(x W1) * (x W3)   (accumulated gate-up)
      row 3: sum_t |Swish(x W1) * (x W3)| (accumulated absolute gate-up)
    """
    gate = swish(x @ w1)
    up = x @ w3
    gu = gate * up
    return jnp.stack(
        [
            jnp.sum(gate, axis=0),
            jnp.sum(jnp.abs(gate), axis=0),
            jnp.sum(gu, axis=0),
            jnp.sum(jnp.abs(gu), axis=0),
        ]
    )


def gate_ref(x, wg):
    """Gating network (Eq. 1): softmax over expert logits."""
    logits = x @ wg
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def topk_mask_ref(scores, top_k):
    """Top-K selection mask (Eq. 2). Ties broken toward lower index.

    Uses lax.top_k (not jnp.sort): sort's JVP lowers to a batched gather
    that this image's xla_client cannot build.
    """
    import jax

    kth = jax.lax.top_k(scores, top_k)[0][:, -1:]
    return (scores >= kth).astype(scores.dtype)


def moe_ref(x, wg, w1s, w3s, w2s, top_k):
    """Dense reference of a full MoE layer (Eq. 3), no dropping.

    Args:
      x:   [T, d_model]
      wg:  [d_model, E]
      w1s/w3s: [E, d_model, d_ffn], w2s: [E, d_ffn, d_model]
      top_k: number of active experts per token.

    Returns [T, d_model].
    """
    scores = gate_ref(x, wg)  # [T, E]
    g = scores * topk_mask_ref(scores, top_k)  # gating weights, zeros elsewhere
    expert_outs = jnp.stack(
        [swiglu_ffn_ref(x, w1s[e], w3s[e], w2s[e]) for e in range(w1s.shape[0])],
        axis=1,
    )  # [T, E, d]
    return jnp.einsum("te,ted->td", g, expert_outs)
