//! Neuron-level sparsity parity suite (ISSUE-10).
//!
//! Three pins:
//!  1. **Byte-identity of the off-switch** — an engine built with
//!     `neuron_keep = Some(1.0)` and quant off produces a run
//!     fingerprint identical to today's dense engine (the keep mask
//!     normalizes away structurally: same artifact names, same args),
//!     and masked/quantized runs are thread-count invariant.
//!  2. **Masked kernel vs naive masked reference** — `swiglu_ffn_masked`
//!     must equal a per-neuron reference that zeroes masked rows, over
//!     fuzzed shapes and masks (empty, full, unsorted), ≤ 1e-5; the
//!     full in-order mask is *byte*-identical to the dense kernel.
//!  3. **Int8 error bounds** — per-element round-trip ≤ scale/2, and
//!     the end-to-end quantized engine moves logits by a nonzero amount
//!     bounded by a documented envelope.

#![allow(clippy::needless_range_loop)]

use std::path::PathBuf;

use dualsparse::calib;
use dualsparse::engine::{Engine, EngineOptions};
use dualsparse::model::Tensor;
use dualsparse::moe::DropPolicy;
use dualsparse::util::linalg::{
    dequantize, max_abs_diff, quantize_symmetric, swiglu_ffn, swiglu_ffn_masked,
    swiglu_ffn_masked_q8, swish,
};
use dualsparse::util::rng::SplitMix64;
use dualsparse::util::threads;

fn artifacts() -> PathBuf {
    std::env::var("DUALSPARSE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn randn(rng: &mut SplitMix64, shape: Vec<usize>, scale: f32) -> Tensor {
    let n = shape.iter().product();
    Tensor::new(shape, (0..n).map(|_| rng.gauss() as f32 * scale).collect())
}

/// Everything deterministic a generation run produces (timings
/// excluded — only those may differ across thread counts).
#[derive(Debug, PartialEq)]
struct RunFingerprint {
    generations: Vec<String>,
    full: u64,
    major_only: u64,
    dropped: u64,
    shared_pairs: u64,
    decode_steps: u64,
    prefill_tokens: u64,
    generated_tokens: u64,
    expert_counts: Vec<Vec<u64>>,
    raw_scores: Vec<f32>,
}

fn run_generation(threads: usize, opts: EngineOptions) -> RunFingerprint {
    threads::set_thread_override(Some(threads));
    // two_t(0.45) exercises full, major-only and dropped bands, so the
    // masked variants run on full/major/minor sub-experts alike.
    let mut e = Engine::new(&artifacts(), "mixtral_ish", DropPolicy::two_t(0.45), opts)
        .expect("hermetic engine");
    let prompts = ["cpy:abcd|", "add:3+4|", "srt:dcba|", "maj:aabab|", "rev:fgh|"];
    let generations = e.generate_batch(&prompts, 8).unwrap();
    threads::set_thread_override(None);
    let t = e.metrics.total_drop();
    RunFingerprint {
        generations,
        full: t.full,
        major_only: t.major_only,
        dropped: t.dropped,
        shared_pairs: e.metrics.shared_pairs,
        decode_steps: e.metrics.decode_steps,
        prefill_tokens: e.metrics.prefill_tokens,
        generated_tokens: e.metrics.generated_tokens,
        expert_counts: e.metrics.expert_counts.clone(),
        raw_scores: e.metrics.raw_scores.clone(),
    }
}

/// Hermetic importance tables for the test model (no artifacts dir, no
/// prior `dualsparse calibrate`).
fn calibrated_importance() -> Vec<Vec<Vec<f32>>> {
    let mut e = Engine::new(
        &artifacts(),
        "mixtral_ish",
        DropPolicy::NoDrop,
        EngineOptions::default(),
    )
    .expect("hermetic engine");
    let tables = calib::run_calibration(&mut e, 256).expect("calibration");
    tables.importance("abs_gate")
}

/// One test (not several) on purpose: the thread override is a
/// process-global, and cargo runs a binary's tests concurrently — two
/// tests flipping it could race and silently compare two runs at the
/// SAME thread count (see rust/tests/parallel.rs for the same pattern).
#[test]
fn keep_one_is_byte_identical_and_sparse_runs_are_thread_invariant() {
    let imp = calibrated_importance();
    let with = |keep: f32, quant: bool| EngineOptions {
        collect_stats: true,
        neuron_keep: Some(keep),
        quant,
        importance: Some(imp.clone()),
        ..Default::default()
    };
    let dense_opts = EngineOptions { collect_stats: true, ..Default::default() };

    // 1. keep = 1.0 / quant off must be indistinguishable from an
    // engine that never heard of ISSUE-10 — at any thread count.
    let dense = run_generation(1, dense_opts.clone());
    assert_eq!(
        run_generation(1, with(1.0, false)),
        dense,
        "keep=1.0/quant-off must be byte-identical to the dense engine"
    );
    assert_eq!(
        run_generation(8, with(1.0, false)),
        dense,
        "…and across thread counts"
    );
    assert_eq!(run_generation(8, dense_opts), dense, "dense baseline itself pins");

    // 2. A genuinely masked run and a quantized run are each
    // deterministic across thread counts (the numerics promise of the
    // threaded hot path extends to the new kernels).
    let masked_1 = run_generation(1, with(0.5, false));
    let masked_8 = run_generation(8, with(0.5, false));
    assert_eq!(masked_1, masked_8, "masked run leaked thread count");

    let quant_1 = run_generation(1, with(1.0, true));
    let quant_8 = run_generation(8, with(1.0, true));
    assert_eq!(quant_1, quant_8, "quantized run leaked thread count");

    let both_1 = run_generation(1, with(0.75, true));
    let both_8 = run_generation(8, with(0.75, true));
    assert_eq!(both_1, both_8, "masked+quantized run leaked thread count");
}

// ---------------------------------------------------------------------
// Masked kernel vs naive masked reference (fuzzed shapes/masks, ≤ 1e-5)
// ---------------------------------------------------------------------

/// Per-neuron reference: masked intermediate rows contribute exactly
/// zero; kept rows accumulate in mask order (the fused kernel gathers
/// the kept columns, so its accumulation order is the mask's too).
fn naive_masked_swiglu(
    x: &Tensor,
    w1: &Tensor,
    w3: &Tensor,
    w2: &Tensor,
    kept: &[usize],
) -> Tensor {
    let (m, d) = (x.shape[0], x.shape[1]);
    let h = w1.shape[1];
    let dout = w2.shape[1];
    let mut out = vec![0.0f32; m * dout];
    for i in 0..m {
        for &j in kept {
            let mut g = 0.0f32;
            let mut u = 0.0f32;
            for p in 0..d {
                g += x.data[i * d + p] * w1.data[p * h + j];
                u += x.data[i * d + p] * w3.data[p * h + j];
            }
            let a = swish(g) * u;
            for o in 0..dout {
                out[i * dout + o] += a * w2.data[j * dout + o];
            }
        }
    }
    Tensor::new(vec![m, dout], out)
}

#[test]
fn masked_kernel_matches_naive_masked_reference_on_fuzzed_shapes() {
    let mut rng = SplitMix64::new(0x15_5e10);
    for case in 0..200 {
        let m = 1 + rng.below(6);
        let d = 1 + rng.below(16);
        let h = 1 + rng.below(32);
        let dout = 1 + rng.below(12);
        let x = randn(&mut rng, vec![m, d], 0.5);
        let w1 = randn(&mut rng, vec![d, h], 0.5);
        let w3 = randn(&mut rng, vec![d, h], 0.5);
        let w2 = randn(&mut rng, vec![h, dout], 0.5);
        // Mask: every 4th case empty, every 4th+1 full (shuffled),
        // otherwise a random-size random-order subset — keep masks are
        // importance-ordered, so unsorted indices are the common case.
        let mut pool: Vec<usize> = (0..h).collect();
        for i in (1..pool.len()).rev() {
            pool.swap(i, rng.below(i + 1));
        }
        let kept: Vec<usize> = match case % 4 {
            0 => Vec::new(),
            1 => pool.clone(),
            _ => pool[..1 + rng.below(h)].to_vec(),
        };
        let got = swiglu_ffn_masked(&x, &w1, &w3, &w2, &kept);
        let want = naive_masked_swiglu(&x, &w1, &w3, &w2, &kept);
        assert!(
            max_abs_diff(&got, &want) <= 1e-5,
            "case {case}: masked kernel diverged (m={m} d={d} h={h} kept={})",
            kept.len()
        );
        if kept.is_empty() {
            assert!(got.data.iter().all(|&v| v == 0.0), "empty mask must be exact zero");
        }
        // Full *in-order* mask: the gather is an identity copy, so the
        // masked kernel is byte-identical to the dense fused kernel.
        let in_order: Vec<usize> = (0..h).collect();
        let full = swiglu_ffn_masked(&x, &w1, &w3, &w2, &in_order);
        let dense = swiglu_ffn(&x, &w1, &w3, &w2);
        assert_eq!(full.data, dense.data, "case {case}: full mask must be byte-identical");
    }
}

#[test]
fn masked_q8_kernel_tracks_dequantized_masked_reference() {
    let mut rng = SplitMix64::new(0x98_beef);
    for case in 0..40 {
        let m = 1 + rng.below(4);
        let d = 1 + rng.below(12);
        let h = 2 + rng.below(24);
        let dout = 1 + rng.below(8);
        let x = randn(&mut rng, vec![m, d], 0.5);
        let w1 = randn(&mut rng, vec![d, h], 0.5);
        let w3 = randn(&mut rng, vec![d, h], 0.5);
        let w2 = randn(&mut rng, vec![h, dout], 0.5);
        let (q1, s1) = quantize_symmetric(&w1);
        let (q3, s3) = quantize_symmetric(&w3);
        let (q2, s2) = quantize_symmetric(&w2);
        let kept: Vec<usize> = (0..h).filter(|_| rng.below(2) == 0).collect();
        let got = swiglu_ffn_masked_q8(&x, &q1, &q3, &q2, &[s1, s3, s2], &kept);
        // Reference: the same masked math on the *dequantized* weights —
        // isolates kernel error (in-register scale folding) from
        // quantization error.
        let want = naive_masked_swiglu(
            &x,
            &dequantize(&q1, s1),
            &dequantize(&q3, s3),
            &dequantize(&q2, s2),
            &kept,
        );
        assert!(
            max_abs_diff(&got, &want) <= 2e-3,
            "case {case}: masked q8 kernel diverged from dequantized reference"
        );
    }
}

// ---------------------------------------------------------------------
// Int8 error bounds: per-element round trip + end-to-end logits
// ---------------------------------------------------------------------

#[test]
fn int8_round_trip_error_is_bounded_by_half_scale() {
    let mut rng = SplitMix64::new(0xc0de);
    for _ in 0..50 {
        let w = randn(&mut rng, vec![1 + rng.below(8), 1 + rng.below(32)], 1.0);
        let (q, scale) = quantize_symmetric(&w);
        let back = dequantize(&q, scale);
        for (a, b) in w.data.iter().zip(&back.data) {
            assert!(
                (a - b).abs() <= scale / 2.0 + 1e-7,
                "round-trip error {} exceeds scale/2 = {}",
                (a - b).abs(),
                scale / 2.0
            );
        }
    }
}

/// End-to-end quantization envelope: the int8 engine's prefill logits
/// vs the f32 engine's, over fixed prompts under NoDrop.
///
/// The bound is a documented loose envelope, not a theorem: per-weight
/// error ≤ scale/2 (≈ 0.4% relative) compounds through 4 layers of the
/// synthetic mixtral_ish preset; measured max|Δlogit| sits well under
/// 0.5 with margin. The `> 0.0` half is the important one — a zero
/// here would mean the quant kernels silently ran dense weights.
#[test]
fn quantized_engine_moves_logits_within_documented_envelope() {
    let prompts = ["cpy:abcd|", "add:3+4|", "srt:dcba|"];
    let logits = |opts: EngineOptions| -> Vec<Vec<f32>> {
        let mut e = Engine::new(&artifacts(), "mixtral_ish", DropPolicy::NoDrop, opts)
            .expect("hermetic engine");
        prompts
            .iter()
            .map(|p| {
                e.kv.reset();
                let slot = e.kv.alloc();
                e.prefill_logits(slot, p.as_bytes()).expect("prefill").1
            })
            .collect()
    };
    let dense = logits(EngineOptions::default());
    let quant = logits(EngineOptions { quant: true, ..Default::default() });
    let mut dmax = 0.0f32;
    for (a, b) in dense.iter().zip(&quant) {
        assert_eq!(a.len(), b.len());
        for (&x, &y) in a.iter().zip(b) {
            dmax = dmax.max((x - y).abs());
        }
    }
    assert!(dmax > 0.0, "quantization must actually engage");
    assert!(dmax <= 0.5, "e2e quant error {dmax} exceeds the documented 0.5 envelope");
}
