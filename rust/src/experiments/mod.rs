//! Experiment drivers: one per paper table/figure (DESIGN.md §5).
//!
//! Every driver prints the paper-style rows/series to stdout and writes
//! a JSON record under `artifacts/results/` for EXPERIMENTS.md.

pub mod accuracy;
pub mod bench;
pub mod comm;
pub mod profiling;
pub mod speed;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::calib::{self, ProbeTables};
use crate::engine::{Engine, EngineOptions, EpOptions};
use crate::moe::DropPolicy;
use crate::tasks::eval::{evaluate, TaskResult};
use crate::util::json::Json;

/// Run one experiment by id ("fig1" … "table3", or "all").
pub fn run(id: &str, artifacts: &Path) -> Result<()> {
    let all = [
        "fig1", "fig4", "fig6", "fig7", "fig9", "fig10", "fig11", "fig12",
        "fig13", "table1", "table2", "table3",
    ];
    if id == "all" {
        for e in all {
            println!("\n================ {e} ================");
            run(e, artifacts)?;
        }
        return Ok(());
    }
    match id {
        "fig1" => profiling::fig1(artifacts),
        "fig4" => profiling::fig4(artifacts),
        "fig6" => profiling::fig6(artifacts),
        "fig7" => accuracy::fig7(artifacts),
        "fig9" => comm::fig9(artifacts),
        "fig10" => speed::fig10(artifacts),
        "fig11" => speed::fig11(artifacts),
        "fig12" => profiling::fig12(artifacts),
        "fig13" => profiling::fig13(artifacts),
        "table1" => accuracy::table1(artifacts),
        "table2" => accuracy::table2(artifacts),
        "table3" => accuracy::table3(artifacts),
        _ => bail!("unknown experiment {id}; one of {all:?} or 'all'"),
    }
}

/// Number of eval prompts per task (kept small: single-core testbed).
pub fn n_eval() -> usize {
    std::env::var("DUALSPARSE_EVAL_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24)
}

/// Calibration token budget.
pub fn n_calib() -> usize {
    std::env::var("DUALSPARSE_CALIB_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2048)
}

/// Build an engine for `model` with default options.
pub fn mk_engine(artifacts: &Path, model: &str, policy: DropPolicy) -> Result<Engine> {
    Engine::new(artifacts, model, policy, EngineOptions::default())
}

/// Build an engine with reconstruction (loads or creates importance
/// tables via calibration).
pub fn mk_engine_reconstructed(
    artifacts: &Path,
    model: &str,
    policy: DropPolicy,
    metric: &str,
) -> Result<Engine> {
    let tables = ensure_importance(artifacts, model)?;
    let opts = EngineOptions {
        reconstructed: true,
        importance: Some(tables.importance(metric)),
        ..Default::default()
    };
    Engine::new(artifacts, model, policy, opts)
}

/// Build an EP-simulated engine (fig10/fig11).
pub fn mk_engine_ep(
    artifacts: &Path,
    model: &str,
    policy: DropPolicy,
    n_devices: usize,
    load_aware: bool,
    reconstructed: bool,
) -> Result<Engine> {
    let importance = if reconstructed {
        Some(ensure_importance(artifacts, model)?.importance("abs_gate"))
    } else {
        None
    };
    let opts = EngineOptions {
        reconstructed,
        importance,
        collect_stats: false,
        ep: Some(EpOptions::new(n_devices, load_aware)),
        ..Default::default()
    };
    Engine::new(artifacts, model, policy, opts)
}

/// Load cached importance tables or run calibration now.
pub fn ensure_importance(artifacts: &Path, model: &str) -> Result<ProbeTables> {
    let path = calib::tables_path(artifacts, model);
    if path.exists() {
        return ProbeTables::load(&path);
    }
    println!("[calib] profiling {model} on {} tokens …", n_calib());
    let mut engine = mk_engine(artifacts, model, DropPolicy::NoDrop)?;
    let tables = calib::run_calibration(&mut engine, n_calib())?;
    tables.save(&path)?;
    Ok(tables)
}

/// Binary-search a 1T threshold that hits `target` drop rate on a probe
/// workload (mirrors the paper's per-model threshold tuning).
pub fn find_threshold(
    artifacts: &Path,
    model: &str,
    target: f64,
) -> Result<f32> {
    let mut engine = mk_engine(artifacts, model, DropPolicy::NoDrop)?;
    let probe = crate::tasks::calibration_tokens(512);
    let (mut lo, mut hi) = (0.0f32, 0.6f32);
    let mut best = 0.1;
    for _ in 0..10 {
        let mid = 0.5 * (lo + hi);
        engine.policy = DropPolicy::OneT(mid);
        engine.reset_metrics();
        for chunk in probe.chunks(32) {
            if chunk.len() < 2 {
                break;
            }
            engine.kv.reset();
            let slot = engine.kv.alloc();
            engine.prefill(slot, chunk)?;
        }
        let rate = engine.metrics.drop_rate();
        best = mid;
        if rate < target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Ok(best)
}

/// Save an experiment record to `artifacts/results/{name}.json`.
pub fn save_result(artifacts: &Path, name: &str, j: Json) -> Result<PathBuf> {
    let dir = artifacts.join("results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, j.to_string()).with_context(|| format!("writing {path:?}"))?;
    Ok(path)
}

/// Json of a full accuracy row.
pub fn acc_json(label: &str, drop_rate: f64, results: &[TaskResult]) -> Json {
    use crate::util::json::{num, obj, s};
    let mut pairs = vec![
        ("label", s(label)),
        ("drop_rate", num(drop_rate)),
        (
            "avg",
            num(crate::tasks::eval::avg_accuracy(results)),
        ),
    ];
    let tasks = Json::Obj(
        results
            .iter()
            .map(|r| (r.task.clone(), Json::Num(r.accuracy)))
            .collect(),
    );
    pairs.push(("tasks", tasks));
    obj(pairs)
}

/// Run the full eval suite and return (results, measured drop rate).
pub fn eval_with_rate(engine: &mut Engine) -> Result<(Vec<TaskResult>, f64)> {
    eval_with_rate_shift(engine, false)
}

/// Like [`eval_with_rate`] but on the *shifted* task distribution —
/// the right benchmark for models fine-tuned on the shifted mixture
/// (evaluating them on the pre-training distribution would measure
/// catastrophic forgetting, not fine-tuned quality; the paper's
/// fine-tune + LM-Eval setup has no such mismatch).
pub fn eval_with_rate_shift(
    engine: &mut Engine,
    shift: bool,
) -> Result<(Vec<TaskResult>, f64)> {
    engine.reset_metrics();
    let res = evaluate(engine, n_eval(), shift)?;
    Ok((res, engine.metrics.drop_rate()))
}
