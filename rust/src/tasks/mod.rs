//! Synthetic benchmark tasks — bit-for-bit mirror of
//! `python/compile/data.py` (same SplitMix64 streams ⇒ identical
//! prompts/answers on both sides; pinned by parity tests).
//!
//! These stand in for the paper's LM-Eval-Harness suite (DESIGN.md §2):
//! nine byte-level tasks with a difficulty spread; `add`/`ind`/`srt`
//! play GSM8K's drop-sensitive role.

pub mod eval;

use crate::util::rng::SplitMix64;

pub const TASKS: [&str; 9] = [
    "cpy", "rev", "pat", "add", "bal", "ind", "srt", "maj", "lm",
];

pub const TRAIN_SEED: u64 = 0x5EED_0001;
pub const FINETUNE_SEED: u64 = 0x5EED_0002;
pub const CALIB_SEED: u64 = 0x5EED_0003;
pub const EVAL_SEED_BASE: u64 = 0x5EED_1000;

const LETTERS: &str = "abcdefgh";
const SHIFT_LETTERS: &str = "ijklmnop";
const SORT_POOL: &str = "abcdef";
const SHIFT_SORT_POOL: &str = "cdefgh";
const IND_KEYS: &str = "abcd";

const PHRASES: [&str; 8] = [
    "the cat sat on the mat",
    "a dog ran to the park",
    "we like to read books",
    "the sun is very warm",
    "birds fly over the sea",
    "she has a red ball",
    "rain falls on the roof",
    "the moon is out now",
];
const SHIFT_PHRASES: [&str; 4] = [
    "the fox hid in the log",
    "he rows a boat at dawn",
    "cold wind blows all day",
    "a bee lands on the rose",
];

fn sample_cpy(rng: &mut SplitMix64, shift: bool) -> (String, String) {
    let pool = if shift { SHIFT_LETTERS } else { LETTERS };
    let n = 3 + rng.below(if shift { 4 } else { 3 });
    let s: String = (0..n).map(|_| rng.choice_byte(pool)).collect();
    (s.clone(), s)
}

fn sample_rev(rng: &mut SplitMix64, shift: bool) -> (String, String) {
    let pool = if shift { SHIFT_LETTERS } else { LETTERS };
    let n = 3 + rng.below(if shift { 4 } else { 3 });
    let s: String = (0..n).map(|_| rng.choice_byte(pool)).collect();
    let r: String = s.chars().rev().collect();
    (s, r)
}

fn sample_pat(rng: &mut SplitMix64, shift: bool) -> (String, String) {
    let period = 2 + rng.below(2);
    let pool = if shift { SHIFT_LETTERS } else { LETTERS };
    let unit: String = (0..period).map(|_| rng.choice_byte(pool)).collect();
    let reps = 6 / period + 1;
    let full = unit.repeat(reps + 2);
    (full[..6].to_string(), full[6..9].to_string())
}

fn sample_add(rng: &mut SplitMix64, shift: bool) -> (String, String) {
    if shift {
        let a = rng.below(100);
        let b = rng.below(100);
        (format!("{a:02}+{b:02}"), format!("{:02}", (a + b) % 100))
    } else {
        let a = rng.below(10);
        let b = rng.below(10);
        (format!("{a}+{b}"), format!("{}", (a + b) % 10))
    }
}

fn gen_balanced(rng: &mut SplitMix64, pairs: usize) -> String {
    let mut s = String::new();
    let mut open = 0i32;
    let mut remaining_open = pairs;
    let mut remaining_close = pairs;
    while remaining_open > 0 || remaining_close > 0 {
        if remaining_open > 0 && (open == 0 || rng.below(2) == 0) {
            s.push('(');
            open += 1;
            remaining_open -= 1;
        } else {
            s.push(')');
            open -= 1;
            remaining_close -= 1;
        }
    }
    s
}

fn sample_bal(rng: &mut SplitMix64, shift: bool) -> (String, String) {
    let pairs = if shift { 3 } else { 2 };
    if rng.below(2) == 0 {
        return (gen_balanced(rng, pairs), "Y".into());
    }
    let n = 2 * pairs;
    let s: String = (0..n)
        .map(|_| if rng.below(2) == 0 { '(' } else { ')' })
        .collect();
    let mut bal = true;
    let mut depth = 0i32;
    for ch in s.chars() {
        depth += if ch == '(' { 1 } else { -1 };
        if depth < 0 {
            bal = false;
        }
    }
    bal = bal && depth == 0;
    (s, if bal { "Y" } else { "N" }.into())
}

fn sample_ind(rng: &mut SplitMix64, _shift: bool) -> (String, String) {
    let nkeys = 3;
    let mut keys: Vec<char> = IND_KEYS.chars().collect();
    // Fisher-Yates, identical call order to the Python side.
    for i in (1..keys.len()).rev() {
        let j = rng.below(i + 1);
        keys.swap(i, j);
    }
    keys.truncate(nkeys);
    let vals: Vec<String> = (0..nkeys).map(|_| rng.below(10).to_string()).collect();
    let q = rng.below(nkeys);
    let inp = keys
        .iter()
        .zip(&vals)
        .map(|(k, v)| format!("{k}{v}"))
        .collect::<Vec<_>>()
        .join(" ")
        + " "
        + &keys[q].to_string();
    (inp, vals[q].clone())
}

fn sample_srt(rng: &mut SplitMix64, shift: bool) -> (String, String) {
    let mut pool: Vec<char> = if shift { SHIFT_SORT_POOL } else { SORT_POOL }
        .chars()
        .collect();
    for i in (1..pool.len()).rev() {
        let j = rng.below(i + 1);
        pool.swap(i, j);
    }
    let s: String = pool[..4].iter().collect();
    let mut sorted: Vec<char> = s.chars().collect();
    sorted.sort();
    (s, sorted.into_iter().collect())
}

fn sample_maj(rng: &mut SplitMix64, _shift: bool) -> (String, String) {
    let s: String = (0..5).map(|_| rng.choice_byte("ab")).collect();
    let na = s.chars().filter(|&c| c == 'a').count();
    (s, if na >= 3 { "a" } else { "b" }.into())
}

fn sample_lm(rng: &mut SplitMix64, shift: bool) -> (String, String) {
    let phrase = if shift {
        *rng.choice(&SHIFT_PHRASES)
    } else {
        *rng.choice(&PHRASES)
    };
    let cut = 6 + rng.below(phrase.len().saturating_sub(10).max(1));
    let end = std::cmp::min(cut + 5, phrase.len());
    (phrase[..cut].to_string(), phrase[cut..end].to_string())
}

/// Sample (input, answer) for a task.
pub fn sample(task: &str, rng: &mut SplitMix64, shift: bool) -> (String, String) {
    match task {
        "cpy" => sample_cpy(rng, shift),
        "rev" => sample_rev(rng, shift),
        "pat" => sample_pat(rng, shift),
        "add" => sample_add(rng, shift),
        "bal" => sample_bal(rng, shift),
        "ind" => sample_ind(rng, shift),
        "srt" => sample_srt(rng, shift),
        "maj" => sample_maj(rng, shift),
        "lm" => sample_lm(rng, shift),
        _ => panic!("unknown task {task}"),
    }
}

/// One full corpus line: `tag:input|answer\n`.
pub fn sample_line(task: &str, rng: &mut SplitMix64, shift: bool) -> String {
    let (inp, ans) = sample(task, rng, shift);
    format!("{task}:{inp}|{ans}\n")
}

/// Deterministic eval set: (prompt-with-`|`, expected answer).
pub fn eval_set(task: &str, n: usize, shift: bool) -> Vec<(String, String)> {
    let ti = TASKS.iter().position(|&t| t == task).expect("unknown task") as u64;
    let mut rng = SplitMix64::new(EVAL_SEED_BASE + ti);
    (0..n)
        .map(|_| {
            let (inp, ans) = sample(task, &mut rng, shift);
            (format!("{task}:{inp}|"), ans)
        })
        .collect()
}

/// Calibration byte stream (mirror of `data.calibration_tokens`).
pub fn calibration_tokens(n_tokens: usize) -> Vec<u8> {
    corpus_tokens(n_tokens, CALIB_SEED, false, None)
}

/// Mixed corpus byte stream (mirror of `data.corpus_tokens`).
pub fn corpus_tokens(
    n_tokens: usize,
    seed: u64,
    shift: bool,
    task_weights: Option<&[usize]>,
) -> Vec<u8> {
    let mut rng = SplitMix64::new(seed);
    let default_w = [1usize; 9];
    let weights = task_weights.unwrap_or(&default_w);
    let bag: Vec<&str> = TASKS
        .iter()
        .zip(weights)
        .flat_map(|(&t, &w)| std::iter::repeat(t).take(w))
        .collect();
    let mut out = Vec::with_capacity(n_tokens + 64);
    while out.len() < n_tokens {
        let t = *rng.choice(&bag);
        out.extend_from_slice(sample_line(t, &mut rng, shift).as_bytes());
    }
    out.truncate(n_tokens);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_sets_are_deterministic() {
        let a = eval_set("add", 5, false);
        let b = eval_set("add", 5, false);
        assert_eq!(a, b);
    }

    #[test]
    fn answers_are_correct_add() {
        for (p, ans) in eval_set("add", 50, false) {
            let body = p.strip_prefix("add:").unwrap().strip_suffix('|').unwrap();
            let (a, b) = body.split_once('+').unwrap();
            let expect = (a.parse::<usize>().unwrap() + b.parse::<usize>().unwrap()) % 10;
            assert_eq!(ans, expect.to_string());
        }
    }

    #[test]
    fn answers_are_correct_srt() {
        for (p, ans) in eval_set("srt", 50, false) {
            let body = p.strip_prefix("srt:").unwrap().strip_suffix('|').unwrap();
            let mut cs: Vec<char> = body.chars().collect();
            cs.sort();
            assert_eq!(ans, cs.into_iter().collect::<String>());
        }
    }

    #[test]
    fn balanced_generator_is_balanced() {
        let mut rng = SplitMix64::new(99);
        for _ in 0..100 {
            let s = gen_balanced(&mut rng, 3);
            let mut depth = 0i32;
            for c in s.chars() {
                depth += if c == '(' { 1 } else { -1 };
                assert!(depth >= 0);
            }
            assert_eq!(depth, 0);
        }
    }

    #[test]
    fn corpus_is_line_structured() {
        let c = corpus_tokens(2000, TRAIN_SEED, false, None);
        let text = String::from_utf8(c).unwrap();
        let first = text.lines().next().unwrap();
        assert!(first.contains(':') && first.contains('|'));
    }

    #[test]
    fn ind_answers_match_pairs() {
        for (p, ans) in eval_set("ind", 30, false) {
            let body = p.strip_prefix("ind:").unwrap().strip_suffix('|').unwrap();
            let parts: Vec<&str> = body.split(' ').collect();
            let query = parts[3].chars().next().unwrap();
            let found = parts[..3]
                .iter()
                .find(|kv| kv.starts_with(query))
                .unwrap();
            assert_eq!(ans, found[1..].to_string());
        }
    }

    #[test]
    fn shift_changes_distribution() {
        let a = eval_set("cpy", 10, false);
        let b = eval_set("cpy", 10, true);
        assert_ne!(a, b);
        // shifted copy uses the i..p alphabet
        assert!(b.iter().all(|(p, _)| p
            .strip_prefix("cpy:")
            .unwrap()
            .chars()
            .take_while(|&c| c != '|')
            .all(|c| ('i'..='p').contains(&c))));
    }
}
