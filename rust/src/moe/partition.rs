//! Expert partition and reconstruction (paper §3 + §4.2a/b), applied at
//! model-load time in the coordinator.
//!
//! * **Partial transformation** (Fig. 3c, Eq. 12): each original expert e
//!   is split into P contiguous sub-experts with ids e·P … e·P+P−1; the
//!   gating network is untouched; scores repeat at the router, no W2
//!   scaling. This is what the DualSparse serving path uses.
//! * **Complete transformation** (Fig. 3b, Eq. 11): gate columns repeat,
//!   W2 scales by P. The Python side performs it for fine-tuning
//!   (Fig. 4 / Table 1); the Rust mirror here exists so property tests
//!   can check consistency on the serving side too.
//! * **Reconstruction** (§4.2b): permute each expert's neurons by a
//!   calibration importance table so the *major* sub-expert holds the
//!   top half. A permutation of the FFN inner dim — output-invariant
//!   when both halves run.

use crate::model::{Tensor, Weights};
use anyhow::Result;

/// One sub-expert's weights (width = d_ffn / P).
#[derive(Debug, Clone)]
pub struct SubExpert {
    pub w1: Tensor,
    pub w3: Tensor,
    pub w2: Tensor,
    pub width: usize,
    /// Original-neuron index of each column: column `t` of this
    /// sub-expert is neuron `cols[t]` of the unsplit expert. Neuron-
    /// level keep masks ([`keep_mask`]) slice the full-width importance
    /// table through this mapping.
    pub cols: Vec<usize>,
}

impl SubExpert {
    fn from_cols(w1: &Tensor, w3: &Tensor, w2: &Tensor, cols: &[usize]) -> SubExpert {
        SubExpert {
            w1: w1.gather_cols(cols),
            w3: w3.gather_cols(cols),
            w2: w2.gather_rows(cols),
            width: cols.len(),
            cols: cols.to_vec(),
        }
    }
}

/// Int8 sidecar of one sub-expert (ISSUE-10 quantized kernels): codes
/// are integer-valued f32 in [-127, 127] so they flow through the
/// unchanged `upload`/exec ABI, `scales = [s_w1, s_w3, s_w2]` are the
/// symmetric per-sub-expert per-matrix scales. Built once at engine
/// construction; the backend dequantizes in-register
/// (`util::linalg::swiglu_ffn_q8`).
#[derive(Debug, Clone)]
pub struct QuantizedWeights {
    pub w1: Tensor,
    pub w3: Tensor,
    pub w2: Tensor,
    pub scales: [f32; 3],
}

impl QuantizedWeights {
    pub fn from_sub_expert(se: &SubExpert) -> QuantizedWeights {
        let (w1, s1) = crate::util::linalg::quantize_symmetric(&se.w1);
        let (w3, s3) = crate::util::linalg::quantize_symmetric(&se.w3);
        let (w2, s2) = crate::util::linalg::quantize_symmetric(&se.w2);
        QuantizedWeights { w1, w3, w2, scales: [s1, s3, s2] }
    }
}

/// An original expert prepared for dual-sparse serving: the full-width
/// weights plus the (major, minor) P=2 split.
#[derive(Debug, Clone)]
pub struct PartitionedExpert {
    pub full: SubExpert,
    pub major: SubExpert,
    pub minor: SubExpert,
}

/// Eq. 12: Top-K expert indices → K·P sub-expert indices, each original
/// expert placed contiguously, relative order preserved per repeat.
pub fn remap_indices(indices: &[usize], p: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(indices.len() * p);
    for rep in 0..p {
        for &i in indices {
            out.push(i * p + rep);
        }
    }
    out
}

/// Descending-importance permutation. **The tiebreak is part of the
/// contract**: equal-importance neurons order by ascending index, and
/// NaN importances order last (among themselves, also by ascending
/// index) — the same total order as routing, via
/// [`crate::moe::gating::cmp_desc_nan_last`]. Because the comparator
/// is a total order with no float-equality ambiguity left to the sort,
/// the permutation — and every keep mask / reconstruction split
/// prefix derived from it — is reproducible across platforms, runs
/// and thread counts.
pub fn importance_order(importance: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..importance.len()).collect();
    idx.sort_by(|&a, &b| {
        crate::moe::gating::cmp_desc_nan_last(a, importance[a], b, importance[b])
    });
    idx
}

/// Neuron-level keep mask for one sub-expert (ISSUE-10, top-p by
/// calibrated importance). `cols` maps the variant's columns to
/// original neurons (see [`SubExpert::cols`]), `importance` is the
/// expert's full-width table, `keep` the kept fraction. Returns the
/// positions (into the variant's own column space, i32 for the kernel
/// ABI) of the top `⌈keep·width⌉` columns under [`importance_order`] —
/// a *prefix* of one fixed permutation, so for keep fractions
/// `p1 ≥ p2` the mask at `p2` is always a subset of the mask at `p1`,
/// and the mask is deterministic (pure function of `cols`,
/// `importance`, `keep` — no threading, no RNG).
pub fn keep_mask(cols: &[usize], importance: &[f32], keep: f32) -> Vec<i32> {
    let imp: Vec<f32> = cols.iter().map(|&c| importance[c]).collect();
    let k = crate::calib::keep_count(cols.len(), keep);
    importance_order(&imp)[..k].iter().map(|&t| t as i32).collect()
}

/// Build the serving-side partitioned experts for one layer.
///
/// `importance`: per-expert `[d_ffn]` tables (§4.2b). When `Some`, the
/// split is by importance (reconstruction); when `None`, it is the
/// contiguous halves of the partial transformation (2T "partition" row
/// of Table 2).
pub fn build_layer(
    weights: &Weights,
    layer: usize,
    importance: Option<&[Vec<f32>]>,
) -> Result<Vec<PartitionedExpert>> {
    let e = weights.config.n_experts;
    let h = weights.config.d_ffn;
    let mut out = Vec::with_capacity(e);
    for ei in 0..e {
        let w1 = weights.expert(layer, "w1", ei)?;
        let w3 = weights.expert(layer, "w3", ei)?;
        let w2 = weights.expert(layer, "w2", ei)?;
        let order: Vec<usize> = match importance {
            Some(tables) => importance_order(&tables[ei]),
            None => (0..h).collect(),
        };
        let full_cols: Vec<usize> = (0..h).collect();
        let major_cols = &order[..h / 2];
        let minor_cols = &order[h / 2..];
        out.push(PartitionedExpert {
            full: SubExpert::from_cols(&w1, &w3, &w2, &full_cols),
            major: SubExpert::from_cols(&w1, &w3, &w2, major_cols),
            minor: SubExpert::from_cols(&w1, &w3, &w2, minor_cols),
        });
    }
    Ok(out)
}

/// Complete transformation of a gate matrix (Fig. 3b step 1): repeat
/// each expert column P times. Returns [d_model, E·P].
pub fn complete_transform_gate(wg: &Tensor, p: usize) -> Tensor {
    let (d, e) = (wg.shape[0], wg.shape[1]);
    let mut data = Vec::with_capacity(d * e * p);
    for r in 0..d {
        let row = wg.row(r);
        for c in 0..e {
            for _ in 0..p {
                data.push(row[c]);
            }
        }
    }
    Tensor::new(vec![d, e * p], data)
}

/// Complete transformation of one expert (Fig. 3b steps 2-3): contiguous
/// neuron split + W2 scaled by P. Returns P sub-experts.
pub fn complete_transform_expert(
    w1: &Tensor,
    w3: &Tensor,
    w2: &Tensor,
    p: usize,
) -> Vec<SubExpert> {
    let h = w1.shape[1];
    let hp = h / p;
    (0..p)
        .map(|pi| {
            let cols: Vec<usize> = (pi * hp..(pi + 1) * hp).collect();
            let mut se = SubExpert::from_cols(w1, w3, w2, &cols);
            se.w2 = se.w2.scale(p as f32);
            se
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn remap_matches_eq12() {
        // I = [i1, i2], P = 2 → [2 i1, 2 i2, 2 i1 + 1, 2 i2 + 1]
        assert_eq!(remap_indices(&[3, 1], 2), vec![6, 2, 7, 3]);
        // P = 3, single expert
        assert_eq!(remap_indices(&[2], 3), vec![6, 7, 8]);
    }

    #[test]
    fn importance_order_descending_stable() {
        let imp = [0.1, 0.9, 0.9, 0.2];
        assert_eq!(importance_order(&imp), vec![1, 2, 3, 0]);
    }

    #[test]
    fn importance_order_ties_break_by_index_nan_last() {
        // All-equal importances must come back in index order — the
        // reproducibility contract keep masks depend on.
        assert_eq!(importance_order(&[0.5; 5]), vec![0, 1, 2, 3, 4]);
        // Interleaved ties keep ascending index within each tie class.
        let imp = [0.2, 0.9, 0.2, 0.9, 0.2];
        assert_eq!(importance_order(&imp), vec![1, 3, 0, 2, 4]);
        // NaNs order last, themselves by ascending index; -inf beats NaN.
        let imp = [f32::NAN, 0.1, f32::NAN, f32::NEG_INFINITY];
        assert_eq!(importance_order(&imp), vec![1, 3, 0, 2]);
        // Deterministic: two calls agree exactly.
        let imp = [0.3, 0.3, f32::NAN, 0.7, 0.3];
        assert_eq!(importance_order(&imp), importance_order(&imp));
    }

    #[test]
    fn keep_mask_is_a_ranked_prefix_in_variant_space() {
        // Variant columns [4, 1, 6] with full-width importance: column
        // importances are imp[4]=0.9, imp[1]=0.1, imp[6]=0.5 → ranked
        // variant positions [0, 2, 1].
        let imp = [0.0, 0.1, 0.0, 0.0, 0.9, 0.0, 0.5];
        let cols = [4usize, 1, 6];
        assert_eq!(keep_mask(&cols, &imp, 1.0), vec![0, 2, 1]);
        assert_eq!(keep_mask(&cols, &imp, 0.67), vec![0, 2, 1]); // ⌈2.01⌉ = 3
        assert_eq!(keep_mask(&cols, &imp, 0.5), vec![0, 2]); // ⌈1.5⌉ = 2
        assert_eq!(keep_mask(&cols, &imp, 0.0), Vec::<i32>::new());
        // nesting: lower keep is a prefix (hence subset) of higher keep
        let hi = keep_mask(&cols, &imp, 1.0);
        let lo = keep_mask(&cols, &imp, 0.5);
        assert_eq!(&hi[..lo.len()], &lo[..]);
    }

    #[test]
    fn quantized_weights_round_trip_within_half_scale() {
        let w1 = Tensor::new(vec![2, 4], (0..8).map(|v| (v as f32 - 4.0) * 0.13).collect());
        let w3 = Tensor::new(vec![2, 4], (0..8).map(|v| (v as f32 - 2.0) * 0.07).collect());
        let w2 = Tensor::new(vec![4, 2], (0..8).map(|v| (v as f32 - 5.0) * 0.11).collect());
        let se = SubExpert::from_cols(&w1, &w3, &w2, &[0, 1, 2, 3]);
        let q = QuantizedWeights::from_sub_expert(&se);
        for (orig, codes, s) in
            [(&se.w1, &q.w1, q.scales[0]), (&se.w3, &q.w3, q.scales[1]), (&se.w2, &q.w2, q.scales[2])]
        {
            for (a, &c) in orig.data.iter().zip(&codes.data) {
                assert!(c == c.round() && c.abs() <= 127.0);
                assert!((a - c * s).abs() <= s / 2.0 + 1e-7);
            }
        }
    }

    #[test]
    fn gate_repeat_matches_eq7() {
        let wg = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let r = complete_transform_gate(&wg, 2);
        assert_eq!(r.shape, vec![2, 4]);
        assert_eq!(r.data, vec![1., 1., 2., 2., 3., 3., 4., 4.]);
    }

    #[test]
    fn complete_expert_scales_w2() {
        let w1 = Tensor::new(vec![2, 4], (0..8).map(|x| x as f32).collect());
        let w3 = w1.clone();
        let w2 = Tensor::new(vec![4, 2], (0..8).map(|x| x as f32).collect());
        let subs = complete_transform_expert(&w1, &w3, &w2, 2);
        assert_eq!(subs.len(), 2);
        assert_eq!(subs[0].w1.shape, vec![2, 2]);
        // W2 rows 0..2 scaled by 2
        assert_eq!(subs[0].w2.data, vec![0., 2., 4., 6.]);
        assert_eq!(subs[1].w2.data, vec![8., 10., 12., 14.]);
    }
}
